"""End-to-end training driver: a deepseek-family LM on the synthetic data
pipeline with the full production stack — fault-tolerant trainer, async
checkpoints, scheduler-driven microbatch overlap, AdamW.

Run (small, ~2-3 min on CPU):
    PYTHONPATH=src python examples/train_lm.py
Run a ~100M-param model (slower):
    PYTHONPATH=src python examples/train_lm.py --scale 100m --steps 300
"""

import argparse

from repro.configs import get_config
from repro.data import DataConfig
from repro.optim import AdamWConfig
from repro.train import Trainer, TrainerConfig
from repro.train.steps import StepConfig


def build_cfg(scale: str):
    base = get_config("deepseek-67b")
    if scale == "100m":
        return base.reduced(n_layers=12, d_model=768, n_heads=12, n_kv_heads=4,
                            head_dim=64, d_ff=2048, vocab_size=32768,
                            dtype="float32")
    return base.reduced(n_layers=4, d_model=256, n_heads=4, n_kv_heads=2,
                        head_dim=64, d_ff=512, vocab_size=4096,
                        dtype="float32")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", choices=("small", "100m"), default="small")
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--micro", type=int, default=2)
    ap.add_argument("--ckpt", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = build_cfg(args.scale)
    print(f"model: {cfg.name} ({cfg.param_count()/1e6:.1f} M params)")
    trainer = Trainer(
        cfg,
        AdamWConfig(lr=3e-3, warmup_steps=20, total_steps=args.steps),
        TrainerConfig(steps=args.steps, ckpt_every=max(20, args.steps // 4),
                      ckpt_dir=args.ckpt, log_every=10),
        DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                   global_batch=args.batch, seed=0),
        step_cfg=StepConfig(microbatches=args.micro, overlap="hybrid"),
    )
    out = trainer.run()
    print(f"finished at step {out['final_step']} "
          f"(restored+resumed runs continue from checkpoints in {args.ckpt})")
    for m in out["metrics"]:
        print(f"  step {m['step']:4d}  loss {m['loss']:.4f}  "
              f"lr {m['lr']:.2e}  gnorm {m['grad_norm']:.2f}  t={m['sec']:.0f}s")
    first, last = out["metrics"][0]["loss"], out["metrics"][-1]["loss"]
    print(f"loss: {first:.3f} -> {last:.3f} ({'OK' if last < first else 'NOT LEARNING'})")


if __name__ == "__main__":
    main()
