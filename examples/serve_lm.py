"""Serving driver: batched prefill + decode with a KV cache.

Loads (or initializes) a small model, prefills a batch of prompts, then
decodes N tokens per request — the serve-side analogue of the dry-run's
decode cells.

Run:  PYTHONPATH=src python examples/serve_lm.py --tokens 32
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import decode_step, init_params, prefill


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-14b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--tokens", type=int, default=32)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    max_len = args.prompt_len + args.tokens + 1

    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)
    batch = {"tokens": prompts}
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(
            jax.random.PRNGKey(2), (args.batch, cfg.n_patches, cfg.d_model),
            cfg.jdtype)
    if cfg.family == "encdec":
        batch["enc_input"] = jax.random.normal(
            jax.random.PRNGKey(2), (args.batch, 32, cfg.d_model), cfg.jdtype)

    prefill_fn = jax.jit(lambda p, b: prefill(p, cfg, b, None, max_len=max_len))
    decode_fn = jax.jit(lambda p, c, t: decode_step(p, cfg, c, t, None))

    t0 = time.perf_counter()
    cache, logits = prefill_fn(params, batch)
    logits.block_until_ready()
    t_prefill = time.perf_counter() - t0

    tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    out_tokens = [tok]
    t0 = time.perf_counter()
    for _ in range(args.tokens - 1):
        cache, logits = decode_fn(params, cache, tok)
        tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        out_tokens.append(tok)
    tok.block_until_ready()
    t_decode = time.perf_counter() - t0

    gen = jnp.concatenate(out_tokens, axis=1)
    print(f"arch={cfg.name} batch={args.batch} prompt={args.prompt_len}")
    print(f"prefill: {t_prefill*1e3:.1f} ms "
          f"({args.batch*args.prompt_len/t_prefill:.0f} tok/s)")
    print(f"decode:  {t_decode*1e3:.1f} ms for {args.tokens-1} steps "
          f"({args.batch*(args.tokens-1)/t_decode:.0f} tok/s)")
    print("sample token ids:", gen[0, :16].tolist())


if __name__ == "__main__":
    main()
