"""Serving driver: batched prefill + decode with a KV cache.

Loads (or initializes) a small model, prefills a batch of prompts, then
decodes N tokens per request.  Three decode schedulers:

* ``jit``     — the original monolithic jitted decode loop (no task graph);
* ``dynamic`` — each decode step is a task graph (per-shard decode/sample
  plus a gather join) executed by a ``Session(scheduler="dynamic")``;
* ``pool``    — the same graphs served by a ``Session(scheduler="pool")``
  (a persistent :class:`~repro.replay.ReplayPool` under the hood): step 1
  records, every later step replays on warm executor threads, drift
  triggers adaptive re-recording.

``--arrivals poisson`` switches from the fixed batch to the request-level
continuous-batching front end (:mod:`repro.serving`): a seeded Poisson
stream of single-prompt requests flows through a bounded admission queue
into per-step dynamically composed batches, with early exit on each
request's token budget and warm pool replays per batch shape.

``--procs N`` (poisson only) shards the request stream across N worker
processes (:mod:`repro.mp`), each hosting its own executor pool; children
rebuild the model from the same seed via :func:`make_serving_fns` and
adopt parent-seeded recordings through ``--cache-dir``, so the sharded
token streams stay bit-identical to single-process serving.

Run:  PYTHONPATH=src python examples/serve_lm.py --tokens 32 --scheduler pool
      PYTHONPATH=src python examples/serve_lm.py --arrivals poisson \
          --rate 100 --requests 12 --scheduler pool
      PYTHONPATH=src python examples/serve_lm.py --arrivals poisson \
          --rate 100 --requests 16 --scheduler pool --procs 2
"""

import argparse
import time

import jax
import jax.numpy as jnp

import repro
from repro.configs import get_config
from repro.models import (build_decode_graph, decode_step, greedy_sample,
                          init_params, make_decode_state, prefill)
from repro.replay import GraphCache


def make_serving_fns(arch="qwen3-14b", prompt_len=64, tokens=32):
    """Engine-fns factory for ``--procs``: worker processes re-import this
    by reference (``serve_lm:make_serving_fns``) and rebuild the exact
    parent model — same reduced config, same ``PRNGKey(0)`` params, same
    jitted step fns — so sharded token streams stay bit-identical to
    single-process serving."""
    cfg = get_config(arch).reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    max_len = prompt_len + tokens + 1
    prefill_fn = jax.jit(
        lambda p, b: prefill(p, cfg, b, None, max_len=max_len))
    decode_fn = jax.jit(lambda p, c, t: decode_step(p, cfg, c, t, None))
    return (lambda cache, tok: decode_fn(params, cache, tok),
            lambda prompt: prefill_fn(params, {"tokens": prompt}))


def serve_poisson(args, cfg, params, prefill_fn, decode_fn):
    """Continuous batching under streaming traffic (--arrivals poisson)."""
    from repro.serving import ContinuousBatchingEngine, PoissonWorkload

    lo, _, hi = args.max_new.partition(":")
    budget = (int(lo), int(hi or lo))
    if budget[1] > args.tokens:
        raise SystemExit(f"--max-new hi {budget[1]} exceeds --tokens "
                         f"{args.tokens} (the KV-cache budget)")
    workload = PoissonWorkload(args.rate, args.requests, seed=args.seed,
                               prompt_len=args.prompt_len,
                               max_new_tokens=budget,
                               vocab_size=cfg.vocab_size)
    print(f"arch={cfg.name} scheduler={args.scheduler} "
          f"workers={args.workers} max_batch={args.max_batch} "
          + (f"procs={args.procs} " if args.procs else "")
          + workload.describe())
    pool = args.scheduler == "pool"
    cache_store = (GraphCache(args.cache_dir)
                   if args.cache_dir and pool else None)
    kwargs = {"pool_kwargs": {"warmup_runs": 0}} if pool else {}
    engine_kwargs = {}
    if args.procs:
        kwargs["procs"] = args.procs
        # children rebuild the model by import reference — see
        # make_serving_fns; launch as `python examples/serve_lm.py` so the
        # examples dir is on sys.path for the spawned workers
        engine_kwargs = {
            "procs": args.procs,
            "fns_ref": ("serve_lm:make_serving_fns",
                        {"arch": args.arch, "prompt_len": args.prompt_len,
                         "tokens": args.tokens}),
        }
    with repro.Session(args.workers, scheduler=args.scheduler,
                       cache=cache_store, trace=bool(args.trace),
                       **kwargs) as session:
        engine = ContinuousBatchingEngine(
            session,
            lambda cache, tok: decode_fn(params, cache, tok),
            lambda prompt: prefill_fn(params, {"tokens": prompt}),
            max_batch=args.max_batch, **engine_kwargs)
        if not args.procs:
            engine.prime()  # step graphs + keys built before traffic starts
        report = engine.run(workload.requests())
        if pool and not args.procs:
            for ckey, stats in session.pool.describe().items():
                print(f"pool[{ckey[:20]}…]: {stats}")
        if args.procs:
            for s in engine.mp_stats["per_proc"]:
                print(f"proc{s['proc']}[pid {s['pid']}]: "
                      f"{s['completed']} requests, {s['steps']} steps "
                      f"({s['warm_steps']} warm), {s['records']} records")
            if engine.mp_stats["dead"]:
                print(f"dead workers {engine.mp_stats['dead']}: "
                      f"{engine.mp_stats['fallback']} requests re-served "
                      "in-process")
    print(report.describe())
    s = report.summary()
    print(f"per-token p50/p99: {s['p50_tok_ms']:.2f}/{s['p99_tok_ms']:.2f} "
          f"ms, ttft p50/p99: {s['ttft_p50_ms']:.2f}/{s['ttft_p99_ms']:.2f} "
          f"ms, sustained {s['tok_s']:.0f} tok/s")
    if args.trace and report.trace is not None:
        from repro.obs import write_trace
        write_trace(report.trace, args.trace,
                    extra={"workers": args.workers, "arch": cfg.name,
                           "scheduler": args.scheduler,
                           "arrivals": "poisson"})
        m = report.trace.metrics()
        print(f"trace:   {args.trace} (most loaded step, dispatch overhead "
              f"{m['dispatch_overhead_fraction']:.1%}, "
              "open in https://ui.perfetto.dev)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-14b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--scheduler", choices=("jit", "dynamic", "pool"),
                    default="pool")
    ap.add_argument("--workers", type=int, default=2,
                    help="runtime workers for dynamic/pool scheduling")
    ap.add_argument("--shards", type=int, default=0,
                    help="batch shards per decode graph (default: batch)")
    ap.add_argument("--cache-dir", default=None,
                    help="on-disk GraphCache dir (pool): recordings persist "
                         "across processes / ship to replicas")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="serve with the flight recorder on and export the "
                         "last decode step as Perfetto JSON here "
                         "(open in https://ui.perfetto.dev)")
    ap.add_argument("--arrivals", choices=("batch", "poisson"),
                    default="batch",
                    help="batch: fixed batch decoded to --tokens; poisson: "
                         "streaming requests through the continuous-"
                         "batching engine")
    ap.add_argument("--rate", type=float, default=100.0,
                    help="poisson arrival rate, requests/s")
    ap.add_argument("--requests", type=int, default=12,
                    help="poisson stream length")
    ap.add_argument("--max-new", default="2:8", metavar="LO:HI",
                    help="poisson per-request token budget span")
    ap.add_argument("--max-batch", type=int, default=4,
                    help="continuous-batching decode slots")
    ap.add_argument("--seed", type=int, default=0,
                    help="poisson workload seed (same seed, same stream)")
    ap.add_argument("--procs", type=int, default=0,
                    help="shard the poisson stream across N worker "
                         "processes (repro.mp), each with --workers "
                         "runtime workers; token streams stay bit-"
                         "identical to --procs 0")
    args = ap.parse_args()
    if args.trace and args.scheduler == "jit":
        ap.error("--trace needs a task-graph scheduler (dynamic or pool)")
    if args.arrivals == "poisson" and args.scheduler == "jit":
        ap.error("--arrivals poisson needs a task-graph scheduler")
    if args.procs and args.arrivals != "poisson":
        ap.error("--procs shards the streaming front end; add "
                 "--arrivals poisson")
    if args.procs and args.trace:
        ap.error("--trace is per-process; not supported with --procs")

    cfg = get_config(args.arch).reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    max_len = args.prompt_len + args.tokens + 1

    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)
    batch = {"tokens": prompts}
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(
            jax.random.PRNGKey(2), (args.batch, cfg.n_patches, cfg.d_model),
            cfg.jdtype)
    if cfg.family == "encdec":
        batch["enc_input"] = jax.random.normal(
            jax.random.PRNGKey(2), (args.batch, 32, cfg.d_model), cfg.jdtype)

    prefill_fn = jax.jit(lambda p, b: prefill(p, cfg, b, None, max_len=max_len))
    decode_fn = jax.jit(lambda p, c, t: decode_step(p, cfg, c, t, None))

    if args.arrivals == "poisson":
        if cfg.family in ("vlm", "encdec"):
            ap.error("--arrivals poisson supports decoder-only families")
        serve_poisson(args, cfg, params, prefill_fn, decode_fn)
        return

    print(f"arch={cfg.name} batch={args.batch} prompt={args.prompt_len} "
          f"scheduler={args.scheduler}")

    if args.scheduler == "jit":
        t0 = time.perf_counter()
        cache, logits = prefill_fn(params, batch)
        logits.block_until_ready()
        t_prefill = time.perf_counter() - t0
        tok = greedy_sample(logits)
        out_tokens = [tok]
        t0 = time.perf_counter()
        for _ in range(args.tokens - 1):
            cache, logits = decode_fn(params, cache, tok)
            tok = greedy_sample(logits)
            out_tokens.append(tok)
        tok.block_until_ready()
        t_decode = time.perf_counter() - t0
        gen = jnp.concatenate(out_tokens, axis=1)
    else:
        n_shards = args.shards or args.batch
        t0 = time.perf_counter()
        state = make_decode_state(params, cfg, batch, n_shards=n_shards,
                                  max_len=max_len, prefill_fn=prefill_fn)
        state.step_tokens.block_until_ready()
        t_prefill = time.perf_counter() - t0

        cache_store = (GraphCache(args.cache_dir)
                       if args.cache_dir and args.scheduler == "pool" else None)
        session = repro.Session(args.workers, scheduler=args.scheduler,
                                cache=cache_store, trace=bool(args.trace))
        report = None
        with session:
            t0 = time.perf_counter()
            for _ in range(args.tokens - 1):
                g = build_decode_graph(state, decode_fn)
                report = session.run(g)
            state.step_tokens.block_until_ready()
            t_decode = time.perf_counter() - t0
            gen = state.tokens()
            if args.scheduler == "pool":
                for ckey, stats in session.pool.describe().items():
                    print(f"pool[{ckey[:20]}…]: {stats}")
        if args.trace and report is not None and report.trace is not None:
            from repro.obs import write_trace
            write_trace(report.trace, args.trace,
                        extra={"workers": args.workers, "arch": cfg.name,
                               "scheduler": args.scheduler})
            m = report.trace.metrics()
            print(f"trace:   {args.trace} "
                  f"(dispatch overhead {m['dispatch_overhead_fraction']:.1%}, "
                  f"open in https://ui.perfetto.dev)")

    print(f"prefill: {t_prefill*1e3:.1f} ms "
          f"({args.batch*args.prompt_len/t_prefill:.0f} tok/s)")
    print(f"decode:  {t_decode*1e3:.1f} ms for {args.tokens-1} steps "
          f"({args.batch*(args.tokens-1)/t_decode:.0f} tok/s)")
    print("sample token ids:", gen[0, :16].tolist())


if __name__ == "__main__":
    main()
