"""Quickstart: the paper's scheduler in 60 lines.

1. Build a task graph with a gang-scheduled nested parallel region.
2. Run it on the threaded work-stealing runtime (Algorithms 1 & 2).
3. Compare victim-selection policies on a paper-scale distributed Cholesky
   graph in the deterministic simulator.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import time

import numpy as np

from repro.core import Runtime, Simulator, TaskGraph
from repro.linalg.dist import build_dist_cholesky_graph
from repro.linalg.tiles import CostModel


def main():
    # ---- 1/2: a graph with a gang region, executed for real ---------------
    g = TaskGraph("demo")

    def panel_task(ctx):
        # a data-parallel panel with a blocking in-region barrier: the
        # classic deadlock hazard, safe under gang scheduling
        def body(tid, region):
            x = np.linalg.norm(np.random.rand(200, 200) @ np.random.rand(200, 200))
            region.barrier()
            return x

        return sum(ctx.parallel(3, body, gang=True))

    p = g.add(panel_task, name="panel", kind="panel")
    for i in range(6):
        g.add(lambda ctx: np.random.rand(200, 200).sum(), deps=[p],
              name=f"trail{i}")

    with Runtime(4, policy="hybrid") as rt:
        t0 = time.perf_counter()
        results = rt.run(g)
        print(f"runtime: graph of {len(g)} tasks incl. gang region "
              f"in {time.perf_counter() - t0:.3f}s; panel={results[p.tid]:.1f}")

    # ---- 3: policy comparison at paper scale ------------------------------
    cm = CostModel(comm_bw=3e9, comm_latency=20e-6)
    graph = build_dist_cholesky_graph(64, 192, ranks=4, cost=cm)
    print(f"\nsimulator: distributed Cholesky ({len(graph)} tasks, 4 ranks x 10 workers)")
    base = None
    for pol in ("history", "random", "hybrid"):
        tr = Simulator(40, ranks=4, policy=pol, seed=0).run(graph)
        base = base or tr.makespan
        print(f"  {pol:8s}: {tr.makespan * 1e3:7.1f} ms "
              f"({100 * (base - tr.makespan) / base:+.1f}% vs history)")


if __name__ == "__main__":
    main()
