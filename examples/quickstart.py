"""Quickstart: the paper's scheduler through the v2 session API.

1. Build a dataflow graph with futures (`Graph.add` returns TaskHandles;
   dependencies are inferred from handle arguments) plus a gang-scheduled
   nested parallel region.
2. Run it in a `Session` and read results off the `RunReport`.
3. Compare victim-selection policies on a paper-scale distributed Cholesky
   graph in the deterministic simulator.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import time

import numpy as np

import repro
from repro.core import Simulator
from repro.linalg.dist import build_dist_cholesky_graph
from repro.linalg.tiles import CostModel


def main():
    # ---- 1/2: a dataflow graph with a gang region, executed for real ------
    g = repro.Graph("demo")

    def panel_task(ctx):
        # a data-parallel panel with a blocking in-region barrier: the
        # classic deadlock hazard, safe under gang scheduling
        def body(tid, region):
            x = np.linalg.norm(np.random.rand(200, 200) @ np.random.rand(200, 200))
            region.barrier()
            return x

        return sum(ctx.parallel(3, body, gang=True))

    p = g.add(panel_task, name="panel", kind="panel")
    trails = [g.add(lambda: np.random.rand(200, 200).sum(), deps=[p],
                    name=f"trail{i}") for i in range(6)]
    # futures as arguments: the reduce depends on every trail — inferred,
    # no deps= needed — and receives their values
    total = g.add(lambda xs: float(sum(xs)), trails, name="total")

    with repro.Session(workers=4, policy="hybrid") as session:
        print(f"plan: {session.plan(g)}")
        t0 = time.perf_counter()
        report = session.run(g)
        print(f"runtime: graph of {len(g)} tasks incl. gang region in "
              f"{time.perf_counter() - t0:.3f}s; panel={report[p]:.1f} "
              f"total={report[total]:.1f}")
        print(f"report: {report.summary()}")

    # ---- 3: policy comparison at paper scale ------------------------------
    cm = CostModel(comm_bw=3e9, comm_latency=20e-6)
    graph = build_dist_cholesky_graph(64, 192, ranks=4, cost=cm)
    print(f"\nsimulator: distributed Cholesky ({len(graph)} tasks, 4 ranks x 10 workers)")
    base = None
    for pol in ("history", "random", "hybrid"):
        tr = Simulator(40, ranks=4, policy=pol, seed=0).run(graph)
        base = base or tr.makespan
        print(f"  {pol:8s}: {tr.makespan * 1e3:7.1f} ms "
              f"({100 * (base - tr.makespan) / base:+.1f}% vs history)")


if __name__ == "__main__":
    main()
