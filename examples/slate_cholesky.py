"""SLATE-style tiled Cholesky, numerically, through the paper's runtime.

Factors a real SPD matrix with the tiled task graph under each victim
policy, validates the result, and reports wall-clock (JAX CPU tile kernels
release the GIL, so work-stealing genuinely parallelizes).  One `Session`
per policy: the policy name is validated up front and the run's steal
statistics come back on the `RunReport`.

Run:  PYTHONPATH=src python examples/slate_cholesky.py [n] [tile]
"""

import sys
import time

import numpy as np

import repro
from repro.linalg import build_cholesky_graph, cholesky_extract, random_spd, to_tiles


def main(n: int = 768, b: int = 96, workers: int = 4):
    a = random_spd(n, seed=0)
    print(f"Cholesky {n}x{n}, tile {b} ({n//b}x{n//b} tiles), {workers} workers")
    for policy in ("history", "random", "hybrid"):
        store = to_tiles(a, b)
        g = build_cholesky_graph(store.nb, b, store=store)
        with repro.Session(workers, policy=policy) as session:
            t0 = time.perf_counter()
            report = session.run(g, timeout=300.0)
            dt = time.perf_counter() - t0
        l = np.asarray(cholesky_extract(store))
        err = np.linalg.norm(l @ l.T - np.asarray(a)) / np.linalg.norm(np.asarray(a))
        print(f"  {policy:8s}: {dt:6.3f}s   ||A - LL^T||/||A|| = {err:.2e}   "
              f"steals={report.stats.get('steals', 0)}")


if __name__ == "__main__":
    n = int(sys.argv[1]) if len(sys.argv) > 1 else 768
    b = int(sys.argv[2]) if len(sys.argv) > 2 else 96
    main(n, b)
