"""Resource-guarded concurrent checkpointing in a toy training loop.

Each "train step" produces N shard payloads in parallel; N writer tasks
then append the shards to a :class:`~repro.checkpoint.CheckpointSink`.
The writers share ONE exclusive checkpoint-file resource and have **no
edges between them**: the arbiter serializes the writes in whatever order
the shards finish, while shard serialization still overlaps across
workers.  Edge-serializing the writers instead would also pin their order
— the resource pins neither (conflicts without dependencies).

Every step builds the same graph shape, so with ``--scheduler replay``
step 1 records (including the resource grant order) and later steps replay
it bit-identically — the manifests' ``write_log`` stops varying.

``--crash`` makes one writer die between ``begin_shard`` and
``commit_shard``: the run aborts with the checkpoint torn, the arbiter
provably drops the dead writer's file grant, and the retry step acquires
it cleanly.

Run:  PYTHONPATH=src python examples/checkpoint_train.py
      PYTHONPATH=src python examples/checkpoint_train.py --scheduler replay
      PYTHONPATH=src python examples/checkpoint_train.py --crash
"""

import argparse
import tempfile
import time

from repro.api import Graph, Session
from repro.checkpoint import (CheckpointSink, add_checkpoint_tasks,
                              checkpoint_resource)
from repro.replay import GraphCache


def build_step_graph(sink, step, n_shards, *, crash_on=None):
    """Same shape every step => one recording serves the whole loop."""
    g = Graph(f"ckpt_step[{n_shards}]")
    ckpt_file = checkpoint_resource()
    shard_out = [None] * n_shards        # train -> writer handoff, per shard

    def train(s, step=step):
        def fn(ctx):
            time.sleep(0.002 * (s % 3 + 1))      # skewed shard compute
            shard_out[s] = {"step": step, "shard": s,
                            "weights": [step * 10 + s]}
            return s
        return fn

    produced = [g.add(train(s), name=f"train{s}", cost=1.0)
                for s in range(n_shards)]
    add_checkpoint_tasks(
        g, sink, list(range(n_shards)),
        resource=ckpt_file,
        serialize=lambda s, _: shard_out[s],   # ordered by the dep edge
        deps=[[h] for h in produced],
        crash_on=crash_on)
    return g


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--shards", type=int, default=4)
    ap.add_argument("--steps", type=int, default=4)
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--scheduler", choices=("dynamic", "replay"),
                    default="dynamic")
    ap.add_argument("--crash", action="store_true",
                    help="kill one writer mid-write on step 1, then retry")
    args = ap.parse_args()

    cache = GraphCache(tempfile.mkdtemp(prefix="ckpt_cache_")) \
        if args.scheduler == "replay" else None
    with Session(workers=args.workers, scheduler=args.scheduler,
                 cache=cache) as session:
        for step in range(args.steps):
            crash = args.crash and step == 1
            sink = CheckpointSink(args.shards)
            g = build_step_graph(sink, step, args.shards,
                                 crash_on=0 if crash else None)
            try:
                rep = session.run(g)
            except Exception as e:
                print(f"step {step}: ABORTED mid-write ({e}); "
                      f"torn={sink.torn} — retrying with a fresh sink")
                sink = CheckpointSink(args.shards)
                rep = session.run(build_step_graph(sink, step, args.shards))
            sink.finalize()
            res = {k: v for k, v in rep.stats.items() if "resource" in k}
            print(f"step {step}: write_log={sink.write_log} "
                  f"complete={sink.complete} stats={res}")


if __name__ == "__main__":
    main()
