"""Record-and-replay: an iterative Cholesky sweep that stops paying for
scheduling after its first step.

Step 1 runs the dynamic gang-scheduling runtime with recording on; every
later step rebuilds the same-shaped graph over fresh tiles, hits the
:class:`~repro.replay.GraphCache` on the structural key, and replays the
recorded schedule with preallocated run lists — no victim selection, no
indegree lock, no worker reservation.

Run:  PYTHONPATH=src python examples/replay_sweep.py
"""

import time

import numpy as np

from repro.core import run_graph
from repro.linalg import (build_cholesky_graph, cholesky_extract,
                          cholesky_graph_key, random_spd, to_tiles)
from repro.replay import GraphCache

NB, B, WORKERS, STEPS = 8, 64, 4, 6


def main():
    cache = GraphCache()          # GraphCache(path="...") would persist
    print(f"cache key: {cholesky_graph_key(NB, B)}")
    ref = None
    for step in range(STEPS):
        a = random_spd(NB * B, seed=step)
        store = to_tiles(a, B)
        graph = build_cholesky_graph(NB, B, store=store)
        t0 = time.perf_counter()
        run_graph(graph, WORKERS, cache=cache)   # records on miss, replays on hit
        L = cholesky_extract(store)
        L.block_until_ready()
        dt = time.perf_counter() - t0
        mode = "record" if step == 0 else "replay"
        err = float(np.abs(np.asarray(L @ L.T) - np.asarray(a)).max())
        print(f"step {step}: {mode:7s} {dt * 1e3:7.2f} ms   "
              f"|LL^T - A|_max = {err:.2e}")
        if ref is None:
            ref = np.asarray(L)
    print(f"\ncached recordings: {len(cache)} "
          f"(one per graph shape x worker-count x policy)")


if __name__ == "__main__":
    main()
