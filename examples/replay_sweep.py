"""Record-and-replay: an iterative Cholesky sweep that stops paying for
scheduling after its first step — driven by the v2 session API.

The session owns a `GraphCache`: step 1's plan says **record** (dynamic
gang-scheduling run with instrumentation), every later step rebuilds the
same-shaped graph over fresh tiles, plans as **replay**, and re-executes
the recorded schedule with preallocated run lists — no victim selection,
no indegree lock, no worker reservation.  The plan is inspectable data and
the recording comes back on the `RunReport` (no `last_recording` global).

Run:  PYTHONPATH=src python examples/replay_sweep.py
"""

import time

import numpy as np

import repro
from repro.linalg import (build_cholesky_graph, cholesky_extract,
                          cholesky_graph_key, random_spd, to_tiles)
from repro.replay import GraphCache

NB, B, WORKERS, STEPS = 8, 64, 4, 6


def main():
    cache = GraphCache()          # GraphCache(path="...") would persist
    print(f"cache key: {cholesky_graph_key(NB, B)}")
    with repro.Session(WORKERS, scheduler="replay", cache=cache) as session:
        for step in range(STEPS):
            a = random_spd(NB * B, seed=step)
            store = to_tiles(a, B)
            graph = build_cholesky_graph(NB, B, store=store)
            plan = session.plan(graph)
            t0 = time.perf_counter()
            report = session.run(graph, plan=plan)
            L = cholesky_extract(store)
            L.block_until_ready()
            dt = time.perf_counter() - t0
            err = float(np.abs(np.asarray(L @ L.T) - np.asarray(a)).max())
            print(f"step {step}: {plan.mode:7s} {dt * 1e3:7.2f} ms   "
                  f"|LL^T - A|_max = {err:.2e}   "
                  f"(recording: {'yes' if report.recording else 'no'})")
    print(f"\ncached recordings: {len(cache)} "
          f"(one per graph shape x worker-count x policy)")


if __name__ == "__main__":
    main()
