"""Tests for the multi-process execution pool (repro.mp).

Covers the pipe protocol (futures, remote errors, timeouts, death), the
cross-process GraphCache shipment channel (writer races, plan-meta
round-trips), and the Session integration (async ``submit``, sharded
``map(procs=N)`` with recording adoption).  Everything here spawns real
processes -> ``pytest.mark.mp``.
"""

import json
import multiprocessing
import os
import threading
import time

import pytest

import mp_helpers
import repro
from repro.api.session import PlanError
from repro.mp import (
    FutureTimeout,
    ProcessPool,
    WorkerDied,
    WorkerError,
    WorkerSpec,
    callable_ref,
)
from repro.replay import GraphCache

pytestmark = pytest.mark.mp


# ---------------------------------------------------------------------------
# protocol / lifecycle
def test_pool_roundtrip_ping_and_submit():
    with ProcessPool(2, WorkerSpec(workers=1)) as pool:
        assert pool.ping(0, "tok") == "tok"
        assert pool.ping(1, {"nested": [1, 2]}) == {"nested": [1, 2]}
        ids = [pool.submit(mp_helpers.whoami, proc=p).result(timeout=60)
               for p in (0, 1)]
        assert [w["index"] for w in ids] == [0, 1]
        assert len({w["pid"] for w in ids}) == 2          # real processes
        assert all(w["pid"] != os.getpid() for w in ids)
        assert pool.submit(mp_helpers.add, 19, 23).result(timeout=60) == 42
    assert not multiprocessing.active_children()


def test_pool_map_round_robins_in_order():
    with ProcessPool(2, WorkerSpec(workers=1)) as pool:
        out = pool.map(mp_helpers.echo, list(range(7)), timeout=60)
    assert out == list(range(7))


def test_worker_init_builds_state_once():
    spec = WorkerSpec(workers=1, init=callable_ref(mp_helpers.init_marker))
    with ProcessPool(1, spec) as pool:
        state = pool.submit(mp_helpers.get_state, proc=0).result(timeout=60)
        assert state["index"] == 0
        assert state["init_pid"] != os.getpid()
        again = pool.submit(mp_helpers.get_state, proc=0).result(timeout=60)
        assert again == state                             # built once


def test_remote_error_ships_kind_and_traceback():
    with ProcessPool(1, WorkerSpec(workers=1)) as pool:
        fut = pool.submit(mp_helpers.boom, "kaboom", proc=0)
        with pytest.raises(WorkerError) as ei:
            fut.result(timeout=60)
        assert ei.value.kind == "ValueError"
        assert "kaboom" in str(ei.value)
        assert "mp_helpers" in ei.value.remote_traceback  # child-side frames
        # the worker survives its task's exception
        assert pool.ping(0, "alive") == "alive"


def test_callable_ref_rejects_closures_and_lambdas():
    def local_fn(ctx):
        return 1

    for bad in (local_fn, (lambda ctx: 1)):
        with pytest.raises(ValueError, match="not shippable"):
            callable_ref(bad)
    assert callable_ref(mp_helpers.echo) == "mp_helpers:echo"


def test_future_timeout_fires_across_spawn_then_kill_reaps():
    """The thread-method watchdog the suite relies on: a parent-side
    ``result(timeout=)`` must fire while the child is wedged in a task
    (a signal-based timeout could not interrupt this blocking recv), and
    killing the wedged child must fail its outstanding futures."""
    with ProcessPool(1, WorkerSpec(workers=1)) as pool:
        fut = pool.submit(mp_helpers.hang, 60.0, proc=0)
        t0 = time.monotonic()
        with pytest.raises(FutureTimeout):
            fut.result(timeout=0.5)
        assert time.monotonic() - t0 < 5.0
        assert not fut.done()                 # still outstanding, not dead
        pool.kill(0)
        with pytest.raises(WorkerDied) as ei:
            fut.result(timeout=30)
        assert ei.value.proc == 0
        assert not pool.alive(0)
    assert not multiprocessing.active_children()


def test_dead_worker_refuses_new_requests_fast():
    with ProcessPool(2, WorkerSpec(workers=1)) as pool:
        pool.kill(1)
        fut = pool.submit(mp_helpers.echo, "x", proc=1)
        with pytest.raises(WorkerDied):
            fut.result(timeout=30)
        assert pool.ping(0, 1) == 1           # sibling unaffected


# ---------------------------------------------------------------------------
# GraphCache as the cross-process shipment channel (satellites 1 + 2)
def test_two_process_cache_writer_race_leaves_no_torn_files(tmp_path):
    """Two worker processes store/swap/plan-meta the SAME cache key
    concurrently; afterwards every on-disk file must parse (atomic
    rename + lock) and nothing may have been quarantined."""
    path = str(tmp_path / "cache")
    with ProcessPool(2, WorkerSpec(workers=1)) as pool:
        futs = [pool.submit(mp_helpers.cache_hammer, path, 40, proc=p)
                for p in (0, 1)]
        outs = [f.result(timeout=300) for f in futs]
    assert outs[0]["digest"] == outs[1]["digest"]
    names = sorted(os.listdir(path))
    assert not [n for n in names if n.endswith(".corrupt")], names
    assert not [n for n in names if n.endswith(".tmp")], names
    parsed = 0
    for n in names:
        if n.endswith(".json"):
            with open(os.path.join(path, n)) as fh:
                json.load(fh)                 # raises on a torn write
            parsed += 1
    assert parsed >= 2                        # recording + plan meta
    # lock files must be invisible to the candidates() scan
    cache = GraphCache(path)
    cands = cache.candidates(outs[0]["digest"])
    assert list(cands) == [2]


def test_plan_meta_round_trips_across_processes(tmp_path):
    """Meta stored by one process is read by another (fresh instance reads
    through to disk), and a swap in process A drops the meta process B
    observes."""
    path = str(tmp_path / "cache")
    meta = {"segments": 3, "fused": 5, "source": "proc0"}
    with ProcessPool(2, WorkerSpec(workers=1)) as pool:
        seed = pool.submit(mp_helpers.seed_recording, path, proc=0).result(
            timeout=120)
        args = (path, seed["digest"], seed["workers"], seed["policy"])
        pool.submit(mp_helpers.store_plan_meta, *args, meta,
                    proc=0).result(timeout=60)
        # cross-process read: proc 1 never wrote this meta
        got = pool.submit(mp_helpers.lookup_plan_meta, *args,
                          proc=1).result(timeout=60)
        assert got == meta
        # swap in proc 0 stales the lowering; proc 1 must observe the drop
        pool.submit(mp_helpers.swap_same_recording, *args,
                    proc=0).result(timeout=60)
        gone = pool.submit(mp_helpers.lookup_plan_meta, *args,
                           proc=1).result(timeout=60)
        assert gone is None


# ---------------------------------------------------------------------------
# Session integration: async submit + sharded map
def test_session_submit_overlaps_build_with_execution():
    with repro.Session(workers=1) as s:
        futs = []
        for i in range(5):                    # build i+1 while i runs
            futs.append(s.submit(mp_helpers.build_chain(i)))
        outs = [f.result(timeout=60) for f in futs]
    for i, rep in enumerate(outs):
        assert set(rep.results.values()) == mp_helpers.chain_expected(i)


def test_session_submit_carries_exceptions_and_close_drains():
    def bad_graph():
        g = repro.Graph("bad")
        g.add(lambda: 1 / 0, name="div")
        return g

    s = repro.Session(workers=1)
    ok = s.submit(mp_helpers.build_chain(3))
    bad = s.submit(bad_graph())
    tail = s.submit(mp_helpers.build_chain(4))
    s.close()                                 # drains: nothing dropped
    assert set(ok.result(timeout=1).results.values()) == \
        mp_helpers.chain_expected(3)
    assert isinstance(bad.exception(timeout=1), ZeroDivisionError)
    assert set(tail.result(timeout=1).results.values()) == \
        mp_helpers.chain_expected(4)
    with pytest.raises(PlanError):
        s.submit(mp_helpers.build_chain(5))


def test_session_map_shards_across_processes_with_adoption(tmp_path):
    """map(procs=2): input 0 records in-process (seeding the shared disk
    cache); every other input executes in a child that ADOPTS the seeded
    recording — mode replay, no child-side recording run."""
    cache = GraphCache(str(tmp_path / "cache"))
    with repro.Session(2, scheduler="replay", cache=cache, procs=2) as s:
        reports = s.map(mp_helpers.build_chain, list(range(7)))
    assert reports[0].plan.mode == "record"   # the in-process seed
    procs_used = set()
    for i, rep in enumerate(reports[1:], start=1):
        assert set(rep.results.values()) == mp_helpers.chain_expected(i)
        assert rep.plan.mode == "replay"      # adopted, never re-recorded
        procs_used.add(rep.stats["mp_proc"])
    assert procs_used == {0, 1}               # round-robined both children


def test_session_map_procs_rejects_unshippable_builder(tmp_path):
    cache = GraphCache(str(tmp_path / "cache"))
    with repro.Session(1, scheduler="replay", cache=cache) as s:
        with pytest.raises(PlanError, match="import reference"):
            s.map(lambda x: mp_helpers.build_chain(x), [1, 2], procs=2)


def test_session_close_shuts_pool_down():
    s = repro.Session(1, procs=2)
    pool = s.process_pool()
    assert pool.ping(0, 1) == 1
    s.close()
    deadline = time.monotonic() + 10
    while multiprocessing.active_children() and time.monotonic() < deadline:
        time.sleep(0.05)
    assert not multiprocessing.active_children()
    with pytest.raises(RuntimeError):
        pool.request(0, "ping", 1)


def test_parent_death_sentinel_reaps_children():
    """A pool owner that exits WITHOUT calling shutdown must not strand
    children: the child's recv loop exits on pipe EOF.  Simulated by
    dropping the parent-side connections."""
    pool = ProcessPool(1, WorkerSpec(workers=1))
    proc = pool._workers[0].process
    pid = proc.pid
    pool._workers[0].conn.close()             # the EOF sentinel
    proc.join(timeout=30)
    assert proc.exitcode == 0                 # clean exit, not a reap
    pool.shutdown()
    assert not multiprocessing.active_children()
    assert pid is not None
