"""Declarative resources & conflict-aware scheduling (repro.resources).

Covers the subsystem end to end: arbiter unit semantics (atomic grant,
FIFO fairness, capacity, shared/exclusive, pinned replay mode, abort),
mutual exclusion under the real threaded executor, record->replay->remap
grant-order determinism, compiled-plan bit-identity, abort-time grant
release through the checkpoint-writer consumer, simulator wait modeling,
graph-digest identity, the serving KV-page consumer, and a property test
over random conflict graphs (hypothesis when available, a seeded sweep
always).
"""

import random
import threading
import time
from collections import Counter

import pytest

from repro import Graph, Session, TaskGraph
from repro.checkpoint import (CheckpointSink, add_checkpoint_tasks,
                              checkpoint_resource)
from repro.core import Simulator
from repro.replay import GraphCache, graph_key, remap_recording
from repro.resources import Resource, ResourceArbiter, grants_by_resource

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


# ---------------------------------------------------------------------------
# arbiter unit semantics (no threads, no executor)
# ---------------------------------------------------------------------------
def _declared_graph():
    """t0 uses A, t1 uses A+B, t2 uses B — the overlap chain the FIFO
    fairness rule exists for."""
    g = TaskGraph("arb")
    a, b = Resource("A"), Resource("B")
    g.add(name="t0", uses=[a])
    g.add(name="t1", uses=[a, b])
    g.add(name="t2", uses=[b])
    return g


def test_arbiter_atomic_grant_and_fifo_fairness():
    g = _declared_graph()
    arb = ResourceArbiter()
    arb.begin(g)
    assert arb.try_acquire(0)                 # A free
    assert not arb.try_acquire(1)             # A held -> deferred (atomic:
    assert not arb.holds(1)                   # B was NOT taken meanwhile)
    # B is free, but t1 queued first and overlaps t2 on B: no overtaking
    assert not arb.try_acquire(2)
    assert arb.waiting_count() == 2
    assert arb.release(0) == [1]              # full set granted atomically
    assert arb.release(1) == [2]
    arb.release(2)
    assert arb.held_count() == 0 and arb.waiting_count() == 0
    assert arb.grant_log() == [0, 1, 2]
    assert grants_by_resource(g, arb.grant_log()) == {0: [0, 1], 1: [1, 2]}


def test_arbiter_capacity_and_shared_readers():
    g = TaskGraph("cap")
    pool = Resource("pool", capacity=2)
    table = Resource("table")
    for _ in range(3):
        g.add(uses=[pool])                    # tids 0..2: exclusive, cap 2
    g.add(uses_shared=[table])                # tid 3: reader
    g.add(uses_shared=[table])                # tid 4: reader
    g.add(uses=[table])                       # tid 5: writer
    arb = ResourceArbiter()
    arb.begin(g)
    assert arb.try_acquire(0) and arb.try_acquire(1)
    assert not arb.try_acquire(2)             # capacity 2 exhausted
    assert arb.release(0) == [2]
    assert arb.try_acquire(3) and arb.try_acquire(4)   # readers overlap
    assert not arb.try_acquire(5)             # writer excluded by readers
    assert arb.release(3) == []
    assert arb.release(4) == [5]              # last reader admits the writer
    assert not arb.try_acquire(3)             # and readers wait on writers


def test_arbiter_pinned_mode_enforces_recorded_order():
    g = _declared_graph()
    arb = ResourceArbiter()
    arb.begin(g, pinned_order=[2, 1, 0])
    assert arb.pinned_heads() == [1, 2]       # A's queue [1,0], B's [2,1]
    assert not arb.try_acquire(0)             # not A's recorded head
    assert not arb.try_acquire(1)             # t1 is behind t2 on B
    assert arb.try_acquire(2)
    assert arb.release(2) == []               # pinned mode never re-queues
    assert arb.runnable_now(1) and arb.try_acquire(1)
    assert not arb.runnable_now(0)
    arb.release(1)
    assert arb.try_acquire(0)
    arb.release(0)
    assert arb.grant_log() == [2, 1, 0]


def test_arbiter_abort_drops_grants_and_waiters():
    g = _declared_graph()
    arb = ResourceArbiter()
    arb.begin(g)
    assert arb.try_acquire(0)
    assert not arb.try_acquire(1)
    assert arb.abort() == [1]                 # the still-deferred tid
    assert arb.held_count() == 0 and arb.waiting_count() == 0
    arb.begin(g)                              # next run starts clean
    assert arb.try_acquire(1)


# ---------------------------------------------------------------------------
# holder tracking for executor-level invariants
# ---------------------------------------------------------------------------
class HolderTracker:
    """Counts concurrent holders per resource name inside task bodies and
    records any state the arbiter must have made unreachable."""

    def __init__(self):
        self.lock = threading.Lock()
        self.excl = Counter()
        self.shared = Counter()
        self.max_excl = Counter()
        self.violations = []

    def enter(self, name, *, shared=False, capacity=1):
        with self.lock:
            if shared:
                if self.excl[name]:
                    self.violations.append(f"reader of {name} with writer in")
                self.shared[name] += 1
            else:
                if self.shared[name]:
                    self.violations.append(f"writer of {name} with reader in")
                if self.excl[name] >= capacity:
                    self.violations.append(f"{name} over capacity {capacity}")
                self.excl[name] += 1
                self.max_excl[name] = max(self.max_excl[name],
                                          self.excl[name])

    def exit(self, name, *, shared=False):
        with self.lock:
            if shared:
                self.shared[name] -= 1
            else:
                self.excl[name] -= 1


def _guarded_body(tracker, name, *, shared=False, capacity=1,
                  hold_s=0.003):
    def body(ctx):
        tracker.enter(name, shared=shared, capacity=capacity)
        time.sleep(hold_s)
        tracker.exit(name, shared=shared)
    return body


def test_exclusive_resource_never_two_holders():
    tracker = HolderTracker()
    g = Graph("mutex")
    res = Resource("acc")
    for i in range(8):
        g.add(_guarded_body(tracker, "acc"), name=f"u{i}", uses=[res])
    with Session(workers=4) as s:
        rep = s.run(g, timeout=60.0)
    assert not tracker.violations
    assert tracker.max_excl["acc"] == 1
    assert rep.stats.get("resource_acquires") == 8


def test_shared_readers_overlap_writer_excluded():
    tracker = HolderTracker()
    g = Graph("rw")
    table = Resource("table")
    for i in range(4):
        g.add(_guarded_body(tracker, "table", shared=True),
              name=f"r{i}", uses_shared=[table])
    for i in range(2):
        g.add(_guarded_body(tracker, "table"), name=f"w{i}", uses=[table])
    with Session(workers=4) as s:
        s.run(g, timeout=60.0)
    assert not tracker.violations            # no reader/writer overlap


def test_capacity_two_bounds_concurrency():
    tracker = HolderTracker()
    g = Graph("cap2")
    pool = Resource("pool", capacity=2)
    for i in range(6):
        g.add(_guarded_body(tracker, "pool", capacity=2),
              name=f"p{i}", uses=[pool])
    with Session(workers=4) as s:
        s.run(g, timeout=60.0)
    assert not tracker.violations
    assert tracker.max_excl["pool"] <= 2


def test_disjoint_resources_run_concurrently():
    """Two tasks on DIFFERENT resources cross-signal: each waits for the
    other's event.  If conflict handling (or steal avoidance) wrongly
    serialized disjoint declarations, one side would time out."""
    ev_a, ev_b = threading.Event(), threading.Event()
    g = Graph("disjoint")

    def left(ctx):
        ev_a.set()
        assert ev_b.wait(10.0), "right task never ran concurrently"

    def right(ctx):
        ev_b.set()
        assert ev_a.wait(10.0), "left task never ran concurrently"

    g.add(left, name="left", uses=[Resource("A")])
    g.add(right, name="right", uses=[Resource("B")])
    with Session(workers=2) as s:
        s.run(g, timeout=30.0)
    assert ev_a.is_set() and ev_b.is_set()


# ---------------------------------------------------------------------------
# record -> replay -> remap determinism
# ---------------------------------------------------------------------------
def _contended_graph(order_sink, n=6):
    """Skewed producers each feeding one guarded update of a single
    accumulator — the update order is the arbiter's to choose (recorded),
    not the graph's."""
    g = Graph("contend")
    res = Resource("acc")
    for i in range(n):
        def feed(ctx, i=i):
            time.sleep(0.001 * ((i * 3) % 5))
            return i

        h = g.add(feed, name=f"feed{i}", kind="compute", cost=1.0)

        def upd(ctx, v, i=i):
            order_sink.append(i)

        g.add(upd, h, name=f"upd{i}", kind="comm", cost=0.2, uses=[res])
    return g


def test_record_then_replay_pins_grant_order():
    cache = GraphCache()
    orders = []
    with Session(workers=3, scheduler="replay", cache=cache) as s:
        for _ in range(3):
            sink = []
            rep = s.run(_contended_graph(sink), timeout=60.0)
            orders.append(list(sink))
    assert rep.plan.mode == "replay"
    rec = rep.recording
    assert rec is not None and list(rec.resource_grants)
    # the recorded order IS the replayed order, bit-identical every run
    assert orders[1] == orders[0] and orders[2] == orders[0]
    g = _contended_graph([])
    (per_res,) = grants_by_resource(g, rec.resource_grants).values()
    replayed_upds = [g.tasks[t].name for t in per_res]
    assert replayed_upds == [f"upd{i}" for i in orders[0]]


def test_remap_preserves_resource_grants():
    cache = GraphCache()
    with Session(workers=2, scheduler="replay", cache=cache) as s:
        rep = s.run(_contended_graph([]), timeout=60.0)
    rec = rep.recording
    assert list(rec.resource_grants)
    for w in (1, 3):
        remapped = remap_recording(rec, w)
        assert list(remapped.resource_grants) == list(rec.resource_grants)
    # and a session at the remapped width replays the same grant order
    sink = []
    with Session(workers=3, scheduler="replay", cache=cache) as s:
        rep3 = s.run(_contended_graph(sink), timeout=60.0)
    assert rep3.plan.mode == "replay"
    g = _contended_graph([])
    want = grants_by_resource(g, rec.resource_grants)
    (per_res,) = want.values()
    assert [f"upd{i}" for i in sink] == [g.tasks[t].name for t in per_res]


def _order_sensitive_graph(out, n=5):
    """Non-commutative accumulator update (x -> 7x + i) under one exclusive
    resource: the final value is a fingerprint of the grant order."""
    g = Graph("horner")
    res = Resource("acc")
    for i in range(n):
        def feed(ctx, i=i):
            time.sleep(0.001 * ((i * 2) % 3))
            return i

        h = g.add(feed, name=f"feed{i}", kind="compute", cost=1.0)

        def upd(ctx, v, i=i):
            out[0] = out[0] * 7 + i

        g.add(upd, h, name=f"upd{i}", kind="comm", cost=0.2, uses=[res])
    return g


def test_compiled_reruns_grant_bit_identically():
    cache = GraphCache()
    values = []
    with Session(workers=2, scheduler="compiled", cache=cache) as s:
        for _ in range(3):
            out = [0]
            rep = s.run(_order_sensitive_graph(out), timeout=60.0)
            values.append(out[0])
    # record run fixed the order; both compiled runs reproduced it exactly
    assert values[1] == values[0] and values[2] == values[0]
    assert rep.plan.mode == "compiled"
    assert rep.stats.get("resource_grants") == 5


# ---------------------------------------------------------------------------
# abort releases grants (the checkpoint-writer consumer)
# ---------------------------------------------------------------------------
def test_crash_mid_write_releases_the_file_grant():
    n_shards = 3
    with Session(workers=3) as s:
        sink = CheckpointSink(n_shards)
        g = Graph("ckpt")
        add_checkpoint_tasks(g, sink, list(range(n_shards)),
                             resource=checkpoint_resource(), crash_on=1)
        with pytest.raises(Exception, match="simulated crash"):
            s.run(g, timeout=30.0)
        assert sink.torn and not sink.complete
        # the dead writer's grant is gone: a fresh attempt on the SAME
        # session acquires the file cleanly (a leak would deadlock here)
        sink2 = CheckpointSink(n_shards)
        g2 = Graph("ckpt")
        add_checkpoint_tasks(g2, sink2, list(range(n_shards)),
                             resource=checkpoint_resource())
        s.run(g2, timeout=30.0)
        assert sink2.complete and sorted(sink2.write_log) == [0, 1, 2]


# ---------------------------------------------------------------------------
# simulator wait modeling
# ---------------------------------------------------------------------------
def test_simulator_models_resource_serialization():
    shared = TaskGraph("sim-shared")
    r = Resource("acc")
    for i in range(3):
        shared.add(name=f"t{i}", cost=1.0, uses=[r])
    disjoint = TaskGraph("sim-disjoint")
    for i in range(3):
        disjoint.add(name=f"t{i}", cost=1.0, uses=[Resource(f"r{i}")])
    tr_shared = Simulator(3).run(shared)
    tr_disjoint = Simulator(3).run(disjoint)
    assert tr_shared.makespan >= 2.9          # serialized by the resource
    assert tr_disjoint.makespan <= 1.5        # disjoint -> full overlap
    assert any(e.label.startswith("res:") for e in tr_shared.events)
    assert not any(e.label.startswith("res:") for e in tr_disjoint.events)


# ---------------------------------------------------------------------------
# graph digest identity
# ---------------------------------------------------------------------------
def _keyed_graph(with_resources):
    g = TaskGraph("key")
    r = Resource("acc", capacity=2) if with_resources else None
    for i in range(3):
        g.add(name=f"t{i}", cost=1.0, uses=[r] if with_resources else ())
    return g


def test_graph_key_resource_identity():
    plain = graph_key(_keyed_graph(False))
    assert graph_key(_keyed_graph(False)) == plain    # resource-free stable
    declared = graph_key(_keyed_graph(True))
    assert declared != plain                          # declarations count
    # fresh handles, same (name, capacity, usage): identical digest — the
    # per-step-rebuild contract serving depends on
    assert graph_key(_keyed_graph(True)) == declared


def test_serving_kv_page_digest_and_maintenance_exclusion():
    import numpy as np

    from repro.models.serving import (DecodeShard, DecodeState,
                                      build_decode_graph, kv_page_resources)

    tracker = HolderTracker()

    def make(with_maint):
        state = DecodeState(None, [DecodeShard(cache=None,
                                               tok=np.array([[s]]))
                                   for s in range(2)])

        def decode_fn(params, cache, tok):
            return cache, np.asarray(tok)

        pages = kv_page_resources(2)
        maint = (lambda st: None) if with_maint else None
        return state, build_decode_graph(
            state, decode_fn, sample_fn=lambda logits: np.asarray(logits),
            kv_pages=pages, maintenance_fn=maint)

    # fresh Resource handles every build, same digest (replayable loop)
    assert graph_key(make(True)[1]) == graph_key(make(True)[1])
    assert graph_key(make(True)[1]) != graph_key(make(False)[1])

    # maintenance (takes every page, no edges) never overlaps a decode
    state = DecodeState(None, [DecodeShard(cache=None, tok=np.array([[s]]))
                               for s in range(2)])
    pages = kv_page_resources(2)

    def decode_fn(params, cache, tok):
        s = int(np.asarray(tok)[0, 0])
        tracker.enter(f"kv{s}")
        time.sleep(0.003)
        tracker.exit(f"kv{s}")
        return cache, np.asarray(tok)

    def maintenance(st):
        for s in range(2):
            tracker.enter(f"kv{s}")
        time.sleep(0.003)
        for s in range(2):
            tracker.exit(f"kv{s}")

    g = build_decode_graph(state, decode_fn,
                           sample_fn=lambda logits: np.asarray(logits),
                           kv_pages=pages, maintenance_fn=maintenance)
    with Session(workers=4) as s:
        s.run(g, timeout=60.0)
    assert not tracker.violations
    assert len(state.history) == 1


# ---------------------------------------------------------------------------
# property: random conflict graphs
# ---------------------------------------------------------------------------
def _run_conflict_instance(seed):
    """One random conflict graph: every task declares a random subset of
    random-capacity resources (shared or exclusive), no edges.  Invariants:
    every task runs (no deadlock), no holder-set the declarations forbid."""
    rng = random.Random(seed)
    n_res = rng.randint(1, 3)
    caps = [rng.randint(1, 2) for _ in range(n_res)]
    resources = [Resource(f"r{j}", capacity=caps[j]) for j in range(n_res)]
    tracker = HolderTracker()
    done = []
    g = Graph(f"prop{seed}")
    n_tasks = rng.randint(4, 9)
    for i in range(n_tasks):
        picks = [(j, rng.random() < 0.4) for j in range(n_res)
                 if rng.random() < 0.6]

        def body(ctx, i=i, picks=picks):
            for j, shared in picks:
                tracker.enter(f"r{j}", shared=shared, capacity=caps[j])
            time.sleep(0.001)
            for j, shared in picks:
                tracker.exit(f"r{j}", shared=shared)
            done.append(i)

        g.add(body, name=f"t{i}",
              uses=[resources[j] for j, sh in picks if not sh],
              uses_shared=[resources[j] for j, sh in picks if sh])
    with Session(workers=4) as s:
        s.run(g, timeout=60.0)
    assert not tracker.violations, tracker.violations
    assert sorted(done) == list(range(n_tasks))


def test_random_conflict_graphs_seeded_sweep():
    for seed in range(6):
        _run_conflict_instance(seed)


if HAVE_HYPOTHESIS:
    @settings(max_examples=15, deadline=None)
    @given(st.integers(min_value=0, max_value=10**6))
    def test_random_conflict_graphs_property(seed):
        _run_conflict_instance(seed)
else:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_random_conflict_graphs_property():
        pass
