"""Numeric validation of the tiled factorizations executed through the
gang-scheduling/work-stealing runtime, under every victim policy.

Schedule independence — the factorization result must not depend on the
scheduling policy — is the core correctness invariant of the scheduler.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

jax.config.update("jax_enable_x64", True)

from repro.core import run_graph
from repro.linalg import (
    build_cholesky_graph,
    build_lu_graph,
    build_qr_graph,
    cholesky_extract,
    lu_extract,
    qr_extract_r,
    qr_reconstruct,
    random_diagdom,
    random_spd,
    to_tiles,
)
from repro.linalg.panels import lu_panel_region, qr_form_t, qr_panel_region


class _SerialRegion:
    def barrier(self):
        pass


# ---------------------------------------------------------------------------
# panel kernels in isolation (serial region)
# ---------------------------------------------------------------------------
def test_lu_panel_matches_reference():
    rng = np.random.default_rng(0)
    p = rng.standard_normal((96, 16))
    p[:16] += np.diag(np.abs(p).sum(axis=0) + 1.0)[:16, :16] @ np.eye(16)
    ref = p.copy()
    body = lu_panel_region(p, 16, 1)
    body(0, _SerialRegion())
    l = np.tril(p[:16], -1) + np.eye(16)
    u = np.triu(p[:16])
    l_full = np.vstack([l, p[16:]])
    np.testing.assert_allclose(l_full @ u, ref, rtol=1e-10, atol=1e-10)


def test_qr_panel_matches_reference():
    rng = np.random.default_rng(1)
    p = rng.standard_normal((64, 16))
    ref = p.copy()
    body, taus = qr_panel_region(p, 16, 1)
    body(0, _SerialRegion())
    r = np.triu(p[:16])
    # reconstruct via compact WY
    T = qr_form_t(p, taus)
    V = np.tril(p, -1)[:, :16] + np.eye(64, 16)
    a = np.vstack([r, np.zeros((48, 16))])
    a = a - V @ (T @ (V.T @ a))
    np.testing.assert_allclose(a, ref, rtol=1e-9, atol=1e-9)
    # R has the right magnitude structure
    np.testing.assert_allclose(np.abs(np.linalg.svd(r, compute_uv=False)),
                               np.linalg.svd(ref, compute_uv=False), rtol=1e-9)


# ---------------------------------------------------------------------------
# full factorizations through the runtime
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("policy", ["history", "random", "hybrid"])
def test_cholesky_numeric_all_policies(policy):
    n, b = 192, 48
    a = random_spd(n, seed=2)
    store = to_tiles(a, b)
    g = build_cholesky_graph(store.nb, b, store=store)
    run_graph(g, 4, policy=policy, seed=0, timeout=120.0)
    l = cholesky_extract(store)
    np.testing.assert_allclose(np.asarray(l @ l.T), np.asarray(a), rtol=1e-8, atol=1e-8)


@pytest.mark.parametrize("policy", ["history", "hybrid"])
def test_lu_numeric_gang_panels(policy):
    n, b = 128, 32
    a = random_diagdom(n, seed=3)
    store = to_tiles(a, b)
    g = build_lu_graph(store.nb, b, store=store, panel_threads=3)
    run_graph(g, 4, policy=policy, seed=0, timeout=120.0)
    l, u = lu_extract(store)
    np.testing.assert_allclose(np.asarray(l @ u), np.asarray(a), rtol=1e-8, atol=1e-8)


@pytest.mark.parametrize("policy", ["history", "hybrid"])
def test_qr_numeric_gang_panels(policy):
    n, b = 128, 32
    rng = np.random.default_rng(4)
    a = jnp.asarray(rng.standard_normal((n, n)))
    store = to_tiles(a, b)
    g = build_qr_graph(store.nb, b, store=store, panel_threads=3)
    run_graph(g, 4, policy=policy, seed=0, timeout=120.0)
    r = qr_extract_r(store)
    # R upper triangular by construction; reconstruction must give A back
    recon = qr_reconstruct(store)
    np.testing.assert_allclose(np.asarray(recon), np.asarray(a), rtol=1e-8, atol=1e-8)
    # orthogonal invariance of singular values
    np.testing.assert_allclose(
        np.linalg.svd(np.asarray(r), compute_uv=False),
        np.linalg.svd(np.asarray(a), compute_uv=False), rtol=1e-8)


def test_schedule_independence_cholesky():
    """The same input must factor to the same L under different policies,
    seeds and worker counts."""
    n, b = 96, 32
    a = random_spd(n, seed=5)
    results = []
    for policy, workers, seed in [("history", 2, 0), ("hybrid", 4, 1), ("random", 3, 2)]:
        store = to_tiles(a, b)
        g = build_cholesky_graph(store.nb, b, store=store)
        run_graph(g, workers, policy=policy, seed=seed, timeout=120.0)
        results.append(np.asarray(cholesky_extract(store)))
    for r in results[1:]:
        np.testing.assert_allclose(r, results[0], rtol=1e-12, atol=1e-12)


def test_lu_graph_cost_mode_structure():
    g = build_lu_graph(6, 64, store=None)
    kinds = g.subgraph_kinds()
    assert kinds["panel"] == 6
    assert kinds["comm"] == 6
    # lookahead column per step except the last
    assert kinds["lookahead"] == 5
    # panels carry nested-parallel specs for the simulator
    panels = [t for t in g if t.kind == "panel"]
    assert all(t.parallel is not None for t in panels)
    length, path = g.critical_path()
    assert length > 0
