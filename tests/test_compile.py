"""Tests for repro.compile: lowering stable recordings into fused serial
plans (PR-8 tentpole).

Covers the contract stack bottom-up: the segmentation invariants of
``compile_recording`` (every task covered exactly once, boundaries recorded
with reasons, stale recordings rejected loudly); bit-identity of the
compiled path against dynamic and replay scheduling for the linalg
factorizations and the pooled decode loop; the warm -> compiled promotion
ladder in :class:`~repro.replay.ReplayPool` including demotion on a failed
compiled serve; and :class:`~repro.replay.GraphCache` round-tripping the
lowering's :class:`~repro.compile.CompiledPlanMeta` next to the recording
(and dropping it when the recording is swapped).
"""

import json

import numpy as np
import pytest

import repro
from repro.compile import (
    CompiledExecutor,
    CompiledPlanMeta,
    CompiledRunError,
    CompileError,
    compile_recording,
)
from repro.core import Runtime
from repro.linalg import (
    build_cholesky_graph,
    build_lu_graph,
    build_qr_graph,
    cholesky_extract,
    lu_extract,
    qr_extract_r,
    random_diagdom,
    random_spd,
    to_tiles,
)
from repro.replay import GraphCache, Recording, ReplayPool

NB, B = 4, 8


def _record_cholesky(workers=2, seed=3):
    a = random_spd(NB * B, seed=seed)
    st = to_tiles(a, B)
    g = build_cholesky_graph(NB, B, store=st)
    with Runtime(workers) as rt:
        rt.run(g, record=True)
    return a, np.asarray(cholesky_extract(st)), g, rt.last_recording


# ---------------------------------------------------------------------------
# compile_recording: segmentation invariants
# ---------------------------------------------------------------------------
def test_plan_covers_every_task_exactly_once():
    _, _, g, rec = _record_cholesky()
    plan = compile_recording(g, rec)
    seen = []
    for entry in plan.program:
        if entry[0] == "fused":
            seen.extend(entry[1].tids)
        elif entry[0] == "task":
            seen.append(entry[1])
        # ("resume", tid, seg) re-enters an already-seen task's frame
    assert sorted(seen) == sorted(t.tid for t in g.tasks)
    assert len(seen) == len(set(seen))
    m = plan.meta
    assert m.n_tasks == len(g.tasks)
    assert m.n_fused_tasks + m.n_opaque == m.n_tasks
    assert m.n_segments == len(plan.program)
    assert m.digest == rec.digest


def test_segment_boundaries_record_their_reasons():
    """The boundary census — why each segment was cut — is the lowering's
    observable shape and lands in the cached plan meta.  Dynamic schedules
    vary run to run, so only schedule-independent facts are asserted; a
    hand-built two-worker interleaving pins the worker_switch reason."""
    _, _, g, rec = _record_cholesky(workers=2)
    plan = compile_recording(g, rec)
    assert plan.meta.n_fused >= 1
    assert plan.meta.jit_segments >= 1
    # each cut emits at most one fused entry, so the census bounds n_fused
    assert sum(plan.meta.boundaries.values()) >= plan.meta.n_fused
    known = {"worker_switch", "opaque", "gang", "resume", "end"}
    assert set(plan.meta.boundaries) <= known
    # single-worker lowering of the same shape needs no worker cuts
    _, _, g1, rec1 = _record_cholesky(workers=1)
    plan1 = compile_recording(g1, rec1)
    assert "worker_switch" not in plan1.meta.boundaries
    assert plan1.meta.n_segments <= plan.meta.n_segments
    # force an interleaving: fold the serial order onto two alternating
    # workers — every consecutive fusible pair now straddles a switch
    r2 = Recording.from_dict(rec1.to_dict())
    serial = list(rec1.worker_orders[0])
    r2.worker_orders = [serial[0::2], serial[1::2]]
    r2.n_workers = 2
    plan2 = compile_recording(g1, r2)
    assert plan2.meta.boundaries.get("worker_switch", 0) >= 1
    assert plan2.meta.n_segments >= plan1.meta.n_segments


def test_stale_recording_rejected_with_compile_error():
    _, _, g, rec = _record_cholesky(workers=2)
    bad = Recording.from_dict(rec.to_dict())
    bad.worker_orders = [list(reversed(o)) for o in bad.worker_orders]
    with pytest.raises(CompileError, match="stale"):
        compile_recording(g, bad)


def test_plan_meta_round_trips_and_ignores_unknown_keys():
    _, _, g, rec = _record_cholesky()
    meta = compile_recording(g, rec).meta
    d = meta.to_dict()
    assert json.loads(json.dumps(d)) == d       # JSON-serializable
    assert CompiledPlanMeta.from_dict(d) == meta
    d["future_field"] = "ignored"
    assert CompiledPlanMeta.from_dict(d) == meta


def test_executor_rejects_digest_mismatch_and_reports_stats():
    a, l_ref, g, rec = _record_cholesky()
    ex = CompiledExecutor(g, compile_recording(g, rec))
    st2 = to_tiles(a, B)
    g2 = build_cholesky_graph(NB, B, store=st2)
    ex.run(g2)                                  # same digest: fine
    assert (np.asarray(cholesky_extract(st2)) == l_ref).all()
    stats = ex.stats
    assert 0.0 <= stats["dispatch_overhead_fraction"] < 1.0
    assert stats["segments"] == ex.plan.meta.n_segments
    other = build_cholesky_graph(NB + 1, B)
    with pytest.raises(CompiledRunError, match="digest"):
        ex.run(other)


# ---------------------------------------------------------------------------
# bit-identity goldens: compiled vs dynamic vs replay
# ---------------------------------------------------------------------------
def _factor_with(scheduler, cache, builder, extract, store, runs=3):
    """Run ``runs`` same-shaped sweeps through one session; return the last
    run's extracted factor(s) and report."""
    report = None
    with repro.Session(2, scheduler=scheduler, cache=cache) as s:
        for st in store[:-1]:
            s.run(builder(st))
        report = s.run(builder(store[-1]))
    return tuple(np.asarray(x) for x in extract(store[-1])), report


@pytest.mark.parametrize("name", ["cholesky", "lu", "qr"])
def test_compiled_factorizations_bit_identical(name):
    if name == "cholesky":
        mat = random_spd(NB * B, seed=7)
        builder = lambda st: build_cholesky_graph(NB, B, store=st)  # noqa: E731
        extract = lambda st: (cholesky_extract(st),)                # noqa: E731
    elif name == "lu":
        mat = random_diagdom(NB * B, seed=7)
        builder = lambda st: build_lu_graph(NB, B, store=st, panel_threads=2)  # noqa: E731
        extract = lu_extract
    else:
        mat = random_spd(NB * B, seed=7)
        builder = lambda st: build_qr_graph(NB, B, store=st, panel_threads=2)  # noqa: E731
        extract = lambda st: (qr_extract_r(st),)                    # noqa: E731

    stores = {k: [to_tiles(mat, B) for _ in range(3)]
              for k in ("dynamic", "replay", "compiled")}
    cache = GraphCache()
    dyn, _ = _factor_with("dynamic", None, builder, extract,
                          stores["dynamic"])
    rep, _ = _factor_with("replay", cache, builder, extract,
                          stores["replay"])
    cmp_, report = _factor_with("compiled", cache, builder, extract,
                                stores["compiled"])
    for d, r, c in zip(dyn, rep, cmp_):
        assert (d == r).all()
        assert (d == c).all()
    assert report.plan.mode == "compiled"
    assert 0.0 <= report.stats["dispatch_overhead_fraction"] < 1.0
    assert report.stats["fused_tasks"] >= 1


def test_compiled_decode_tokens_identical():
    import jax.numpy as jnp

    from repro.models import DecodeShard, DecodeState, build_decode_graph

    vocab = 7

    def toy_decode(params, cache, tok):
        h = cache["h"] * 31 + tok[:, 0] + 7
        logits = jnp.stack(
            [jnp.sin(h[:, None] * (i + 1)).astype(jnp.float32)
             for i in range(vocab)], axis=-1)
        return {"h": h}, logits

    def fresh_state(n_shards=3):
        shards = [
            DecodeShard(cache={"h": jnp.full((1,), s + 1, jnp.int32)},
                        tok=jnp.full((1, 1), s, jnp.int32))
            for s in range(n_shards)
        ]
        return DecodeState(params=None, shards=shards)

    def loop(run):
        state = fresh_state()
        for _ in range(6):
            run(build_decode_graph(state, toy_decode))
        return np.asarray(state.tokens())

    with repro.Session(1) as s:
        tok_dyn = loop(s.run)
    reports = []
    with repro.Session(1, scheduler="compiled") as s:
        tok_cmp = loop(lambda g: reports.append(s.run(g)))
    assert (tok_dyn == tok_cmp).all()
    assert reports[0].plan.mode == "record"
    assert all(r.plan.mode == "compiled" for r in reports[1:])


def test_session_map_parity_across_schedulers():
    """session.map plans once and reuses the plan for the whole sweep; the
    compiled sweep must match per-call dynamic runs bit-for-bit."""
    mats = [random_spd(NB * B, seed=s) for s in (11, 12, 13)]

    dyn = []
    with repro.Session(2) as s:
        for m in mats:
            st = to_tiles(m, B)
            s.run(build_cholesky_graph(NB, B, store=st))
            dyn.append(np.asarray(cholesky_extract(st)))

    stores = [to_tiles(m, B) for m in mats]
    with repro.Session(2, scheduler="compiled") as s:
        reports = s.map(lambda st: build_cholesky_graph(NB, B, store=st),
                        stores)
    got = [np.asarray(cholesky_extract(st)) for st in stores]
    for d, c in zip(dyn, got):
        assert (d == c).all()
    assert reports[0].plan.mode == "record"
    assert [r.plan.mode for r in reports[1:]] == ["compiled", "compiled"]


# ---------------------------------------------------------------------------
# ReplayPool promotion ladder
# ---------------------------------------------------------------------------
def test_pool_promotes_after_clean_replays_and_serves_compiled():
    a = random_spd(NB * B, seed=5)
    with Runtime(1) as rt:
        st = to_tiles(a, B)
        rt.run(build_cholesky_graph(NB, B, store=st))
        ref = np.asarray(cholesky_extract(st))

    modes, runs = [], []
    with ReplayPool(warmup_runs=1, compile_after=2) as pool:
        for _ in range(7):
            st = to_tiles(a, B)
            run = pool.serve(build_cholesky_graph(NB, B, store=st), 1)
            modes.append(run.mode)
            runs.append(run)
            assert (np.asarray(cholesky_extract(st)) == ref).all()
        assert modes[:2] == ["warmup", "record"]
        assert modes[2:4] == ["replay", "replay"]
        assert all(m == "compiled" for m in modes[4:])
        stats = runs[-1].stats
        assert stats["compiles"] == 1
        assert stats["compiled_serves"] == 3
        assert "compiled_stats" in stats
        assert 0.0 <= \
            stats["compiled_stats"]["dispatch_overhead_fraction"] < 1.0
        # the lowering's meta landed in the pool's cache
        rec = runs[-1].recording
        meta = pool.cache.lookup_plan_meta(rec.digest, 1, "hybrid")
        assert meta is not None
        assert CompiledPlanMeta.from_dict(meta).digest == rec.digest


def test_pool_demotes_on_compiled_failure_then_repromotes():
    a = random_spd(NB * B, seed=6)

    class _Broken:
        stats = {}

        def run(self, graph, check_digest=False):
            raise CompiledRunError("injected stall")

    with ReplayPool(warmup_runs=1, compile_after=2) as pool:
        def serve():
            st = to_tiles(a, B)
            return pool.serve(build_cholesky_graph(NB, B, store=st), 1)

        for _ in range(5):
            run = serve()
        assert run.mode == "compiled"
        entry = next(iter(pool._entries.values()))
        entry.compiled = _Broken()
        run = serve()                       # failed compiled serve -> replay
        assert run.mode == "replay"
        assert run.stats["compile_failures"] == 1
        assert entry.compiled is None       # clean streak must be re-earned
        run = serve()                       # second clean replay...
        assert run.mode == "replay"
        run = serve()                       # ...then promoted again
        assert run.mode == "compiled"
        assert run.stats["compiles"] == 2


# ---------------------------------------------------------------------------
# GraphCache plan-meta round trip
# ---------------------------------------------------------------------------
def test_cache_plan_meta_persists_and_drops_on_swap(tmp_path):
    _, _, g, rec = _record_cholesky(workers=2)
    meta = compile_recording(g, rec).meta

    cache = GraphCache(tmp_path)
    cache.store(rec)
    cache.store_plan_meta(rec.digest, rec.n_workers, "hybrid",
                          meta.to_dict())
    got = cache.lookup_plan_meta(rec.digest, rec.n_workers, "hybrid")
    assert CompiledPlanMeta.from_dict(got) == meta
    # a cold process sees the same lowering shape without recompiling
    warm = GraphCache(tmp_path)
    got = warm.lookup_plan_meta(rec.digest, rec.n_workers, "hybrid")
    assert CompiledPlanMeta.from_dict(got) == meta
    assert warm.lookup_plan_meta(rec.digest, rec.n_workers + 1,
                                 "hybrid") is None
    # swapping in a fresh recording stales any cached lowering
    cache.swap(rec)
    assert cache.lookup_plan_meta(rec.digest, rec.n_workers,
                                  "hybrid") is None
