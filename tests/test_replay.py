"""Tests for the record-and-replay subsystem (repro.replay).

Covers the contract documented in ``repro/replay/__init__.py``:
bit-identical replay results, structural GraphKey identity, stale-recording
fallback (no deadlock, no oversubscription), and the monotonic-gang-id
issue discipline.
"""

import numpy as np
import pytest

from repro.core import ListScheduler, Runtime, run_graph, TaskGraph
from repro.linalg import (
    build_cholesky_graph,
    build_lu_graph,
    cholesky_extract,
    cholesky_graph_key,
    lu_extract,
    lu_graph_key,
    random_diagdom,
    random_spd,
    to_tiles,
)
from repro.replay import (
    GraphCache,
    Recording,
    RecordingError,
    ReplayExecutor,
    cache_key,
    graph_key,
    replay_graph,
)

NB, B = 6, 16


def _record_cholesky(workers=4, seed=1, nb=NB, b=B):
    a = random_spd(nb * b, seed=seed)
    st = to_tiles(a, b)
    g = build_cholesky_graph(nb, b, store=st)
    with Runtime(workers) as rt:
        rt.run(g, record=True)
    return a, np.asarray(cholesky_extract(st)), rt.last_recording


# ---------------------------------------------------------------------------
# GraphKey
# ---------------------------------------------------------------------------
def test_graph_key_stable_across_rebuilds():
    k1 = cholesky_graph_key(NB, B)
    k2 = cholesky_graph_key(NB, B)
    assert k1 == k2 and hash(k1) == hash(k2)


def test_graph_key_ignores_callables():
    a = random_spd(NB * B, seed=0)
    numeric = build_cholesky_graph(NB, B, store=to_tiles(a, B))
    costmodel = build_cholesky_graph(NB, B)
    assert graph_key(numeric) == graph_key(costmodel)


def test_graph_key_distinguishes_shapes():
    base = cholesky_graph_key(NB, B)
    assert base != cholesky_graph_key(NB + 1, B)          # nb
    assert base != cholesky_graph_key(NB, B * 2)          # b (costs)
    assert base != lu_graph_key(NB, B)                    # kernel
    assert lu_graph_key(NB, B, panel_threads=2) != \
        lu_graph_key(NB, B, panel_threads=4)              # parallel spec


def test_cache_key_distinguishes_worker_count_and_policy():
    k = cholesky_graph_key(NB, B)
    assert cache_key(k, 2, "hybrid") != cache_key(k, 4, "hybrid")
    assert cache_key(k, 4, "hybrid") != cache_key(k, 4, "history")


# ---------------------------------------------------------------------------
# replay == dynamic, bit-identical
# ---------------------------------------------------------------------------
def test_replay_cholesky_bit_identical():
    a, l_dyn, rec = _record_cholesky()
    st = to_tiles(a, B)
    replay_graph(build_cholesky_graph(NB, B, store=st), rec)
    assert (np.asarray(cholesky_extract(st)) == l_dyn).all()


def test_replay_lu_bit_identical_with_gang_panels():
    m = random_diagdom(5 * B, seed=2)
    st = to_tiles(m, B)
    g = build_lu_graph(5, B, store=st, panel_threads=3)
    with Runtime(4) as rt:
        rt.run(g, record=True)
    rec = rt.last_recording
    l1, u1 = (np.asarray(x) for x in lu_extract(st))
    assert rec.gang_issue_order, "numeric LU must record panel forks"

    st2 = to_tiles(m, B)
    replay_graph(build_lu_graph(5, B, store=st2, panel_threads=3), rec)
    l2, u2 = (np.asarray(x) for x in lu_extract(st2))
    assert (l1 == l2).all() and (u1 == u2).all()


def test_replay_task_results_match_dynamic():
    def mk():
        g = TaskGraph("arith")
        xs = [g.add(lambda ctx, i=i: i * i, name=f"x{i}") for i in range(8)]
        s = g.add(lambda ctx: sum(ctx.dep_results()), deps=xs, name="sum")
        g.add(lambda ctx: ctx[s] * 2, deps=[s], name="double")
        return g

    res_dyn = run_graph(mk(), 3, record=True)
    rec = run_graph.last_recording
    res_rep = replay_graph(mk(), rec)
    assert res_rep == res_dyn


# ---------------------------------------------------------------------------
# gang-id issue discipline
# ---------------------------------------------------------------------------
def test_replay_gang_issue_order_matches_recording():
    m = random_diagdom(5 * B, seed=3)
    st = to_tiles(m, B)
    with Runtime(4) as rt:
        rt.run(build_lu_graph(5, B, store=st, panel_threads=3), record=True)
    rec = rt.last_recording
    recorded_ids = [rec.gang_placements[t].gang_id for t in rec.gang_issue_order]
    assert recorded_ids == sorted(recorded_ids), "recorded ids are monotonic"

    st2 = to_tiles(m, B)
    ex = ReplayExecutor(rec)
    with ex:
        ex.run(build_lu_graph(5, B, store=st2, panel_threads=3))
        assert list(ex.issued_gang_ids) == recorded_ids


def test_replay_gang_placement_no_oversubscription():
    """Recorded blocking-region placements use distinct workers per region."""
    m = random_diagdom(5 * B, seed=4)
    st = to_tiles(m, B)
    with Runtime(4) as rt:
        rt.run(build_lu_graph(5, B, store=st, panel_threads=3), record=True)
    for p in rt.last_recording.gang_placements.values():
        assert len(set(p.workers)) == len(p.workers)


# ---------------------------------------------------------------------------
# stale recordings & fallback
# ---------------------------------------------------------------------------
def test_stale_recording_digest_rejected_then_fallback_completes():
    from repro.linalg import CostModel

    a, l_dyn, rec = _record_cholesky()
    slow = CostModel(flop_rate=CostModel().flop_rate / 7.0)   # perturbed costs
    st = to_tiles(a, B)
    g = build_cholesky_graph(NB, B, store=st, cost=slow)
    with pytest.raises(RecordingError):
        replay_graph(g, rec)                                  # digest mismatch
    replay_graph(g, rec, check_digest=False)                  # fallback path
    assert (np.asarray(cholesky_extract(st)) == l_dyn).all()


def test_scrambled_recording_completes_via_fallback():
    """Reversed run lists violate the start-order invariant everywhere; the
    dynamic fallback must still finish the graph (no deadlock)."""
    a, l_dyn, rec = _record_cholesky()
    bad = Recording.from_dict(rec.to_dict())
    bad.worker_orders = [list(reversed(o)) for o in bad.worker_orders]
    st = to_tiles(a, B)
    ex = ReplayExecutor(bad, stall_timeout=1e-4)
    with ex:
        ex.run(build_cholesky_graph(NB, B, store=st), timeout=60.0)
        assert ex.stats["fallback_steals"] > 0
    assert (np.asarray(cholesky_extract(st)) == l_dyn).all()


def test_recording_refuses_double_fork_per_task():
    """Recordings key regions by spawning task: a task forking twice must be
    rejected at record time, not silently corrupt the recording."""
    g = TaskGraph("twofork")

    def forks_twice(ctx):
        ctx.parallel(2, lambda tid, region: tid)
        ctx.parallel(2, lambda tid, region: tid)

    g.add(forks_twice, name="p", kind="panel")
    with pytest.raises(ValueError, match="more than one parallel region"):
        run_graph(g, 3, record=True)


def test_recording_must_cover_graph():
    _, _, rec = _record_cholesky()
    bad = Recording.from_dict(rec.to_dict())
    # drop tasks from the busiest worker's list (a recorded order can
    # legitimately be empty — truncating that one would drop nothing)
    w = max(range(len(bad.worker_orders)),
            key=lambda i: len(bad.worker_orders[i]))
    bad.worker_orders[w] = bad.worker_orders[w][:-2]
    with pytest.raises(RecordingError):
        replay_graph(build_cholesky_graph(NB, B), bad, check_digest=False)


# ---------------------------------------------------------------------------
# static-schedule seeding
# ---------------------------------------------------------------------------
def test_static_schedule_seeds_recording():
    a, l_dyn, _ = _record_cholesky()
    gcost = build_cholesky_graph(NB, B)
    sched = ListScheduler(4, policy="hybrid").schedule(gcost)
    rec = Recording.from_static_schedule(sched, gcost)
    assert rec.source == "static"
    assert rec.collective_order == sched.collective_order()
    rec.validate_against(gcost)

    st = to_tiles(a, B)
    replay_graph(build_cholesky_graph(NB, B, store=st), rec)
    assert (np.asarray(cholesky_extract(st)) == l_dyn).all()


# ---------------------------------------------------------------------------
# cache + persistence + run_graph integration
# ---------------------------------------------------------------------------
def test_run_graph_cache_records_then_replays():
    a = random_spd(NB * B, seed=5)
    cache = GraphCache()
    results = []
    for _ in range(3):
        st = to_tiles(a, B)
        run_graph(build_cholesky_graph(NB, B, store=st), 4, cache=cache)
        results.append(np.asarray(cholesky_extract(st)))
    assert len(cache) == 1
    assert (results[0] == results[1]).all() and (results[1] == results[2]).all()


def test_graph_cache_on_disk_roundtrip(tmp_path):
    a, l_dyn, rec = _record_cholesky()
    cache = GraphCache(tmp_path)
    cache.store(rec)
    fresh = GraphCache(tmp_path)                      # new process analogue
    hit = fresh.lookup(build_cholesky_graph(NB, B), rec.n_workers, rec.policy)
    assert hit is not None
    st = to_tiles(a, B)
    replay_graph(build_cholesky_graph(NB, B, store=st), hit)
    assert (np.asarray(cholesky_extract(st)) == l_dyn).all()


def test_recording_json_roundtrip():
    _, _, rec = _record_cholesky()
    rec2 = Recording.from_json(rec.to_json())
    assert rec2.to_dict() == rec.to_dict()
