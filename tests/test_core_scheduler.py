"""Unit tests for the core scheduler: task graphs, policies, gang logic,
simulator semantics, static schedule extraction."""

import pytest

from repro.core import (
    DeadlockError,
    GangState,
    HybridPolicy,
    ListScheduler,
    ParallelSpec,
    TaskGraph,
    is_eligible_to_sched,
    make_policy,
    microbatch_overlap_graph,
    simulate,
)


# ---------------------------------------------------------------------------
# TaskGraph
# ---------------------------------------------------------------------------
def diamond() -> TaskGraph:
    g = TaskGraph("diamond")
    a = g.add(name="a", cost=1.0)
    b = g.add(name="b", deps=[a], cost=2.0)
    c = g.add(name="c", deps=[a], cost=3.0)
    g.add(name="d", deps=[b, c], cost=1.0)
    return g


def test_taskgraph_topology():
    g = diamond()
    order = [t.name for t in g.topological_order()]
    assert order.index("a") < order.index("b") < order.index("d")
    assert order.index("a") < order.index("c") < order.index("d")
    assert [t.name for t in g.roots()] == ["a"]
    assert sorted(s.name for s in g.successors(0)) == ["b", "c"]


def test_taskgraph_critical_path():
    g = diamond()
    length, path = g.critical_path()
    assert length == pytest.approx(1.0 + 3.0 + 1.0)
    assert [t.name for t in path] == ["a", "c", "d"]
    assert g.total_work() == pytest.approx(7.0)


def test_taskgraph_rejects_forward_dep():
    g = TaskGraph()
    with pytest.raises(ValueError):
        g.add(name="x", deps=[5])


# ---------------------------------------------------------------------------
# Victim policies (Algorithm 2)
# ---------------------------------------------------------------------------
def test_hybrid_policy_alternates_after_success():
    p = HybridPolicy(worker_id=0, n_workers=8, seed=1)
    # first select: empty history => random
    v1 = p.select()
    assert v1 != 0
    p.record(v1, True)           # success: slot <- v1, cursor advances
    v2 = p.select()              # fresh slot => random probe
    p.record(v2, False)          # failure: cursor retreats
    v3 = p.select()              # back on the successful slot => history
    assert v3 == v1


def test_history_policy_sticks_to_victim():
    p = make_policy("history", 0, 8, seed=0)
    v = p.select()
    p.record(v, True)
    assert p.select() == v
    p.record(v, True)
    assert p.select() == v
    p.record(v, False)
    # after failure, the victim is dropped
    assert p.last_victim == -1


def test_random_policy_never_self():
    p = make_policy("random", 3, 4, seed=7)
    for _ in range(100):
        assert p.select() != 3


# ---------------------------------------------------------------------------
# Gang logic (Algorithm 1)
# ---------------------------------------------------------------------------
def test_get_workers_prefers_neighbors_and_balance():
    gs = GangState(8)
    r = gs.get_workers(cur_worker_id=2, n_request=3)
    assert r == [3, 4, 5]          # adjacent to spawner
    gs.account_gang(r)
    r2 = gs.get_workers(cur_worker_id=2, n_request=3)
    # loaded workers 3,4,5 are above average now; selection skips them
    assert set(r2).isdisjoint({3, 4, 5})
    assert len(r2) == 3


def test_get_workers_wraps_near_top():
    gs = GangState(8)
    r = gs.get_workers(cur_worker_id=7, n_request=4)
    assert len(r) == 4
    assert len(set(r)) == 4


def test_eligibility_predicate():
    # idle worker takes anything
    assert is_eligible_to_sched(5, 1, -1, 0)
    # deeper regions always eligible
    assert is_eligible_to_sched(9, 2, 3, 1)
    # same level, same gang: eligible
    assert is_eligible_to_sched(3, 1, 3, 1)
    # same level, different gang: NOT eligible (deadlock hazard)
    assert not is_eligible_to_sched(4, 1, 3, 1)
    # shallower level: NOT eligible
    assert not is_eligible_to_sched(2, 0, 3, 1)


# ---------------------------------------------------------------------------
# Simulator
# ---------------------------------------------------------------------------
def test_simulator_serial_graph_makespan():
    g = TaskGraph("chain")
    prev = None
    for i in range(5):
        prev = g.add(name=f"t{i}", cost=1.0, deps=[prev] if prev else [])
    tr = simulate(g, 4, policy="hybrid", mode="gang", seed=0)
    assert tr.makespan == pytest.approx(5.0, rel=1e-3)


def test_simulator_parallel_speedup():
    g = TaskGraph("wide")
    for i in range(16):
        g.add(name=f"t{i}", cost=1.0)
    tr1 = simulate(g, 1, seed=0)
    tr4 = simulate(g, 4, seed=0)
    assert tr1.makespan == pytest.approx(16.0, rel=1e-3)
    assert tr4.makespan < 16.0 / 4 + 1.0     # near-linear scaling


def test_simulator_all_policies_complete():
    g = diamond()
    for pol in ("history", "random", "hybrid"):
        tr = simulate(g, 4, policy=pol, seed=1)
        assert tr.makespan >= 5.0  # critical path bound


def test_simulator_gang_region_completes():
    g = TaskGraph("gangy")
    g.add(name="p", cost=0.1,
          parallel=ParallelSpec(n_threads=4, cost_per_thread=1.0, n_barriers=4))
    tr = simulate(g, 8, mode="gang", seed=0)
    # 4 threads of 1.0s work on distinct reserved workers: ~1.0s + overheads
    assert tr.makespan < 1.5


def test_simulator_naive_ult_deadlocks_fig1():
    """Paper Fig. 1(a): more blocking ULTs than workers, no gang
    coordination => deadlock (detected, not hung)."""
    g = TaskGraph("fig1")
    g.add(name="region", cost=0.01,
          parallel=ParallelSpec(n_threads=8, cost_per_thread=0.1, n_barriers=2,
                                blocking=True))
    with pytest.raises(DeadlockError):
        simulate(g, 4, mode="ult_naive", seed=0)


def test_simulator_gang_mode_handles_fig1_when_it_fits():
    g = TaskGraph("fig1-fits")
    g.add(name="region", cost=0.01,
          parallel=ParallelSpec(n_threads=4, cost_per_thread=0.1, n_barriers=2,
                                blocking=True))
    tr = simulate(g, 4, mode="gang", seed=0)
    assert tr.makespan < 0.5


def test_simulator_two_gangs_no_deadlock():
    """Two concurrent gangs contending for the same workers complete under
    the monotonic-gang-id ordering."""
    g = TaskGraph("two-gangs")
    g.add(name="r1", cost=0.01,
          parallel=ParallelSpec(n_threads=3, cost_per_thread=0.2, n_barriers=3))
    g.add(name="r2", cost=0.01,
          parallel=ParallelSpec(n_threads=3, cost_per_thread=0.2, n_barriers=3))
    tr = simulate(g, 4, mode="gang", seed=0)
    assert tr.makespan < 1.0


def test_simulator_oversubscribe_slower_than_gang():
    """The paper's core claim: oversubscribed nested regions are slower than
    gang-scheduled ones (context switching + interference)."""
    def graph():
        # 4 cores saturated with trailing work while 4-thread panel regions
        # (barrier-heavy) fork — the SLATE LU/QR pattern at paper scale.
        g = TaskGraph("nested")
        prev = None
        for i in range(6):
            t = g.add(name=f"panel{i}", kind="panel", cost=0.01,
                      deps=[prev] if prev else [],
                      parallel=ParallelSpec(n_threads=4, cost_per_thread=0.06,
                                            n_barriers=12))
            # trailing work that keeps every core busy into the next panel
            for j in range(8):
                g.add(name=f"tr{i}.{j}", kind="compute", cost=0.03, deps=[t])
            prev = t
        return g

    gang = simulate(graph(), 4, mode="gang", seed=0).makespan
    over = simulate(graph(), 4, mode="oversubscribe", seed=0).makespan
    assert gang < over


def test_simulator_deterministic():
    g = diamond()
    t1 = simulate(g, 4, policy="hybrid", seed=42).makespan
    t2 = simulate(g, 4, policy="hybrid", seed=42).makespan
    assert t1 == t2


# ---------------------------------------------------------------------------
# Static schedules
# ---------------------------------------------------------------------------
def test_static_schedule_covers_all_tasks():
    g = diamond()
    sched = ListScheduler(4, policy="hybrid").schedule(g)
    assert {it.tid for it in sched.items} == {t.tid for t in g}
    assert sched.makespan >= 5.0


def test_static_schedule_waves_respect_deps():
    g = diamond()
    sched = ListScheduler(2, policy="hybrid").schedule(g)
    waves = sched.waves()
    pos = {}
    for i, wave in enumerate(waves):
        for tid in wave:
            pos[tid] = i
    for t in g:
        for d in t.deps:
            assert pos[d] <= pos[t.tid]


def test_microbatch_overlap_hybrid_beats_history():
    """Fig. 2: hybrid victim selection overlaps per-microbatch all-reduce
    with the next microbatch's compute; history serializes them."""
    g = microbatch_overlap_graph(8, compute_cost=1.0, comm_cost=0.5)
    hist = ListScheduler(2, policy="history", seed=0).schedule(g)
    hyb = ListScheduler(2, policy="hybrid", seed=0).schedule(g)
    assert hyb.makespan <= hist.makespan + 1e-9
    assert hyb.overlap_fraction() >= hist.overlap_fraction() - 1e-9


def test_collective_order_is_deterministic():
    g = microbatch_overlap_graph(4)
    s1 = ListScheduler(2, policy="hybrid", seed=3).schedule(g).collective_order()
    s2 = ListScheduler(2, policy="hybrid", seed=3).schedule(g).collective_order()
    assert s1 == s2
