"""Dry-run machinery tested in-process on a small host-device mesh.

The production dry-run needs 512 devices (subprocess, see launch/dryrun.py);
here we validate the same build_cell plumbing end-to-end on an 8-device
debug mesh via a subprocess so the XLA device-count flag doesn't leak into
the rest of the suite.
"""

import json
import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8 " \
    "--xla_disable_hlo_passes=while-loop-invariant-code-motion"
import json
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_config
from repro.models import lm
from repro.sharding.rules import make_ctx
from repro.launch.shapes import input_specs
from repro.launch import hlo_analysis
from repro.train.steps import StepConfig, make_train_step
from repro.optim.adamw import AdamWConfig

mesh = jax.make_mesh((4, 2), ("data", "model"))
cfg = get_config("deepseek-67b").reduced(n_layers=3, d_model=128, vocab_size=1024,
                                         n_heads=4, n_kv_heads=2, head_dim=32,
                                         d_ff=256, dtype="bfloat16")
ctx = make_ctx(mesh, cfg)
pspecs = lm.param_pspecs(cfg, ctx)
param_sh = jax.tree.map(lambda p: NamedSharding(mesh, p), pspecs,
                        is_leaf=lambda x: isinstance(x, P))
params = lm.abstract_params(cfg)
batch = {"tokens": jax.ShapeDtypeStruct((8, 64), jnp.int32),
         "labels": jax.ShapeDtypeStruct((8, 64), jnp.int32)}
batch_sh = {k: NamedSharding(mesh, P(("data",), None)) for k in batch}
opt = {"m": jax.tree.map(lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32), params),
       "v": jax.tree.map(lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32), params),
       "step": jax.ShapeDtypeStruct((), jnp.int32)}
opt_sh = {"m": param_sh, "v": param_sh, "step": NamedSharding(mesh, P())}
fn = make_train_step(cfg, AdamWConfig(), ctx, StepConfig(microbatches=2),
                     grad_pspecs=param_sh)
jitted = jax.jit(fn, in_shardings=(param_sh, opt_sh, batch_sh),
                 out_shardings=(param_sh, opt_sh, None))
with mesh:
    lowered = jitted.lower(params, opt, batch)
    compiled = lowered.compile()
mem = compiled.memory_analysis()
hlo = compiled.as_text()
out = {
    "temp": int(mem.temp_size_in_bytes),
    "collectives": hlo_analysis.collective_bytes(hlo)["total_bytes"],
    "dot_flops": hlo_analysis.dot_flops(hlo),
}
print("RESULT:" + json.dumps(out))
"""


@pytest.mark.slow
def test_small_mesh_train_cell_compiles():
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, env=env, timeout=600)
    assert r.returncode == 0, r.stderr[-3000:]
    line = [l for l in r.stdout.splitlines() if l.startswith("RESULT:")][0]
    out = json.loads(line[len("RESULT:"):])
    assert out["temp"] > 0
    assert out["collectives"] > 0          # grad reductions present
    assert out["dot_flops"] > 0            # trip-count-scaled matmuls counted
