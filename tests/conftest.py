"""Suite-wide fixtures: worker-thread leak detection.

Every executor in the repo (dynamic :class:`~repro.core.runtime.Runtime`,
:class:`~repro.replay.ReplayExecutor`, the pool's shared cores) spawns
worker threads with well-known name prefixes.  A test that forgets to shut
a facade down — or an executor whose shutdown stops joining its threads —
leaks them silently; this hook turns that into a loud CI failure.
"""

import threading
import time

import pytest

# name prefixes of every thread the repo's executors spawn
_WORKER_PREFIXES = (
    "repro-worker",        # Runtime's private core
    "replay-worker",       # ReplayExecutor's private core
    "pool",                # ReplayPool private cores (pool{N}-worker)
    "exec-core",           # bare ExecutorCore default + registry shared cores
    "session",             # Session private cores (session{N}-worker)
    "replay-pool-rerecord",  # background re-recording threads
)


def _leaked_worker_threads():
    return [t.name for t in threading.enumerate()
            if t.name.startswith(_WORKER_PREFIXES)]


@pytest.fixture(autouse=True, scope="session")
def _no_worker_thread_leaks():
    """Assert every executor worker thread is gone when the suite ends."""
    yield
    deadline = time.monotonic() + 10.0
    leaked = _leaked_worker_threads()
    while leaked and time.monotonic() < deadline:
        time.sleep(0.05)          # grace period for daemon teardown
        leaked = _leaked_worker_threads()
    assert not leaked, (
        f"worker-thread leak: {len(leaked)} executor thread(s) still alive "
        f"after the suite: {sorted(leaked)}")


@pytest.fixture(autouse=True, scope="session")
def _no_orphaned_child_processes():
    """Assert no :mod:`repro.mp` worker process outlives the suite — the
    process analogue of the thread-leak check.  ``ProcessPool.shutdown``
    joins and closes every child (and a dying parent's children exit on
    pipe EOF), so anything still in ``multiprocessing.active_children()``
    at session end is a real orphan."""
    yield
    import multiprocessing

    deadline = time.monotonic() + 10.0
    leaked = multiprocessing.active_children()
    while leaked and time.monotonic() < deadline:
        time.sleep(0.05)          # grace period for terminate/join races
        leaked = multiprocessing.active_children()
    assert not leaked, (
        f"orphaned-process leak: {len(leaked)} worker process(es) still "
        f"alive after the suite: "
        f"{sorted((p.name, p.pid) for p in leaked)}")


@pytest.fixture(autouse=True, scope="session")
def _no_orphaned_frames():
    """Assert no suspended task frame stays parked on a channel/event when
    the suite ends — the frame analogue of the thread-leak check: an
    aborted run must drain its parked frames, not orphan them."""
    yield
    from repro.core.taskgraph import live_parked_frames

    deadline = time.monotonic() + 10.0
    leaked = live_parked_frames()
    while leaked and time.monotonic() < deadline:
        time.sleep(0.05)
        leaked = live_parked_frames()
    assert not leaked, (
        f"orphaned-frame leak: {len(leaked)} suspended frame(s) still "
        f"parked after the suite: "
        f"{sorted(f.task.name for f in leaked)}")


@pytest.fixture(autouse=True, scope="session")
def _no_leaked_trace_buffers():
    """Assert no flight-recorder ring buffer outlives its session/executor
    when the suite ends — traced runs must not pin event rings (and their
    label strings) in shared registry cores or module globals."""
    yield
    import gc

    from repro.obs.recorder import live_recorders

    deadline = time.monotonic() + 10.0
    gc.collect()
    leaked = live_recorders()
    while leaked and time.monotonic() < deadline:
        time.sleep(0.05)
        gc.collect()              # recorders are only weakly registered
        leaked = live_recorders()
    assert not leaked, (
        f"trace-buffer leak: {len(leaked)} flight recorder(s) still "
        f"reachable after the suite (workers: "
        f"{sorted(r.n_workers for r in leaked)})")
