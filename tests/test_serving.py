"""Tests for the decode-step serving graphs (repro.models.serving) and their
integration with run_graph(pool=...).

Uses a deterministic toy decode function (real jnp ops, no model) so the
serving loop runs fast; the full-LM path is exercised by
benchmarks/bench_serving.py and examples/serve_lm.py.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import run_graph
from repro.models import (
    DecodeShard,
    DecodeState,
    build_decode_graph,
    decode_graph_key,
    greedy_sample,
    shard_batch,
)
from repro.replay import ReplayPool, graph_key

VOCAB = 11


def _toy_decode(params, cache, tok):
    """Deterministic toy decode: cache carries a running hash, logits rotate
    with it — token streams are reproducible and shard-local."""
    h = cache["h"] * 31 + tok[:, 0] + 7
    logits = jnp.stack(
        [jnp.sin(h[:, None] * (i + 1)).astype(jnp.float32)
         for i in range(VOCAB)], axis=-1)
    return {"h": h}, logits


def _fresh_state(n_shards=4, per=1):
    shards = [
        DecodeShard(cache={"h": jnp.full((per,), s + 1, jnp.int32)},
                    tok=jnp.full((per, 1), s, jnp.int32))
        for s in range(n_shards)
    ]
    return DecodeState(params=None, shards=shards)


def _decode_loop(steps, workers, pool=None, n_shards=4):
    state = _fresh_state(n_shards)
    for _ in range(steps):
        g = build_decode_graph(state, _toy_decode)
        run_graph(g, workers, pool=pool)
    return np.asarray(state.tokens())


def test_decode_graph_shape_is_stable_across_steps():
    state = _fresh_state()
    k1 = graph_key(build_decode_graph(state, _toy_decode))
    # run a step: the state mutates, the *shape* must not
    run_graph(build_decode_graph(state, _toy_decode), 2)
    k2 = graph_key(build_decode_graph(state, _toy_decode))
    assert k1 == k2
    assert k1 == decode_graph_key(4)
    assert k1 != decode_graph_key(2)


def test_decode_graph_tasks_and_results():
    state = _fresh_state(n_shards=3)
    g = build_decode_graph(state, _toy_decode)
    assert len(g) == 3 * 2 + 1
    results = run_graph(g, 2)
    gather = [t for t in g.tasks if t.name == "gather"][0]
    assert (np.asarray(results[gather.tid]) ==
            np.asarray(state.step_tokens)).all()
    assert len(state.history) == 1
    assert state.step_tokens.shape == (3, 1)


def test_pooled_decode_matches_dynamic_bit_identical():
    tok_dyn = _decode_loop(6, workers=2)
    with ReplayPool() as pool:
        tok_pool = _decode_loop(6, workers=2, pool=pool)
        (stats,) = pool.describe().values()
    assert tok_dyn.shape == (4, 6)
    assert (tok_dyn == tok_pool).all()
    assert stats["records"] == 1 and stats["warmups"] == 1
    # a loaded box can trip the drift detector (stall fallbacks) and turn
    # a replay into a re-record serve; both count as warm serves
    assert stats["replays"] + stats["rerecords"] == 4


def test_pooled_decode_remap_across_worker_counts():
    """The same decode-step recording serves 1-, 2- and 3-worker replicas
    (pool remaps on miss), bit-identical streams throughout."""
    ref = _decode_loop(5, workers=2)
    with ReplayPool(warmup_runs=0) as pool:
        assert (_decode_loop(5, workers=2, pool=pool) == ref).all()
        assert (_decode_loop(5, workers=1, pool=pool) == ref).all()
        assert (_decode_loop(5, workers=3, pool=pool) == ref).all()
        by_key = pool.describe()
    records = sum(s["records"] for s in by_key.values())
    remaps = sum(s["remaps"] for s in by_key.values())
    assert records == 1, by_key
    assert remaps == 2, by_key


def test_pool_precomputed_key_skips_hashing_not_safety():
    """pool.run(key=...) serves the hot path without re-hashing; a wrong
    key still fails loudly at the executor's 1:1 cover check."""
    ref = _decode_loop(4, workers=2)
    key = decode_graph_key(4)
    with ReplayPool(warmup_runs=0) as pool:
        state = _fresh_state(4)
        for _ in range(4):
            g = build_decode_graph(state, _toy_decode)
            pool.run(g, 2, key=key)
        assert (np.asarray(state.tokens()) == ref).all()
        wrong = _fresh_state(2)
        with pytest.raises(Exception):
            pool.run(build_decode_graph(wrong, _toy_decode), 2, key=key)


def test_pool_shutdown_is_terminal():
    state = _fresh_state(2)
    pool = ReplayPool(warmup_runs=0)
    pool.run(build_decode_graph(state, _toy_decode), 2)
    pool.shutdown()
    with pytest.raises(RuntimeError, match="shut down"):
        pool.run(build_decode_graph(state, _toy_decode), 2)


def test_shard_batch():
    batch = {"tokens": jnp.arange(8).reshape(4, 2)}
    shards = shard_batch(batch, 2)
    assert len(shards) == 2
    assert shards[1]["tokens"].shape == (2, 2)
    with pytest.raises(ValueError, match="shard"):
        shard_batch(batch, 3)
    with pytest.raises(ValueError, match="batch"):
        shard_batch({"a": jnp.zeros((4, 1)), "b": jnp.zeros((2, 1))}, 2)


def test_greedy_sample_shape_and_dtype():
    logits = jnp.stack([jnp.zeros((2, 3)), jnp.ones((2, 3))], axis=-1)
    tok = greedy_sample(logits)
    assert tok.shape == (2, 1) and tok.dtype == jnp.int32
    assert (np.asarray(tok) == 1).all()
