"""Module-level helpers shipped to repro.mp worker processes by reference.

The tests directory has no ``__init__.py``, so pytest puts it on
``sys.path`` and these helpers import inside spawned children as the
top-level module ``mp_helpers`` — which is exactly what
:func:`repro.mp.callable_ref` derives.  Everything here must stay
module-level and picklable-by-reference: no closures, no fixtures.
"""

import os
import time

import numpy as np

import repro

VOCAB = 13
PRIME = 10_007


# ---------------------------------------------------------------------------
# toy hash-walk LM (mirrors tests/test_serving_engine.py): per-request
# integer caches, so token streams are independent of batch composition
def _logits(h):
    row = [0.0] * VOCAB
    row[h % VOCAB] = 1.0
    return row


def toy_prefill(prompt):
    h = (int(np.asarray(prompt).sum()) * 31 + 7) % PRIME
    return {"h": h}, _logits(h)


def toy_decode(cache, tok):
    h = (cache["h"] * 31 + int(tok) + 7) % PRIME
    return {"h": h}, _logits(h)


def toy_sample(logits):
    return int(np.argmax(np.asarray(logits)))


def make_toy_fns():
    """Engine-fns factory for ``fns_ref`` (child processes re-import it)."""
    return toy_decode, toy_prefill, toy_sample


def make_slow_toy_fns(delay=0.002):
    """Toy fns whose decode sleeps ``delay`` seconds — keeps a serving
    stream in flight long enough for chaos tests to kill a child mid-run."""
    def slow_decode(cache, tok):
        time.sleep(delay)
        return toy_decode(cache, tok)
    return slow_decode, toy_prefill, toy_sample


def per_request_reference(requests):
    """Each request decoded alone, straight through the toy model — the
    ground truth any batched/sharded serve must match bit-for-bit."""
    out = {}
    for req in requests:
        cache, logits = toy_prefill(req.prompt)
        tok = toy_sample(logits)
        toks = [tok]
        while len(toks) < req.max_new_tokens and tok != req.eos_token:
            cache, logits = toy_decode(cache, tok)
            tok = toy_sample(logits)
            toks.append(tok)
        out[req.rid] = toks
    return out


# ---------------------------------------------------------------------------
# graph builders (same shape for every input -> one cache key per sweep)
def build_chain(x):
    g = repro.Graph("mp-chain")
    a = g.add(lambda: x, name="src")
    b = g.add(lambda v: v + 1, a, name="inc")
    g.add(lambda v: v * 2, b, name="dbl")
    return g


def chain_expected(x):
    return {x, x + 1, (x + 1) * 2}


# ---------------------------------------------------------------------------
# plain worker tasks (fn(ctx, *args) protocol)
def whoami(ctx):
    return {"pid": os.getpid(), "index": ctx.index}


def echo(ctx, value):
    return value


def add(ctx, a, b):
    return a + b


def boom(ctx, message):
    raise ValueError(message)


def hang(ctx, seconds):
    time.sleep(seconds)
    return "woke"


def init_marker(ctx):
    """WorkerSpec.init target: runs once at child-session build time."""
    return {"init_pid": os.getpid(), "index": ctx.index}


def get_state(ctx):
    ctx.session                       # force the lazy session (runs init)
    return ctx.state


# ---------------------------------------------------------------------------
# GraphCache cross-process helpers (each call opens a FRESH instance so it
# reads through to disk — the documented cross-process consumption pattern)
def seed_recording(ctx, path, workers=2):
    """Record one real graph into the cache at ``path``; returns its key
    coordinates for later cross-process lookups."""
    from repro.replay import GraphCache
    cache = GraphCache(path)
    with repro.Session(workers, scheduler="replay", cache=cache) as s:
        rep = s.run(build_chain(1))
    return {"digest": rep.plan.digest, "workers": workers,
            "policy": s.policy, "pid": os.getpid()}


def cache_hammer(ctx, path, iters, workers=2):
    """Hammer the on-disk cache with store/swap/plan-meta writes of the
    same key — run on two processes at once, this is a true writer race
    on one target file."""
    from repro.replay import GraphCache
    cache = GraphCache(path)
    with repro.Session(workers, scheduler="replay", cache=cache) as s:
        rep = s.run(build_chain(1))
    rec = rep.recording
    if rec is None:                   # this process adopted; read it back
        rec = cache.lookup(rep.plan.digest, workers, s.policy)
    for i in range(iters):
        cache.store(rec)
        cache.swap(rec)
        cache.store_plan_meta(rec.digest, rec.n_workers, rec.policy,
                              {"pid": os.getpid(), "iter": i})
    return {"pid": os.getpid(), "digest": rec.digest, "writes": 3 * iters}


def store_plan_meta(ctx, path, digest, workers, policy, meta):
    from repro.replay import GraphCache
    return GraphCache(path).store_plan_meta(digest, workers, policy, meta)


def lookup_plan_meta(ctx, path, digest, workers, policy):
    from repro.replay import GraphCache
    return GraphCache(path).lookup_plan_meta(digest, workers, policy)


def swap_same_recording(ctx, path, digest, workers, policy):
    """Re-swap the on-disk recording for this key (drops its plan meta on
    disk — the event a *second* process must observe)."""
    from repro.replay import GraphCache
    cache = GraphCache(path)
    rec = cache.lookup(digest, workers, policy)
    assert rec is not None, "nothing to swap: seed the cache first"
    cache.swap(rec)
    return True
