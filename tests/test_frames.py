"""Suspendable task frames: cooperative preemption + blocking channels.

Covers the frame lifecycle (running -> suspended -> resumable -> resumed /
stolen), the Channel/TaskEvent primitives, soft-vs-hard blocking in the
deadlock detector, record/replay of frame interleavings, remap adjacency,
abort draining, the process-global core registry, and the static-schedule
gang placements for numeric LU/QR.
"""

import threading
import time

import numpy as np
import pytest

from repro.core import (
    Channel,
    ChannelEmpty,
    DeadlockError,
    Runtime,
    TaskEvent,
    TaskGraph,
    run_graph,
)
from repro.core.taskgraph import FrameResume, live_parked_frames
from repro.exec import REGISTRY, release_shared_core, shared_core
from repro.replay import Recording, ReplayPool, remap_recording, replay_graph
from repro.replay.executor import ReplayExecutor


# ---------------------------------------------------------------------------
# primitives
# ---------------------------------------------------------------------------
def test_channel_basics():
    ch = Channel("c")
    assert len(ch) == 0
    with pytest.raises(ChannelEmpty):
        ch.recv_nowait()
    ch.send(1)
    ch.send(2)
    assert len(ch) == 2
    assert ch.recv_nowait() == 1          # FIFO
    ok, v = ch.try_recv()
    assert ok and v == 2
    ok, _ = ch.try_recv()
    assert not ok


def test_event_basics():
    ev = TaskEvent("e")
    assert not ev.is_set()
    ev.set()
    ev.set()                              # idempotent
    assert ev.is_set()


# ---------------------------------------------------------------------------
# suspension semantics (dynamic dispatch)
# ---------------------------------------------------------------------------
def test_generator_body_returns_value():
    g = TaskGraph("gen")
    ch = Channel("c")

    def consumer(ctx):
        v = yield ctx.recv(ch)
        return v * 2

    t = g.add(consumer, name="consumer")
    g.add(lambda ctx: ch.send(21), name="producer")
    assert run_graph(g, 1)[t.tid] == 42


def test_recv_suspends_without_occupying_worker():
    """The acceptance scenario: N frames on ONE worker all block on a
    channel fed by a task scheduled after them.  Under the old contract
    (body pins its worker) this deadlocks; frames complete it."""
    g = TaskGraph("fanin")
    ch = Channel("c")
    consumers = []
    for i in range(6):
        def body(ctx, i=i):
            v = yield ctx.recv(ch)
            return (i, v)
        consumers.append(g.add(body, name=f"cons{i}"))

    def feeder(ctx):
        for i in range(6):
            ch.send(i)

    g.add(feeder, name="feeder")
    results = run_graph(g, 1, timeout=30.0)
    got = sorted(results[c.tid][1] for c in consumers)
    assert got == list(range(6))
    assert live_parked_frames() == []


def test_plain_body_recv_is_work_conserving():
    """A plain (non-generator) body blocking in ctx.recv keeps its worker
    scheduling: the feeder queued behind it still runs on 1 worker."""
    g = TaskGraph("plain")
    ch = Channel("c")

    def consumer(ctx):
        return ctx.recv(ch)

    t = g.add(consumer, name="cons")
    g.add(lambda ctx: ch.send(7), name="feed")
    assert run_graph(g, 1, timeout=30.0)[t.tid] == 7


def test_wait_event_and_yield_interleaving():
    g = TaskGraph("evyield")
    ev = TaskEvent("e")
    log = []

    def a(ctx):
        log.append("a1")
        yield ctx.yield_()
        log.append("a2")
        yield ctx.wait(ev)
        log.append("a3")
        return "done"

    def b(ctx):
        log.append("b1")
        ev.set()

    ta = g.add(a, name="a")
    g.add(b, name="b")
    assert run_graph(g, 1)[ta.tid] == "done"
    # a suspended at its first yield, letting b run before a finished
    assert log.index("b1") < log.index("a3")


def test_resumed_frame_is_stealable():
    """A frame resumed onto a busy worker's deque is stolen and finished by
    another worker (completion is the observable: the busy worker never
    reaches it before the run would time out otherwise)."""
    g = TaskGraph("steal")
    ch = Channel("c")
    release = threading.Event()

    def sleeper(ctx):                     # pins worker 0 after feeding
        ch.send("x")
        release.wait(timeout=30.0)

    def consumer(ctx):
        v = yield ctx.recv(ch)
        release.set()                     # proves we ran while sleeper pinned
        return v

    t = g.add(consumer, name="cons")
    g.add(sleeper, name="sleeper")
    results = run_graph(g, 2, timeout=30.0)
    assert results[t.tid] == "x"


def test_send_racing_park_stress():
    """Tight producer/consumer races: a send landing while the frame parks
    must never be lost (delivery happens under the channel lock)."""
    for it in range(30):
        g = TaskGraph(f"race{it}")
        ch = Channel("c")

        def consumer(ctx):
            a = yield ctx.recv(ch)
            b = yield ctx.recv(ch)
            return a + b

        t = g.add(consumer, name="cons")
        g.add(lambda ctx: (ch.send(1), ch.send(2)), name="prod")
        assert run_graph(g, 2, timeout=30.0)[t.tid] == 3
    assert live_parked_frames() == []


# ---------------------------------------------------------------------------
# deadlock detection: soft-suspended vs hard-blocked
# ---------------------------------------------------------------------------
def test_suspension_only_deadlock_detected():
    g = TaskGraph("dead")
    ch = Channel("never")

    def stuck(ctx):
        yield ctx.recv(ch)

    g.add(stuck, name="stuck")
    t0 = time.monotonic()
    with pytest.raises(DeadlockError, match="suspension deadlock"):
        run_graph(g, 2, timeout=60.0)
    assert time.monotonic() - t0 < 30.0   # detected, not timed out
    assert live_parked_frames() == []


def test_plain_body_recv_deadlock_detected():
    g = TaskGraph("dead2")
    ch = Channel("never")

    def stuck(ctx):
        ctx.recv(ch)

    g.add(stuck, name="p0")
    g.add(stuck, name="p1")
    with pytest.raises(DeadlockError, match="recv/wait"):
        run_graph(g, 2, timeout=60.0)


def test_replay_plain_body_recv_deadlock_detected():
    """Replay mirrors the dynamic dispatch's no-progress detection: a
    replayed plain body blocking on a channel the (drifted) twin graph
    never feeds raises DeadlockError, not a 300s TimeoutError."""
    def build(feed):
        g = TaskGraph("replay-dl")
        ch = Channel("c")

        def consumer(ctx):
            return ctx.recv(ch)

        t = g.add(consumer, name="cons")
        g.add((lambda ctx: ch.send(1)) if feed else (lambda ctx: None),
              name="feed")
        return g, t

    g, t = build(True)
    rt = Runtime(2)
    with rt:
        assert rt.run(g, record=True)[t.tid] == 1
    rec = rt.last_recording
    g2, _ = build(False)             # same shape, silent feeder
    t0 = time.monotonic()
    with pytest.raises(DeadlockError, match="recv/wait"):
        replay_graph(g2, rec, timeout=60.0)
    assert time.monotonic() - t0 < 30.0


def test_mixed_barrier_deadlock_with_suspended_frame():
    """A suspended frame must NOT mask the Fig.-1 barrier deadlock (it is
    soft-blocked, excluded from the hard-block count)."""
    g = TaskGraph("fig1+frame")
    ch = Channel("never")

    def suspended(ctx):
        yield ctx.recv(ch)

    g.add(suspended, name="susp")

    def forker(ctx):
        # non-gang region with blocking barriers on 2 workers: Fig. 1
        ctx.parallel(3, lambda tid, region: region.barrier(), gang=False)

    g.add(forker, name="forker")
    with pytest.raises(DeadlockError):
        run_graph(g, 2, timeout=60.0)
    assert live_parked_frames() == []


def test_abort_drains_parked_frames_and_blocked_accounting():
    """The satellite fix: a failing task while a gang thread waits at a
    blocking barrier (and a frame sits suspended) must surface the original
    error — not a misfired DeadlockError — and leave nothing parked."""
    g = TaskGraph("abort")
    ch = Channel("never")

    def suspended(ctx):
        yield ctx.recv(ch)

    g.add(suspended, name="susp")

    def forker(ctx):
        def body(tid, region):
            if tid == 0:
                time.sleep(0.02)          # let tid 1 reach the barrier
                raise ValueError("boom")
            region.barrier()
        ctx.parallel(2, body)

    g.add(forker, name="forker")
    rt = Runtime(2)
    with rt:
        with pytest.raises(ValueError, match="boom"):
            rt.run(g, timeout=60.0)
        deadline = time.monotonic() + 5.0
        while time.monotonic() < deadline and (
                rt.core._blocked_count or live_parked_frames()):
            time.sleep(0.01)
        assert rt.core._blocked_count == 0
        assert live_parked_frames() == []


# ---------------------------------------------------------------------------
# record / replay of frame interleavings
# ---------------------------------------------------------------------------
def _pipeline_graph(log):
    """Producer chain feeding two consumer frames over one channel."""
    g = TaskGraph("pipe")
    ch = Channel("c")
    outs = []
    for i in range(3):
        def body(ctx, i=i):
            v = yield ctx.recv(ch)
            log.append(("seg", i))
            w = yield ctx.recv(ch)
            log.append(("seg2", i))
            return v + w
        outs.append(g.add(body, name=f"cons{i}"))

    def feeder(ctx):
        for i in range(6):
            ch.send(i)

    g.add(feeder, name="feeder")
    return g, outs


def test_record_replay_reproduces_frame_interleaving():
    log1 = []
    g, outs = _pipeline_graph(log1)
    rt = Runtime(1)
    with rt:
        res1 = rt.run(g, record=True)
    rec = rt.last_recording
    entries = [e for o in rec.worker_orders for e in o]
    assert any(isinstance(e, FrameResume) for e in entries)
    rec.validate_against(g)

    # JSON round-trip preserves resume entries
    rec2 = Recording.from_json(rec.to_json())
    assert rec2.worker_orders == rec.worker_orders

    log2 = []
    g2, outs2 = _pipeline_graph(log2)
    res2 = replay_graph(g2, rec2)
    assert [res1[t.tid] for t in outs] == [res2[t.tid] for t in outs2]
    # single worker => the recorded global segment order is reproduced
    # bit-identically
    assert log1 == log2


def test_replay_validate_rejects_bad_resume_entries():
    log = []
    g, _ = _pipeline_graph(log)
    rt = Runtime(1)
    with rt:
        rt.run(g, record=True)
    rec = rt.last_recording
    bad = Recording.from_json(rec.to_json())
    for order in bad.worker_orders:
        dup = [e for e in order if isinstance(e, FrameResume)]
        if dup:
            order.append(dup[0])          # duplicate (tid, seg)
            break
    from repro.replay import RecordingError
    g2, _ = _pipeline_graph([])
    with pytest.raises(RecordingError, match="frame-resume"):
        replay_graph(g2, bad)


def test_remap_keeps_resume_entries_adjacent():
    log = []
    g, outs = _pipeline_graph(log)
    rt = Runtime(2)
    with rt:
        res_ref = rt.run(g, record=True)
    rec = rt.last_recording
    for new_w in (1, 3):
        mapped = remap_recording(rec, new_w)
        for order in mapped.worker_orders:
            seen_start = set()
            last_seg = {}
            for e in order:
                if isinstance(e, int):
                    seen_start.add(e)
                elif isinstance(e, FrameResume):
                    # resume entries live on their frame's home list, after
                    # the start entry, segments ascending
                    assert e.tid in seen_start
                    assert e.seg == last_seg.get(e.tid, 0) + 1
                    last_seg[e.tid] = e.seg
        # every resume entry survived the fold on exactly one list
        total = sum(1 for o in mapped.worker_orders for e in o
                    if isinstance(e, FrameResume))
        orig = sum(1 for o in rec.worker_orders for e in o
                   if isinstance(e, FrameResume))
        assert total == orig
        g2, outs2 = _pipeline_graph([])
        res2 = replay_graph(g2, mapped)
        # a remap changes worker count, so which consumer receives which
        # token may legitimately change (multi-consumer channels are
        # arrival-ordered); conservation must hold: every token delivered
        # exactly once.  (Single-consumer channels — the serving gather —
        # stay bit-identical across remaps: bench_serving asserts that.)
        assert sum(res2[t.tid] for t in outs2) == sum(
            res_ref[t.tid] for t in outs)


def test_pool_serves_frame_graphs():
    """The serving path end to end: a channel-based frame graph through the
    pool records once and replays, results identical to dynamic."""
    def build(state):
        g = TaskGraph("frame-serve")
        ch = Channel("c")

        def gather(ctx):
            total = 0
            for _ in range(3):
                total += (yield ctx.recv(ch))
            state.append(total)
            return total

        g.add(gather, name="gather")
        for i in range(3):
            g.add(lambda ctx, i=i: ch.send(i + 1), name=f"send{i}")
        return g

    ref = []
    for _ in range(5):
        run_graph(build(ref), 2)
    pooled = []
    with ReplayPool() as pool:
        for _ in range(5):
            run_graph(build(pooled), 2, pool=pool)
        (stats,) = pool.describe().values()
    assert ref == pooled == [6] * 5
    assert stats["records"] == 1 and stats["replays"] == 3


# ---------------------------------------------------------------------------
# process-global core registry (cross-pool sharing)
# ---------------------------------------------------------------------------
def _exec_core_threads(n):
    return [t.name for t in threading.enumerate()
            if t.name.startswith(f"exec-core{n}-")]


def test_shared_core_refcounting():
    a = shared_core(3)
    b = shared_core(3)
    assert a is b
    assert REGISTRY.refcounts()[3] == 2
    assert len(_exec_core_threads(3)) == 3
    release_shared_core(a)
    assert REGISTRY.refcounts()[3] == 1
    release_shared_core(b)
    assert 3 not in REGISTRY.refcounts()
    deadline = time.monotonic() + 5.0
    while _exec_core_threads(3) and time.monotonic() < deadline:
        time.sleep(0.01)
    assert _exec_core_threads(3) == []


def test_pools_share_one_core_per_worker_count():
    def serve(pool, tag):
        g = TaskGraph(f"shape-{tag}")
        t = g.add(lambda ctx: tag, name=f"t-{tag}")
        return pool.run(g, 2)[t.tid]

    p1, p2 = ReplayPool(warmup_runs=0), ReplayPool(warmup_runs=0)
    try:
        assert serve(p1, "a") == "a"
        assert serve(p2, "b") == "b"
        # both pools lease the SAME registry core: exactly 2 worker threads
        assert len(_exec_core_threads(2)) == 2
        assert REGISTRY.refcounts()[2] == 2
    finally:
        p1.shutdown()
        assert len(_exec_core_threads(2)) == 2    # p2 still holds the lease
        p2.shutdown()
    deadline = time.monotonic() + 5.0
    while _exec_core_threads(2) and time.monotonic() < deadline:
        time.sleep(0.01)
    assert _exec_core_threads(2) == []


def test_private_core_pool_opt_out():
    with ReplayPool(warmup_runs=0, shared_cores=False) as pool:
        g = TaskGraph("priv")
        t = g.add(lambda ctx: 1, name="t")
        assert pool.run(g, 2)[t.tid] == 1
        assert 2 not in REGISTRY.refcounts()


# ---------------------------------------------------------------------------
# static-schedule gang placements for numeric LU/QR
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("kernel", ["lu", "qr"])
def test_static_recording_replays_panels_placed(kernel):
    from repro.linalg import (
        build_lu_graph,
        build_qr_graph,
        lu_extract,
        lu_static_recording,
        qr_reconstruct,
        qr_static_recording,
        random_diagdom,
        to_tiles,
    )

    NB, B, W, PT = 4, 16, 2, 2
    if kernel == "lu":
        rec = lu_static_recording(NB, B, n_workers=W, panel_threads=PT)
    else:
        rec = qr_static_recording(NB, B, n_workers=W, panel_threads=PT)
    # every panel task is PLACED (the satellite: no dynamic-fallback forks)
    assert len(rec.gang_placements) == NB
    assert rec.gang_issue_order == sorted(
        rec.gang_placements,
        key=lambda t: rec.gang_placements[t].gang_id)
    for p in rec.gang_placements.values():
        assert len(set(p.workers)) == len(p.workers)          # distinct
    # ULT entries are present in the run lists for each placed worker
    gang_entries = {(e[0], e[1])
                    for o in rec.worker_orders for e in o
                    if isinstance(e, tuple)}
    for tid, p in rec.gang_placements.items():
        for i in range(len(p.workers)):
            assert (tid, i) in gang_entries

    a = np.asarray(random_diagdom(NB * B, seed=3))
    st = to_tiles(a, B)
    build = build_lu_graph if kernel == "lu" else build_qr_graph
    g = build(NB, B, store=st, panel_threads=PT)
    rec.validate_against(g)
    ex = ReplayExecutor(rec)
    with ex:
        ex.run(g, timeout=120.0)
        issued = list(ex.issued_gang_ids)
    assert issued == [rec.gang_placements[t].gang_id
                      for t in rec.gang_issue_order]
    if kernel == "lu":
        l, u = lu_extract(st)
        recon = np.asarray(l) @ np.asarray(u)
    else:
        recon = np.asarray(qr_reconstruct(st))
    assert np.allclose(recon, a, rtol=1e-4, atol=1e-4)
