"""Tests for benchmarks.check_artifacts (the extracted CI checks)."""

import json

import pytest

from benchmarks.check_artifacts import (
    ArtifactError,
    check_wellformed,
    expected_bench,
    main,
    noise_table,
)


def _poisson_row(**over):
    row = {
        "bench": "serving_poisson", "workers": 2, "rate": 60.0,
        "p50_tok_ms": 4.0, "p99_tok_ms": 9.0,
        "ttft_p50_ms": 3.0, "ttft_p99_ms": 8.0,
        "pooled_tok_s": 420.0, "dynamic_tok_s": 400.0,
        "warm_hit_rate": 0.7, "occupancy": 0.5, "identical": True,
    }
    row.update(over)
    return row


def _compiled_row(**over):
    row = {
        "bench": "serving_compiled", "workers": 4,
        "dynamic_ms": 8.0, "replay_ms": 7.0, "compiled_ms": 5.0,
        "speedup_vs_dynamic": 1.6, "speedup_vs_replay": 1.4,
        "compiled_overhead_fraction": 0.02, "replay_overhead_fraction": 0.5,
        "segments": 13, "fused_tasks": 4, "identical": True, "noise": 0.1,
    }
    row.update(over)
    return row


def _procs_row(**over):
    row = {
        "bench": "serving_procs", "procs": 2, "workers": 1, "rate": 60.0,
        "procs_tok_s": 430.0, "single_tok_s": 410.0, "speedup": 1.05,
        "warm_hit_rate": 0.9, "identical": True, "noise": 0.1,
        "no_slower": True,
    }
    row.update(over)
    return row


def _resource_row(**over):
    row = {
        "bench": "resource_contention", "workers": 2, "tasks": 8,
        "edges_ms": 26.0, "resources_ms": 14.0, "speedup": 1.857,
        "resource_acquires": 8, "resource_waits": 3,
        "identical": True, "no_slower": True, "noise": 0.1,
    }
    row.update(over)
    return row


def _runtime_extra_rows():
    return [
        {"bench": "victim_frames", "workers": 2, "noise": 0.05,
         "no_slower": True},
        {"bench": "compiled_linalg", "workers": 2, "noise": 0.2,
         "no_slower": True},
        {"bench": "async_overlap", "workers": 2, "noise": 0.1,
         "no_slower": True},
        _resource_row(),
    ]


def _write(tmp_path, name, payload):
    path = tmp_path / name
    path.write_text(json.dumps(payload))
    return str(path)


@pytest.fixture
def artifacts(tmp_path):
    runtime = _write(tmp_path, "BENCH_runtime.json", {
        "bench": "runtime",
        "rows": [
            {"bench": "warm_reuse", "workers": 1, "noise": 0.08,
             "no_slower": True},
            {"bench": "suspend_frames", "workers": 2, "noise": 0.31,
             "no_slower": True},
        ] + _runtime_extra_rows(),
    })
    serving = _write(tmp_path, "BENCH_serving.json", {
        "bench": "serving",
        "rows": [
            {"bench": "serving", "workers": 1, "identical": True},
            _compiled_row(),
            _procs_row(),
            _poisson_row(),
        ],
    })
    return runtime, serving


def test_expected_bench_naming_contract():
    assert expected_bench("x/y/BENCH_serving.json") == "serving"
    with pytest.raises(ArtifactError, match="infer"):
        expected_bench("results.json")


def test_wellformed_accepts_good_artifacts(artifacts):
    assert "2 files" in check_wellformed(list(artifacts))


def test_wellformed_rejects_wrong_bench_or_empty(tmp_path):
    p = _write(tmp_path, "BENCH_runtime.json",
               {"bench": "replay", "rows": [{"bench": "x", "workers": 1}]})
    with pytest.raises(ArtifactError, match="want bench='runtime'"):
        check_wellformed([p])
    p = _write(tmp_path, "BENCH_serving.json", {"bench": "serving",
                                                "rows": []})
    with pytest.raises(ArtifactError, match="rows"):
        check_wellformed([p])


def test_wellformed_rejects_contract_violations(tmp_path):
    p = _write(tmp_path, "BENCH_serving.json", {
        "bench": "serving",
        "rows": [_poisson_row(identical=False)]})
    with pytest.raises(ArtifactError, match="diverged"):
        check_wellformed([p])
    p = _write(tmp_path, "BENCH_runtime.json", {
        "bench": "runtime",
        "rows": [{"bench": "suspend_frames", "workers": 2, "noise": 0.1,
                  "no_slower": False}]})
    with pytest.raises(ArtifactError, match="no_slower"):
        check_wellformed([p])


def test_wellformed_requires_suspend_frames_and_noise(tmp_path):
    p = _write(tmp_path, "BENCH_runtime.json", {
        "bench": "runtime",
        "rows": [{"bench": "warm_reuse", "workers": 1, "noise": 0.1}]})
    with pytest.raises(ArtifactError, match="suspend_frames"):
        check_wellformed([p])
    p = _write(tmp_path, "BENCH_runtime.json", {
        "bench": "runtime",
        "rows": [{"bench": "suspend_frames", "workers": 2}]
        + _runtime_extra_rows()})
    with pytest.raises(ArtifactError, match="noise"):
        check_wellformed([p])


def test_wellformed_requires_victim_and_compiled_rows(tmp_path):
    p = _write(tmp_path, "BENCH_runtime.json", {
        "bench": "runtime",
        "rows": [{"bench": "suspend_frames", "workers": 2, "noise": 0.1},
                 {"bench": "compiled_linalg", "workers": 2, "noise": 0.1}]})
    with pytest.raises(ArtifactError, match="victim_frames"):
        check_wellformed([p])
    p = _write(tmp_path, "BENCH_runtime.json", {
        "bench": "runtime",
        "rows": [{"bench": "suspend_frames", "workers": 2, "noise": 0.1},
                 {"bench": "victim_frames", "workers": 2, "noise": 0.1}]})
    with pytest.raises(ArtifactError, match="compiled_linalg"):
        check_wellformed([p])


def test_wellformed_requires_compiled_rows_and_columns(tmp_path):
    p = _write(tmp_path, "BENCH_serving.json", {
        "bench": "serving",
        "rows": [_poisson_row()]})
    with pytest.raises(ArtifactError, match="serving_compiled"):
        check_wellformed([p])
    p = _write(tmp_path, "BENCH_serving.json", {
        "bench": "serving",
        "rows": [_compiled_row(workers=2), _poisson_row()]})
    with pytest.raises(ArtifactError, match="workers=4"):
        check_wellformed([p])
    row = _compiled_row()
    del row["compiled_overhead_fraction"]
    p = _write(tmp_path, "BENCH_serving.json",
               {"bench": "serving", "rows": [row, _poisson_row()]})
    with pytest.raises(ArtifactError, match="compiled_overhead_fraction"):
        check_wellformed([p])


def test_wellformed_requires_poisson_rows_and_columns(tmp_path):
    p = _write(tmp_path, "BENCH_serving.json", {
        "bench": "serving",
        "rows": [{"bench": "serving", "workers": 1, "identical": True},
                 _compiled_row(), _procs_row()]})
    with pytest.raises(ArtifactError, match="serving_poisson"):
        check_wellformed([p])
    row = _poisson_row()
    del row["warm_hit_rate"]
    p = _write(tmp_path, "BENCH_serving.json",
               {"bench": "serving",
                "rows": [_compiled_row(), _procs_row(), row]})
    with pytest.raises(ArtifactError, match="warm_hit_rate"):
        check_wellformed([p])
    p = _write(tmp_path, "BENCH_serving.json",
               {"bench": "serving",
                "rows": [_compiled_row(), _procs_row(), _poisson_row(
                    warm_hit_rate=1.5)]})
    with pytest.raises(ArtifactError, match="out of range"):
        check_wellformed([p])


def test_wellformed_requires_procs_rows_and_columns(tmp_path):
    p = _write(tmp_path, "BENCH_serving.json", {
        "bench": "serving",
        "rows": [_compiled_row(), _poisson_row()]})
    with pytest.raises(ArtifactError, match="serving_procs"):
        check_wellformed([p])
    row = _procs_row()
    del row["single_tok_s"]
    p = _write(tmp_path, "BENCH_serving.json",
               {"bench": "serving",
                "rows": [_compiled_row(), row, _poisson_row()]})
    with pytest.raises(ArtifactError, match="single_tok_s"):
        check_wellformed([p])
    p = _write(tmp_path, "BENCH_serving.json",
               {"bench": "serving",
                "rows": [_compiled_row(), _procs_row(warm_hit_rate=-0.1),
                         _poisson_row()]})
    with pytest.raises(ArtifactError, match="out of range"):
        check_wellformed([p])


def test_wellformed_requires_async_overlap_rows(tmp_path):
    rows = [{"bench": "suspend_frames", "workers": 2, "noise": 0.1}] + [
        r for r in _runtime_extra_rows() if r["bench"] != "async_overlap"]
    p = _write(tmp_path, "BENCH_runtime.json",
               {"bench": "runtime", "rows": rows})
    with pytest.raises(ArtifactError, match="async_overlap"):
        check_wellformed([p])


def test_wellformed_requires_resource_contention_rows_and_columns(tmp_path):
    rows = [{"bench": "suspend_frames", "workers": 2, "noise": 0.1}] + [
        r for r in _runtime_extra_rows()
        if r["bench"] != "resource_contention"]
    p = _write(tmp_path, "BENCH_runtime.json",
               {"bench": "runtime", "rows": rows})
    with pytest.raises(ArtifactError, match="resource_contention"):
        check_wellformed([p])
    row = _resource_row()
    del row["edges_ms"]
    p = _write(tmp_path, "BENCH_runtime.json", {
        "bench": "runtime",
        "rows": [{"bench": "suspend_frames", "workers": 2, "noise": 0.1}]
        + _runtime_extra_rows()[:-1] + [row]})
    with pytest.raises(ArtifactError, match="edges_ms"):
        check_wellformed([p])
    p = _write(tmp_path, "BENCH_runtime.json", {
        "bench": "runtime",
        "rows": [{"bench": "suspend_frames", "workers": 2, "noise": 0.1}]
        + _runtime_extra_rows()[:-1]
        + [_resource_row(resource_acquires=3)]})
    with pytest.raises(ArtifactError, match="fewer times"):
        check_wellformed([p])


def test_noise_table_and_summary(artifacts, tmp_path, monkeypatch):
    runtime, _ = artifacts
    text, worst = noise_table(runtime)
    assert worst == 0.31
    assert "| suspend_frames | 2 | 31.0% |" in text
    assert "worst observed spread: 31.0%" in text
    summary = tmp_path / "summary.md"
    monkeypatch.setenv("GITHUB_STEP_SUMMARY", str(summary))
    assert main(["noise", runtime]) == 0
    assert "31.0%" in summary.read_text()


def test_cli_exit_codes(artifacts, tmp_path, capsys):
    runtime, serving = artifacts
    assert main(["wellformed", runtime, serving]) == 0
    bad = _write(tmp_path, "BENCH_replay.json",
                 {"bench": "replay", "rows": [{"bench": "replay",
                                               "workers": 1,
                                               "identical": False}]})
    assert main(["wellformed", bad]) == 1
    assert "FAIL" in capsys.readouterr().err
    assert main(["noise", str(tmp_path / "missing.json")]) == 1


def test_real_artifacts_in_repo_root_if_present():
    import os
    paths = [p for p in ("BENCH_runtime.json", "BENCH_serving.json")
             if os.path.exists(p)]
    if not paths:
        pytest.skip("no bench artifacts in cwd")
    check_wellformed(paths)
