"""Tests for sharded multi-process serving (engine ``procs=N``).

The contract under test: sharding a request stream across worker
processes changes THROUGHPUT, never CONTENT — per-request token streams
stay bit-identical to single-process (and to per-request, unbatched)
serving, children adopt the parent-seeded recordings instead of
re-recording, backpressure crosses the pipe, and a killed child demotes
its unfinished shard to the in-process fallback without dropping a
request.
"""

import threading
import time

import pytest

import mp_helpers
import repro
from repro.mp import WorkerError
from repro.replay import GraphCache
from repro.serving import ContinuousBatchingEngine
from repro.serving.workload import constant_prompt_requests

pytestmark = pytest.mark.mp


def _requests(budgets, arrivals=None, prompt=(1, 2, 3)):
    arrivals = [0.0] * len(budgets) if arrivals is None else arrivals
    return constant_prompt_requests(arrivals, budgets, list(prompt))


def _pool_session(cache_dir, workers=1, procs=None):
    return repro.Session(
        workers, scheduler="pool", cache=GraphCache(str(cache_dir)),
        pool_kwargs={"warmup_runs": 0}, procs=procs)


def test_procs2_bit_identical_to_single_process_and_reference(tmp_path):
    reqs = _requests([4, 6, 3, 5, 4, 6, 3, 5],
                     arrivals=[i * 0.01 for i in range(8)])
    with _pool_session(tmp_path / "a") as s:
        single = ContinuousBatchingEngine(
            s, mp_helpers.toy_decode, mp_helpers.toy_prefill,
            sample_fn=mp_helpers.toy_sample, max_batch=4).run(reqs)
    with _pool_session(tmp_path / "b", procs=2) as s:
        eng = ContinuousBatchingEngine(
            s, mp_helpers.toy_decode, mp_helpers.toy_prefill,
            sample_fn=mp_helpers.toy_sample, max_batch=4,
            procs=2, fns_ref="mp_helpers:make_toy_fns")
        sharded = eng.run(reqs)
    assert sharded.tokens_by_rid() == single.tokens_by_rid()
    assert sharded.tokens_by_rid() == mp_helpers.per_request_reference(reqs)
    assert eng.mp_stats["dead"] == []
    assert eng.mp_stats["fallback"] == 0
    # both shards actually served (rid % 2 split)
    assert [p["completed"] for p in eng.mp_stats["per_proc"]] == [4, 4]


def test_children_adopt_parent_seeded_recordings_zero_rerecords(tmp_path):
    """Steady state: the parent seeds the shared disk cache (one in-process
    drive); the mp drive's children must then serve WARM — zero child-side
    records, zero re-records, every step driven by a recording."""
    cache_dir = tmp_path / "cache"
    reqs = _requests([5, 5, 5, 5, 5, 5])
    # the seed stream has an ODD count: its singleton tail records lane
    # shape 1 as well as shape 2 — the exact shapes each 3-request child
    # shard will hit
    with _pool_session(cache_dir) as s:
        ContinuousBatchingEngine(
            s, mp_helpers.toy_decode, mp_helpers.toy_prefill,
            sample_fn=mp_helpers.toy_sample, max_batch=2).run(
                _requests([5] * 7))
    with _pool_session(cache_dir, procs=2) as s:
        eng = ContinuousBatchingEngine(
            s, mp_helpers.toy_decode, mp_helpers.toy_prefill,
            sample_fn=mp_helpers.toy_sample, max_batch=2,
            procs=2, fns_ref="mp_helpers:make_toy_fns")
        report = eng.run(reqs)
    assert report.tokens_by_rid() == mp_helpers.per_request_reference(reqs)
    for summary in eng.mp_stats["per_proc"]:
        assert summary["records"] == 0       # adopted, never recorded
        assert summary["rerecords"] == 0
        assert summary["warm_steps"] == summary["steps"] > 0


def test_admission_backpressure_crosses_the_pipe(tmp_path):
    """Raw protocol: a child whose bounded admission queue is full answers
    a serve_submit with an AdmissionFull error future the parent can
    retry — and the engine path's own throttle keeps outstanding work
    under its cap."""
    with _pool_session(tmp_path, procs=1) as s:
        pool = s.process_pool()
        pool.request(0, "serve_open", {
            "stream": 999, "fns_ref": ("mp_helpers:make_slow_toy_fns",
                                       {"delay": 0.005}),
            "engine": {"max_batch": 1, "admission_capacity": 1,
                       "step_time": 0.01},
        }).result(timeout=60)
        reqs = _requests([30] * 6)
        futs = [pool.request(0, "serve_submit", {"stream": 999, "request": r})
                for r in reqs]
        refused = [(f, r) for f, r in zip(futs, reqs)
                   if isinstance(f.exception(timeout=120), WorkerError)]
        assert refused, "6 instant submits into 1 lane + 1 slot must refuse"
        assert all(f.exception(timeout=0).kind == "AdmissionFull"
                   for f, _ in refused)
        done = [f for f in futs if f.exception(timeout=0) is None]
        # retry the refused requests until the child accepts them all
        deadline = time.monotonic() + 120
        pending = [r for _, r in refused]
        while pending and time.monotonic() < deadline:
            fut = pool.request(0, "serve_submit",
                               {"stream": 999, "request": pending[0]})
            if isinstance(fut.exception(timeout=120), WorkerError):
                time.sleep(0.02)
                continue
            done.append(fut)
            pending.pop(0)
        assert not pending
        records = [f.result(timeout=120) for f in done]
        assert sorted(r.rid for r in records) == [r.rid for r in reqs]
        summary = pool.request(0, "serve_close",
                               {"stream": 999}).result(timeout=60)
        assert summary["completed"] == len(reqs)


def test_engine_throttle_respects_outstanding_cap(tmp_path):
    with _pool_session(tmp_path, procs=2) as s:
        eng = ContinuousBatchingEngine(
            s, mp_helpers.toy_decode, mp_helpers.toy_prefill,
            sample_fn=mp_helpers.toy_sample, max_batch=2,
            admission_capacity=2, procs=2, fns_ref="mp_helpers:make_toy_fns")
        report = eng.run(_requests([6] * 12))
    assert len(report.records) == 12
    cap = eng.mp_stats["cap"]
    assert cap == 4                           # admission_capacity + max_batch
    assert all(peak <= cap
               for peak in eng.mp_stats["peak_outstanding"].values())


def test_killed_child_falls_back_in_process_without_dropping(tmp_path):
    """Chaos: kill child 1 mid-stream.  Its unfinished requests must be
    re-served by the in-process fallback engine — every rid present, every
    stream still bit-identical to the per-request reference."""
    reqs = _requests([60] * 8)
    with _pool_session(tmp_path, procs=2) as s:
        pool = s.process_pool()               # pre-spawn so the killer can aim
        eng = ContinuousBatchingEngine(
            s, *mp_helpers.make_slow_toy_fns(0.003)[:2],
            sample_fn=mp_helpers.toy_sample, max_batch=2, procs=2,
            fns_ref=("mp_helpers:make_slow_toy_fns", {"delay": 0.003}))
        killer = threading.Timer(0.25, pool.kill, args=(1,))
        killer.start()
        try:
            report = eng.run(reqs, timeout=300)
        finally:
            killer.cancel()
    assert sorted(report.records) == [r.rid for r in reqs]
    assert report.tokens_by_rid() == mp_helpers.per_request_reference(reqs)
    assert eng.mp_stats["dead"] == [1]
    assert eng.mp_stats["fallback"] > 0       # something was actually rescued
