"""End-to-end behaviour tests for the paper's system: full factorizations
through the gang-scheduling/work-stealing runtime and the paper's headline
claims reproduced in the rank-aware simulator."""

import numpy as np
import pytest

from repro.core import DeadlockError, ParallelSpec, Simulator, TaskGraph, run_graph, simulate
from repro.linalg import (
    build_cholesky_graph,
    cholesky_extract,
    random_spd,
    to_tiles,
)
from repro.linalg.dist import build_dist_cholesky_graph, build_dist_panel_graph
from repro.linalg.tiles import CostModel


def test_end_to_end_cholesky_through_runtime():
    """Factor a real SPD matrix through the full runtime (hybrid policy,
    gang default) and validate numerics."""
    a = random_spd(192, seed=11)
    store = to_tiles(a, 48)
    g = build_cholesky_graph(store.nb, 48, store=store)
    run_graph(g, 4, policy="hybrid", timeout=120.0)
    l = cholesky_extract(store)
    np.testing.assert_allclose(np.asarray(l @ l.T), np.asarray(a), rtol=1e-8, atol=1e-8)


def test_paper_claim_gang_beats_oversubscription_lu():
    """Paper §5.2/Fig 7: gang-scheduled LU panels beat the oversubscribed
    baseline."""
    g1 = build_dist_panel_graph("lu", 24, 192, ranks=2)
    gang = Simulator(16, ranks=2, mode="gang", policy="hybrid", seed=0).run(g1).makespan
    over = Simulator(16, ranks=2, mode="oversubscribe", policy="hybrid", seed=0).run(g1).makespan
    assert gang < over


def test_paper_claim_hybrid_wins_cholesky():
    """Paper §5.4/Fig 11: hybrid victim selection gives a double-digit
    improvement on distributed Cholesky."""
    cm = CostModel(comm_bw=3e9, comm_latency=20e-6)
    g = build_dist_cholesky_graph(64, 192, ranks=4, cost=cm)
    hist = Simulator(40, ranks=4, policy="history", seed=0).run(g).makespan
    hyb = Simulator(40, ranks=4, policy="hybrid", seed=0).run(g).makespan
    assert (hist - hyb) / hist > 0.10


def test_paper_claim_deadlock_freedom():
    """Paper Fig 1: naive ULT scheduling deadlocks where gang scheduling
    completes — same workload, both modes."""
    def graph():
        g = TaskGraph("fig1")
        g.add(name="region", cost=0.01,
              parallel=ParallelSpec(n_threads=4, cost_per_thread=0.1,
                                    n_barriers=4, blocking=True))
        return g

    with pytest.raises(DeadlockError):
        simulate(graph(), 2, mode="ult_naive", seed=0)   # 4 ULTs on 2 workers
    tr = simulate(graph(), 4, mode="gang", seed=0)
    assert tr.makespan < 1.0
