"""API v2 tests: futures-based construction, sessions/plans/reports,
old-vs-new parity (bit-identical recordings through the shims), the policy
registry, `ctx.wait_any` multi-wait and bounded channels.
"""

import json
import threading

import numpy as np
import pytest

import repro
from repro.core import (
    Channel,
    ChannelFull,
    PolicyError,
    TaskEvent,
    TaskGraph,
    run_graph,
)
from repro.replay import GraphCache, replay_graph

WORKERS = 3


# ---------------------------------------------------------------------------
# futures-based construction
# ---------------------------------------------------------------------------

def test_handles_infer_deps_and_flow_values():
    g = repro.Graph("flow")
    a = g.add(lambda: 3, name="a")
    b = g.add(lambda: 4, name="b")
    # nested containers: handles found in tuples and dicts
    c = g.add(lambda pair, d: pair[0] * pair[1] + d["b"], (a, b), {"b": b},
              name="c")
    assert g.tasks[c.tid].deps == (a.tid, b.tid)
    with repro.Session(2) as s:
        report = s.run(g)
    assert report[c] == 16
    assert c.result(report) == 16


def test_explicit_deps_compose_with_inferred():
    g = repro.Graph("mixed")
    a = g.add(lambda: 1, name="a")
    side = g.add(lambda: None, name="side")
    b = g.add(lambda x: x + 1, a, deps=[side], name="b")
    # explicit first, inferred appended, deduplicated
    assert g.tasks[b.tid].deps == (side.tid, a.tid)
    c = g.add(lambda x: x, a, deps=[a], name="c")
    assert g.tasks[c.tid].deps == (a.tid,)


def test_ctx_convention_and_generator_bodies():
    g = repro.Graph("ctx")
    ch = Channel("api.ch")
    a = g.add(lambda: 5, name="a")

    def consumer(ctx, base):
        v = yield ctx.recv(ch)
        return base + v

    cons = g.add(consumer, a, name="cons")
    g.add(lambda ctx: ch.send(10), name="prod")
    with repro.Session(2) as s:
        report = s.run(g)
    assert report[cons] == 15


def test_handle_in_set_rejected_at_build_time():
    g = repro.Graph("sets")
    a = g.add(lambda: 1)
    with pytest.raises(TypeError, match="inside a set"):
        g.add(lambda xs: xs, {a})


def test_foreign_handle_rejected():
    g1, g2 = repro.Graph("g1"), repro.Graph("g2")
    h = g1.add(lambda: 1)
    with pytest.raises(ValueError, match="belongs to graph"):
        g2.add(lambda x: x, h)


def test_graph_is_a_taskgraph_everywhere():
    g = repro.Graph("compat")
    a = g.add(lambda: 1, name="a")
    g.add(lambda x: x, a, name="b")
    assert isinstance(g, TaskGraph)
    res = run_graph(g, 2)                    # v1 entry point accepts it
    assert res[a.tid] == 1


def test_dataflow_deps_match_explicit_declaration_hypothesis():
    """Property: a random DAG declared via handle arguments has exactly the
    same dependency structure (and digest) as the explicitly-wired twin."""
    hypothesis = pytest.importorskip("hypothesis")
    st = hypothesis.strategies

    @hypothesis.given(st.data())
    @hypothesis.settings(max_examples=40, deadline=None)
    def prop(data):
        n = data.draw(st.integers(min_value=1, max_value=12))
        dep_sets = []
        for tid in range(n):
            pool = list(range(tid))
            deps = data.draw(st.lists(st.sampled_from(pool) if pool else
                                      st.nothing(), unique=True, max_size=4))
            dep_sets.append(deps)
        implicit, explicit = repro.Graph("dag"), repro.Graph("dag")
        ih, eh = [], []

        def fn(*xs):
            return sum(xs) + 1

        for tid, deps in enumerate(dep_sets):
            ih.append(implicit.add(fn, *[ih[d] for d in deps],
                                   name=f"t{tid}"))
            eh.append(explicit.add(
                lambda ctx, _d=tuple(deps): sum(
                    ctx.result(t.tid) for t in [eh[d] for d in _d]) + 1,
                deps=[eh[d] for d in deps], name=f"t{tid}"))
        for tid in range(n):
            assert implicit.tasks[tid].deps == explicit.tasks[tid].deps
        from repro.replay import graph_key
        assert graph_key(implicit) == graph_key(explicit)

    prop()


# ---------------------------------------------------------------------------
# sessions, plans, reports
# ---------------------------------------------------------------------------

def _arith_graph(n=6):
    g = repro.Graph("arith")
    root = g.add(lambda: 1, name="root")
    mids = [g.add(lambda x, i=i: x + i, root, name=f"m{i}") for i in range(n)]
    total = g.add(lambda xs: sum(xs), mids, name="total")
    return g, total


def test_session_plan_modes_and_report():
    g, total = _arith_graph()
    cache = GraphCache()
    with repro.Session(2, cache=cache) as s:
        p1 = s.plan(g)
        assert p1.mode == "record" and "miss" in p1.reason
        r1 = s.run(g, plan=p1)
        assert r1.recording is not None and r1[total] == 21
        assert r1.wall_s > 0 and "steals" in r1.stats
        p2 = s.plan(g)
        assert p2.mode == "replay" and p2.recording is not None
        r2 = s.run(g, plan=p2)
        assert r2[total] == 21 and r2.stats.get("skips") == 0
    # no cache: warm dynamic, record only on request
    with repro.Session(2) as s:
        assert s.plan(g).mode == "warm"
        assert s.plan(g, record=True).mode == "record"
        rep = s.run(g)
        assert rep.recording is None and rep[total] == 21


def test_session_replay_scheduler_remaps_across_worker_counts():
    g, total = _arith_graph()
    cache = GraphCache()
    with repro.Session(2, cache=cache) as s:
        s.run(g, record=True)
    with repro.Session(3, scheduler="replay", cache=cache) as s:
        plan = s.plan(g)
        assert plan.mode == "replay" and plan.remapped_from == 2
        report = s.run(g, plan=plan)
        assert report[total] == 21
        # the remapped recording was adopted: next plan is a pure hit
        assert s.plan(g).remapped_from is None


def test_session_plan_reuse_across_same_shaped_graphs():
    cache = GraphCache()
    with repro.Session(2, scheduler="replay", cache=cache) as s:
        g0, t0 = _arith_graph()
        s.run(g0)                                    # records
        plan = s.plan(_arith_graph()[0])
        assert plan.mode == "replay"
        for _ in range(3):
            g, total = _arith_graph()
            assert s.run(g, plan=plan)[total] == 21

    g_other = repro.Graph("other")
    g_other.add(lambda: 0)
    with repro.Session(2, scheduler="replay", cache=cache) as s:
        g, _t = _arith_graph()
        plan = s.plan(g)
        with pytest.raises(repro.PlanError, match="hashes differently"):
            s.run(g_other, plan=plan)


def test_session_pool_scheduler_reports_pool_modes():
    with repro.Session(2, scheduler="pool",
                       pool_kwargs={"warmup_runs": 1}) as s:
        g, total = _arith_graph()
        modes = []
        for _ in range(4):
            g, total = _arith_graph()
            rep = s.run(g)
            assert rep[total] == 21
            modes.append(rep.stats["pool_mode"])
        assert modes == ["warmup", "record", "replay", "replay"]
        assert rep.recording is not None


def test_session_closed_is_terminal_and_releases_lease():
    s = repro.Session(2)
    g, total = _arith_graph()
    assert s.run(g)[total] == 21
    s.close()
    with pytest.raises(repro.PlanError, match="closed"):
        s.run(g)
    from repro.exec import REGISTRY
    assert REGISTRY.refcounts().get(2, 0) == 0


# ---------------------------------------------------------------------------
# policy registry
# ---------------------------------------------------------------------------

def test_policy_typo_fails_at_the_api_boundary_with_names():
    g, _total = _arith_graph()
    with pytest.raises(PolicyError, match="history, hybrid, random"):
        repro.Session(2, policy="hybird")
    with pytest.raises(PolicyError, match="valid policies"):
        run_graph(g, 2, policy="historyy")
    from repro.replay import ReplayPool
    with ReplayPool() as pool:
        with pytest.raises(PolicyError, match="valid policies"):
            pool.serve(g, 2, policy="nope")


def test_register_policy_extends_every_entry_point():
    from repro.core.policies import POLICIES, RandomPolicy, register_policy

    @register_policy("test-rr")
    class RoundRobin(RandomPolicy):
        name = "test-rr"

        def select(self):
            return (self.worker_id + 1) % self.n_workers

    try:
        assert "test-rr" in repro.available_policies()
        g, total = _arith_graph()
        with repro.Session(2, policy="test-rr") as s:
            assert s.run(g)[total] == 21
    finally:
        POLICIES.pop("test-rr", None)


# ---------------------------------------------------------------------------
# old-vs-new parity (the shim contract)
# ---------------------------------------------------------------------------

def _cholesky_setup(nb=4, b=16, seed=0):
    from repro.linalg import (build_cholesky_graph, cholesky_extract,
                              random_spd, to_tiles)

    a = random_spd(nb * b, seed=seed)
    st = to_tiles(a, b)
    return build_cholesky_graph(nb, b, store=st), st, cholesky_extract


def test_parity_cholesky_recording_bit_identical_at_one_worker():
    """At 1 worker a dynamic schedule is deterministic: the recording made
    through the v1 shim and the one on the v2 RunReport must be
    byte-identical JSON, and both factorizations bit-identical."""
    g_old, st_old, extract = _cholesky_setup()
    run_graph(g_old, 1, record=True)
    with pytest.warns(DeprecationWarning):
        rec_old = run_graph.last_recording
    g_new, st_new, _ = _cholesky_setup()
    with repro.Session(1) as s:
        report = s.run(g_new, record=True)
    assert json.dumps(rec_old.to_dict(), sort_keys=True) == \
        json.dumps(report.recording.to_dict(), sort_keys=True)
    assert (np.asarray(extract(st_old)) == np.asarray(extract(st_new))).all()


@pytest.mark.parametrize("builder", ["cholesky", "lu", "qr"])
def test_parity_factorizations_old_vs_new_api(builder):
    """Dynamic old-API run vs new-API session run: bit-identical factors;
    one shim-made recording replayed through BOTH APIs: bit-identical
    factors and equal deviation stats."""
    from repro.linalg import to_tiles
    if builder == "cholesky":
        from repro.linalg import build_cholesky_graph as build
        from repro.linalg import cholesky_extract as extract
        from repro.linalg import random_spd as gen
        kw = {}
    elif builder == "lu":
        from repro.linalg import build_lu_graph as build
        from repro.linalg import lu_extract as extract
        from repro.linalg import random_diagdom as gen
        kw = {"panel_threads": 2}
    else:
        from repro.linalg import build_qr_graph as build
        from repro.linalg import qr_extract_r as extract
        from repro.linalg import random_diagdom as gen
        kw = {"panel_threads": 2}
    nb, b = 4, 16
    a = gen(nb * b, seed=1)

    def factor(run):
        st = to_tiles(a, b)
        run(build(nb, b, store=st, **kw))
        out = extract(st)
        return np.asarray(out if not isinstance(out, tuple) else out[0])

    l_old = factor(lambda g: run_graph(g, WORKERS, record=True))
    with pytest.warns(DeprecationWarning):
        rec = run_graph.last_recording
    with repro.Session(WORKERS) as s:
        l_new = factor(lambda g: s.run(g))
    assert (l_old == l_new).all()

    # the same recording drives both replay paths bit-identically
    l_rep_old = factor(lambda g: run_graph(g, WORKERS, replay=rec))
    cache = GraphCache()
    cache.store(rec)
    with repro.Session(WORKERS, scheduler="replay", cache=cache) as s:
        l_rep_new = factor(lambda g: s.run(g))
    assert (l_rep_old == l_old).all() and (l_rep_new == l_old).all()


def test_parity_serving_decode_old_vs_new():
    """The pooled decode loop through the v1 shim vs through a
    Session(scheduler='pool'): identical token streams, and the live
    recording reported by the session encodes identically to the one the
    shim's pool produced for the same deterministic (1-worker) loop."""
    import jax.numpy as jnp

    from repro.models import DecodeShard, DecodeState, build_decode_graph
    from repro.replay import ReplayPool

    vocab = 7

    def toy_decode(params, cache, tok):
        h = cache["h"] * 31 + tok[:, 0] + 7
        logits = jnp.stack(
            [jnp.sin(h[:, None] * (i + 1)).astype(jnp.float32)
             for i in range(vocab)], axis=-1)
        return {"h": h}, logits

    def fresh_state(n_shards=3):
        shards = [
            DecodeShard(cache={"h": jnp.full((1,), s + 1, jnp.int32)},
                        tok=jnp.full((1, 1), s, jnp.int32))
            for s in range(n_shards)
        ]
        return DecodeState(params=None, shards=shards)

    def loop(run):
        state = fresh_state()
        for _ in range(5):
            run(build_decode_graph(state, toy_decode))
        return np.asarray(state.tokens())

    with ReplayPool(warmup_runs=1) as pool:
        tok_old = loop(lambda g: run_graph(g, 1, pool=pool))
    with pytest.warns(DeprecationWarning):
        rec_old = run_graph.last_recording
    reports = []
    with repro.Session(1, scheduler="pool",
                       pool_kwargs={"warmup_runs": 1}) as s:
        tok_new = loop(lambda g: reports.append(s.run(g)))
    assert (tok_old == tok_new).all()
    assert [r.stats["pool_mode"] for r in reports] == \
        ["warmup", "record", "replay", "replay", "replay"]
    # 1-worker decode recordings are deterministic: bit-identical encodings
    rec_new = reports[-1].recording
    assert json.dumps(rec_old.to_dict(), sort_keys=True) == \
        json.dumps(rec_new.to_dict(), sort_keys=True)


def test_run_graph_replay_kwarg_matches_replay_graph():
    g, total = _arith_graph()
    run_graph(g, 2, record=True)
    with pytest.warns(DeprecationWarning):
        rec = run_graph.last_recording
    g2, total2 = _arith_graph()
    res_shim = run_graph(g2, 2, replay=rec)
    g3, total3 = _arith_graph()
    res_lib = replay_graph(g3, rec)
    assert res_shim[total2.tid] == res_lib[total3.tid] == 21


def test_last_recording_alias_is_thread_local():
    """The v1 global leaked recordings across threads; the shim alias must
    not: each thread sees its own last recording."""
    seen = {}

    def worker(tag, n):
        g = repro.Graph(f"tl-{tag}")
        g.add(lambda: tag)
        for _ in range(n):
            run_graph(g, 1, record=True)
        with pytest.warns(DeprecationWarning):
            seen[tag] = run_graph.last_recording.graph_name

    threads = [threading.Thread(target=worker, args=(f"t{i}", 3))
               for i in range(3)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert seen == {f"t{i}": f"tl-t{i}" for i in range(3)}


# ---------------------------------------------------------------------------
# wait_any multi-wait
# ---------------------------------------------------------------------------

def test_wait_any_frame_first_ready_wins():
    g = repro.Graph("select")
    fast, slow = Channel("fast"), Channel("slow")

    def selector(ctx):
        idx, v = yield ctx.wait_any(slow, fast)
        return idx, v

    sel = g.add(selector, name="sel")
    g.add(lambda ctx: fast.send("f"), name="pf")
    with repro.Session(2) as s:
        idx, v = s.run(g)[sel]
    assert (idx, v) == (1, "f")
    assert len(slow) == 0                     # the loser was not consumed


def test_wait_any_loser_requeue_survives_full_bounded_channel():
    """A losing wait_any racer hands its consumed item back even when the
    bounded channel refilled meanwhile: the requeue bypasses the capacity
    check instead of raising ChannelFull in the sender's callback."""
    from repro.core.taskgraph import RecvRequest, WaitAnyRequest

    ch_a, ch_b = Channel("wa"), Channel("wb", capacity=1)
    req = WaitAnyRequest([RecvRequest(ch_a), RecvRequest(ch_b)])
    fired = []
    status, _ = req.park(fired.append)
    assert status == "parked"
    stale = ch_b._waiters[0]          # child 1's waiter, as a sender sees it
    ch_a.send("winner")               # child 0 claims; cancels child 1
    assert fired == [(0, "winner")]
    # simulate the race: a sender popped child 1 BEFORE the cancel landed,
    # and by now the bounded channel is full again
    ch_b.send("fill")
    stale("racing-item")              # must not raise, must not drop
    assert fired == [(0, "winner")]   # the loser never double-delivers
    assert [ch_b.recv_nowait(), ch_b.recv_nowait()] == \
        ["fill", "racing-item"]


def test_run_graph_pool_shim_refreshes_pool_last_recording():
    from repro.replay import ReplayPool

    with ReplayPool(warmup_runs=0) as pool:
        run_graph(_arith_graph()[0], 2, pool=pool)     # records
        run_graph(_arith_graph()[0], 2, pool=pool)     # replays
        assert pool.last_recording is not None
        assert pool.last_recording.n_workers == 2


def test_wait_any_event_and_plain_body():
    g = repro.Graph("select-plain")
    ch, ev = Channel("ch"), TaskEvent("ev")

    def plain(ctx):
        return ctx.wait_any(ch, ev)

    sel = g.add(plain, name="sel")
    g.add(lambda ctx: ev.set(), name="setter")
    with repro.Session(2) as s:
        idx, v = s.run(g)[sel]
    assert (idx, v) == (1, None)


def test_wait_any_replay_pins_recorded_choice():
    """Record a select whose winner is data-driven, then replay: the same
    branch must be taken (the recorded deterministic choice), even though
    at replay time both sources are ready."""
    ref = None
    for attempt in ("record", "replay"):
        g = repro.Graph("select-replay")
        a, b = Channel("a"), Channel("b")

        def selector(ctx):
            taken = []
            for _ in range(2):
                idx, v = yield ctx.wait_any(a, b)
                taken.append((idx, v))
            return taken

        sel = g.add(selector, name="sel")

        def producer(ctx):
            a.send("va")
            b.send("vb")

        g.add(producer, name="prod")
        if attempt == "record":
            res = run_graph(g, 2, record=True)
            with pytest.warns(DeprecationWarning):
                rec = run_graph.last_recording
            ref = res[sel.tid]
            assert sorted(ref) == [(0, "va"), (1, "vb")]
            assert rec.wait_choices          # the choices were instrumented
            # recordings round-trip the choices through JSON
            from repro.replay import Recording
            assert Recording.from_json(rec.to_json()).wait_choices == \
                rec.wait_choices
        else:
            res = replay_graph(g, rec)
            assert res[sel.tid] == ref


# ---------------------------------------------------------------------------
# bounded channels
# ---------------------------------------------------------------------------

def test_bounded_channel_raw_send_raises_when_full():
    ch = Channel("bounded", capacity=2)
    ch.send(1)
    ch.send(2)
    with pytest.raises(ChannelFull, match="capacity 2"):
        ch.send(3)
    assert ch.recv_nowait() == 1
    ch.send(3)                               # slot freed
    assert len(ch) == 2
    with pytest.raises(ValueError, match="capacity"):
        Channel("bad", capacity=0)


def test_bounded_channel_suspends_frame_senders():
    """A producer frame on a capacity-1 channel parks between sends; the
    consumer's receives free slots and resume it.  FIFO order holds."""
    g = repro.Graph("backpressure")
    ch = Channel("bp", capacity=1)
    n = 6

    def producer(ctx):
        for i in range(n):
            yield ctx.send(ch, i)
        return "done"

    def consumer(ctx):
        out = []
        for _ in range(n):
            v = yield ctx.recv(ch)
            out.append(v)
        return out

    prod = g.add(producer, name="prod")
    cons = g.add(consumer, name="cons")
    with repro.Session(2) as s:
        report = s.run(g)
    assert report[cons] == list(range(n)) and report[prod] == "done"
    assert report.stats["frame_suspends"] > 0


def test_bounded_channel_blocks_plain_senders_work_conservingly():
    """A plain-body producer on a full channel blocks work-conservingly
    while a plain consumer on another worker drains it.  At ONE worker the
    same pair is a genuine plain-body limitation (the consumer nests on
    the producer's stack and neither can finish) — the suspension-deadlock
    detector must raise instead of hanging; generator frames are the
    supported 1-worker shape."""
    from repro.core import DeadlockError

    def build(frame_consumer):
        g = repro.Graph("bp-plain")
        ch = Channel("bp2", capacity=1)
        n = 4

        def producer(ctx):
            for i in range(n):
                ctx.send(ch, i)
            return "done"

        def frame_cons(ctx):
            out = []
            for _ in range(n):
                out.append((yield ctx.recv(ch)))
            return out

        def plain_cons(ctx):
            return [ctx.recv(ch) for _ in range(n)]

        prod = g.add(producer, name="prod")
        cons = g.add(frame_cons if frame_consumer else plain_cons,
                     name="cons")
        return g, prod, cons, n

    for workers in (1, 2):
        g, prod, cons, n = build(frame_consumer=True)
        res = run_graph(g, workers, timeout=30.0)
        assert res[cons.tid] == list(range(n)) and res[prod.tid] == "done"
    # plain-plain at one worker: the consumer nests on the producer's
    # stack and neither can finish — detected, not hung
    g, prod, cons, n = build(frame_consumer=False)
    with pytest.raises(DeadlockError):
        run_graph(g, 1, timeout=30.0)


def test_bounded_channel_record_replay_parity():
    def build():
        g = repro.Graph("bp-rr")
        ch = Channel("bp3", capacity=2)

        def producer(ctx):
            for i in range(5):
                yield ctx.send(ch, i)

        def consumer(ctx):
            out = []
            for _ in range(5):
                out.append((yield ctx.recv(ch)))
            return out

        g.add(producer, name="prod")
        cons = g.add(consumer, name="cons")
        return g, cons

    g, cons = build()
    res = run_graph(g, 2, record=True)
    with pytest.warns(DeprecationWarning):
        rec = run_graph.last_recording
    g2, cons2 = build()
    assert replay_graph(g2, rec)[cons2.tid] == res[cons.tid] == list(range(5))


def test_bounded_channel_sender_deadlock_detected():
    """A frame sender filling a bounded channel nobody drains must raise a
    suspension deadlock, not hang."""
    from repro.core import DeadlockError

    g = repro.Graph("bp-dead")
    ch = Channel("bp4", capacity=1)

    def producer(ctx):
        yield ctx.send(ch, 1)
        yield ctx.send(ch, 2)

    g.add(producer, name="prod")
    with pytest.raises(DeadlockError, match="suspension deadlock"):
        run_graph(g, 2, timeout=30.0)
