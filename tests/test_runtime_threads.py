"""Tests for the threaded gang-scheduling + work-stealing runtime.

These run real Python threads; JAX CPU ops release the GIL, so compute
genuinely overlaps.  Kept small so the suite stays fast.
"""

import threading
import time

import numpy as np
import pytest

from repro.core import DeadlockError, Runtime, TaskGraph, run_graph


def test_runtime_executes_graph_and_returns_results():
    g = TaskGraph("sum")
    a = g.add(lambda ctx: 2, name="a")
    b = g.add(lambda ctx: 3, name="b")
    c = g.add(lambda ctx: ctx[a] + ctx[b], deps=[a, b], name="c")
    res = run_graph(g, 4, policy="hybrid")
    assert res[c.tid] == 5


def test_runtime_dependency_order():
    order = []
    lock = threading.Lock()

    def mk(name):
        def fn(ctx):
            with lock:
                order.append(name)
        return fn

    g = TaskGraph("diamond")
    a = g.add(mk("a"), name="a")
    b = g.add(mk("b"), deps=[a], name="b")
    c = g.add(mk("c"), deps=[a], name="c")
    g.add(mk("d"), deps=[b, c], name="d")
    run_graph(g, 4)
    assert order[0] == "a" and order[-1] == "d"


def test_runtime_wide_fanout_all_policies():
    for pol in ("history", "random", "hybrid"):
        g = TaskGraph("wide")
        tasks = [g.add(lambda ctx, i=i: i * i, name=f"t{i}") for i in range(64)]
        res = run_graph(g, 4, policy=pol, seed=1)
        assert all(res[t.tid] == i * i for i, t in enumerate(tasks))


def test_runtime_task_failure_propagates():
    g = TaskGraph("boom")
    g.add(lambda ctx: 1 / 0, name="boom")
    with pytest.raises(ZeroDivisionError):
        run_graph(g, 2)


def test_gang_region_with_blocking_barrier():
    """A gang-scheduled region using a real blocking barrier completes —
    members are guaranteed distinct workers (paper §3.1.2)."""
    hits = []

    def body(tid, region):
        hits.append(("pre", tid))
        region.barrier()
        hits.append(("post", tid))
        return tid * 10

    def task(ctx):
        return ctx.parallel(4, body, gang=True)

    g = TaskGraph("gang")
    t = g.add(task, name="spawn")
    res = run_graph(g, 4)
    assert sorted(res[t.tid]) == [0, 10, 20, 30]
    pre = [h for h in hits if h[0] == "pre"]
    # all 4 ULTs reached the barrier before any passed it
    assert len(pre) == 4
    assert {h[1] for h in hits if h[0] == "post"} == {0, 1, 2, 3}


def test_multiple_concurrent_gangs_no_deadlock():
    """Two sibling tasks each fork a 3-thread gang with multi-round barriers
    on 4 workers — the monotonic gang-id order must prevent deadlock."""

    def body(tid, region):
        for _ in range(3):
            region.barrier()
        return tid

    def mk_task(ctx):
        return ctx.parallel(3, body, gang=True)

    g = TaskGraph("two-gangs")
    a = g.add(mk_task, name="ra")
    b = g.add(mk_task, name="rb")
    res = run_graph(g, 4, timeout=60.0)
    assert sorted(res[a.tid]) == [0, 1, 2]
    assert sorted(res[b.tid]) == [0, 1, 2]


def test_nongang_blocking_region_deadlocks_and_is_detected():
    """Fig. 1(a): ULTs of a non-gang region with a blocking barrier are
    multiplexed on fewer workers than members => detected deadlock."""

    def body(tid, region):
        region.barrier()   # needs all 6 simultaneously; only 3 workers exist
        return tid

    def task(ctx):
        return ctx.parallel(6, body, gang=False)

    g = TaskGraph("fig1")
    g.add(task, name="spawn")
    with pytest.raises((DeadlockError, TimeoutError)):
        run_graph(g, 3, timeout=20.0)


def test_gang_request_larger_than_pool_rejected():
    def body(tid, region):
        region.barrier()

    def task(ctx):
        return ctx.parallel(8, body, gang=True)

    g = TaskGraph("toolarge")
    g.add(task, name="spawn")
    with pytest.raises(ValueError):
        run_graph(g, 4, timeout=20.0)


def test_runtime_overlap_comm_compute():
    """Hybrid victim selection must not serialize a sleep-based comm task
    behind compute: total time << serial sum."""
    g = TaskGraph("overlap")
    root = g.add(lambda ctx: None, name="root")
    for i in range(4):
        g.add(lambda ctx: time.sleep(0.15), deps=[root], kind="comm", name=f"comm{i}")
        g.add(lambda ctx: np.linalg.norm(np.random.rand(300, 300) @ np.random.rand(300, 300)),
              deps=[root], kind="compute", name=f"comp{i}")
    t0 = time.perf_counter()
    run_graph(g, 4, policy="hybrid", timeout=60.0)
    elapsed = time.perf_counter() - t0
    # serial would be >= 4*0.15 = 0.6s of sleep alone; overlapped run must
    # beat the serial sleep time
    assert elapsed < 0.55


def test_runtime_reuse_across_graphs():
    rt = Runtime(4, policy="hybrid")
    with rt:
        for trial in range(3):
            g = TaskGraph(f"g{trial}")
            ts = [g.add(lambda ctx, i=i: i, name=f"t{i}") for i in range(16)]
            res = rt.run(g)
            assert all(res[t.tid] == i for i, t in enumerate(ts))
