"""Numerical validation of the sharded MoE paths against the local
reference, on a small host-device mesh (subprocess isolates XLA flags)."""

import json
import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_config
from repro.models import layers as L
from repro.sharding.rules import ShardCtx

mesh = jax.make_mesh((2, 4), ("data", "model"))
# capacity_factor high enough that no path drops tokens (drops differ
# between per-shard and global capacity accounting — both are standard
# MoE semantics; droplessness isolates the arithmetic)
cfg = get_config("qwen3-moe-235b-a22b").reduced(
    n_layers=1, d_model=64, n_experts=8, top_k=2, d_expert=32,
    vocab_size=512, dtype="float32", capacity_factor=8.0)
key = jax.random.PRNGKey(0)
p = L.materialize(L.moe_spec(cfg), key, jnp.float32)
x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 64), jnp.float32)

ref = L.moe(p, cfg, x, shard_ctx=None)

results = {}
for gather_tokens in (False, True):
    ctx = ShardCtx(mesh=mesh)
    ctx.moe_gather_tokens = gather_tokens
    with mesh:
        out = jax.jit(lambda pp, xx: L.moe(pp, cfg, xx, shard_ctx=ctx))(p, x)
    err = float(jnp.max(jnp.abs(out - ref)))
    scale = float(jnp.max(jnp.abs(ref))) + 1e-6
    results["gather" if gather_tokens else "psum"] = err / scale
print("RESULT:" + json.dumps(results))
"""


@pytest.mark.slow
def test_moe_sharded_paths_match_reference():
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, env=env, timeout=600)
    assert r.returncode == 0, r.stderr[-3000:]
    line = [l for l in r.stdout.splitlines() if l.startswith("RESULT:")][0]
    out = json.loads(line[len("RESULT:"):])
    # EP psum path must match the local reference bit-for-bit-ish
    assert out["psum"] < 1e-5, out
    # token-gather path: same math, different reduction order (f32 psums)
    assert out["gather"] < 1e-4, out
