"""Flight-recorder / observability tests (repro.obs).

Covers the always-on-tracing contract: the off path allocates nothing, the
on path assembles a :class:`~repro.obs.trace.RuntimeTrace` whose counters
reconcile exactly with ``RunReport.stats``, Perfetto export round-trips to
an equal trace, and the session/pool plumbing surfaces traces + serving
stats end to end.  The suite-level leak check (no ring buffer outliving
its session) lives in ``conftest.py``.
"""

import gc
import json
import sys

import pytest

import repro
from repro.core.policies import POLICIES, VictimPolicy, register_policy
from repro.core.tracing import (
    EV_TASK_START,
    KIND_BARRIER,
    KIND_COMPUTE,
    KIND_STEAL,
    KIND_SWITCH,
    SPAN_KINDS,
)
from repro.obs import (
    NULL_RECORDER,
    FlightRecorder,
    RuntimeTrace,
    load_trace,
    validate_trace_json,
    write_trace,
)
from repro.obs.export import main as export_main
from repro.obs.recorder import _Ring


# ---------------------------------------------------------------------------
# graph builders
# ---------------------------------------------------------------------------

def _mixed_graph(fanout=6):
    """Fan-out of plain tasks plus a channel-coupled producer/consumer frame
    pair: exercises task, steal, frame-suspend/resume and block events."""
    g = repro.Graph("obs-mixed")
    ch = repro.Channel("obs.ch", capacity=1)

    def producer(ctx):
        for i in range(3):
            yield ctx.send(ch, i)
        return "done"

    def consumer(ctx):
        total = 0
        for _ in range(3):
            v = yield ctx.recv(ch)
            total += v
        return total

    root = g.add(lambda: 1, name="root")
    mids = [g.add(lambda x: x + 1, root, name=f"m{i}") for i in range(fanout)]
    p = g.add(producer, deps=[root], name="producer")
    c = g.add(consumer, deps=[root], name="consumer")
    join = g.add(lambda *xs: sum(x for x in xs if isinstance(x, int)),
                 *mids, c, deps=[p], name="join")
    return g, c, join


# ---------------------------------------------------------------------------
# the off path is free
# ---------------------------------------------------------------------------

class _FakeTask:
    kind = "compute"
    name = "t"
    tid = 7


class _FakeFrame:
    task = _FakeTask()
    resumes = 2


class _FakeRequest:
    @staticmethod
    def source_uid():
        return 3

    @staticmethod
    def describe():
        return "recv(ch)"


def test_null_recorder_emits_allocate_nothing():
    """The tracing-off hot path — ``NULL_RECORDER.emit*`` with raw objects —
    must not allocate: no f-strings, no ``*args`` tuple packing."""
    task, frame, req = _FakeTask(), _FakeFrame(), _FakeRequest()
    r = NULL_RECORDER

    def burst(n=2000):
        for _ in range(n):
            r.emit(0, EV_TASK_START, "x", 1, 2)
            r.emit(0, EV_TASK_START)
            r.emit_task_start(0, task)
            r.emit_frame_resume(1, frame)
            r.emit_frame_suspend(1, frame, req)
            r.begin_run()

    burst(100)                      # warm free lists / specializations
    gc.disable()
    try:
        deltas = []
        for _ in range(5):
            before = sys.getallocatedblocks()
            burst()
            deltas.append(sys.getallocatedblocks() - before)
    finally:
        gc.enable()
    # interpreter background noise can add a block or two once; a per-call
    # cost would show in EVERY sample across 12k calls
    assert min(deltas) == 0, f"no-op emit path allocates: deltas={deltas}"


def test_untraced_runtime_uses_null_recorder_singleton():
    from repro.core.runtime import Runtime

    rt = Runtime(2)
    assert rt._dispatch.recorder is NULL_RECORDER
    assert rt.last_trace is None
    rt.shutdown()


# ---------------------------------------------------------------------------
# ring mechanics
# ---------------------------------------------------------------------------

def test_ring_wraps_and_counts_dropped():
    ring = _Ring(4)
    for i in range(7):
        ring.append((float(i), "k", "", i, 0))
    assert ring.dropped == 3
    assert [e[3] for e in ring.snapshot()] == [3, 4, 5, 6]
    ring.reset()
    assert ring.snapshot() == [] and ring.dropped == 0


def test_recorder_routes_external_threads_to_extra_ring():
    rec = FlightRecorder(2, capacity=8)
    rec.emit(0, "a", "", 1)
    rec.emit(-1, "b", "", 2)       # non-worker thread (e.g. outside waker)
    snap = rec.snapshot()
    assert [(w, k) for (w, _, k, _, _, _) in snap] == [(0, "a"), (-1, "b")]


# ---------------------------------------------------------------------------
# session plumbing + reconciliation
# ---------------------------------------------------------------------------

def test_untraced_session_report_has_no_trace():
    g, _, join = _mixed_graph()
    with repro.Session(2) as s:
        report = s.run(g)
    assert report.trace is None
    assert join in report


def test_traced_dynamic_run_reconciles_with_stats(tmp_path):
    g, c, join = _mixed_graph()
    with repro.Session(2, trace=True) as s:
        report = s.run(g)
    trace = report.trace
    assert isinstance(trace, RuntimeTrace)
    assert report[c] == 0 + 1 + 2
    # every counted scheduler event has a matching recorded event
    assert trace.reconcile(report.stats) == {}
    assert trace.counters["frame_suspends"] >= 1
    assert trace.counters["tasks"] == len(g.tasks)
    assert set(e.kind for e in trace.events) <= SPAN_KINDS
    assert trace.metrics()["dropped_events"] == 0


def test_traced_one_worker_replay_reconciles_exactly():
    """On one worker the replay is deterministic: suspend/resume/fallback
    counters in ``RunReport.stats`` must equal the trace's event counts."""
    g1, _, _ = _mixed_graph(fanout=3)
    with repro.Session(1, scheduler="replay", trace=True) as s:
        first = s.run(g1)                       # records
        assert first.plan.mode == "record"
        g2, _, _ = _mixed_graph(fanout=3)
        second = s.run(g2)                      # replays
    assert second.plan.mode == "replay"
    trace = second.trace
    assert isinstance(trace, RuntimeTrace)
    assert trace.reconcile(second.stats) == {}
    assert trace.counters["frame_suspends"] == second.stats["frame_suspends"]
    assert trace.counters["fallback_steals"] == second.stats["fallback_steals"]


def test_trace_breakdown_shares_simulator_vocabulary():
    from repro.core import microbatch_overlap_graph, simulate

    sim_trace = simulate(microbatch_overlap_graph(8), 2, seed=0)
    g, _, _ = _mixed_graph()
    with repro.Session(2, trace=True) as s:
        run_trace = s.run(g).trace
    # same Event schema + kind vocabulary: the same analysis code runs on
    # both the offline simulator trace and the live flight recorder
    for tr in (sim_trace, run_trace):
        b = tr.breakdown()
        assert set(b) <= SPAN_KINDS
        assert 0.0 <= tr.utilization() <= 1.0
    assert run_trace.breakdown().get(KIND_COMPUTE, 0.0) > 0.0


# ---------------------------------------------------------------------------
# Perfetto export
# ---------------------------------------------------------------------------

def _traced_run(workers=2):
    g, _, _ = _mixed_graph()
    with repro.Session(workers, trace=True) as s:
        return s.run(g).trace


def test_perfetto_roundtrip_is_exact(tmp_path):
    trace = _traced_run()
    path = tmp_path / "trace.json"
    write_trace(trace, path)
    loaded = load_trace(path)
    assert loaded == trace
    assert loaded.metrics() == trace.metrics()


def test_perfetto_json_shape_and_validation(tmp_path):
    trace = _traced_run()
    path = tmp_path / "trace.json"
    write_trace(trace, path)
    info = validate_trace_json(path)
    assert info["schema"] == "repro.obs/1"
    assert info["rows"] == trace.n_workers + 1      # + external row
    data = json.loads(path.read_text())
    events = data["traceEvents"]
    # one named row per worker (+ external), slices, and steal/frame flows
    assert sum(1 for e in events if e["ph"] == "M"
               and e["name"] == "thread_name") == trace.n_workers + 1
    assert any(e["ph"] == "X" for e in events)
    if trace.steal_flows or trace.frame_flows:
        assert any(e["ph"] == "s" for e in events)
        assert any(e["ph"] == "f" for e in events)
    assert data["otherData"]["counters"] == trace.counters


def test_validate_rejects_malformed_trace(tmp_path):
    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps({"traceEvents": [
        {"ph": "X", "name": "x", "ts": 0, "dur": -5, "pid": 1, "tid": 0,
         "cat": "nope"}]}))
    with pytest.raises(ValueError, match="schema"):
        validate_trace_json(bad)


def test_export_cli_demo_and_validate(tmp_path, capsys):
    out = tmp_path / "demo.json"
    assert export_main(["--out", str(out), "--workers", "2",
                        "--steps", "2"]) == 0
    assert export_main(["--validate", str(out),
                        "--summarize", str(out)]) == 0
    text = capsys.readouterr().out
    assert "breakdown" in text and "steal success" in text


# ---------------------------------------------------------------------------
# pool serving stats + rolling trace metrics (ROADMAP item 4 plumbing)
# ---------------------------------------------------------------------------

def test_pool_surfaces_mode_replay_stats_and_trace_metrics():
    with repro.Session(2, scheduler="pool", trace=True,
                       pool_kwargs={"warmup_runs": 1}) as s:
        modes = []
        for _ in range(3):
            g, _, _ = _mixed_graph(fanout=3)
            report = s.run(g)
            modes.append(report.stats["pool_mode"])
            assert isinstance(report.trace, RuntimeTrace)
        assert modes == ["warmup", "record", "replay"]
        # the replay serve carries the executor's raw deviation counters —
        # a speedup<1 row is explainable from the outcome alone
        rs = report.stats["replay_stats"]
        assert {"fallback_steals", "stalls", "skips",
                "run_ahead"} <= set(rs)
        (entry_stats,) = s.pool.describe().values()
        tm = entry_stats["trace_metrics"]
        assert {"steal_success_rate", "dispatch_overhead_fraction",
                "utilization", "resume_latency_mean_s"} <= set(tm)
        assert 0.0 <= tm["utilization"] <= 1.0


def test_untraced_pool_keeps_trace_metrics_empty():
    with repro.Session(2, scheduler="pool") as s:
        g, _, _ = _mixed_graph(fanout=3)
        report = s.run(g)
        assert report.trace is None
        (entry_stats,) = s.pool.describe().values()
        assert entry_stats["trace_metrics"] == {}


# ---------------------------------------------------------------------------
# victim-policy feedback
# ---------------------------------------------------------------------------

def test_traced_runs_feed_policy_observe():
    observed = []

    @register_policy("obs-spy")
    class SpyPolicy(VictimPolicy):
        name = "obs-spy"

        def select(self):
            return self._rand_victim()

        def record(self, victim, success):
            pass

        def observe(self, metrics):
            observed.append(metrics)

    try:
        g, _, _ = _mixed_graph()
        with repro.Session(2, policy="obs-spy", trace=True) as s:
            s.run(g)
        # one observe() per worker's policy, fed the assembled metrics
        assert len(observed) == 2
        assert "steal_by_victim" in observed[0]
        assert "resume_latency" in observed[0]
    finally:
        POLICIES.pop("obs-spy", None)


def test_untraced_runs_do_not_feed_policies():
    observed = []

    @register_policy("obs-spy2")
    class SpyPolicy(VictimPolicy):
        name = "obs-spy2"

        def select(self):
            return self._rand_victim()

        def record(self, victim, success):
            pass

        def observe(self, metrics):
            observed.append(metrics)

    try:
        g, _, _ = _mixed_graph()
        with repro.Session(2, policy="obs-spy2") as s:
            s.run(g)
        assert observed == []
    finally:
        POLICIES.pop("obs-spy2", None)


# ---------------------------------------------------------------------------
# assembled-span sanity
# ---------------------------------------------------------------------------

def test_assembled_spans_are_well_formed():
    trace = _traced_run()
    assert trace.events, "traced run produced no spans"
    for e in trace.events:
        assert e.t1 >= e.t0 >= 0.0
        assert -1 <= e.worker < trace.n_workers
    # zero-length markers are reserved for steal/switch instants
    for e in trace.events:
        if e.kind not in (KIND_STEAL, KIND_SWITCH, KIND_BARRIER):
            assert e.dt >= 0.0
