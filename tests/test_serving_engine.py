"""Tests for the continuous-batching serving layer (repro.serving).

Uses a pure-python toy model (integer hash caches, list logits) so the
engine, admission queue, and pool integration run fast and
deterministically with no jax in the loop; the full-LM path is exercised
by benchmarks/bench_serving.py and examples/serve_lm.py.
"""

import threading

import numpy as np
import pytest

import repro
from repro.serving import (
    AdmissionFull,
    ContinuousBatchingEngine,
    PoissonWorkload,
)
from repro.serving.workload import constant_prompt_requests

VOCAB = 13
PRIME = 10_007


def toy_prefill(prompt):
    h = (int(np.asarray(prompt).sum()) * 31 + 7) % PRIME
    return {"h": h}, _logits(h)


def toy_decode(cache, tok):
    h = (cache["h"] * 31 + int(tok) + 7) % PRIME
    return {"h": h}, _logits(h)


def _logits(h):
    row = [0.0] * VOCAB
    row[h % VOCAB] = 1.0
    return row


def toy_sample(logits):
    return int(np.argmax(np.asarray(logits)))


def _engine(session, **kw):
    kw.setdefault("max_batch", 3)
    kw.setdefault("step_time", 0.01)
    return ContinuousBatchingEngine(
        session, toy_decode, toy_prefill, sample_fn=toy_sample, **kw)


def _requests(budgets, arrivals=None, prompt=(1, 2, 3), eos=None):
    arrivals = [0.0] * len(budgets) if arrivals is None else arrivals
    return constant_prompt_requests(
        arrivals, budgets, np.asarray(prompt), eos_token=eos)


def _per_request_reference(requests):
    """Decode each request alone, serially, straight through the toy model
    (no engine, no runtime) — the ground-truth token streams."""
    out = {}
    for req in requests:
        cache, logits = toy_prefill(req.prompt)
        tok = toy_sample(logits)
        toks = [tok]
        while len(toks) < req.max_new_tokens and tok != req.eos_token:
            cache, logits = toy_decode(cache, tok)
            tok = toy_sample(logits)
            toks.append(tok)
        out[req.rid] = toks
    return out


# ---------------------------------------------------------------------------
# workload generator
def test_poisson_workload_deterministic_under_seed():
    a = PoissonWorkload(50.0, 20, seed=7, prompt_len=(4, 12),
                        max_new_tokens=(2, 9))
    b = PoissonWorkload(50.0, 20, seed=7, prompt_len=(4, 12),
                        max_new_tokens=(2, 9))
    assert np.array_equal(a.arrivals, b.arrivals)
    ra, rb = a.requests(), b.requests()
    assert [r.max_new_tokens for r in ra] == [r.max_new_tokens for r in rb]
    assert all(np.array_equal(x.prompt, y.prompt) for x, y in zip(ra, rb))
    assert (np.diff(a.arrivals) >= 0).all()
    c = PoissonWorkload(50.0, 20, seed=8, prompt_len=(4, 12),
                        max_new_tokens=(2, 9))
    assert not np.array_equal(a.arrivals, c.arrivals)


def test_poisson_workload_validation():
    with pytest.raises(ValueError, match="rate"):
        PoissonWorkload(0.0, 4)
    with pytest.raises(ValueError, match="request"):
        PoissonWorkload(1.0, 0)
    with pytest.raises(ValueError, match="span"):
        PoissonWorkload(1.0, 4, max_new_tokens=(5, 2))


def test_workload_budget_and_eos_stamp():
    w = PoissonWorkload(10.0, 6, seed=0, max_new_tokens=(3, 3), eos_token=2)
    reqs = w.requests()
    assert w.total_budget() == 18
    assert all(r.max_new_tokens == 3 and r.eos_token == 2 for r in reqs)


# ---------------------------------------------------------------------------
# engine basics: composition, early exit, determinism
def test_streams_bit_identical_to_per_request_dynamic_baseline():
    """Continuous batching (pooled, batch 3) and the per-request dynamic
    baseline (batch 1, FCFS) produce bit-identical per-request streams."""
    reqs = _requests([6, 4, 8, 3, 5, 7])
    with repro.Session(2, scheduler="pool") as s:
        batched = _engine(s).run(_requests([6, 4, 8, 3, 5, 7]))
    with repro.Session(2) as s:
        baseline = _engine(s, max_batch=1).run(reqs)
    assert batched.tokens_by_rid() == baseline.tokens_by_rid()
    assert batched.tokens_by_rid() == _per_request_reference(reqs)
    assert baseline.warm_hit_rate == 0.0        # dynamic serves, no pool


def test_early_exit_releases_batch_slots():
    """A finished request's slot is handed to the next queued request on
    the very next step, and occupancy never exceeds max_batch."""
    reqs = _requests([2, 5, 4])
    with repro.Session(2, scheduler="pool") as s:
        eng = _engine(s, max_batch=2)
        report = eng.run(reqs)
    recs = report.records
    # budget 2 = prefill token + one decode step, then the slot frees
    assert len(recs[0].tokens) == 2
    assert recs[2].admitted_s >= recs[0].done_s
    # both slots stayed busy the whole time: every step ran 2 lanes
    assert report.shape_counts == {2: 4}
    assert report.occupancy == 1.0
    assert [len(recs[r].tokens) for r in (0, 1, 2)] == [2, 5, 4]


def test_eos_stops_a_request_early():
    """toy_decode is a deterministic hash walk; find a token the walk hits
    and declare it EOS — the request must stop there, under budget."""
    ref = _per_request_reference(_requests([10]))[0]
    # first token value not seen earlier in the walk — a sound EOS marker
    idx = next(i for i in range(1, len(ref)) if ref[i] not in ref[:i])
    eos = ref[idx]
    (req,) = _requests([10], eos=eos)
    with repro.Session(1, scheduler="pool") as s:
        report = _engine(s, max_batch=1).run([req])
    toks = report.records[0].tokens
    assert toks == ref[: idx + 1]
    assert toks[-1] == eos and len(toks) < 10


def test_virtual_clock_composition_is_deterministic():
    """Same seeded workload + virtual clock => identical step compositions
    and latency numbers, run to run."""
    w = PoissonWorkload(200.0, 10, seed=3, prompt_len=4,
                        max_new_tokens=(2, 6), vocab_size=50)
    outs = []
    for _ in range(2):
        with repro.Session(2, scheduler="pool") as s:
            outs.append(_engine(s).run(w.requests()))
    assert outs[0].shape_counts == outs[1].shape_counts
    assert outs[0].tokens_by_rid() == outs[1].tokens_by_rid()
    assert outs[0].summary() == outs[1].summary()


# ---------------------------------------------------------------------------
# admission backpressure
def test_admission_backpressure_under_full_queue():
    with repro.Session(1) as s:
        eng = _engine(s, max_batch=1, admission_capacity=2)
        reqs = _requests([3, 3, 3, 3, 3])
        eng.submit(reqs[0])
        eng.submit(reqs[1])
        with pytest.raises(AdmissionFull, match="admission queue full"):
            eng.submit(reqs[2])
        assert not eng.try_submit(reqs[2])
        assert eng.queue_depth() == 2
        # a decode step admits one into the freed lane -> a slot opens
        assert eng.step()
        eng.submit(reqs[2])
        with pytest.raises(AdmissionFull):
            eng.submit(reqs[3], block=True, timeout=0.01)
        # a blocked submitter gets through once steps drain the queue
        t = threading.Thread(target=eng.submit, args=(reqs[3],),
                             kwargs={"block": True, "timeout": 30.0})
        t.start()
        for _ in range(40):
            if not eng.step() and not eng.queue_depth():
                break
        t.join(timeout=30.0)
        assert not t.is_alive()
        while eng.in_flight() or eng.queue_depth():
            eng.step()
        report = eng.report()
    assert sorted(report.records) == [0, 1, 2, 3]
    assert all(len(r.tokens) == 3 for r in report.records.values())


def test_duplicate_rid_rejected():
    with repro.Session(1) as s:
        eng = _engine(s)
        (req,) = _requests([2])
        eng.submit(req)
        with pytest.raises(ValueError, match="duplicate"):
            eng.submit(req)
        while eng.in_flight() or eng.queue_depth():
            eng.step()


# ---------------------------------------------------------------------------
# warm replay under churn
def test_shape_churn_still_replays_warm():
    """Ragged budgets churn the lane count step to step; every distinct
    shape records once and the rest of the steps replay warm."""
    budgets = [7, 5, 9, 4, 6, 8, 3, 5]
    with repro.Session(2, scheduler="pool",
                       pool_kwargs={"warmup_runs": 0}) as s:
        eng = _engine(s, max_batch=3)
        report = eng.run(_requests(budgets))
        by_key = s.pool.describe()
    shapes = len(report.shape_counts)
    assert shapes >= 2                      # churn actually happened
    # each shape pays at most its one recording run (plus, rarely, a
    # drift-triggered re-record under a loaded box) — everything else
    # must be a warm replay
    assert report.steps > 2 * shapes
    assert report.warm_steps >= report.steps - 2 * shapes
    assert report.warm_hit_rate > 0.5
    assert sum(e["records"] for e in by_key.values()) == shapes
    assert report.tokens_by_rid() == _per_request_reference(
        _requests(budgets))


def test_remap_absorbs_worker_count_churn():
    """Recordings made by a 2-worker replica serve a 3-worker replica via
    remap_recording: no re-recording, streams bit-identical."""
    from repro.replay import GraphCache

    budgets = [6, 4, 7, 5]
    cache = GraphCache()
    with repro.Session(2, scheduler="pool", cache=cache,
                       pool_kwargs={"warmup_runs": 0}) as s:
        ref = _engine(s, max_batch=2).run(_requests(budgets))
    with repro.Session(3, scheduler="pool", cache=cache,
                       pool_kwargs={"warmup_runs": 0}) as s:
        eng = _engine(s, max_batch=2)
        out = eng.run(_requests(budgets))
        by_key = s.pool.describe()
    assert out.tokens_by_rid() == ref.tokens_by_rid()
    assert sum(e["records"] for e in by_key.values()) == 0
    assert sum(e["remaps"] for e in by_key.values()) == len(
        out.shape_counts)


def test_prime_builds_graphs_off_the_hot_path():
    with repro.Session(1, scheduler="pool") as s:
        eng = _engine(s, max_batch=3)
        eng.prime()
        assert sorted(eng._graphs) == [1, 2, 3]
        graphs_before = {k: g for k, (g, _) in eng._graphs.items()}
        eng.run(_requests([4, 3, 2]))
        # the loop reused the primed graphs, never rebuilt them
        assert all(eng._graphs[k][0] is g for k, g in graphs_before.items())


# ---------------------------------------------------------------------------
# session key pass-through
def test_session_key_passthrough_skips_hash_not_safety():
    from repro.replay import graph_key

    g = repro.Graph("keyed")
    a = g.add(lambda: 3, name="a")
    g.add(lambda x: x + 1, a, name="b")
    key = graph_key(g)
    with repro.Session(1, scheduler="pool",
                       pool_kwargs={"warmup_runs": 0}) as s:
        r1 = s.run(g, key=key)
        r2 = s.run(g, key=key)
        assert r1.results[1] == r2.results[1] == 4
        assert r2.stats.get("pool_mode") == "replay"
        wrong = repro.Graph("wrong")
        wrong.add(lambda: 0, name="only")
        with pytest.raises(Exception):
            s.run(wrong, key=key)
    with repro.Session(1) as s:
        plan = s.plan(g, key=key)
        assert plan.digest == key.digest and plan.key is key


def test_report_refuses_requests_still_in_flight():
    with repro.Session(1) as s:
        eng = _engine(s, max_batch=2)
        eng.submit(_requests([5])[0])
        eng.step()
        with pytest.raises(RuntimeError, match="in flight"):
            eng.report()
        while eng.in_flight() or eng.queue_depth():
            eng.step()
        assert eng.report().completed == 1
