"""Tests for the replay-serving pool (repro.replay.pool), worker-count
remapping (repro.replay.remap), and GraphCache durability.

Covers the PR-2 contract: persistent executors serve repeated same-shaped
graphs without per-request construction; recordings remap across worker
counts with bit-identical results; sustained plan deviation triggers
adaptive re-recording with a hot swap into the cache; a corrupt on-disk
cache entry is ignored and re-recorded, never fatal.
"""

import json
import os
import subprocess
import sys
import time

import numpy as np
import pytest

from repro.core import Runtime, TaskGraph, run_graph
from repro.linalg import (
    build_cholesky_graph,
    build_lu_graph,
    cholesky_extract,
    lu_extract,
    random_diagdom,
    random_spd,
    to_tiles,
)
from repro.replay import (
    GraphCache,
    Recording,
    RemapError,
    ReplayPool,
    remap_recording,
    replay_graph,
)

NB, B = 6, 16


def _record_cholesky(workers=4, seed=1):
    a = random_spd(NB * B, seed=seed)
    st = to_tiles(a, B)
    with Runtime(workers) as rt:
        rt.run(build_cholesky_graph(NB, B, store=st), record=True)
    return a, np.asarray(cholesky_extract(st)), rt.last_recording


def _scrambled(rec: Recording) -> Recording:
    bad = Recording.from_dict(rec.to_dict())
    bad.worker_orders = [list(reversed(o)) for o in bad.worker_orders]
    return bad


# ---------------------------------------------------------------------------
# remap_recording
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("new_workers", [3, 2, 1, 5])
def test_remap_cholesky_bit_identical(new_workers):
    a, l_dyn, rec = _record_cholesky()
    r2 = remap_recording(rec, new_workers)
    assert r2.n_workers == new_workers
    assert len(r2.worker_orders) == new_workers
    assert r2.digest == rec.digest
    st = to_tiles(a, B)
    replay_graph(build_cholesky_graph(NB, B, store=st), r2)
    assert (np.asarray(cholesky_extract(st)) == l_dyn).all()


def test_remap_preserves_intra_worker_order():
    _, _, rec = _record_cholesky()
    r2 = remap_recording(rec, 3)
    r2.validate_against(build_cholesky_graph(NB, B))
    flat = {w: [e for e in o if isinstance(e, int)]
            for w, o in enumerate(r2.worker_orders)}
    for ow, order in enumerate(rec.worker_orders):
        tasks = [e for e in order if isinstance(e, int)]
        folded = flat[ow % 3]
        positions = [folded.index(t) for t in tasks]
        assert positions == sorted(positions), f"old worker {ow} reordered"


def test_remap_identity_and_bad_counts():
    _, _, rec = _record_cholesky()
    same = remap_recording(rec, rec.n_workers)
    assert same.to_dict() == rec.to_dict()
    with pytest.raises(RemapError):
        remap_recording(rec, 0)


def test_remap_lu_gang_coplacement():
    """Folding must keep every blocking gang on distinct workers, and the
    gang entries must follow their repaired placement."""
    m = random_diagdom(5 * B, seed=2)
    st = to_tiles(m, B)
    with Runtime(4) as rt:
        rt.run(build_lu_graph(5, B, store=st, panel_threads=3), record=True)
    l1, u1 = (np.asarray(x) for x in lu_extract(st))
    rec = rt.last_recording
    assert rec.gang_placements

    r3 = remap_recording(rec, 3)
    owner = {}
    for w, order in enumerate(r3.worker_orders):
        for e in order:
            if not isinstance(e, int):
                owner[tuple(e)] = w
    for tid, p in r3.gang_placements.items():
        assert len(set(p.workers)) == len(p.workers), "gang not distinct"
        for i, w in enumerate(p.workers):
            assert owner[(tid, i)] == w, "gang entry off its placement"

    st2 = to_tiles(m, B)
    replay_graph(build_lu_graph(5, B, store=st2, panel_threads=3), r3)
    l2, u2 = (np.asarray(x) for x in lu_extract(st2))
    assert (l1 == l2).all() and (u1 == u2).all()


def test_remap_refuses_gang_wider_than_workers():
    m = random_diagdom(5 * B, seed=3)
    st = to_tiles(m, B)
    with Runtime(4) as rt:
        rt.run(build_lu_graph(5, B, store=st, panel_threads=3), record=True)
    with pytest.raises(RemapError, match="gang"):
        remap_recording(rt.last_recording, 2)


# ---------------------------------------------------------------------------
# ReplayPool: persistent serving
# ---------------------------------------------------------------------------
def test_pool_records_once_then_replays():
    a = random_spd(NB * B, seed=5)
    results = []
    with ReplayPool(warmup_runs=0) as pool:
        for _ in range(4):
            st = to_tiles(a, B)
            run_graph(build_cholesky_graph(NB, B, store=st), 4, pool=pool)
            results.append(np.asarray(cholesky_extract(st)))
        (stats,) = pool.describe().values()
        assert stats["records"] == 1 and stats["replays"] == 3
        assert len(pool) == 1
        entry = next(iter(pool._entries.values()))
        first_executor = entry.executor
        st = to_tiles(a, B)
        run_graph(build_cholesky_graph(NB, B, store=st), 4, pool=pool)
        assert entry.executor is first_executor, "executor not persistent"
    for r in results[1:]:
        assert (r == results[0]).all()


def test_pool_warmup_runs_precede_recording():
    a = random_spd(NB * B, seed=6)
    with ReplayPool(warmup_runs=2) as pool:
        for _ in range(4):
            st = to_tiles(a, B)
            run_graph(build_cholesky_graph(NB, B, store=st), 2, pool=pool)
        (stats,) = pool.describe().values()
        assert stats["warmups"] == 2
        assert stats["records"] == 1
        assert stats["replays"] == 1


def test_pool_adopts_shipped_recording_via_remap():
    """A recording made at 4 workers serves a 3-worker replica with no
    dynamic recording run (the cross-process shipment story)."""
    a, l_dyn, rec = _record_cholesky()
    cache = GraphCache()
    cache.store(rec)
    with ReplayPool(cache) as pool:
        st = to_tiles(a, B)
        run_graph(build_cholesky_graph(NB, B, store=st), 3, pool=pool)
        (stats,) = pool.describe().values()
        assert stats["remaps"] == 1
        assert stats["records"] == 0 and stats["warmups"] == 0
        assert (np.asarray(cholesky_extract(st)) == l_dyn).all()
    # the remapped recording is now cached for the next 3-worker replica
    assert cache.lookup(rec.digest, 3, rec.policy) is not None


def test_pool_serves_multiple_shapes_and_worker_counts():
    a = random_spd(NB * B, seed=7)
    m = random_diagdom(4 * B, seed=7)
    with ReplayPool(warmup_runs=0, allow_remap=False) as pool:
        for _ in range(2):
            st = to_tiles(a, B)
            run_graph(build_cholesky_graph(NB, B, store=st), 2, pool=pool)
            st = to_tiles(a, B)
            run_graph(build_cholesky_graph(NB, B, store=st), 3, pool=pool)
            stl = to_tiles(m, B)
            run_graph(build_lu_graph(4, B, store=stl, panel_threads=2), 2,
                      pool=pool)
        assert len(pool) == 3
        for stats in pool.describe().values():
            assert stats["records"] == 1 and stats["replays"] == 1


# ---------------------------------------------------------------------------
# adaptive re-recording
# ---------------------------------------------------------------------------
def test_pool_rerecords_after_sustained_drift():
    """A scrambled recording replays only through fallback steals; the pool
    must notice the sustained drift, re-record inline on the next request,
    and hot-swap the fresh recording into the cache."""
    a, l_dyn, rec = _record_cholesky()
    bad = _scrambled(rec)
    cache = GraphCache()
    cache.store(bad)
    with ReplayPool(cache, drift_threshold=0.05, drift_patience=2,
                    warmup_runs=0) as pool:
        for i in range(4):
            st = to_tiles(a, B)
            run_graph(build_cholesky_graph(NB, B, store=st), 4, pool=pool)
            assert (np.asarray(cholesky_extract(st)) == l_dyn).all(), i
        (stats,) = pool.describe().values()
        assert stats["rerecords"] == 1, stats
        # post-swap runs replay the fresh recording: only timing-noise
        # deviations remain, far below the scrambled plan's near-total
        # deviation (a hard 0.05 bound here is flaky under machine load)
        assert stats["drift"] < 0.25, stats
    swapped = cache.lookup(rec.digest, 4, rec.policy)
    assert swapped.worker_orders != bad.worker_orders


def test_pool_background_rerecord_with_builder():
    """With a registered side-effect-free twin builder, re-recording happens
    off the request path and hot-swaps executor + cache entry."""
    a, l_dyn, rec = _record_cholesky()
    bad = _scrambled(rec)
    cache = GraphCache()
    cache.store(bad)
    with ReplayPool(cache, drift_threshold=0.05, drift_patience=2,
                    warmup_runs=0) as pool:
        pool.register_builder(bad.digest, lambda: build_cholesky_graph(NB, B))
        deadline = time.monotonic() + 30.0
        while time.monotonic() < deadline:
            st = to_tiles(a, B)
            run_graph(build_cholesky_graph(NB, B, store=st), 4, pool=pool)
            assert (np.asarray(cholesky_extract(st)) == l_dyn).all()
            (stats,) = pool.describe().values()
            if stats["rerecords"] == 1 and stats["drift"] < 0.05:
                break
            time.sleep(0.01)
        entry = next(iter(pool._entries.values()))
        assert entry.last_error is None
        (stats,) = pool.describe().values()
        assert stats["rerecords"] == 1, stats
        # every request was served by replay (never the dynamic path)
        assert stats["replays"] == stats["requests"], stats
    swapped = cache.lookup(rec.digest, 4, rec.policy)
    assert swapped.worker_orders != bad.worker_orders


# ---------------------------------------------------------------------------
# GraphCache durability
# ---------------------------------------------------------------------------
def test_cache_on_disk_roundtrip_across_processes(tmp_path):
    """A recording stored by another *process* is adopted via the on-disk
    cache (real subprocess, not a fresh in-process GraphCache)."""
    script = """
import sys
from repro.core import Runtime, TaskGraph
from repro.replay import GraphCache

g = TaskGraph("xproc")
xs = [g.add(lambda ctx, i=i: i + 1, name=f"x{i}") for i in range(6)]
g.add(lambda ctx: sum(ctx.dep_results()), deps=xs, name="sum")
with Runtime(2) as rt:
    rt.run(g, record=True)
GraphCache(sys.argv[1]).store(rt.last_recording)
"""
    src = os.path.join(os.path.dirname(os.path.dirname(__file__)), "src")
    env = dict(os.environ)
    env["PYTHONPATH"] = src + os.pathsep * bool(env.get("PYTHONPATH", "")) \
        + env.get("PYTHONPATH", "")
    subprocess.run([sys.executable, "-c", script, str(tmp_path)],
                   env=env, check=True, timeout=120)

    def mk():
        g = TaskGraph("xproc")
        xs = [g.add(lambda ctx, i=i: i + 1, name=f"x{i}") for i in range(6)]
        g.add(lambda ctx: sum(ctx.dep_results()), deps=xs, name="sum")
        return g

    cache = GraphCache(tmp_path)
    rec = cache.lookup(mk(), 2, "hybrid")
    assert rec is not None, "shipped recording not found on disk"
    assert replay_graph(mk(), rec) == run_graph(mk(), 2)


@pytest.mark.parametrize("corruption", ["truncate", "garbage", "empty", "schema"])
def test_cache_ignores_corrupt_file_and_rerecords(tmp_path, corruption):
    a, _, rec = _record_cholesky()
    cache = GraphCache(tmp_path)
    ckey = cache.store(rec)
    f = os.path.join(str(tmp_path), f"{ckey}.json")
    blob = open(f).read()
    with open(f, "w") as fh:
        fh.write({"truncate": blob[:len(blob) // 2], "garbage": "{not json!",
                  "empty": "", "schema": json.dumps({"v": 1})}[corruption])

    fresh = GraphCache(tmp_path)                      # new process analogue
    assert fresh.lookup(build_cholesky_graph(NB, B), 4, "hybrid") is None
    assert os.path.exists(f + ".corrupt"), "bad file not quarantined"
    # the serving path recovers by re-recording over the bad entry
    st = to_tiles(a, B)
    run_graph(build_cholesky_graph(NB, B, store=st), 4, cache=fresh)
    assert fresh.lookup(build_cholesky_graph(NB, B), 4, "hybrid") is not None
    rec2 = GraphCache(tmp_path).lookup(build_cholesky_graph(NB, B), 4, "hybrid")
    rec2.validate_against(build_cholesky_graph(NB, B))


def test_cache_candidates_swap_invalidate(tmp_path):
    _, _, rec = _record_cholesky()
    cache = GraphCache(tmp_path)
    cache.store(rec)
    cache.store(remap_recording(rec, 2))
    # candidates sees both worker counts, from memory and from disk
    assert sorted(cache.candidates(rec.digest)) == [2, 4]
    assert sorted(GraphCache(tmp_path).candidates(rec.digest)) == [2, 4]
    old = cache.swap(_scrambled(rec))
    assert old is not None and old.worker_orders == rec.worker_orders
    assert cache.invalidate(rec.digest, 2, rec.policy)
    assert cache.lookup(rec.digest, 2, rec.policy) is None
    assert not GraphCache(tmp_path).candidates(rec.digest).get(2)
