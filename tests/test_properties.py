"""Property-based tests (hypothesis) on the scheduler's invariants."""

import pytest

pytest.importorskip("hypothesis")

from hypothesis import given, settings, strategies as st

from repro.core import (
    GangState,
    ListScheduler,
    Simulator,
    TaskGraph,
    is_eligible_to_sched,
    make_policy,
)

settings.register_profile("ci", max_examples=40, deadline=None)
settings.load_profile("ci")


# ---------------------------------------------------------------------------
# random DAG generator
# ---------------------------------------------------------------------------
@st.composite
def dags(draw, max_tasks=40):
    n = draw(st.integers(min_value=1, max_value=max_tasks))
    g = TaskGraph("prop")
    kinds = ["compute", "comm", "panel", "lookahead"]
    for i in range(n):
        n_deps = draw(st.integers(min_value=0, max_value=min(i, 4)))
        deps = sorted(draw(st.sets(st.integers(min_value=0, max_value=i - 1),
                                   min_size=n_deps, max_size=n_deps))) if i else []
        g.add(name=f"t{i}",
              kind=draw(st.sampled_from(kinds)),
              cost=draw(st.floats(min_value=1e-5, max_value=1e-2)),
              priority=draw(st.integers(min_value=0, max_value=3)),
              deps=list(deps))
    return g


# ---------------------------------------------------------------------------
# scheduler invariants
# ---------------------------------------------------------------------------
@given(dags(), st.integers(min_value=1, max_value=8),
       st.sampled_from(["history", "random", "hybrid"]),
       st.integers(min_value=0, max_value=5))
def test_simulator_executes_every_task_exactly_once(g, workers, policy, seed):
    sim = Simulator(workers, policy=policy, seed=seed)
    tr = sim.run(g)
    names = [e.label for e in tr.events if e.label.startswith("t")]
    assert sorted(names) == sorted(t.name for t in g)


@given(dags(), st.integers(min_value=1, max_value=8),
       st.sampled_from(["history", "random", "hybrid"]),
       st.integers(min_value=0, max_value=5))
def test_simulator_respects_dependencies(g, workers, policy, seed):
    tr = Simulator(workers, policy=policy, seed=seed).run(g)
    start = {}
    end = {}
    for e in tr.events:
        if e.label in start:
            start[e.label] = min(start[e.label], e.t0)
            end[e.label] = max(end[e.label], e.t1)
        else:
            start[e.label], end[e.label] = e.t0, e.t1
    for t in g:
        for d in t.deps:
            dn = g.tasks[d].name
            assert end[dn] <= start[t.name] + 1e-9, \
                f"{t.name} started before dep {dn} finished"


@given(dags(), st.integers(min_value=1, max_value=6),
       st.integers(min_value=0, max_value=3))
def test_makespan_bounds(g, workers, seed):
    """critical path <= makespan <= total work + overheads."""
    tr = Simulator(workers, policy="hybrid", seed=seed,
                   locality_penalty=0.0).run(g)
    cp, _ = g.critical_path()
    total = g.total_work()
    overhead = 1e-3 * (len(g) + 10)
    assert tr.makespan >= cp - 1e-9
    assert tr.makespan <= total + overhead


@given(dags(), st.integers(min_value=2, max_value=6))
def test_static_schedule_is_valid(g, slots):
    sched = ListScheduler(slots, policy="hybrid").schedule(g)
    # every task appears exactly once
    assert sorted(i.tid for i in sched.items) == sorted(t.tid for t in g)
    # no slot runs two tasks at once
    by_slot = sched.order
    for slot, items in by_slot.items():
        for a, b in zip(items, items[1:]):
            assert a.t1 <= b.t0 + 1e-9
    # dependencies respected in time
    tmap = {i.tid: i for i in sched.items}
    for t in g:
        for d in t.deps:
            assert tmap[d].t1 <= tmap[t.tid].t0 + 1e-9


# ---------------------------------------------------------------------------
# gang logic invariants
# ---------------------------------------------------------------------------
@given(st.integers(min_value=1, max_value=64), st.integers(min_value=0, max_value=63),
       st.integers(min_value=1, max_value=64))
def test_get_workers_returns_distinct_valid_workers(n_workers, cur, n_request):
    cur = cur % n_workers
    gs = GangState(n_workers)
    r = gs.get_workers(cur, n_request)
    assert len(r) == min(n_request, n_workers)
    assert len(set(r)) == len(r)
    assert all(0 <= w < n_workers for w in r)


@given(st.integers(min_value=-1, max_value=10), st.integers(min_value=0, max_value=5),
       st.integers(min_value=-1, max_value=10), st.integers(min_value=0, max_value=5))
def test_eligibility_is_antisymmetric_across_gangs(g1, l1, g2, l2):
    """Two workers in different gangs at the same nest level can never both
    steal each other's ULTs (the cycle that causes deadlock)."""
    if g1 < 0 or g2 < 0 or g1 == g2:
        return
    both = (is_eligible_to_sched(g1, l1, g2, l2) and
            is_eligible_to_sched(g2, l2, g1, l1))
    if l1 == l2:
        assert not both


@given(st.sampled_from(["history", "random", "hybrid"]),
       st.integers(min_value=2, max_value=16),
       st.integers(min_value=0, max_value=10))
def test_policies_never_select_self(policy, n_workers, seed):
    p = make_policy(policy, 0, n_workers, seed)
    for i in range(50):
        v = p.select()
        assert v != 0
        assert 0 <= v < n_workers
        p.record(v, i % 3 == 0)
