"""Per-architecture smoke tests: reduced same-family configs, one forward /
loss(+grad) step and one prefill+decode step on CPU, asserting shapes and
no NaNs.  The FULL configs are exercised only via the dry-run."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.models import (
    decode_step,
    forward,
    init_params,
    loss_fn,
    prefill,
)
from repro.models.lm import padded_vocab

B, S = 2, 64


def make_batch(cfg, key):
    kt, kl, kp = jax.random.split(key, 3)
    batch = {
        "tokens": jax.random.randint(kt, (B, S), 0, cfg.vocab_size),
        "labels": jax.random.randint(kl, (B, S), 0, cfg.vocab_size),
    }
    if cfg.family == "encdec":
        batch["enc_input"] = jax.random.normal(kp, (B, 32, cfg.d_model),
                                               cfg.jdtype)
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(kp, (B, cfg.n_patches, cfg.d_model),
                                             cfg.jdtype)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_is_valid(arch):
    cfg = get_config(arch)
    assert cfg.param_count() > 1e8  # these are real multi-B-param configs
    assert padded_vocab(cfg) % 256 == 0


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_loss(arch):
    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    batch = make_batch(cfg, jax.random.PRNGKey(1))

    h = jax.jit(lambda p, b: forward(p, cfg, b, None))(params, batch)
    assert h.shape == (B, S, cfg.d_model)
    assert not bool(jnp.any(jnp.isnan(h)))

    loss, grads = jax.jit(jax.value_and_grad(
        lambda p: loss_fn(p, cfg, batch, None)))(params)
    assert np.isfinite(float(loss))
    # a full-vocab CE on random labels should sit near log(V)
    assert 0.2 * np.log(cfg.vocab_size) < float(loss) < 3.0 * np.log(cfg.vocab_size)
    gnorm = jax.tree_util.tree_reduce(
        lambda a, l: a + float(jnp.sum(jnp.abs(l))), grads, 0.0)
    assert np.isfinite(gnorm) and gnorm > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_prefill_decode(arch):
    cfg = get_config(arch).reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = make_batch(cfg, jax.random.PRNGKey(1))
    max_len = S + 4

    cache, logits = jax.jit(
        lambda p, b: prefill(p, cfg, b, None, max_len=max_len))(params, batch)
    assert logits.shape[0] == B and logits.shape[1] == 1
    assert not bool(jnp.any(jnp.isnan(logits)))
    assert int(cache["index"]) == S

    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    cache2, logits2 = jax.jit(
        lambda p, c, t: decode_step(p, cfg, c, t, None))(params, cache, tok)
    assert logits2.shape[1] == 1
    assert not bool(jnp.any(jnp.isnan(logits2)))
    assert int(cache2["index"]) == S + 1


@pytest.mark.parametrize("arch", ["deepseek-67b", "mamba2-2.7b", "zamba2-7b",
                                  "gemma3-12b"])
def test_decode_matches_forward(arch):
    """Teacher-forced decode must reproduce the forward pass logits: run
    prefill on s tokens, then decode the next token and compare with the
    full-sequence forward."""
    cfg = get_config(arch).reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    full = make_batch(cfg, jax.random.PRNGKey(1))
    s0 = S - 1
    pre_batch = dict(full, tokens=full["tokens"][:, :s0])

    cache, logits_pre = jax.jit(
        lambda p, b: prefill(p, cfg, b, None, max_len=S + 1))(params, pre_batch)
    cache, logits_dec = jax.jit(
        lambda p, c, t: decode_step(p, cfg, c, t, None))(
            params, cache, full["tokens"][:, s0:s0 + 1])

    from repro.models.lm import logits_from_hidden
    h = jax.jit(lambda p, b: forward(p, cfg, b, None))(params, full)
    logits_full = logits_from_hidden(params, cfg, h)

    np.testing.assert_allclose(
        np.asarray(logits_dec[:, 0], dtype=np.float32),
        np.asarray(logits_full[:, s0], dtype=np.float32),
        rtol=2e-2, atol=2e-2)
