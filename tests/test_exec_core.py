"""Tests for the unified executor core (repro.exec).

Covers the refactor contract: one worker substrate under dynamic, replay
and pooled scheduling — Runtime reuses warm threads across runs, a dynamic
and a replay dispatch share one core with identical results, the pool caps
threads per worker count and evicts LRU shapes cleanly (including under
request races), the centralized deadlock detector fires under nested
``parallel()``, latency-aware drift re-records consistently imbalanced
recordings, and worker-count expansion seeds the new workers with work.
"""

import threading
import time

import numpy as np
import pytest

from repro.core import DeadlockError, Runtime, TaskGraph, run_graph
from repro.exec import ExecutorCore
from repro.linalg import (
    build_cholesky_graph,
    cholesky_extract,
    random_spd,
    to_tiles,
)
from repro.replay import Recording, ReplayExecutor, ReplayPool, remap_recording, replay_graph

NB, B = 6, 16


def _arith_graph(n: int, name: str = "arith") -> TaskGraph:
    g = TaskGraph(name)
    xs = [g.add(lambda ctx, i=i: i * 3, name=f"x{i}") for i in range(n)]
    s = g.add(lambda ctx: sum(ctx.dep_results()), deps=xs, name="sum")
    g.add(lambda ctx: ctx[s] + 1, deps=[s], name="inc")
    return g


def _threads_named(prefix: str):
    return sorted(t.ident for t in threading.enumerate()
                  if t.name.startswith(prefix) and t.is_alive())


# ---------------------------------------------------------------------------
# warm thread reuse
# ---------------------------------------------------------------------------
def test_runtime_thread_reuse_across_runs():
    """Repeated Runtime.run calls execute on the same parked workers — no
    thread respawn between runs."""
    with Runtime(3) as rt:
        res = rt.run(_arith_graph(8))
        assert res[8] == sum(i * 3 for i in range(8))
        idents = _threads_named("repro-worker")
        assert len(idents) == 3
        for trial in range(4):
            res = rt.run(_arith_graph(8, name=f"g{trial}"))
            assert res[9] == res[8] + 1
            assert _threads_named("repro-worker") == idents, \
                "worker threads were respawned between runs"
    assert _threads_named("repro-worker") == []


def test_dynamic_and_replay_dispatch_share_one_core():
    """A recording made by the dynamic dispatch replays on the *same* core
    (same threads) with identical results — the refactor's core claim."""
    with ExecutorCore(3) as core:
        rt = Runtime(3, core=core)
        res_dyn = rt.run(_arith_graph(12), record=True)
        rec = rt.last_recording
        idents = _threads_named("exec-core")
        assert len(idents) == 3

        ex = ReplayExecutor(rec, core=core)
        res_rep = ex.run(_arith_graph(12))
        assert res_rep == res_dyn
        assert _threads_named("exec-core") == idents, \
            "replay executor spawned its own threads despite the shared core"
        # facade shutdown releases the lease but leaves the core warm
        ex.shutdown()
        rt.shutdown()
        assert _threads_named("exec-core") == idents
        assert rt.run(_arith_graph(12)) == res_dyn
    assert _threads_named("exec-core") == []


def test_shared_core_rejects_mismatched_worker_count():
    with ExecutorCore(2) as core:
        with pytest.raises(ValueError, match="workers"):
            Runtime(3, core=core)
        rt = Runtime(2, core=core)
        rec = None
        rt.run(_arith_graph(4), record=True)
        rec = rt.last_recording
    with ExecutorCore(3) as other:
        with pytest.raises(ValueError, match="workers"):
            ReplayExecutor(rec, core=other)


# ---------------------------------------------------------------------------
# pool: shared cores + LRU eviction
# ---------------------------------------------------------------------------
def test_pool_shares_cores_across_shapes():
    """N shapes at one worker count lease ONE thread set — the pool caps
    threads by distinct worker counts, not by shapes."""
    # shared_cores=False: this test pins down the PER-POOL core capping
    # semantics (cross-pool registry sharing is covered in test_frames.py)
    with ReplayPool(warmup_runs=0, shared_cores=False) as pool:
        for n in (5, 7, 9):
            for _ in range(2):
                res = run_graph(_arith_graph(n), 2, pool=pool)
                assert res[n] == sum(i * 3 for i in range(n))
        run_graph(_arith_graph(5), 3, pool=pool)
        assert len(pool) == 4                      # 3 shapes @2w + 1 @3w
        assert len(_threads_named("pool2-worker")) == 2
        assert len(_threads_named("pool3-worker")) == 3
    assert _threads_named("pool") == []


def test_pool_max_shapes_evicts_lru():
    with ReplayPool(warmup_runs=0, max_shapes=2) as pool:
        for n in (5, 7, 9):
            run_graph(_arith_graph(n), 2, pool=pool)
        assert len(pool) == 2 and pool.evictions == 1
        # shape 5 was least recently used; 7 and 9 are resident
        resident = set(pool.describe())
        run_graph(_arith_graph(7), 2, pool=pool)   # hit: no new eviction
        assert pool.evictions == 1
        assert set(pool.describe()) == resident
        # the evicted shape re-materializes as a fresh entry — eviction
        # dropped its lease, not its cached recording, so it adopts the
        # recording and replays instead of paying a new recording run
        res = run_graph(_arith_graph(5), 2, pool=pool)
        assert res[5] == sum(i * 3 for i in range(5))
        assert pool.evictions == 2                 # 9 is now the LRU victim
        stats = pool.describe()
        assert any(st["requests"] == 1 and st["replays"] == 1
                   and st["records"] == 0 for st in stats.values())


def test_pool_eviction_race_with_requests():
    """Concurrent requests across more shapes than max_shapes: every
    request must be served correctly while entries churn through the LRU,
    and all leases shut down cleanly."""
    shapes = {n: sum(i * 3 for i in range(n)) for n in (4, 6, 8)}
    errors = []

    with ReplayPool(warmup_runs=0, max_shapes=1, shared_cores=False) as pool:
        def hammer(seed):
            try:
                for round_ in range(6):
                    for n, want in shapes.items():
                        res = run_graph(_arith_graph(n), 2, pool=pool)
                        assert res[n] == want, (seed, round_, n)
            except BaseException as e:  # noqa: BLE001
                errors.append(e)

        threads = [threading.Thread(target=hammer, args=(s,)) for s in range(3)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=120)
        assert not errors, errors
        assert len(pool) == 1 and pool.evictions > 0
        assert len(_threads_named("pool2-worker")) == 2
    assert _threads_named("pool") == []


# ---------------------------------------------------------------------------
# deadlock detection under nested parallel()
# ---------------------------------------------------------------------------
def test_nested_nongang_blocking_region_deadlock_detected():
    """A gang ULT forks a non-gang blocking region wider than the worker
    pool: the ULTs multiplex, every worker ends up hard-blocked, and the
    core's centralized detector must raise instead of hanging."""

    def task(ctx):
        def outer_body(tn, region):
            if tn == 0:
                return ctx.parallel(
                    4, lambda i, r: (r.barrier(), i)[1], gang=False)
            return tn

        return ctx.parallel(2, outer_body, gang=True)

    g = TaskGraph("nested-fig1")
    g.add(task, name="spawn")
    with pytest.raises((DeadlockError, TimeoutError)):
        run_graph(g, 3, timeout=20.0)


def test_failed_run_releases_gang_accounting_on_reuse():
    """An aborted run can strand queued gang ULTs; starting the next run on
    the same (warm) runtime must release their GangState accounting or
    get_workers' load balancing skews forever."""

    def spawner(ctx):
        return ctx.parallel(2, lambda i, r: i, gang=True)

    with Runtime(2) as rt:
        g = TaskGraph("boom-with-gang")
        g.add(spawner, name="gang")
        g.add(lambda ctx: 1 / 0, name="boom")
        with pytest.raises(ZeroDivisionError):
            rt.run(g, timeout=30.0)
        # a clean run on the same threads must find balanced accounting
        ok = TaskGraph("after")
        t = ok.add(spawner, name="gang2")
        res = rt.run(ok, timeout=30.0)
        assert sorted(res[t.tid]) == [0, 1]
        # totals must balance (per-worker loads may carry the pre-existing
        # steal skew: releases land on the executing worker, not the
        # reserved one — harmless to get_workers' average-load filter)
        assert rt.gang_state.n_gang_threads == 0


def test_nested_gang_regions_complete():
    """Nested gang regions (deeper nest level => stealable by outer-gang
    members) complete with correct per-thread results on the unified core."""

    def task(ctx):
        def outer_body(tn, region):
            region.barrier()
            if tn == 0:
                return ctx.parallel(2, lambda i, r: i * 10, gang=True)
            return tn

        return ctx.parallel(3, outer_body, gang=True)

    g = TaskGraph("nested-gang")
    t = g.add(task, name="spawn")
    res = run_graph(g, 4, timeout=60.0)
    assert res[t.tid][0] == [0, 10]
    assert res[t.tid][1:] == [1, 2]


# ---------------------------------------------------------------------------
# latency-aware drift
# ---------------------------------------------------------------------------
def test_pool_latency_drift_rerecords_imbalanced_recording():
    """A shipped recording that serializes every task on one worker replays
    with ZERO plan deviation (its owner runs its list faithfully) yet far
    slower than dynamic scheduling.  The deviation-rate trigger is blind to
    this; the latency EWMA trigger must re-record — including for *adopted*
    recordings, whose dynamic baseline is seeded by a one-off probe run."""
    from repro.replay import GraphCache

    def mk():
        g = TaskGraph("sleepy")
        for i in range(8):
            g.add(lambda ctx: time.sleep(0.004), name=f"s{i}")
        return g

    # record once, then squash: all eight sleeps serialized on worker 0
    with Runtime(4) as rt:
        rt.run(mk(), record=True)
    rec = rt.last_recording
    squashed = Recording.from_dict(rec.to_dict())
    flat = [e for o in squashed.worker_orders for e in o]
    squashed.worker_orders = [flat] + [[] for _ in range(rec.n_workers - 1)]
    cache = GraphCache()
    cache.store(squashed)

    with ReplayPool(cache,
                    drift_threshold=10.0,          # rate trigger disabled
                    drift_patience=2,
                    latency_drift_factor=1.5,
                    stall_timeout=5.0) as pool:    # helpers never steal
        run_graph(mk(), 4, pool=pool)              # adopt + baseline probe
        (stats,) = pool.describe().values()
        assert stats["warmups"] == 1 and stats["dynamic_ms"] > 0.0, stats

        for _ in range(8):
            run_graph(mk(), 4, pool=pool)
            (stats,) = pool.describe().values()
            if stats["rerecords"]:
                break
        (stats,) = pool.describe().values()
        assert stats["rerecords"] >= 1, stats
        # it was the latency trigger, not plan deviation, that fired
        assert stats["drift_strikes"] == 0, stats
        assert stats["replay_ms"] > stats["dynamic_ms"], stats


# ---------------------------------------------------------------------------
# expansion rebalancing
# ---------------------------------------------------------------------------
def _record_cholesky(workers=2, seed=11):
    a = random_spd(NB * B, seed=seed)
    st = to_tiles(a, B)
    with Runtime(workers) as rt:
        rt.run(build_cholesky_graph(NB, B, store=st), record=True)
    return a, np.asarray(cholesky_extract(st)), rt.last_recording


def test_remap_expansion_seeds_new_workers():
    """Expanding a recording to more workers must seed the new workers with
    split run lists (not leave them as fallback-only helpers), preserve
    relative order within every split, and stay bit-identical on replay."""
    a, l_dyn, rec = _record_cholesky(workers=2)
    r4 = remap_recording(rec, 4)
    assert all(r4.worker_orders[w] for w in range(4)), \
        "expansion left a worker with an empty run list"
    r4.validate_against(build_cholesky_graph(NB, B))

    # every new list's tasks from one original worker keep their order
    orig_pos = {}
    for ow, order in enumerate(rec.worker_orders):
        for i, e in enumerate(order):
            if isinstance(e, int):
                orig_pos[e] = (ow, i)
    for order in r4.worker_orders:
        by_owner = {}
        for e in order:
            if isinstance(e, int):
                ow, i = orig_pos[e]
                by_owner.setdefault(ow, []).append(i)
        for ow, positions in by_owner.items():
            assert positions == sorted(positions), \
                f"expansion reordered old worker {ow}'s entries"

    st = to_tiles(a, B)
    replay_graph(build_cholesky_graph(NB, B, store=st), r4)
    assert (np.asarray(cholesky_extract(st)) == l_dyn).all()


def test_remap_expansion_via_pool_stays_identical():
    """The pool's remap-adoption path serves an expanded recording with the
    seeded run lists and matches the dynamic result."""
    from repro.replay import GraphCache

    a, l_dyn, rec = _record_cholesky(workers=2)
    cache = GraphCache()
    cache.store(rec)
    with ReplayPool(cache) as pool:
        st = to_tiles(a, B)
        run_graph(build_cholesky_graph(NB, B, store=st), 4, pool=pool)
        (stats,) = pool.describe().values()
        assert stats["remaps"] == 1 and stats["records"] == 0
        assert (np.asarray(cholesky_extract(st)) == l_dyn).all()
    adopted = cache.lookup(rec.digest, 4, rec.policy)
    assert adopted is not None
    assert all(adopted.worker_orders[w] for w in range(4))
