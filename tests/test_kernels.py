"""Pallas kernel validation: interpret-mode execution on CPU swept over
shapes/dtypes against the pure-jnp oracles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.ssd_scan import ssd_scan_ref


def rnd(key, shape, dtype):
    x = jax.random.normal(key, shape, jnp.float32)
    return x.astype(dtype)


TOL = {jnp.float32: dict(rtol=2e-5, atol=2e-5),
       jnp.bfloat16: dict(rtol=3e-2, atol=3e-2)}


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,S,d", [(1, 2, 256, 64), (2, 1, 512, 128)])
@pytest.mark.parametrize("causal,window", [(True, 0), (True, 128), (False, 0)])
def test_flash_attention_matches_ref(B, H, S, d, dtype, causal, window):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = rnd(ks[0], (B, H, S, d), dtype)
    k = rnd(ks[1], (B, H, S, d), dtype)
    v = rnd(ks[2], (B, H, S, d), dtype)
    out = ops.flash_attention(q, k, v, causal=causal, window=window,
                              mode="interpret", bq=128, bk=128)
    expect = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32), **TOL[dtype])


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,S,d,length", [(2, 2, 1024, 64, 700),
                                            (1, 4, 2048, 128, 2048)])
def test_decode_attention_matches_ref(B, H, S, d, length, dtype):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = rnd(ks[0], (B, H, d), dtype)
    k = rnd(ks[1], (B, S, H, d), dtype)
    v = rnd(ks[2], (B, S, H, d), dtype)
    out = ops.decode_attention(q, k, v, length, mode="interpret", bk=256)
    expect = ref.decode_attention_ref(q, k, v, length)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32), **TOL[dtype])


# ---------------------------------------------------------------------------
# tile matmul
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("M,K,N,bm,bn,bk", [
    (256, 256, 256, 128, 128, 128),
    (512, 256, 128, 256, 128, 256),
])
def test_tile_matmul_matches_ref(M, K, N, bm, bn, bk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(2), 2)
    a = rnd(ks[0], (M, K), dtype)
    b = rnd(ks[1], (K, N), dtype)
    out = ops.tile_matmul(a, b, mode="interpret", bm=bm, bn=bn, bk=bk)
    expect = ref.tile_matmul_ref(a, b)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32), **TOL[dtype])


# ---------------------------------------------------------------------------
# SSD chunk scan
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32])
@pytest.mark.parametrize("B,nc,L,H,N,P", [(1, 3, 32, 4, 16, 32),
                                          (2, 2, 64, 2, 32, 64)])
def test_ssd_scan_matches_ref(B, nc, L, H, N, P, dtype):
    ks = jax.random.split(jax.random.PRNGKey(3), 4)
    xdt = rnd(ks[0], (B, nc, L, H, P), dtype) * 0.2
    # negative cumulative log-decay (monotone decreasing within chunk)
    la = -jnp.abs(rnd(ks[1], (B, nc, L, H), jnp.float32)) * 0.05
    cs = jnp.cumsum(la, axis=2)
    Bm = rnd(ks[2], (B, nc, L, N), dtype) * 0.3
    Cm = rnd(ks[3], (B, nc, L, N), dtype) * 0.3
    y, s = ops.ssd_scan(xdt, cs, Bm, Cm, mode="interpret")
    y_ref, s_ref = ssd_scan_ref(xdt, cs, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# the ssd oracle itself vs the model's chunked implementation
# ---------------------------------------------------------------------------
def test_ssd_ref_consistent_with_model_ssd():
    """kernels.ref and models.ssm implement the same recurrence."""
    from repro.models.ssm import ssd_chunked
    B, T, H, P, N = 1, 96, 2, 16, 8
    L = 32
    ks = jax.random.split(jax.random.PRNGKey(4), 4)
    xs = rnd(ks[0], (B, T, H, P), jnp.float32) * 0.3
    dt = jnp.abs(rnd(ks[1], (B, T, H), jnp.float32)) * 0.1 + 0.01
    a = -jnp.abs(rnd(ks[2], (H,), jnp.float32)) - 0.1
    Bm = rnd(ks[3], (B, T, N), jnp.float32) * 0.3
    Cm = rnd(ks[0], (B, T, N), jnp.float32) * 0.3

    y_model, s_model = ssd_chunked(xs, dt, a, Bm, Cm, chunk=L)

    # rebuild the kernel layout
    nc = T // L
    la = (dt * a).reshape(B, nc, L, H)
    cs = jnp.cumsum(la, axis=2)
    xdt = (xs * dt[..., None]).reshape(B, nc, L, H, P)
    y_k, s_k = ssd_scan_ref(xdt, cs, Bm.reshape(B, nc, L, N),
                            Cm.reshape(B, nc, L, N))
    np.testing.assert_allclose(np.asarray(y_model),
                               np.asarray(y_k.reshape(B, T, H, P)),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s_model), np.asarray(s_k),
                               rtol=1e-4, atol=1e-4)
