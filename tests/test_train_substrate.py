"""Tests for optimizer, data pipeline, checkpointing, and the fault-tolerant
trainer (checkpoint/restart equivalence, preemption)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer
from repro.configs import get_config
from repro.data import DataConfig, SyntheticLMData
from repro.optim import AdamWConfig, adamw_init, adamw_update, lr_schedule
from repro.train import Trainer, TrainerConfig
from repro.train.steps import StepConfig, make_train_step
from repro.models import init_params


def tiny_cfg():
    return get_config("deepseek-67b").reduced(n_layers=2, d_model=64,
                                              vocab_size=256, d_ff=128)


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------
def test_adamw_descends_quadratic():
    cfg = AdamWConfig(lr=0.1, warmup_steps=0, total_steps=100, weight_decay=0.0,
                      clip_norm=100.0)
    params = {"w": jnp.array([3.0, -2.0])}
    state = adamw_init(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}         # d/dw |w|^2
        params, state, info = adamw_update(cfg, params, grads, state)
    assert float(jnp.abs(params["w"]).max()) < 0.1


def test_lr_schedule_shape():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100, min_lr_frac=0.1)
    assert float(lr_schedule(cfg, jnp.int32(0))) == pytest.approx(0.0)
    assert float(lr_schedule(cfg, jnp.int32(10))) == pytest.approx(1.0, abs=0.02)
    assert float(lr_schedule(cfg, jnp.int32(100))) == pytest.approx(0.1, abs=0.02)


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------
def test_data_deterministic_and_sharded():
    c0 = DataConfig(vocab_size=100, seq_len=16, global_batch=8, seed=7,
                    n_hosts=2, host_id=0)
    c1 = DataConfig(vocab_size=100, seq_len=16, global_batch=8, seed=7,
                    n_hosts=2, host_id=1)
    d0a = SyntheticLMData(c0).batch_at(5)
    d0b = SyntheticLMData(c0).batch_at(5)
    d1 = SyntheticLMData(c1).batch_at(5)
    np.testing.assert_array_equal(d0a["tokens"], d0b["tokens"])
    assert not np.array_equal(d0a["tokens"], d1["tokens"])   # host shards differ
    assert d0a["tokens"].shape == (4, 16)
    assert (d0a["tokens"] >= 0).all() and (d0a["tokens"] < 100).all()


def test_data_prefetch_iterator():
    d = SyntheticLMData(DataConfig(vocab_size=50, seq_len=8, global_batch=4))
    d.start(from_step=3)
    it = iter(d)
    step, batch = next(it)
    assert step == 3
    step2, _ = next(it)
    assert step2 == 4
    d.stop()


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------
def test_checkpoint_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    tree = {"a": jnp.arange(6).reshape(2, 3), "b": {"c": jnp.ones(4)}}
    ck.save(10, tree, extra={"foo": 1})
    restored, manifest = ck.restore()
    assert manifest["step"] == 10 and manifest["extra"]["foo"] == 1
    np.testing.assert_array_equal(np.asarray(restored["a"]), np.arange(6).reshape(2, 3))
    np.testing.assert_array_equal(np.asarray(restored["b"]["c"]), np.ones(4))


def test_checkpoint_gc_and_async(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    for s in (1, 2, 3):
        ck.save_async(s, {"x": jnp.full((2,), s)})
    ck.wait()
    assert ck.all_steps() == [2, 3]
    restored, m = ck.restore(step=2)
    assert float(restored["x"][0]) == 2


# ---------------------------------------------------------------------------
# trainer: loss goes down, checkpoint/restart, preemption
# ---------------------------------------------------------------------------
def _mk_trainer(tmp_path, steps, ckpt_every=50):
    cfg = tiny_cfg()
    return Trainer(
        cfg,
        AdamWConfig(lr=1e-2, warmup_steps=5, total_steps=steps, clip_norm=1.0),
        TrainerConfig(steps=steps, ckpt_every=ckpt_every,
                      ckpt_dir=str(tmp_path), log_every=5),
        DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=8, seed=3),
    )


def test_trainer_loss_decreases(tmp_path):
    out = _mk_trainer(tmp_path, steps=30).run()
    losses = [m["loss"] for m in out["metrics"]]
    assert out["final_step"] == 30
    assert losses[-1] < losses[0]          # synthetic stream is learnable


def test_trainer_restart_resumes(tmp_path):
    t1 = _mk_trainer(tmp_path, steps=10, ckpt_every=10)
    out1 = t1.run()
    assert out1["final_step"] == 10
    # restart with a higher step budget: resumes from step 10, not 0
    t2 = _mk_trainer(tmp_path, steps=15, ckpt_every=10)
    params, opt_state, start = t2.init_or_restore()
    assert start == 10
    out2 = t2.run()
    assert out2["final_step"] == 15


def test_trainer_preemption_checkpoint(tmp_path):
    t = _mk_trainer(tmp_path, steps=1000, ckpt_every=1000)
    # inject preemption after a few steps via the log hook
    orig_step = t.step_fn
    count = {"n": 0}

    def counting_step(*a):
        count["n"] += 1
        if count["n"] == 4:
            t.request_preemption()
        return orig_step(*a)

    t.step_fn = counting_step
    out = t.run()
    assert out["preempted"]
    assert out["final_step"] == 4
    # the preemption checkpoint is restorable
    t2 = _mk_trainer(tmp_path, steps=1000)
    _, _, start = t2.init_or_restore()
    assert start == 4


def test_microbatch_overlap_matches_serial():
    """The hybrid-overlap accumulation must be numerically equivalent to the
    serial baseline (same buckets, different issue schedule)."""
    cfg = tiny_cfg()
    params = init_params(cfg, jax.random.PRNGKey(0))
    from repro.optim import adamw_init
    opt = AdamWConfig(lr=1e-3, warmup_steps=0, total_steps=10)
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0, cfg.vocab_size),
        "labels": jax.random.randint(jax.random.PRNGKey(2), (8, 32), 0, cfg.vocab_size),
    }
    outs = {}
    for mode in ("serial", "hybrid"):
        st = adamw_init(params)
        step = jax.jit(make_train_step(
            cfg, opt, None, StepConfig(microbatches=4, overlap=mode)))
        p2, _, m = step(params, st, batch)
        outs[mode] = (p2, m["loss"])
    np.testing.assert_allclose(float(outs["serial"][1]), float(outs["hybrid"][1]),
                               rtol=1e-6)
    for a, b in zip(jax.tree.leaves(outs["serial"][0]),
                    jax.tree.leaves(outs["hybrid"][0])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), rtol=1e-4, atol=1e-5)


def test_grad_compression_close_to_exact():
    cfg = tiny_cfg()
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = AdamWConfig(lr=1e-3, warmup_steps=0, total_steps=10)
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0, cfg.vocab_size),
        "labels": jax.random.randint(jax.random.PRNGKey(2), (8, 32), 0, cfg.vocab_size),
    }
    from repro.optim import adamw_init
    losses = {}
    for compress in (False, True):
        st = adamw_init(params)
        step = jax.jit(make_train_step(
            cfg, opt, None,
            StepConfig(microbatches=2, overlap="hybrid", compress_grads=compress)))
        _, _, m = step(params, st, batch)
        losses[compress] = float(m["loss"])
    assert losses[False] == pytest.approx(losses[True], rel=1e-5)
