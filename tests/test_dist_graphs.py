"""Tests for the multi-rank SLATE graphs and the rank-aware simulator."""

import pytest

from repro.core import Simulator
from repro.linalg.dist import build_dist_cholesky_graph, build_dist_panel_graph
from repro.linalg.tiles import CostModel


def test_dist_cholesky_graph_structure():
    g = build_dist_cholesky_graph(8, 96, ranks=2)
    g.validate()
    # every task is rank-pinned
    assert all(t.meta.get("rank") is not None for t in g)
    # one send per step; receivers on the other ranks
    sends = [t for t in g if t.name.startswith("bcast[")]
    recvs = [t for t in g if t.name.startswith("recv[")]
    assert len(sends) == 8
    assert len(recvs) == 8  # R-1 = 1 receiver per step


def test_rank_pools_do_not_cross_steal():
    g = build_dist_cholesky_graph(10, 96, ranks=2)
    sim = Simulator(8, ranks=2, policy="hybrid", seed=0)
    tr = sim.run(g)
    # tasks pinned to rank 0 must execute on workers 0..3, rank 1 on 4..7
    by_name = {t.name: t for t in g}
    for e in tr.events:
        t = by_name.get(e.label)
        if t is None:
            continue
        r = t.meta["rank"]
        assert e.worker // 4 == r, f"{e.label} ran on worker {e.worker}, rank {r}"


@pytest.mark.parametrize("kernel", ["lu", "qr"])
def test_dist_panel_graphs_complete_with_gangs(kernel):
    g = build_dist_panel_graph(kernel, 8, 96, ranks=2, panel_threads=3)
    tr = Simulator(8, ranks=2, policy="hybrid", mode="gang", seed=0).run(g)
    assert tr.makespan > 0
    # gang panel regions executed (panel ULT events present)
    assert any(e.kind == "panel" for e in tr.events)


def test_cholesky_policy_ordering_at_scale():
    """The paper's headline direction: hybrid <= history < random for
    distributed Cholesky at multi-rank scale."""
    cm = CostModel(comm_bw=3e9, comm_latency=20e-6)
    g = build_dist_cholesky_graph(64, 192, ranks=4, cost=cm)
    times = {}
    for pol in ("history", "random", "hybrid"):
        times[pol] = Simulator(40, ranks=4, policy=pol, seed=0).run(g).makespan
    assert times["hybrid"] < times["history"] * 0.95   # double-digit gain
    assert times["hybrid"] < times["random"]
    assert times["random"] < times["history"]          # overlap beats locality-only


def test_lu_insensitive_to_policy():
    """Paper Fig. 9: LU/QR are barely affected by victim selection (heavy
    gang panels dominate)."""
    g = build_dist_panel_graph("lu", 32, 192, ranks=4)
    times = {}
    for pol in ("history", "hybrid"):
        times[pol] = Simulator(32, ranks=4, policy=pol, seed=0).run(g).makespan
    rel = abs(times["history"] - times["hybrid"]) / times["history"]
    assert rel < 0.05
