"""Per-request lifecycle records and their roll-up.

The engine stamps each request's lifecycle (arrival, admission, first
token, every decode token, completion) into a :class:`RequestRecord`; a
:class:`ServingReport` aggregates the stream into the numbers the serving
bench reports: p50/p99 per-token latency, time-to-first-token percentiles,
sustained tok/s over the loaded span, mean batch occupancy and the pool's
warm-replay hit rate.  Timestamps are engine-clock seconds (wall clock, or
the deterministic virtual clock when the engine runs with ``step_time``).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import numpy as np


@dataclasses.dataclass
class RequestRecord:
    """One request's observed lifecycle."""

    rid: int
    arrival_s: float
    admitted_s: float = 0.0       # left the admission queue (prefill start)
    first_token_s: float = 0.0    # prefill done, first token out
    done_s: float = 0.0
    tokens: List[int] = dataclasses.field(default_factory=list)
    token_times_s: List[float] = dataclasses.field(default_factory=list)

    @property
    def ttft_s(self) -> float:
        """Time-to-first-token: arrival -> first generated token (includes
        any admission-queue wait — that is the point)."""
        return self.first_token_s - self.arrival_s

    @property
    def queue_wait_s(self) -> float:
        return self.admitted_s - self.arrival_s

    def token_latencies_s(self) -> List[float]:
        """Gaps between consecutive generated tokens (decode cadence)."""
        times = self.token_times_s
        return [times[i] - times[i - 1] for i in range(1, len(times))]


def _pct(values: List[float], q: float) -> float:
    return float(np.percentile(np.asarray(values), q)) if values else 0.0


@dataclasses.dataclass
class ServingReport:
    """Everything one engine drive produced.

    ``records`` maps rid -> :class:`RequestRecord` (completed requests
    only; the engine refuses to finish with requests stranded).  ``steps``
    counts decode-step graphs executed, ``warm_steps`` how many of them the
    pool served as warm replays (0 under a dynamic session),
    ``lane_steps`` the total lanes occupied across steps (occupancy =
    ``lane_steps / steps / max_batch``).  ``trace`` is the flight-recorder
    trace of the most heavily loaded step when the session traced.
    """

    records: Dict[int, RequestRecord]
    steps: int
    warm_steps: int
    lane_steps: int
    max_batch: int
    wall_s: float
    shape_counts: Dict[int, int] = dataclasses.field(default_factory=dict)
    trace: Optional[Any] = None            # repro.obs.trace.RuntimeTrace

    # ------------------------------------------------------------------
    @property
    def completed(self) -> int:
        return len(self.records)

    @property
    def total_tokens(self) -> int:
        return sum(len(r.tokens) for r in self.records.values())

    @property
    def warm_hit_rate(self) -> float:
        """Fraction of decode steps served as warm pool replays."""
        return self.warm_steps / self.steps if self.steps else 0.0

    @property
    def occupancy(self) -> float:
        """Mean fraction of batch slots occupied per decode step."""
        if not self.steps or not self.max_batch:
            return 0.0
        return self.lane_steps / (self.steps * self.max_batch)

    def token_latencies_s(self) -> List[float]:
        out: List[float] = []
        for rec in self.records.values():
            out.extend(rec.token_latencies_s())
        return out

    def sustained_tok_s(self) -> float:
        """Generated tokens per second over the loaded span (first arrival
        to last completion)."""
        recs = self.records.values()
        if not recs:
            return 0.0
        span = (max(r.done_s for r in recs)
                - min(r.arrival_s for r in recs))
        return self.total_tokens / span if span > 0 else 0.0

    def tokens_by_rid(self) -> Dict[int, List[int]]:
        """{rid: generated token ids} — the bit-identity comparison view."""
        return {rid: list(rec.tokens) for rid, rec in self.records.items()}

    # ------------------------------------------------------------------
    def summary(self) -> Dict[str, float]:
        """The bench-row numbers, all in ms / tok/s / rates."""
        lats = self.token_latencies_s()
        ttfts = [r.ttft_s for r in self.records.values()]
        return {
            "completed": float(self.completed),
            "tokens": float(self.total_tokens),
            "steps": float(self.steps),
            "p50_tok_ms": round(_pct(lats, 50) * 1e3, 3),
            "p99_tok_ms": round(_pct(lats, 99) * 1e3, 3),
            "ttft_p50_ms": round(_pct(ttfts, 50) * 1e3, 3),
            "ttft_p99_ms": round(_pct(ttfts, 99) * 1e3, 3),
            "tok_s": round(self.sustained_tok_s(), 1),
            "warm_hit_rate": round(self.warm_hit_rate, 3),
            "occupancy": round(self.occupancy, 3),
        }

    def describe(self) -> str:
        s = self.summary()
        return (f"served {self.completed} requests / {self.total_tokens} "
                f"tokens in {self.steps} steps ({self.wall_s:.3f}s): "
                f"per-token p50 {s['p50_tok_ms']:.2f} ms "
                f"p99 {s['p99_tok_ms']:.2f} ms, "
                f"ttft p50 {s['ttft_p50_ms']:.2f} ms, "
                f"{s['tok_s']:.0f} tok/s sustained, "
                f"warm-replay hit rate {self.warm_hit_rate:.0%}, "
                f"occupancy {self.occupancy:.0%}")
