"""Requests and their in-flight decode state.

A :class:`Request` is what a client submits: a prompt, a token budget, an
optional EOS token and the (workload-relative) arrival time.  A
:class:`RequestState` is the engine's in-flight view of one admitted
request: its private KV cache, current token, and generated-token history.
Each request decodes against *its own* cache, so the per-request token
stream is independent of how requests are batched together — the property
the bit-identical continuous-batching-vs-per-request tests lean on.
"""

from __future__ import annotations

import dataclasses
from typing import Any, List, Optional

import numpy as np


def token_id(tok: Any) -> int:
    """Collapse a sampled token (jax/numpy array of any 1-element shape, or
    a plain int) to a python int — the form stored in request records and
    compared against ``eos_token``.  Forces materialization, so the step
    timestamp taken right after it covers the real compute."""
    arr = np.asarray(tok)
    if arr.size != 1:
        raise ValueError(f"expected a single sampled token, got shape {arr.shape}")
    return int(arr.reshape(()))


@dataclasses.dataclass
class Request:
    """One client request.

    ``prompt`` is whatever the engine's ``prefill_fn`` accepts (for the LM
    path: an int array of token ids shaped ``(1, prompt_len)``).
    ``max_new_tokens`` counts *all* generated tokens, including the one the
    prefill's logits yield — a budget of 1 completes at admission without
    ever occupying a decode slot.  ``arrival_s`` is the arrival offset from
    the start of the workload; ``eos_token`` stops the request early when
    the sampler draws it.
    """

    rid: int
    prompt: Any
    max_new_tokens: int
    arrival_s: float = 0.0
    eos_token: Optional[int] = None

    def __post_init__(self) -> None:
        if self.max_new_tokens < 1:
            raise ValueError(
                f"request {self.rid}: max_new_tokens must be >= 1, "
                f"got {self.max_new_tokens}")


class RequestState:
    """In-flight decode state of one admitted request (one batch *lane*).

    ``cache``/``tok``/``logits`` are read and written only by this
    request's decode/sample tasks inside a step graph; the engine mutates
    the rest between steps.
    """

    __slots__ = ("request", "cache", "tok", "logits", "tokens")

    def __init__(self, request: Request, cache: Any, tok: Any):
        self.request = request
        self.cache = cache
        self.tok = tok
        self.logits: Any = None
        self.tokens: List[int] = []      # generated token ids, prefill first

    @property
    def rid(self) -> int:
        return self.request.rid

    def note_token(self, tok: Any) -> int:
        """Record a sampled token; returns its id."""
        tid = token_id(tok)
        self.tokens.append(tid)
        return tid

    def done(self) -> bool:
        """Budget exhausted or EOS drawn — the lane frees this step."""
        if len(self.tokens) >= self.request.max_new_tokens:
            return True
        eos = self.request.eos_token
        return eos is not None and bool(self.tokens) and self.tokens[-1] == eos
