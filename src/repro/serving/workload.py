"""Seeded streaming-traffic generators.

:class:`PoissonWorkload` models an open-loop request stream: exponential
inter-arrival times at a target ``rate`` (requests/s) and a ragged
per-request token budget.  Everything is drawn from one
``numpy.random.Generator`` seeded at construction, so two workloads built
with the same parameters produce *identical* requests — arrival times,
prompts and budgets — which is what makes the serving tests and benches
reproducible (and their token streams comparable bit-for-bit).
"""

from __future__ import annotations

from typing import List, Optional, Sequence, Tuple, Union

import numpy as np

from .request import Request

Span = Union[int, Tuple[int, int]]


def _as_span(value: Span, what: str) -> Tuple[int, int]:
    if isinstance(value, int):
        lo = hi = value
    else:
        lo, hi = value
    if lo < 1 or hi < lo:
        raise ValueError(f"{what} span must satisfy 1 <= lo <= hi, got {value}")
    return lo, hi


class PoissonWorkload:
    """A deterministic Poisson-arrival request stream.

    Parameters
    ----------
    rate:
        Mean arrival rate in requests/second (exponential inter-arrivals).
    n_requests:
        Stream length.
    seed:
        Seeds the generator; equal seeds give equal streams.
    prompt_len:
        Prompt length in tokens — an int, or an inclusive ``(lo, hi)`` span
        sampled per request.
    max_new_tokens:
        Per-request generation budget (incl. the prefill token) — int or
        inclusive span; the span is what drives batch-shape churn.
    vocab_size:
        Prompt token ids are drawn uniformly from ``[0, vocab_size)``.
    eos_token:
        Stamped onto every request (early exit when sampled); None disables.
    """

    def __init__(
        self,
        rate: float,
        n_requests: int,
        *,
        seed: int = 0,
        prompt_len: Span = 16,
        max_new_tokens: Span = (2, 8),
        vocab_size: int = 256,
        eos_token: Optional[int] = None,
    ):
        if rate <= 0.0:
            raise ValueError(f"arrival rate must be > 0, got {rate}")
        if n_requests < 1:
            raise ValueError(f"need >= 1 request, got {n_requests}")
        self.rate = float(rate)
        self.n_requests = int(n_requests)
        self.seed = int(seed)
        self.prompt_len = _as_span(prompt_len, "prompt_len")
        self.max_new_tokens = _as_span(max_new_tokens, "max_new_tokens")
        self.vocab_size = int(vocab_size)
        self.eos_token = eos_token
        rng = np.random.default_rng(self.seed)
        self.arrivals = np.cumsum(
            rng.exponential(1.0 / self.rate, self.n_requests))
        self._prompt_lens = rng.integers(
            self.prompt_len[0], self.prompt_len[1] + 1, self.n_requests)
        self._budgets = rng.integers(
            self.max_new_tokens[0], self.max_new_tokens[1] + 1,
            self.n_requests)
        self._prompts = [
            rng.integers(0, self.vocab_size, (1, int(n)), dtype=np.int32)
            for n in self._prompt_lens
        ]

    def requests(self) -> List[Request]:
        """The stream, in arrival order."""
        return [
            Request(rid=i, prompt=self._prompts[i],
                    max_new_tokens=int(self._budgets[i]),
                    arrival_s=float(self.arrivals[i]),
                    eos_token=self.eos_token)
            for i in range(self.n_requests)
        ]

    def total_budget(self) -> int:
        """Sum of per-request token budgets (upper bound on tokens served;
        exact when no request exits early on EOS)."""
        return int(self._budgets.sum())

    def describe(self) -> str:
        return (f"poisson(rate={self.rate}/s, n={self.n_requests}, "
                f"seed={self.seed}, prompt={self.prompt_len}, "
                f"budget={self.max_new_tokens})")


def constant_prompt_requests(
    arrivals: Sequence[float],
    budgets: Sequence[int],
    prompt: object,
    *,
    eos_token: Optional[int] = None,
) -> List[Request]:
    """Hand-built stream helper for tests: explicit arrival offsets and
    budgets, one shared prompt object."""
    if len(arrivals) != len(budgets):
        raise ValueError("arrivals and budgets must have equal length")
    return [
        Request(rid=i, prompt=prompt, max_new_tokens=int(b),
                arrival_s=float(a), eos_token=eos_token)
        for i, (a, b) in enumerate(zip(arrivals, budgets))
    ]
