"""Continuous-batching decode engine.

One :class:`ContinuousBatchingEngine` turns a stream of
:class:`~repro.serving.request.Request`\\ s into decode-step task graphs
executed by a caller-owned :class:`~repro.api.session.Session`:

* **admission queue** — a bounded :class:`~repro.core.taskgraph.Channel`.
  :meth:`submit` refuses (:class:`AdmissionFull`) or blocks when the queue
  is full; the queue drains only as decode slots free up, so backpressure
  propagates to the client with no extra machinery.
* **per-step dynamic batch composition** — every step serves whatever is
  in flight *right now*: new arrivals join as slots free, finished
  requests leave immediately (early exit on EOS or token budget), nobody
  waits for a fixed batch to fill or drain.
* **per-batch-shape graphs, built off the hot path** — the step graph for
  ``k`` active lanes is built (and its structural
  :func:`~repro.replay.graph_key` computed) exactly once, then reused:
  task bodies read the engine's current lane list, so the same graph
  object serves every step with ``k`` lanes.  The steady-state loop does
  no graph construction and no hashing — the precomputed key rides
  :meth:`Session.run(key=...) <repro.api.session.Session.run>`.
* **warm replay under shape churn** — with ``scheduler="pool"`` each lane
  count is one :class:`~repro.replay.ReplayPool` shape: the pool records a
  shape the first time the batch hits it and replays it every time the
  churn returns there, remapping recordings across worker counts
  (:func:`~repro.replay.remap.remap_recording`) when the cache was filled
  by a replica with a different core count.

Each shard of work is one request's private ``decode -> sample`` chain;
the step's join is a channel-fed suspendable gather frame (samples stream
their token as soon as it is drawn).  Because every request decodes
against its own KV cache, its token stream is independent of batch
composition — continuous batching is *bit-identical* to serving each
request alone.

The engine clock is wall time by default; passing ``step_time`` switches
to a deterministic virtual clock (each decode step advances time by that
amount) so tests can assert batch compositions and latency numbers
exactly.
"""

from __future__ import annotations

import itertools
import time
from collections import deque
from typing import Any, Callable, Deque, Dict, List, Optional, Tuple

from ..api.graph import Graph
from ..core.taskgraph import Channel
from .metrics import RequestRecord, ServingReport
from .request import Request, RequestState

DecodeFn = Callable[[Any, Any], Tuple[Any, Any]]   # (cache, tok) -> (cache, logits)
PrefillFn = Callable[[Any], Tuple[Any, Any]]       # prompt -> (cache, logits)
SampleFn = Callable[[Any], Any]                    # logits -> token

#: pool serve modes driven by a warm recording (the hit side of the
#: warm-replay hit rate; warmup/record/rerecord are dynamic serves).
#: ``compiled`` counts as warm: it is the promoted form of a warm replay.
_WARM_MODES = ("replay", "adopt", "remap", "compiled")


class AdmissionFull(RuntimeError):
    """The bounded admission queue refused a request (backpressure)."""


class _LaneFuseState:
    """Fuse-state adapter over the engine's live lane list: ``("cache", i)``
    / ``("tok", i)`` / ``("logits", i)`` resolve to lane ``i``'s in-flight
    :class:`~repro.serving.request.RequestState` *at call time* — lanes
    shift between steps, so the adapter must read through ``_active``, not
    bind states at graph-build time."""

    __slots__ = ("engine",)

    def __init__(self, engine: "ContinuousBatchingEngine"):
        self.engine = engine

    def __getitem__(self, k):
        return getattr(self.engine._active[k[1]], k[0])

    def __setitem__(self, k, v):
        setattr(self.engine._active[k[1]], k[0], v)


class ContinuousBatchingEngine:
    """Request-level continuous batching over a ``Session`` (see module
    docstring).

    Parameters
    ----------
    session:
        Caller-owned :class:`~repro.api.session.Session` executing the
        decode-step graphs.  ``scheduler="pool"`` gives warm replays per
        batch shape; ``"dynamic"`` is the scheduling baseline.  With
        ``max_batch=1`` the engine degrades to FCFS per-request serving —
        the baseline the benches compare against.
    decode_fn / prefill_fn / sample_fn:
        ``decode_fn(cache, tok) -> (cache, logits)`` and
        ``prefill_fn(prompt) -> (cache, logits)`` close over model params;
        ``sample_fn(logits) -> token`` defaults to the LM greedy sampler.
    max_batch:
        Decode-slot count (max lanes per step graph).
    admission_capacity:
        Bounded admission-queue depth (default ``2 * max_batch``).
    step_time:
        None (default): wall-clock timestamps.  A float switches to the
        deterministic virtual clock: each decode step advances engine time
        by exactly this many seconds.
    procs / fns_ref:
        ``procs=N`` shards :meth:`run` across ``N`` worker *processes*
        (the session's :class:`~repro.mp.ProcessPool`): requests route by
        ``rid % N`` to child-local engines, each with its own interpreter
        — no GIL sharing — and per-request streams stay bit-identical
        because every request decodes against its own KV cache regardless
        of which child batches it.  ``fns_ref`` is then required: a
        module-level factory reference (``"module:qualname"`` or
        ``(ref, kwargs)``) returning ``(decode_fn, prefill_fn[, sample_fn])``
        — code ships by import, never by pickle.  A child that dies
        mid-stream has its remaining requests served by a fresh in-process
        engine (same fns), so no request is ever dropped.
    """

    #: process-wide unique serve-stream ids (several engines may share one
    #: session's pool)
    _mp_stream_ids = itertools.count(1)

    def __init__(
        self,
        session: Any,
        decode_fn: DecodeFn,
        prefill_fn: PrefillFn,
        *,
        max_batch: int = 4,
        admission_capacity: Optional[int] = None,
        sample_fn: Optional[SampleFn] = None,
        step_time: Optional[float] = None,
        procs: Optional[int] = None,
        fns_ref: Any = None,
    ):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        if procs is not None:
            if procs < 1:
                raise ValueError(f"procs must be >= 1 (or None), got {procs}")
            if fns_ref is None:
                raise ValueError(
                    "procs=N needs fns_ref: child processes rebuild the "
                    "engine fns from a module-level factory reference "
                    "(callables do not cross a spawn boundary)")
        capacity = (2 * max_batch if admission_capacity is None
                    else admission_capacity)
        if capacity < 1:
            raise ValueError(
                f"admission_capacity must be >= 1, got {capacity}")
        if sample_fn is None:
            from ..models.serving import greedy_sample
            sample_fn = greedy_sample
        self.session = session
        self.max_batch = max_batch
        self.step_time = step_time
        self.procs = procs
        self.fns_ref = fns_ref
        #: per-proc summaries / fallback accounting of the last mp run
        self.mp_stats: Optional[Dict[str, Any]] = None
        self._decode_fn = decode_fn
        self._prefill_fn = prefill_fn
        self._sample_fn = sample_fn
        self._admission = Channel("serve.admission", capacity=capacity)

        self._active: List[RequestState] = []
        self._records: Dict[int, RequestRecord] = {}
        self._done = 0
        self._graphs: Dict[int, Tuple[Graph, Any]] = {}   # k -> (graph, key)
        self._step_tokens: List[Any] = []
        self._steps = 0
        self._warm_steps = 0
        self._lane_steps = 0
        self._shape_counts: Dict[int, int] = {}
        self._trace: Optional[Any] = None
        self._trace_k = 0
        self._vnow = 0.0
        self._t0 = time.perf_counter()

    # ------------------------------------------------------------------
    # clock
    def _now(self) -> float:
        if self.step_time is not None:
            return self._vnow
        return time.perf_counter() - self._t0

    def _reset_clock(self) -> None:
        self._vnow = 0.0
        self._t0 = time.perf_counter()

    # ------------------------------------------------------------------
    # admission (client side)
    @property
    def admission_capacity(self) -> int:
        return int(self._admission.capacity)

    def queue_depth(self) -> int:
        return len(self._admission)

    def in_flight(self) -> int:
        return len(self._active)

    def submit(self, request: Request, *, block: bool = False,
               timeout: Optional[float] = None) -> None:
        """Enqueue ``request`` for admission.  When the bounded queue is
        full: raise :class:`AdmissionFull` (default), or with ``block``
        wait for a decode step to drain a slot — up to ``timeout`` seconds
        (forever when None).  Thread-safe."""
        if request.rid in self._records:
            raise ValueError(f"duplicate request id {request.rid}")
        self._records[request.rid] = RequestRecord(
            rid=request.rid, arrival_s=request.arrival_s)
        deadline = (None if timeout is None
                    else time.monotonic() + timeout)
        while not self._admission.try_send(request):
            if not block or (deadline is not None
                             and time.monotonic() >= deadline):
                del self._records[request.rid]
                raise AdmissionFull(
                    f"admission queue full ({self.admission_capacity} "
                    f"waiting, {len(self._active)}/{self.max_batch} lanes "
                    "busy); retry after a decode step frees a slot")
            time.sleep(5e-4)

    def try_submit(self, request: Request) -> bool:
        """Non-raising :meth:`submit`; False when the queue refused it."""
        try:
            self.submit(request)
            return True
        except AdmissionFull:
            return False

    # ------------------------------------------------------------------
    # per-shape step graphs (built once per lane count, off the hot path)
    def _graph_for(self, k: int) -> Tuple[Graph, Any]:
        cached = self._graphs.get(k)
        if cached is not None:
            return cached
        from ..compile.fuse import FuseSpec

        g = Graph(f"serve_step[{k}]")
        g.fuse_state = _LaneFuseState(self)
        tokens = Channel(f"serve.tokens[{k}]")
        for i in range(k):
            def _decode(i=i):
                st = self._active[i]
                st.cache, st.logits = self._decode_fn(st.cache, st.tok)
                return st.logits

            # fusible for the pool's warm -> compiled promotion: decode_fn
            # is the pure kernel (usually pre-jitted); jit_safe=False so the
            # compiled driver calls it exactly like the dynamic body does
            dec = g.add(_decode, name=f"decode{i}", kind="compute", cost=1.0,
                        fuse=FuseSpec(self._decode_fn,
                                      (("cache", i), ("tok", i)),
                                      (("cache", i), ("logits", i)),
                                      result_key=("logits", i),
                                      jit_safe=False))

            def _sample(logits, i=i):
                st = self._active[i]
                st.tok = self._sample_fn(logits)
                tokens.send((i, st.tok))
                return st.tok

            g.add(_sample, dec, name=f"sample{i}", kind="compute", cost=0.1)

        def _gather(ctx):
            # suspendable frame: assemble lane tokens as they stream in,
            # never pinning a worker while the remaining lanes decode
            out: List[Any] = [None] * k
            for _ in range(k):
                i, tok = yield ctx.recv(tokens)
                out[i] = tok
            self._step_tokens = out
            return out

        g.add(_gather, name="gather", kind="comm", cost=0.05)
        from ..replay.graph_key import graph_key
        entry = (g, graph_key(g))
        self._graphs[k] = entry
        return entry

    def prime(self, up_to: Optional[int] = None) -> None:
        """Pre-build the step graphs (and their structural keys) for lane
        counts ``1..up_to`` (default ``max_batch``) so the serving loop
        never constructs or hashes a graph on the request path."""
        for k in range(1, (up_to or self.max_batch) + 1):
            self._graph_for(k)

    # ------------------------------------------------------------------
    # the decode loop
    def _admit(self, now: float) -> bool:
        """Fill free lanes from the admission queue; prefill each admitted
        request (its first token comes from the prefill logits).  Requests
        whose budget is 1 token (or whose first token is EOS) complete
        here without ever occupying a decode slot."""
        admitted = False
        while len(self._active) < self.max_batch:
            ok, req = self._admission.try_recv()
            if not ok:
                break
            admitted = True
            rec = self._records[req.rid]
            rec.admitted_s = now
            cache, logits = self._prefill_fn(req.prompt)
            st = RequestState(req, cache, self._sample_fn(logits))
            tid = st.note_token(st.tok)
            t_first = self._now()
            rec.first_token_s = t_first
            rec.tokens.append(tid)
            rec.token_times_s.append(t_first)
            if st.done():
                rec.done_s = t_first
                self._done += 1
            else:
                self._active.append(st)
        return admitted

    def step(self) -> bool:
        """Admit arrivals into free lanes, then run one decode step over
        the in-flight set.  Returns False when there was nothing to do."""
        admitted = self._admit(self._now())
        if not self._active:
            return admitted
        k = len(self._active)
        graph, key = self._graph_for(k)
        report = self.session.run(graph, key=key)
        if self.step_time is not None:
            self._vnow += self.step_time
        now = self._now()
        self._steps += 1
        self._lane_steps += k
        self._shape_counts[k] = self._shape_counts.get(k, 0) + 1
        if report.stats.get("pool_mode") in _WARM_MODES:
            self._warm_steps += 1
        if report.trace is not None and k >= self._trace_k:
            # keep the most heavily loaded step's trace: the steady-state
            # window the bench exports
            self._trace, self._trace_k = report.trace, k
        still: List[RequestState] = []
        for i, st in enumerate(self._active):
            tid = st.note_token(self._step_tokens[i])
            rec = self._records[st.rid]
            rec.tokens.append(tid)
            rec.token_times_s.append(now)
            if st.done():
                rec.done_s = now
                self._done += 1
            else:
                still.append(st)
        self._active = still
        return True

    # ------------------------------------------------------------------
    # workload driving
    def run(self, requests: Any, *, timeout: float = 600.0) -> ServingReport:
        """Drive a whole request stream to completion: submit each request
        when its ``arrival_s`` comes due (arrivals that hit a full
        admission queue wait — their queue delay is the backpressure
        showing up in TTFT), step the decode loop until every request has
        finished, and return the :class:`ServingReport`."""
        if self.procs is not None:
            return self._run_mp(requests, timeout=timeout)
        pending: Deque[Request] = deque(
            sorted(requests, key=lambda r: (r.arrival_s, r.rid)))
        self._reset_clock()
        t_limit = time.monotonic() + timeout
        while pending or len(self._admission) or self._active:
            if time.monotonic() > t_limit:
                raise TimeoutError(
                    f"serving loop exceeded {timeout}s with "
                    f"{len(pending)} pending / {self.in_flight()} in flight")
            now = self._now()
            while pending and pending[0].arrival_s <= now:
                if not self.try_submit(pending[0]):
                    break                      # queue full: backpressure
                pending.popleft()
            worked = self.step()
            if not worked and pending and not len(self._admission):
                # idle gap before the next arrival: jump (virtual clock)
                # or nap (wall clock) instead of spinning
                nxt = pending[0].arrival_s
                if self.step_time is not None:
                    self._vnow = max(self._vnow, nxt)
                else:
                    gap = nxt - self._now()
                    if gap > 0:
                        time.sleep(min(gap, 2e-3))
        return self.report()

    # ------------------------------------------------------------------
    # sharded multi-process serving
    def _run_mp(self, requests: Any, *, timeout: float) -> ServingReport:
        """Drive the stream across the session's process pool.

        Requests shard by ``rid % procs`` into per-child serve streams;
        the parent releases each request when its ``arrival_s`` comes due
        (parent **wall** clock — children may run a virtual clock for
        deterministic latency numbers, but admission ordering is real
        time), throttled to a per-child outstanding cap of
        ``admission_capacity + max_batch`` on top of the child's own
        bounded queue.  A child-side :class:`AdmissionFull` crosses the
        pipe as a failed future and the request is retried; a dead child
        moves its unfinished shard to an in-process fallback engine.  The
        merged report carries every request's record plus the summed child
        step counters."""
        from ..mp.futures import WorkerDied, WorkerError

        pool = self.session.process_pool(self.procs)
        n = pool.n_procs
        sid = next(self._mp_stream_ids)
        open_futs = pool.broadcast("serve_open", {
            "stream": sid,
            "fns_ref": self.fns_ref,
            "engine": {"max_batch": self.max_batch,
                       "admission_capacity": self.admission_capacity,
                       "step_time": self.step_time},
        })
        live = set()
        for p, fut in enumerate(open_futs):
            try:
                fut.result(timeout=60.0)
                live.add(p)
            except (WorkerDied, WorkerError):
                pass
        if not live:
            raise RuntimeError(
                f"no live worker process accepted serve stream {sid}")

        shards: Dict[int, Deque[Request]] = {p: deque() for p in range(n)}
        for req in sorted(requests, key=lambda r: (r.arrival_s, r.rid)):
            shards[req.rid % n].append(req)
        retries: Dict[int, Deque[Request]] = {p: deque() for p in range(n)}
        outstanding: Dict[int, int] = {p: 0 for p in range(n)}
        peak: Dict[int, int] = {p: 0 for p in range(n)}
        cap = self.admission_capacity + self.max_batch
        in_flight: List[Tuple[Any, int, Request]] = []
        records: Dict[int, RequestRecord] = {}
        fallback: List[Request] = []
        dead: List[int] = []

        def _bury(p: int) -> None:
            """Move everything worker ``p`` still owes to the fallback.
            Records the death even when ``p`` never went live (a worker
            killed while its serve_open was still in flight)."""
            live.discard(p)
            if p not in dead:
                dead.append(p)
            fallback.extend(retries[p])
            retries[p].clear()
            fallback.extend(shards[p])
            shards[p].clear()

        for p in range(n):
            if p not in live:
                _bury(p)

        t0 = time.perf_counter()
        t_limit = time.monotonic() + timeout
        while any(shards.values()) or any(retries.values()) or in_flight:
            if time.monotonic() > t_limit:
                raise TimeoutError(
                    f"mp serving loop exceeded {timeout}s with "
                    f"{len(in_flight)} submits outstanding")
            now = time.perf_counter() - t0
            progressed = False
            for p in list(live):
                queue = retries[p] if retries[p] else shards[p]
                while (queue and outstanding[p] < cap
                       and (queue is retries[p]
                            or queue[0].arrival_s <= now)):
                    req = queue.popleft()
                    fut = pool.request(
                        p, "serve_submit", {"stream": sid, "request": req})
                    in_flight.append((fut, p, req))
                    outstanding[p] += 1
                    peak[p] = max(peak[p], outstanding[p])
                    progressed = True
                    queue = retries[p] if retries[p] else shards[p]
            still: List[Tuple[Any, int, Request]] = []
            for fut, p, req in in_flight:
                if not fut.done():
                    still.append((fut, p, req))
                    continue
                outstanding[p] -= 1
                progressed = True
                try:
                    rec = fut.result(timeout=0)
                except WorkerError as e:
                    if e.kind == "AdmissionFull":
                        retries[p].append(req)   # backpressure: resubmit
                    else:
                        _bury(p)
                        fallback.append(req)
                except WorkerDied:
                    _bury(p)
                    fallback.append(req)
                else:
                    records[rec.rid] = rec
            in_flight = still
            if not progressed:
                time.sleep(1e-3)

        summaries: List[Dict[str, Any]] = []
        for p in sorted(live):
            try:
                summaries.append(pool.request(
                    p, "serve_close", {"stream": sid}).result(timeout=60.0))
            except (WorkerDied, WorkerError):
                dead.append(p)

        steps = sum(s["steps"] for s in summaries)
        warm_steps = sum(s["warm_steps"] for s in summaries)
        lane_steps = sum(s["lane_steps"] for s in summaries)
        shape_counts: Dict[int, int] = {}
        for s in summaries:
            for k, c in s["shape_counts"].items():
                shape_counts[k] = shape_counts.get(k, 0) + c
        if fallback:
            # a dead child's stranded requests are re-served in-process:
            # per-request KV caches make the token streams identical to
            # what the child would have produced
            rescue = ContinuousBatchingEngine(
                self.session, self._decode_fn, self._prefill_fn,
                sample_fn=self._sample_fn, max_batch=self.max_batch,
                admission_capacity=self.admission_capacity,
                step_time=self.step_time)
            report = rescue.run(fallback, timeout=timeout)
            records.update(report.records)
            steps += report.steps
            warm_steps += report.warm_steps
            lane_steps += report.lane_steps
            for k, c in report.shape_counts.items():
                shape_counts[k] = shape_counts.get(k, 0) + c
        self.mp_stats = {
            "stream": sid,
            "per_proc": summaries,
            "dead": sorted(set(dead)),
            "fallback": len(fallback),
            "peak_outstanding": peak,
            "cap": cap,
        }
        return ServingReport(
            records=records,
            steps=steps,
            warm_steps=warm_steps,
            lane_steps=lane_steps,
            max_batch=self.max_batch,
            wall_s=time.perf_counter() - t0,
            shape_counts=shape_counts,
            trace=None,
        )

    def report(self) -> ServingReport:
        """Snapshot of everything served so far (complete requests only
        appear with their final token streams)."""
        if self._done != len(self._records):
            stranded = [rid for rid, rec in self._records.items()
                        if not rec.done_s]
            raise RuntimeError(
                f"{len(stranded)} request(s) still in flight: "
                f"{stranded[:8]}")
        return ServingReport(
            records=dict(self._records),
            steps=self._steps,
            warm_steps=self._warm_steps,
            lane_steps=self._lane_steps,
            max_batch=self.max_batch,
            wall_s=time.perf_counter() - self._t0,
            shape_counts=dict(self._shape_counts),
            trace=self._trace,
        )
