"""Request-level continuous-batching serving layer.

``examples/serve_lm.py`` historically decoded one fixed batch in lockstep —
every request started together, padded to the slowest finisher.  Real
traffic is a *stream*: requests arrive at random times, want different
numbers of tokens, and leave as soon as they are done.  This package serves
that stream on the primitives the runtime already has:

* :class:`~repro.serving.workload.PoissonWorkload` — a seeded,
  deterministic open-loop arrival process (Poisson inter-arrivals, ragged
  per-request token budgets);
* :class:`~repro.serving.engine.ContinuousBatchingEngine` — a bounded
  :class:`~repro.core.taskgraph.Channel` admission queue (backpressure for
  free: a full queue refuses/blocks submitters), per-step dynamic batch
  composition from the in-flight set, per-request early exit on EOS /
  max-token budget, and per-batch-shape decode-step graphs served through a
  :class:`~repro.api.session.Session` — with ``scheduler="pool"`` most
  steps replay a warm recording even as the batch size churns;
* :class:`~repro.serving.metrics.ServingReport` — per-request lifecycle
  records rolled up into p50/p99 per-token latency, time-to-first-token,
  sustained tok/s and the pool's warm-replay hit rate.
"""

from .engine import AdmissionFull, ContinuousBatchingEngine
from .metrics import RequestRecord, ServingReport
from .request import Request, RequestState
from .workload import PoissonWorkload

__all__ = [
    "AdmissionFull",
    "ContinuousBatchingEngine",
    "PoissonWorkload",
    "Request",
    "RequestRecord",
    "RequestState",
    "ServingReport",
]
