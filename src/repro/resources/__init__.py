"""Declarative resources & conflict-aware scheduling (ROADMAP item 3).

QuickSched-style scheduling with dependencies *and conflicts*: a task may
declare resources it ``uses`` (exclusively) or ``uses_shared`` (reader
mode) with no ordering edge to the other users.  The
:class:`ResourceArbiter` grants every task's full resource set atomically
at dispatch time — a task never holds one resource while waiting for
another, so conflict scheduling can never deadlock — and defers contended
tasks on a FIFO-fair wait list instead of parking the worker.
"""

from .arbiter import ResourceArbiter, grants_by_resource
from .handle import Resource

__all__ = ["Resource", "ResourceArbiter", "grants_by_resource"]
