"""The declarative resource handle.

A :class:`Resource` names a piece of shared state — a KV-cache page, an
optimizer shard, a checkpoint directory — that tasks may use without an
inherent order.  The handle itself carries no runtime state: holders,
wait queues and grant logs live in the per-run
:class:`~repro.resources.arbiter.ResourceArbiter`, so one handle can be
declared across many graphs and many runs concurrently.
"""

from __future__ import annotations

import itertools

# process-wide monotonic uids (names are user-chosen and may collide; the
# flight recorder and arbiter diagnostics tag events with the uid)
_resource_uids = itertools.count()


class Resource:
    """A named, optionally counted resource tasks can declare via
    ``g.add(fn, uses=[res])`` (exclusive) or ``uses_shared=[res]``.

    ``capacity=N`` makes the resource a counting semaphore: up to ``N``
    exclusive holders at once (a page pool, a bounded writer slot set).
    Shared (reader) holders are unlimited among themselves but mutually
    exclusive with any exclusive holder, regardless of capacity.
    """

    __slots__ = ("name", "capacity", "uid", "__weakref__")

    def __init__(self, name: str = "resource", capacity: int = 1):
        if capacity < 1:
            raise ValueError(f"resource capacity must be >= 1, got {capacity}")
        self.name = name
        self.capacity = capacity
        self.uid = next(_resource_uids)

    def __repr__(self) -> str:
        cap = f", capacity={self.capacity}" if self.capacity != 1 else ""
        return f"Resource({self.name!r}{cap})@r{self.uid}"
