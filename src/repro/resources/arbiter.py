"""Conflict-aware resource arbitration (deadlock-free by construction).

The arbiter grants a task's *entire* declared resource set atomically at
dispatch time (all-or-nothing): a task never holds one resource while
waiting for another, so there is no hold-and-wait and conflict scheduling
alone can never deadlock — the classic QuickSched argument.  Contended
tasks are deferred on a single global FIFO wait list and re-granted
fairly on release: a waiter is overtaken only by tasks whose resource
sets are disjoint from every earlier waiter's, so no task starves.

Two modes share the holder accounting:

* **dynamic** — grants in arrival order, defers on contention, and logs
  the global grant order (the ``resource_grants`` section of a
  :class:`~repro.replay.recording.Recording`);
* **pinned** (replay / compiled) — a recorded grant order is replayed:
  a task is grantable only when it is at the head of the recorded
  per-resource grant queue *and* capacity is free, which reproduces the
  recorded acquisition order bit-identically.  Per-resource queues are
  derived from one recorded total order, so they can never cross-block.
"""

from __future__ import annotations

import threading
from collections import deque
from typing import Deque, Dict, List, Optional, Sequence, Tuple

from .handle import Resource

#: a task's deduplicated declaration: ((rindex, shared), ...)
Needs = Tuple[Tuple[int, bool], ...]


def task_needs(graph, tid: int) -> Needs:
    """The (rindex, shared) pairs task ``tid`` declares, deduplicated
    (exclusive wins when a resource appears in both lists)."""
    task = graph.tasks[tid]
    index = graph.resource_index()
    out: Dict[int, bool] = {}
    for r in getattr(task, "uses_shared", ()):
        out[index[id(r)]] = True
    for r in getattr(task, "uses", ()):
        out[index[id(r)]] = False
    return tuple(sorted(out.items()))


def grants_by_resource(graph, grants: Sequence[int]) -> Dict[int, List[int]]:
    """Derive per-resource grant sequences from a global grant order —
    the determinism contract replay enforces and tests compare."""
    out: Dict[int, List[int]] = {i: [] for i in range(len(graph.resources))}
    for tid in grants:
        for rindex, _shared in task_needs(graph, tid):
            out[rindex].append(tid)
    return out


class ResourceArbiter:
    """Per-run grant state for one dispatch.  All methods are thread-safe
    under one internal lock (grants are rare relative to task dispatch:
    only resource-declaring tasks ever enter the arbiter)."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self.active = False          # any task of the current graph declares
        self._resources: List[Resource] = []
        self._needs: Dict[int, Needs] = {}
        self._excl: List[int] = []       # exclusive holders per rindex
        self._shared: List[int] = []     # shared holders per rindex
        self._caps: List[int] = []
        self._held: Dict[int, Needs] = {}
        self._waiting: List[int] = []    # global FIFO of deferred tids
        self._waiting_set: set = set()
        self._grants: List[int] = []     # global grant order (tids)
        # pinned (replay) mode: per-resource recorded grant queues
        self._pinned: Optional[Dict[int, Deque[int]]] = None

    # ------------------------------------------------------------------
    def begin(self, graph, pinned_order: Optional[Sequence[int]] = None) -> None:
        """Reset for one run of ``graph``.  ``pinned_order`` switches the
        arbiter to replay mode enforcing that recorded global grant order."""
        with self._lock:
            self._resources = list(getattr(graph, "resources", ()))
            n = len(self._resources)
            self._needs = {}
            if n:
                for t in graph.tasks:
                    if getattr(t, "uses", ()) or getattr(t, "uses_shared", ()):
                        self._needs[t.tid] = task_needs(graph, t.tid)
            self.active = bool(self._needs)
            self._excl = [0] * n
            self._shared = [0] * n
            self._caps = [r.capacity for r in self._resources]
            self._held = {}
            self._waiting = []
            self._waiting_set = set()
            self._grants = []
            if pinned_order is None:
                self._pinned = None
            else:
                pinned: Dict[int, Deque[int]] = {i: deque() for i in range(n)}
                for tid in pinned_order:
                    for rindex, _shared in self._needs.get(tid, ()):
                        pinned[rindex].append(tid)
                self._pinned = pinned

    # ------------------------------------------------------------------
    # queries (read-only; safe for steal-awareness checks)
    def needs(self, tid: int) -> Needs:
        return self._needs.get(tid, ())

    def holds(self, tid: int) -> bool:
        return tid in self._held

    def held_count(self) -> int:
        with self._lock:
            return len(self._held)

    def waiting_count(self) -> int:
        with self._lock:
            return len(self._waiting)

    def grant_log(self) -> List[int]:
        with self._lock:
            return list(self._grants)

    def grant_orders(self) -> Dict[int, List[int]]:
        """Per-resource grant sequences of the run so far (the order
        compared bit-for-bit across dynamic, replay and compiled runs)."""
        with self._lock:
            out: Dict[int, List[int]] = {
                i: [] for i in range(len(self._resources))}
            for tid in self._grants:
                for rindex, _shared in self._needs.get(tid, ()):
                    out[rindex].append(tid)
            return out

    def pinned_heads(self) -> List[int]:
        """Pinned mode: the next recorded grantee of each resource queue
        (deduplicated) — replay's post-release wakeup targets."""
        with self._lock:
            if self._pinned is None:
                return []
            heads: List[int] = []
            for q in self._pinned.values():
                if q and q[0] not in heads:
                    heads.append(q[0])
            return heads

    def would_defer(self, tid: int) -> bool:
        """True when acquiring now would defer ``tid`` — the conflict-aware
        steal check (racy by nature: a definitive answer is acquire time's,
        but a thief should not burn a steal on a likely-deferred task)."""
        needs = self._needs.get(tid)
        if needs is None or tid in self._held:
            return False
        with self._lock:
            return not self._grantable(tid, needs)

    def runnable_now(self, tid: int) -> bool:
        """Pinned-mode gating for replay run-ahead/fallback: can ``tid``
        be granted right now (or does it hold / declare nothing)?"""
        needs = self._needs.get(tid)
        if needs is None or tid in self._held:
            return True
        with self._lock:
            return self._grantable(tid, needs)

    # ------------------------------------------------------------------
    # grant / release
    def _grantable(self, tid: int, needs: Needs) -> bool:
        """Caller holds the lock.  Availability + (pinned) head-of-queue +
        (dynamic) FIFO fairness against earlier waiters."""
        for rindex, shared in needs:
            if self._pinned is not None:
                q = self._pinned[rindex]
                if not q or q[0] != tid:
                    return False
            if shared:
                if self._excl[rindex] > 0:
                    return False
            else:
                if (self._shared[rindex] > 0
                        or self._excl[rindex] >= self._caps[rindex]):
                    return False
        if self._pinned is None and self._waiting:
            # fairness: an arrival may not overtake an earlier waiter that
            # shares any of its resources (head-of-line FIFO per resource)
            mine = {rindex for rindex, _ in needs}
            for other in self._waiting:
                if other == tid:
                    break
                if any(rindex in mine
                       for rindex, _ in self._needs.get(other, ())):
                    return False
        return True

    def _grant(self, tid: int, needs: Needs) -> None:
        for rindex, shared in needs:
            if shared:
                self._shared[rindex] += 1
            else:
                self._excl[rindex] += 1
            if self._pinned is not None:
                self._pinned[rindex].popleft()
        self._held[tid] = needs
        self._grants.append(tid)

    def try_acquire(self, tid: int) -> bool:
        """Grant ``tid``'s full resource set atomically.  On contention:
        dynamic mode defers the task on the FIFO wait list (the caller
        must not run it — :meth:`release` hands it back when granted);
        pinned mode returns False with no side effects (replay's stall
        machinery retries).  Idempotent for already-granted tids."""
        needs = self._needs.get(tid)
        if needs is None:
            return True
        with self._lock:
            if tid in self._held:
                return True
            if self._grantable(tid, needs):
                self._grant(tid, needs)
                return True
            if self._pinned is None and tid not in self._waiting_set:
                self._waiting.append(tid)
                self._waiting_set.add(tid)
            return False

    def release(self, tid: int) -> List[int]:
        """Release ``tid``'s grants.  Dynamic mode scans the wait list in
        FIFO order, grants every now-grantable waiter (a blocked earlier
        waiter shadows later overlapping ones — fairness), and returns the
        newly granted tids for the dispatch to re-queue.  No-op for tasks
        that hold nothing."""
        with self._lock:
            needs = self._held.pop(tid, None)
            if needs is None:
                return []
            for rindex, shared in needs:
                if shared:
                    self._shared[rindex] -= 1
                else:
                    self._excl[rindex] -= 1
            if self._pinned is not None or not self._waiting:
                return []
            granted: List[int] = []
            shadow: set = set()
            still_waiting: List[int] = []
            for waiter in self._waiting:
                wneeds = self._needs[waiter]
                overlaps = any(r in shadow for r, _ in wneeds)
                if not overlaps and self._grantable_plain(wneeds):
                    self._grant(waiter, wneeds)
                    self._waiting_set.discard(waiter)
                    granted.append(waiter)
                else:
                    still_waiting.append(waiter)
                    shadow.update(r for r, _ in wneeds)
            self._waiting = still_waiting
            return granted

    def _grantable_plain(self, needs: Needs) -> bool:
        """Availability only (caller holds the lock; fairness is the
        release scan's shadow set)."""
        for rindex, shared in needs:
            if shared:
                if self._excl[rindex] > 0:
                    return False
            else:
                if (self._shared[rindex] > 0
                        or self._excl[rindex] >= self._caps[rindex]):
                    return False
        return True

    def abort(self) -> List[int]:
        """Drop every grant and waiter (run abort / reuse).  Returns the
        tids that were still deferred so the dispatch can rebalance its
        suspension accounting."""
        with self._lock:
            waiting = list(self._waiting)
            n = len(self._resources)
            self._excl = [0] * n
            self._shared = [0] * n
            self._held = {}
            self._waiting = []
            self._waiting_set = set()
            return waiting
