from .steps import make_eval_step, make_train_step
from .trainer import Trainer, TrainerConfig

__all__ = ["Trainer", "TrainerConfig", "make_eval_step", "make_train_step"]
