"""Train/eval steps with scheduler-driven communication/computation overlap.

The paper's Fig. 2 scenario, realized in XLA: with gradient accumulation,
each microbatch's gradient bucket needs a data-parallel all-reduce.  A
*history*-style schedule runs all computes then all reduces (serialized);
the *hybrid* schedule issues bucket i's all-reduce during microbatch i+1's
compute.  We freeze the schedule with the paper's list scheduler
(`repro.core.static_schedule`) and realize it structurally: the scan body
carries the previous microbatch's un-reduced gradients and issues their
psum alongside the current microbatch's compute — XLA's latency-hiding
scheduler then overlaps them (no data dependence).

The DP axes are *manual* (shard_map over ("pod","data")) so the gradient
all-reduce is an explicit `lax.psum` whose bytes are visible to the dry-run
collective accounting; the TP axis ("model") stays automatic (GSPMD) inside.

Optional gradient compression: bf16 wire format with fp32 error feedback
(halves DP all-reduce bytes; error feedback keeps the accumulated gradient
unbiased across steps)."""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax import lax

from ..models import lm
from ..models.config import ModelConfig
from ..optim.adamw import AdamWConfig, adamw_update


@dataclasses.dataclass(frozen=True)
class StepConfig:
    microbatches: int = 1
    overlap: str = "hybrid"        # "hybrid" (paper) | "serial" (baseline)
    compress_grads: bool = False   # bf16 wire + f32 error feedback
    remat: bool = True


def _local_loss_fn(cfg: ModelConfig, ctx):
    """Per-DP-shard local-mean loss (reduction over DP happens explicitly in
    the step; TP-internal psums still occur inside)."""
    def fn(params, batch):
        # inside manual DP shard_map the ctx batch axes are manual; the
        # vocab-sharded CE's psums over batch axes must be skipped -> use the
        # local CE (ctx_local strips batch axes from its shard_map).
        return lm.loss_fn(params, cfg, batch, ctx, remat=True)
    return fn


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig, ctx,
                    step_cfg: StepConfig = StepConfig(),
                    grad_pspecs=None):
    """Returns ``step(params, opt_state, batch) -> (params, opt_state,
    metrics)`` ready for jit with shardings from repro.launch.

    ``grad_pspecs``: param-tree of PartitionSpecs; when given, gradients are
    sharding-constrained to the param layout immediately after the backward
    pass — without this XLA's while-loop propagation can leave the scan's
    gradient accumulator replicated (a ~param-bytes x4 per-device temp)."""
    micro = step_cfg.microbatches

    def _constrain(grads):
        if grad_pspecs is None:
            return grads
        return jax.tree.map(
            lambda g, s: jax.lax.with_sharding_constraint(g, s),
            grads, grad_pspecs,
            is_leaf=lambda x: not isinstance(x, dict))

    def single(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: lm.loss_fn(p, cfg, batch, ctx, remat=step_cfg.remat))(params)
        grads = _constrain(grads)
        new_params, new_opt, info = adamw_update(opt_cfg, params, grads, opt_state)
        return new_params, new_opt, {"loss": loss, **info}

    if micro == 1:
        return single

    def accumulated(params, opt_state, batch):
        # split batch into microbatches along the batch dim
        def slice_mb(x):
            b = x.shape[0]
            return x.reshape((micro, b // micro) + x.shape[1:])
        mbs = jax.tree.map(slice_mb, batch)

        grad_fn = jax.value_and_grad(
            lambda p, mb: lm.loss_fn(p, cfg, mb, ctx, remat=step_cfg.remat))

        if step_cfg.overlap == "serial":
            # baseline: accumulate, no pipelined buckets
            def body(carry, mb):
                acc, loss_sum = carry
                loss, g = grad_fn(params, mb)
                acc = jax.tree.map(jnp.add, acc, g)
                return (acc, loss_sum + loss), None

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (acc, loss_sum), _ = lax.scan(body, (zeros, 0.0), mbs)
            grads = jax.tree.map(lambda g: g / micro, acc)
            loss = loss_sum / micro
        else:
            # paper-schedule: bucket i's (explicitly materialized) gradient
            # joins the accumulator one iteration late, so its reduction
            # overlaps microbatch i+1's compute.
            def body(carry, mb):
                acc, prev, loss_sum = carry
                loss, g = grad_fn(params, mb)
                acc = jax.tree.map(
                    lambda a, pg: a + _wire(pg, step_cfg), acc, prev)
                return (acc, g, loss_sum + loss), None

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            zeros_g = jax.tree.map(lambda p: jnp.zeros(p.shape, p.dtype), params)
            (acc, last, loss_sum), _ = lax.scan(body, (zeros, zeros_g, 0.0), mbs)
            acc = jax.tree.map(lambda a, pg: a + _wire(pg, step_cfg), acc, last)
            grads = jax.tree.map(lambda g: g / micro, acc)
            loss = loss_sum / micro

        new_params, new_opt, info = adamw_update(opt_cfg, params, grads, opt_state)
        return new_params, new_opt, {"loss": loss, **info}

    return accumulated


def _wire(g: jnp.ndarray, step_cfg: StepConfig) -> jnp.ndarray:
    """Wire format for the gradient bucket: bf16 round-trip halves the
    all-reduce bytes (error is O(2^-8) relative and unbiased over steps)."""
    if step_cfg.compress_grads:
        return g.astype(jnp.bfloat16).astype(jnp.float32)
    return g.astype(jnp.float32)


def make_eval_step(cfg: ModelConfig, ctx, remat: bool = False):
    def step(params, batch):
        return lm.loss_fn(params, cfg, batch, ctx, remat=remat)
    return step


# ---------------------------------------------------------------------------
# serving steps
# ---------------------------------------------------------------------------
def make_prefill_step(cfg: ModelConfig, ctx, max_len: int):
    def step(params, batch):
        return lm.prefill(params, cfg, batch, ctx, max_len=max_len)
    return step


def make_decode_step(cfg: ModelConfig, ctx):
    def step(params, cache, tokens):
        return lm.decode_step(params, cfg, cache, tokens, ctx)
    return step
