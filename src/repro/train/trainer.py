"""Fault-tolerant training loop.

* checkpoint/restart: async sharded checkpoints every N steps; on start the
  trainer restores the latest checkpoint (elastic: any mesh shape) and the
  data pipeline resumes deterministically from the restored step;
* preemption handling: SIGTERM (or an injected flag) triggers a synchronous
  final checkpoint before exit — restart resumes exactly;
* straggler mitigation at this layer is the input pipeline's prefetch
  (device never waits for the host) and the scheduler-driven comm overlap in
  the step function; on-device stealing does not exist on TPU (DESIGN.md).
"""

from __future__ import annotations

import dataclasses
import signal
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint import Checkpointer
from ..data import DataConfig, SyntheticLMData
from ..models import lm
from ..models.config import ModelConfig
from ..optim.adamw import AdamWConfig, adamw_init
from .steps import StepConfig, make_train_step


@dataclasses.dataclass
class TrainerConfig:
    steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: str = "/tmp/repro_ckpt"
    log_every: int = 10
    seed: int = 0


class Trainer:
    def __init__(self, cfg: ModelConfig, opt_cfg: AdamWConfig,
                 tcfg: TrainerConfig, data_cfg: DataConfig,
                 ctx=None, step_cfg: StepConfig = StepConfig(),
                 shardings: Optional[Dict[str, Any]] = None):
        self.cfg = cfg
        self.opt_cfg = opt_cfg
        self.tcfg = tcfg
        self.ctx = ctx
        self.data = SyntheticLMData(data_cfg)
        self.ckpt = Checkpointer(tcfg.ckpt_dir)
        self.step_fn = jax.jit(make_train_step(cfg, opt_cfg, ctx, step_cfg))
        self._preempted = False
        self.metrics_log = []

    def request_preemption(self, *_args) -> None:
        """SIGTERM handler / test hook: checkpoint and stop at the next
        step boundary."""
        self._preempted = True

    # ------------------------------------------------------------------
    def init_or_restore(self):
        restored, manifest = self.ckpt.restore()
        if restored is not None:
            params = restored["params"]
            opt_state = restored["opt_state"]
            start = int(manifest["step"])
            return params, opt_state, start
        params = lm.init_params(self.cfg, jax.random.PRNGKey(self.tcfg.seed))
        opt_state = adamw_init(params)
        return params, opt_state, 0

    def run(self, install_sigterm: bool = False) -> Dict[str, Any]:
        if install_sigterm:
            signal.signal(signal.SIGTERM, self.request_preemption)
        params, opt_state, start = self.init_or_restore()
        self.data.start(from_step=start)
        it = iter(self.data)
        step = start
        t0 = time.perf_counter()
        try:
            while step < self.tcfg.steps and not self._preempted:
                _, host_batch = next(it)
                batch = {k: jnp.asarray(v) for k, v in host_batch.items()}
                if self.cfg.family == "encdec" and "enc_input" not in batch:
                    batch["enc_input"] = jnp.zeros(
                        (batch["tokens"].shape[0], 16, self.cfg.d_model),
                        self.cfg.jdtype)
                if self.cfg.family == "vlm" and "patches" not in batch:
                    batch["patches"] = jnp.zeros(
                        (batch["tokens"].shape[0], self.cfg.n_patches,
                         self.cfg.d_model), self.cfg.jdtype)
                params, opt_state, metrics = self.step_fn(params, opt_state, batch)
                step += 1
                if step % self.tcfg.log_every == 0 or step == self.tcfg.steps:
                    m = {k: float(v) for k, v in metrics.items()}
                    m["step"] = step
                    m["sec"] = time.perf_counter() - t0
                    self.metrics_log.append(m)
                if step % self.tcfg.ckpt_every == 0:
                    self.ckpt.save_async(
                        step, {"params": params, "opt_state": opt_state},
                        extra={"data": self.data.state_dict()})
        finally:
            self.data.stop()
        # preemption or completion: synchronous final checkpoint
        self.ckpt.save(step, {"params": params, "opt_state": opt_state},
                       extra={"data": self.data.state_dict(),
                              "preempted": self._preempted})
        self.ckpt.wait()
        return {"final_step": step, "params": params, "opt_state": opt_state,
                "metrics": self.metrics_log, "preempted": self._preempted}
