"""Sharded AdamW with global-norm clipping and a warmup+cosine schedule.

Optimizer state mirrors the param tree (m, v per leaf) and inherits the
param PartitionSpecs — with TP-sharded params this is ZeRO-ish for the
model-parallel axis for free; the data-parallel axes hold replicated state
(full ZeRO-1 over DP is a documented hillclimb lever: shard m/v over
("pod","data") and all-gather at update).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    clip_norm: float = 1.0


def lr_schedule(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(1.0, cfg.warmup_steps)
    prog = (step - cfg.warmup_steps) / jnp.maximum(
        1.0, cfg.total_steps - cfg.warmup_steps)
    prog = jnp.clip(prog, 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def adamw_init(params) -> Dict[str, Any]:
    zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def clip_by_global_norm(grads, max_norm: float):
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        grads), gn


def adamw_update(cfg: AdamWConfig, params, grads, state):
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    step = state["step"] + 1
    lr = lr_schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m_new = b1 * m + (1 - b1) * g32
        v_new = b2 * v + (1 - b2) * g32 * g32
        mhat = m_new / bc1
        vhat = v_new / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m_new, v_new

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, {"lr": lr, "grad_norm": gnorm}
