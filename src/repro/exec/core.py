"""The shared executor core: one worker substrate for every scheduler.

Before this module existed the repo had grown *two* thread pools: the
dynamic :class:`~repro.core.runtime.Runtime` and the replay
:class:`~repro.replay.executor.ReplayExecutor` each owned worker threads,
a parallel-region implementation (``_Region`` vs ``_ReplayRegion``),
blocked-thread accounting and abort plumbing.  Following the
shared-substrate designs of low-contention tasking runtimes (Taskgraph,
nOS-V), this package extracts the common machinery once:

* :class:`ExecutorCore` — persistent worker threads with a generation-based
  park/wake protocol: between runs every worker parks on one condition
  variable; :meth:`ExecutorCore.run` installs a :class:`DispatchStrategy`,
  bumps the generation, and the workers execute ``dispatch.worker_loop(w)``
  until the run drains.  A core outlives any number of runs *and any number
  of dispatch strategies* — the same warm threads serve dynamic scheduling,
  replay, and the serving pool's leases.
* :class:`GangRegion` — the unified parallel region (the merge of the old
  ``_Region``/``_ReplayRegion``): a blocking in-region barrier wired into
  the core's blocked-thread accounting and deadlock detector, per-thread
  claim slots (used by replay and by dynamic fallback helpers), and
  completion bookkeeping.
* :class:`DispatchStrategy` — the pluggable scheduling brain.  Two
  implementations exist: :class:`~repro.exec.dynamic.DynamicDispatch`
  (per-worker deques, Algorithm-2 victim selection, Algorithm-1 gang
  reservation) and :class:`~repro.exec.replay.ReplayDispatch`
  (preallocated run lists, recorded gang placements, run-ahead and
  stall-triggered dynamic fallback).

Deadlock detection is centralized and oversubscription-safe: only workers
inside *blocking* barriers count as hard-blocked (join-waiters keep
scheduling and are excluded); when every worker of the core is hard-blocked
while dispatch-owned work is starved, :meth:`ExecutorCore.check_deadlock`
raises :class:`~repro.core.simulator.DeadlockError` instead of hanging —
the paper's Fig. 1 state, detected identically under both strategies.
"""

from __future__ import annotations

import threading
import time
from typing import Any, Callable, Dict, List, Optional

from ..core.simulator import DeadlockError
from ..core.taskgraph import TaskGraph
from ..core.tracing import EV_BARRIER_DONE, EV_BARRIER_WAIT, EV_DEADLOCK_POLL
from ..obs.recorder import NULL_RECORDER


class GangRegion:
    """A running parallel region (one gang), shared by every dispatch.

    Combines the dynamic runtime's region (blocking barrier + per-thread
    results) with the replay executor's (claim slots so recorded owners and
    fallback helpers can race for ULTs without running one twice).
    """

    __slots__ = ("rid", "gang_id", "nest_level", "n_threads", "core",
                 "spawn_task", "spawn_tid", "body", "lock", "cv",
                 "barrier_round", "arrived", "done", "started", "results")

    def __init__(
        self,
        core: "ExecutorCore",
        n_threads: int,
        *,
        gang_id: int = -1,
        nest_level: int = 0,
        rid: int = -1,
        spawn_task: Any = None,
        spawn_tid: int = -1,
        body: Optional[Callable[[int, "GangRegion"], Any]] = None,
    ):
        self.core = core
        self.n_threads = n_threads
        self.gang_id = gang_id
        self.nest_level = nest_level
        self.rid = rid
        self.spawn_task = spawn_task
        self.spawn_tid = spawn_task.tid if spawn_task is not None else spawn_tid
        self.body = body
        self.lock = threading.Lock()
        self.cv = threading.Condition(self.lock)
        self.barrier_round = 0
        self.arrived = 0
        self.done = 0
        self.started = [False] * n_threads
        self.results: List[Any] = [None] * n_threads

    # -- the in-region blocking barrier (paper: blocking sync inside tasks) -
    def barrier(self) -> None:
        """Blocking barrier across the region's ULTs.  The waiting kernel
        thread is accounted as hard-blocked and polls the core's deadlock
        detector — the Fig. 1 state raises instead of hanging."""
        core = self.core
        with self.cv:
            my_round = self.barrier_round
            self.arrived += 1
            if self.arrived == self.n_threads:
                self.arrived = 0
                self.barrier_round += 1
                self.cv.notify_all()
                return
            core.enter_blocked()
            w = core.worker_id(default=-1)
            core.recorder.emit(w, EV_BARRIER_WAIT, "", self.rid)
            try:
                while self.barrier_round == my_round:
                    if core.aborted:
                        raise DeadlockError(core.abort_reason())
                    if not self.cv.wait(timeout=core.block_poll):
                        core.check_deadlock()
            finally:
                core.recorder.emit(w, EV_BARRIER_DONE, "", self.rid)
                core.exit_blocked()

    # -- claim slots (replay owners / dynamic+replay fallback helpers) ------
    def claim(self, thread_num: int) -> bool:
        with self.lock:
            if self.started[thread_num]:
                return False
            self.started[thread_num] = True
            return True

    def claim_any(self) -> Optional[int]:
        with self.lock:
            for i, s in enumerate(self.started):
                if not s:
                    self.started[i] = True
                    return i
            return None

    def thread_done(self, thread_num: int, result: Any) -> bool:
        with self.cv:
            self.results[thread_num] = result
            self.done += 1
            finished = self.done == self.n_threads
            if finished:
                self.cv.notify_all()
            return finished

    @property
    def finished(self) -> bool:
        return self.done == self.n_threads

    def notify_nowait(self) -> None:
        """Best-effort wakeup of the region's waiters.  Non-blocking on the
        region lock: abort paths (``wake_all``) may run on a thread that
        already holds this very cv (a barrier waiter polls the deadlock
        detector while inside ``with self.cv``) — a lock holder is awake by
        definition, and every waiter re-polls on ``block_poll`` timeouts, so
        skipping a held lock costs latency, never correctness."""
        if self.cv.acquire(blocking=False):
            try:
                self.cv.notify_all()
            finally:
                self.cv.release()


class _RunState:
    """Abort state scoped to ONE run.  A fresh object is installed per run,
    so a caller that drained its run can never observe the *next* run's
    failure (or lose its own timeout to the next run's reset) on a shared
    core — it holds a reference to its own run's state.

    ``suspended`` counts frames currently parked on a channel/event (soft-
    blocked: their workers are free, so they are *excluded* from the Fig.-1
    hard-block count); ``resume_epoch`` increments on every frame wakeup so
    the suspension-deadlock detector can confirm quiescence across its
    confirmation window."""

    __slots__ = ("failure", "deadlock", "suspended", "resume_epoch")

    def __init__(self) -> None:
        self.failure: Optional[BaseException] = None
        self.deadlock: Optional[str] = None
        self.suspended = 0
        self.resume_epoch = 0


class DispatchStrategy:
    """The pluggable scheduling brain an :class:`ExecutorCore` drives.

    A strategy owns all per-run scheduling state (queues or run lists,
    readiness bookkeeping, results) and the region fork/join logic; the
    core owns the threads, the run lifecycle, abort plumbing and deadlock
    accounting.  One strategy instance is bound to at most one core at a
    time, but may be re-run any number of times (the serving pool keeps a
    warm :class:`~repro.exec.replay.ReplayDispatch` per shape and leases
    core time for each request).
    """

    core: "ExecutorCore" = None  # type: ignore[assignment]

    def bind(self, core: "ExecutorCore") -> None:
        if self.core is not None and self.core is not core:
            raise RuntimeError(
                "dispatch strategy is already bound to a different core")
        self.core = core

    # -- run lifecycle -----------------------------------------------------
    def begin_run(self, graph: TaskGraph) -> None:
        """Reset per-run state.  Called with the core quiescent (every
        worker parked) before the generation is bumped."""
        raise NotImplementedError

    def worker_loop(self, w: int) -> None:
        """Worker ``w``'s body for one run: schedule work until
        :attr:`drained` or ``core.aborted``.  Exceptions escaping here are
        recorded as the run's failure."""
        raise NotImplementedError

    @property
    def drained(self) -> bool:
        """True once every unit of the current run has completed."""
        raise NotImplementedError

    def results(self) -> Dict[int, Any]:
        """{tid: result} of the drained run."""
        raise NotImplementedError

    # -- parallel regions (TaskContext.parallel delegates here) -------------
    def parallel(self, n_threads: int, body, *, gang=None, spawn_ctx=None):
        raise NotImplementedError

    # -- diagnostics / abort ------------------------------------------------
    def pending_units(self) -> int:
        """Starved schedulable units, for deadlock messages."""
        return 0

    def wake_all(self) -> None:
        """Wake every waiter this strategy parked (called on abort)."""

    def drain_frames(self) -> None:
        """Cancel every parked :class:`~repro.core.taskgraph.TaskFrame` of
        the current run (called by the core when a run aborts, and by
        ``begin_run`` before reuse) so no frame stays orphaned on a channel
        or event that outlives the run."""


class ExecutorCore:
    """Persistent worker threads + run lifecycle, shared by all schedulers.

    ``run(dispatch, graph)`` executes one graph under one strategy; between
    runs the workers stay parked and warm.  Calls serialize: a second
    ``run`` (from any thread) waits until the previous run's workers are
    idle, which is what makes a core shareable between a pool's shapes and
    between dynamic warmup runs and replays.
    """

    def __init__(
        self,
        n_workers: int,
        *,
        block_poll: float = 0.05,
        name: str = "exec-core",
    ):
        self.n_workers = n_workers
        self.block_poll = block_poll
        self.name = name

        self._threads: List[threading.Thread] = []
        self._started = False
        self._shutdown = False
        self._tls = threading.local()
        # flight recorder of the dispatch currently running on this core;
        # reset to the no-op singleton between runs so a shared registry
        # core never keeps a trace buffer alive past its session
        self.recorder = NULL_RECORDER

        # run lifecycle: workers park on _gen_cv between runs
        self._gen_cv = threading.Condition()
        self._generation = 0
        self._workers_idle = n_workers
        self._dispatch: Optional[DispatchStrategy] = None

        # abort state of the CURRENT run (a fresh _RunState per run; workers
        # of run G can only ever see G's state — run G+1 cannot install
        # until they are all idle)
        self._run_state = _RunState()
        self._done_cv = threading.Condition()

        # hard-blocked accounting (blocking barriers only)
        self._blocked_count = 0
        self._blocked_lock = threading.Lock()

    # ------------------------------------------------------------------
    # lifecycle
    def start(self) -> None:
        if self._started:
            return
        self._started = True
        self._shutdown = False
        for w in range(self.n_workers):
            th = threading.Thread(target=self._worker_main, args=(w,),
                                  daemon=True, name=f"{self.name}-{w}")
            self._threads.append(th)
            th.start()

    def shutdown(self) -> None:
        self._shutdown = True
        with self._gen_cv:
            self._gen_cv.notify_all()
        dispatch = self._dispatch
        if dispatch is not None:
            dispatch.wake_all()
        with self._done_cv:
            self._done_cv.notify_all()
        for th in self._threads:
            th.join(timeout=5.0)
        alive = any(th.is_alive() for th in self._threads)
        self._threads.clear()
        self._started = False
        if not alive:
            # a straggler stuck in a long task body must keep seeing the
            # shutdown flag so it exits instead of rejoining the pool
            self._shutdown = False

    def __enter__(self) -> "ExecutorCore":
        self.start()
        return self

    def __exit__(self, *exc) -> None:
        self.shutdown()

    # ------------------------------------------------------------------
    # worker identity
    def worker_id(self, default: int = 0) -> int:
        return getattr(self._tls, "wid", default)

    # ------------------------------------------------------------------
    # abort plumbing
    @property
    def aborted(self) -> bool:
        run = self._run_state
        return (self._shutdown or run.failure is not None
                or run.deadlock is not None)

    def abort_reason(self) -> str:
        run = self._run_state
        if self._shutdown:
            return "executor core shut down"
        if run.deadlock is not None:
            return run.deadlock
        return f"run aborted: {run.failure!r}"

    def fail(self, exc: BaseException) -> None:
        """Record the run's first failure and wake every waiter."""
        run = self._run_state
        if run.failure is None:
            run.failure = exc
        dispatch = self._dispatch
        if dispatch is not None:
            dispatch.wake_all()
        self.signal_done()

    def signal_done(self) -> None:
        with self._done_cv:
            self._done_cv.notify_all()

    # ------------------------------------------------------------------
    # blocked accounting + deadlock detection (Fig. 1)
    def enter_blocked(self) -> None:
        with self._blocked_lock:
            self._blocked_count += 1

    def exit_blocked(self) -> None:
        with self._blocked_lock:
            self._blocked_count -= 1

    # -- suspended-frame accounting (soft-blocked: worker-free) ------------
    def note_frame_suspended(self) -> None:
        run = self._run_state
        with self._blocked_lock:
            run.suspended += 1

    def note_frame_resumed(self) -> None:
        run = self._run_state
        with self._blocked_lock:
            run.suspended -= 1
            run.resume_epoch += 1

    @property
    def suspended_frames(self) -> int:
        with self._blocked_lock:
            return self._run_state.suspended

    @property
    def resume_epoch(self) -> int:
        with self._blocked_lock:
            return self._run_state.resume_epoch

    def check_deadlock(self) -> None:
        """The Fig. 1 state: every worker is stuck inside a *blocking*
        barrier (kernel-thread semantics — cannot schedule anything) while
        the units that would satisfy those barriers sit starved with the
        dispatch.  Safe under oversubscription: join-waiters keep stealing
        and are never counted as hard-blocked; frames suspended on a
        channel/event are soft-blocked (their worker is free) and never
        count either — they appear in the message only as context."""
        self.recorder.emit(self.worker_id(default=-1), EV_DEADLOCK_POLL)
        if self.aborted:
            # the run is already tearing down: barrier waiters drain their
            # enter_blocked accounting on the way out, and a transiently
            # full blocked count must not masquerade as a fresh deadlock
            return
        with self._blocked_lock:
            blocked = self._blocked_count
            suspended = self._run_state.suspended
        if blocked < self.n_workers:
            return
        dispatch = self._dispatch
        starved = dispatch.pending_units() if dispatch is not None else 0
        msg = (f"deadlock: all {blocked} workers blocked at blocking "
               f"barriers; {starved} ULT(s)/task(s) starved"
               + (f"; {suspended} frame(s) suspended" if suspended else ""))
        self._run_state.deadlock = msg
        self.signal_done()
        if dispatch is not None:
            dispatch.wake_all()
        raise DeadlockError(msg)

    def frame_deadlock(self, msg: str) -> None:
        """Report a *suspension* deadlock (all remaining work is frames
        parked on channels/events that nothing left in the run can satisfy).
        Unlike :meth:`check_deadlock` the reporting worker is idle, not
        blocked — it records the state and lets every worker observe
        ``aborted``."""
        run = self._run_state
        if run.deadlock is None and run.failure is None:
            run.deadlock = msg
        self.signal_done()
        dispatch = self._dispatch
        if dispatch is not None:
            dispatch.wake_all()

    # ------------------------------------------------------------------
    # the worker loop
    def _worker_main(self, w: int) -> None:
        self._tls.wid = w
        my_gen = 0
        while True:
            with self._gen_cv:
                while self._generation == my_gen and not self._shutdown:
                    self._gen_cv.wait(timeout=0.5)
                if self._shutdown:
                    return
                my_gen = self._generation
                dispatch = self._dispatch
            try:
                dispatch.worker_loop(w)
            except BaseException as e:  # noqa: BLE001 - propagate to run()
                self.fail(e)
            with self._gen_cv:
                self._workers_idle += 1
                self._gen_cv.notify_all()

    # ------------------------------------------------------------------
    # run lifecycle
    def run(
        self,
        dispatch: DispatchStrategy,
        graph: TaskGraph,
        timeout: float = 300.0,
    ) -> Dict[int, Any]:
        """Execute ``graph`` under ``dispatch`` on the warm workers; returns
        ``{tid: result}``.  Raises :class:`DeadlockError` on the Fig. 1
        state, re-raises the first task failure, raises ``TimeoutError``
        past ``timeout``.  Concurrent callers serialize."""
        if not self._started:
            self.start()
        with self._gen_cv:
            while self._workers_idle < self.n_workers:
                if self._shutdown:
                    raise RuntimeError("executor core is shut down")
                self._gen_cv.wait(timeout=0.05)
            if self._shutdown:
                raise RuntimeError("executor core is shut down")
            run_state = self._run_state = _RunState()
            dispatch.bind(self)
            dispatch.begin_run(graph)
            self.recorder = getattr(dispatch, "recorder", NULL_RECORDER)
            self._dispatch = dispatch
            self._workers_idle = 0
            self._generation += 1
            self._gen_cv.notify_all()

        # from here on read abort state ONLY through run_state: on a shared
        # core the next run may install (and reset self._run_state) as soon
        # as this run's workers go idle
        deadline = time.monotonic() + timeout
        try:
            with self._done_cv:
                while not dispatch.drained:
                    if (self._shutdown or run_state.deadlock is not None
                            or run_state.failure is not None):
                        break
                    if not self._done_cv.wait(timeout=0.05):
                        if time.monotonic() > deadline:
                            run_state.failure = TimeoutError(
                                f"graph {graph.name!r} did not finish within "
                                f"{timeout}s")
                            break
            if self._shutdown and not dispatch.drained:
                dispatch.drain_frames()
                raise RuntimeError("executor core was shut down mid-run")
            if run_state.deadlock is not None:
                dispatch.drain_frames()
                raise DeadlockError(run_state.deadlock)
            if run_state.failure is not None:
                failure = run_state.failure
                dispatch.wake_all()
                dispatch.drain_frames()
                raise failure
            return dispatch.results()
        finally:
            self.recorder = NULL_RECORDER
