"""Dynamic dispatch: work-stealing deques + Algorithm-1 gang scheduling.

The scheduling brain of the paper's integrated runtime, extracted from the
old monolithic ``Runtime`` so it runs on the shared
:class:`~repro.exec.core.ExecutorCore` substrate:

* per-worker work-stealing deques; ready tasks are pushed to the queue of
  the worker that resolved their last dependency (paper §2.1);
* Algorithm 2 victim selection (``history`` / ``random`` / ``hybrid``);
* Algorithm 1 gang scheduling: parallel regions spawned by tasks are
  gang-scheduled onto reserved workers under the fork lock with a monotonic
  gang id; gang ULTs are stealable subject to ``is_eligible_to_sched``;
* region barriers: gang regions may use *blocking* barriers safely (all
  members are guaranteed distinct workers); at the *join* barrier a gang
  ULT steals eligible work instead of idling (the paper's scheduling
  point); non-gang regions with blocking barriers reproduce the Fig. 1
  deadlock, which the core's detector raises as
  :class:`~repro.core.simulator.DeadlockError`.

Record-and-replay instrumentation (per-worker start orders, steals, gang
placements, fork order) lives here too: recording is a property of the
*dynamic* schedule, not of the substrate.

Suspendable task frames (the paper's ULT-style preemption): a task body
written as a generator compiles into a :class:`~repro.core.taskgraph.TaskFrame`.
Yielding ``ctx.recv``/``ctx.wait``/``ctx.yield_`` parks the frame on the
waited-on primitive and *frees the worker*; a matching ``send``/``set``
moves the frame onto the resume deque of the worker that last ran it
(resume locality — siblings keep their cache affinity), where it is a
stealable work item under the same Algorithm-2 victim policies as fresh
tasks.  Suspended frames are soft-blocked: they are excluded from the
Fig.-1 hard-block count, and a run whose only remaining work is frames
nobody can resume is detected as a *suspension* deadlock instead of
hanging.  With recording on, every yield point suspends (no inline fast
path) so each resume segment lands in the run lists as a
:class:`~repro.core.taskgraph.FrameResume` entry and replay can reproduce
the exact frame interleaving.
"""

from __future__ import annotations

import itertools
import threading
import time
from collections import deque
from types import GeneratorType
from typing import Any, Callable, Deque, Dict, List, Optional, Tuple

from ..core.gang import GangState, is_eligible_to_sched
from ..core.policies import make_policy
from ..core.simulator import DeadlockError
from ..core.taskgraph import (
    Channel,
    FrameResume,
    Task,
    TaskContext,
    TaskEvent,
    TaskFrame,
    TaskGraph,
    WaitAnyRequest,
    activity_epoch,
    note_parked,
    note_unparked,
)
from ..core.tracing import (
    EV_BLOCK,
    EV_DEADLOCK_POLL,
    EV_FRAME_WAKE,
    EV_GANG_ENTER,
    EV_GANG_EXIT,
    EV_GANG_RESERVE,
    EV_PARK,
    EV_RESOURCE_ACQUIRE,
    EV_RESOURCE_RELEASE,
    EV_RESOURCE_WAIT,
    EV_STEAL_ATTEMPT,
    EV_STEAL_HIT,
    EV_TASK_END,
    EV_UNBLOCK,
    EV_WAKE,
)
from ..obs.recorder import NULL_RECORDER, FlightRecorder
from ..resources.arbiter import ResourceArbiter
from .core import DispatchStrategy, ExecutorCore, GangRegion


class _GangULT:
    __slots__ = ("region", "thread_num")

    def __init__(self, region: GangRegion, thread_num: int):
        self.region = region
        self.thread_num = thread_num

    @property
    def gang_id(self) -> int:
        return self.region.gang_id

    @property
    def nest_level(self) -> int:
        return self.region.nest_level


class DynamicDispatch(DispatchStrategy):
    """Work-stealing + gang-scheduling dispatch (the paper's scheduler)."""

    def __init__(
        self,
        n_workers: int,
        *,
        policy: str = "hybrid",
        gang_default: bool = True,
        seed: int = 0,
        steal_backoff: float = 20e-6,
        trace: bool = False,
    ):
        self.core: Optional[ExecutorCore] = None
        self.n_workers = n_workers
        self.policy_name = policy
        self.gang_default = gang_default
        self.seed = seed
        self.steal_backoff = steal_backoff
        self.trace_enabled = trace
        # flight recorder: hot paths call emit unconditionally — with
        # tracing off this is the no-op singleton (one attribute call)
        self.recorder = FlightRecorder(n_workers) if trace else NULL_RECORDER

        self._fork_lock = threading.Lock()          # the paper's fork-phase lock
        self.gang_state = GangState(n_workers)
        self._region_ids = itertools.count()

        self._locals: List[Deque[Task]] = [deque() for _ in range(n_workers)]
        self._local_locks = [threading.Lock() for _ in range(n_workers)]
        self._gang_deqs: List[Deque[_GangULT]] = [deque() for _ in range(n_workers)]
        self._gang_locks = [threading.Lock() for _ in range(n_workers)]
        # resumed frames: per-worker deques keyed by resume locality (the
        # worker that last ran the frame); stealable like fresh tasks
        self._resume_deqs: List[Deque[TaskFrame]] = [deque() for _ in range(n_workers)]
        self._resume_locks = [threading.Lock() for _ in range(n_workers)]
        self._policies = [make_policy(policy, w, n_workers, seed)
                          for w in range(n_workers)]

        # parked (suspended) frames of the current run, keyed by task id
        self._suspended: Dict[int, TaskFrame] = {}
        self._suspend_lock = threading.Lock()
        # no-progress detection inputs: per-worker unit-nesting depth and a
        # "top of stack is blocked in ctx.recv/ctx.wait" flag (each worker
        # writes only its own slot; readers confirm via the wakeup epochs)
        self._depth = [0] * n_workers
        self._stalled = [False] * n_workers
        # live gang regions (abort must wake their barrier waiters promptly)
        self._live_regions: Dict[int, GangRegion] = {}
        self._region_lock = threading.Lock()

        # worker context stacks: list of (gang_id, nest_level)
        self._contexts: List[List[Tuple[int, int]]] = [[] for _ in range(n_workers)]

        self._graph: Optional[TaskGraph] = None
        self._indeg: List[int] = []
        self._indeg_lock = threading.Lock()
        self._results: Dict[int, Any] = {}
        self._results_lock = threading.Lock()
        self._remaining = 0
        self._remaining_lock = threading.Lock()
        self._work_available = threading.Condition()

        # record-and-replay instrumentation; populated when recording is on
        self._recording = False
        self._rec_entries: List[List[Any]] = []
        self._rec_steals: List[List[Tuple[int, Any]]] = []
        self._rec_forks: List[Tuple[int, int, int]] = []
        self._rec_comms: List[int] = []
        self._rec_comm_lock = threading.Lock()
        # wait_any winners: (tid, seg) -> winning source index (replay pins
        # the recorded choice, making selects deterministic)
        self._rec_wait_choices: Dict[Tuple[int, int], int] = {}

        # conflict-aware resource grants (declarative `uses=`; ROADMAP 3)
        self.arbiter = ResourceArbiter()

        # always-on lightweight run counters (surfaced in RunReport.stats)
        self.run_stats: Dict[str, int] = {
            "steals": 0, "steal_attempts": 0, "frame_suspends": 0}

    # ------------------------------------------------------------------
    # DispatchStrategy interface
    def set_recording(self, record: bool) -> None:
        self._recording = record

    def begin_run(self, graph: TaskGraph) -> None:
        self._graph = graph
        self._indeg = graph.indegrees()
        self._results = {}
        self._remaining = len(graph)
        # a previous aborted run may have left stale queue entries / context;
        # discarded gang ULTs must also release their GangState accounting
        # or get_workers' load balancing skews forever on a reused runtime
        for dq in self._locals:
            dq.clear()
        for w, dq in enumerate(self._gang_deqs):
            for ult in dq:
                if ult.region.gang_id >= 0:
                    self.gang_state.release_gang_thread(w)
            dq.clear()
        # frames of an aborted run: cancel parked ones, close resumed-but-
        # never-rerun ones (the orphaned-frame leak check covers both).
        # Stale arbiter waiters are discarded first: their suspension
        # accounting died with the old run's state and must not touch the
        # fresh run's counters.
        self.arbiter.abort()
        self.drain_frames()
        for w, dq in enumerate(self._resume_deqs):
            with self._resume_locks[w]:
                stale = list(dq)
                dq.clear()
            for frame in stale:
                frame.close()
        with self._region_lock:
            self._live_regions.clear()
        self._depth = [0] * self.n_workers
        self._stalled = [False] * self.n_workers
        self._contexts = [[] for _ in range(self.n_workers)]
        if self._recording:
            self._rec_entries = [[] for _ in range(self.n_workers)]
            self._rec_steals = [[] for _ in range(self.n_workers)]
            self._rec_forks = []
            self._rec_comms = []
            self._rec_wait_choices = {}
        self.run_stats = {"steals": 0, "steal_attempts": 0,
                          "frame_suspends": 0, "resource_acquires": 0,
                          "resource_waits": 0, "resource_releases": 0}
        self.arbiter.begin(graph)
        self.recorder.begin_run()
        # master thread (worker 0's queue) receives the roots
        for t in graph.roots():
            self._locals[0].append(t)

    @property
    def drained(self) -> bool:
        return self._remaining <= 0

    def results(self) -> Dict[int, Any]:
        return dict(self._results)

    def pending_units(self) -> int:
        return (sum(len(d) for d in self._gang_deqs)
                + sum(len(d) for d in self._locals)
                + sum(len(d) for d in self._resume_deqs))

    def wake_all(self) -> None:
        with self._work_available:
            self._work_available.notify_all()
        # barrier waiters inside live gang regions must observe the abort
        # promptly (and drain their hard-blocked accounting on the way out);
        # non-blocking: the caller may itself hold a region cv (a barrier
        # waiter runs the deadlock detector inside `with region.cv`)
        with self._region_lock:
            regions = list(self._live_regions.values())
        for region in regions:
            region.notify_nowait()

    def worker_loop(self, w: int) -> None:
        core = self.core
        emit = self.recorder.emit
        idle = False   # park/wake events on transitions only (no flood)
        while not self.drained and not core.aborted:
            progressed = self.schedule_once(w)
            if progressed:
                if idle:
                    idle = False
                    emit(w, EV_WAKE)
                continue
            if not idle:
                idle = True
                emit(w, EV_PARK)
            with self._work_available:
                if self.drained or core.aborted:
                    return
                self._work_available.wait(timeout=self.steal_backoff * 50)
            if not self.drained and not core.aborted:
                self._check_no_progress()

    def _active_workers(self) -> int:
        """Workers that can still make progress on their own: executing a
        unit whose stack top is NOT blocked in a plain-body recv/wait."""
        return sum(1 for w in range(self.n_workers)
                   if self._depth[w] > 0 and not self._stalled[w])

    def _check_no_progress(self) -> None:
        """Suspension deadlock: nothing queued, no worker executing freely
        (each is idle or stalled at a plain-body recv/wait), yet tasks
        remain — every wakeup would have to come from work that no longer
        exists.  Confirmed across a poll window against both wakeup epochs
        (frame resumes and raw channel/event activity), so a sender racing
        the window is never mistaken for quiescence.  The contract this
        enforces: wakeups come from the run's own work — a feeder outside
        the graph that stays silent past the window is indistinguishable
        from deadlock and aborts the run.  Workers hard-blocked at barriers
        count as active here; the Fig.-1 detector
        (:meth:`ExecutorCore.check_deadlock`) owns that state."""
        core = self.core
        if (self.drained or core.aborted or self.pending_units() > 0
                or self._active_workers() > 0):
            return
        suspended, stalled = core.suspended_frames, sum(self._stalled)
        if suspended <= 0 and stalled == 0:
            return
        self.recorder.emit(core.worker_id(default=-1), EV_DEADLOCK_POLL)
        resume_epoch, act_epoch = core.resume_epoch, activity_epoch()
        time.sleep(core.block_poll)
        if (not self.drained and not core.aborted
                and self.pending_units() == 0 and self._active_workers() == 0
                and (core.suspended_frames > 0 or sum(self._stalled) > 0)
                and core.resume_epoch == resume_epoch
                and activity_epoch() == act_epoch):
            with self._suspend_lock:
                waits = [f"{f.task.name}<-{f.request.describe()}"
                         for f in self._suspended.values()
                         if f.request is not None][:6]
            core.frame_deadlock(
                f"suspension deadlock: {core.suspended_frames} frame(s) "
                f"suspended ({', '.join(waits)}), {sum(self._stalled)} "
                "worker(s) blocked in task-body recv/wait, and no runnable "
                "work left to satisfy them")

    # ------------------------------------------------------------------
    # queues
    def _push_local(self, w: int, task: Task) -> None:
        with self._local_locks[w]:
            self._locals[w].append(task)

    def _pop_local(self, w: int) -> Optional[Task]:
        with self._local_locks[w]:
            dq = self._locals[w]
            if not dq:
                return None
            # priority-aware LIFO pop (bounded scan, paper's priority clause)
            best_i, best_p = len(dq) - 1, dq[-1].priority
            for i in range(len(dq) - 1, max(-1, len(dq) - 9), -1):
                if dq[i].priority > best_p:
                    best_i, best_p = i, dq[i].priority
            t = dq[best_i]
            del dq[best_i]
            return t

    def _steal_local(self, victim: int) -> Optional[Task]:
        with self._local_locks[victim]:
            dq = self._locals[victim]
            if not dq:
                return None
            if not self.arbiter.active:
                return dq.popleft()
            # conflict-aware: don't burn the steal on a task whose resources
            # are currently held — it would only bounce into the arbiter's
            # wait list (bounded FIFO-end scan, mirrors the priority pop)
            for i in range(min(len(dq), 8)):
                if not self.arbiter.would_defer(dq[i].tid):
                    t = dq[i]
                    del dq[i]
                    return t
            return None

    def _pop_resume(self, victim: int) -> Optional[TaskFrame]:
        with self._resume_locks[victim]:
            dq = self._resume_deqs[victim]
            return dq.popleft() if dq else None

    def _pop_gang(self, thief: int, victim: int) -> Optional[_GangULT]:
        ctx = self._contexts[thief]
        cur_gang, cur_nest = (ctx[-1] if ctx else (-1, 0))
        with self._gang_locks[victim]:
            dq = self._gang_deqs[victim]
            if not dq:
                return None
            head = dq[0]
            if is_eligible_to_sched(head.gang_id, head.nest_level, cur_gang, cur_nest):
                return dq.popleft()
            return None

    def _notify_work(self) -> None:
        with self._work_available:
            self._work_available.notify_all()

    # ------------------------------------------------------------------
    # scheduling
    def schedule_once(self, w: int) -> bool:
        """One scheduling point: gang deque > resumed frames > local deque >
        steal.  Returns True if a unit of work was executed."""
        if self.core.aborted:
            return False
        ult = self._pop_gang(w, w)
        if ult is not None:
            self._run_gang_ult(w, ult)
            return True
        frame = self._pop_resume(w)
        if frame is not None:
            self._run_frame_segment(w, frame)
            return True
        task = self._pop_local(w)
        if task is not None:
            self._run_task(w, task)
            return True
        # work stealing (Algorithm 2 policy)
        pol = self._policies[w]
        victim = pol.select()
        got: Any = None
        if victim != w:
            self.run_stats["steal_attempts"] += 1
            self.recorder.emit(w, EV_STEAL_ATTEMPT, "", victim)
            got = self._pop_gang(w, victim)
            if got is None:
                got = self._pop_resume(victim)
            if got is None:
                got = self._steal_local(victim)
        pol.record(victim, got is not None)
        if got is None:
            return False
        self.run_stats["steals"] += 1
        if self._recording:
            if isinstance(got, _GangULT):
                entry = (got.region.spawn_tid, got.thread_num) \
                    if got.region.spawn_task is not None else None
            elif isinstance(got, TaskFrame):
                entry = FrameResume(got.task.tid, got.resumes + 1)
            else:
                entry = got.tid
            if entry is not None:
                self._rec_steals[w].append((victim, entry))
        if isinstance(got, _GangULT):
            self.recorder.emit(w, EV_STEAL_HIT, "gang", victim)
            self._run_gang_ult(w, got)
        elif isinstance(got, TaskFrame):
            self.recorder.emit(w, EV_STEAL_HIT, "frame", victim)
            self._run_frame_segment(w, got)
        else:
            self.recorder.emit(w, EV_STEAL_HIT, "task", victim)
            self._run_task(w, got)
        return True

    # ------------------------------------------------------------------
    # task execution
    def _begin_unit(self, w: int) -> None:
        self._depth[w] += 1       # own slot only; no lock needed

    def _end_unit(self, w: int) -> None:
        self._depth[w] -= 1

    def _run_task(self, w: int, task: Task) -> None:
        arbiter = self.arbiter
        if arbiter.active and arbiter.needs(task.tid):
            if arbiter.holds(task.tid):
                pass        # pre-granted by a releaser's FIFO scan
            elif arbiter.try_acquire(task.tid):
                self.run_stats["resource_acquires"] += 1
                self.recorder.emit_resource(w, EV_RESOURCE_ACQUIRE, task,
                                            len(arbiter.needs(task.tid)))
            else:
                # contended: the task now sits on the arbiter's FIFO wait
                # list (soft-blocked, like a suspended frame — the worker
                # moves on); release() re-queues it when granted
                self.run_stats["resource_waits"] += 1
                self.recorder.emit_resource(w, EV_RESOURCE_WAIT, task)
                self.core.note_frame_suspended()
                return
        self.recorder.emit_task_start(w, task)
        if self._recording:
            # per-worker list, appended only by worker w: start order, no lock
            self._rec_entries[w].append(task.tid)
            if task.kind == "comm":
                with self._rec_comm_lock:
                    self._rec_comms.append(task.tid)
        ctx = TaskContext(self._graph, task, self._results, runtime=self)
        ctx.worker_id = w  # type: ignore[attr-defined]
        self._begin_unit(w)
        try:
            try:
                result = task.fn(ctx) if task.fn is not None else None
            except BaseException as e:  # noqa: BLE001 - propagate to run()
                self.core.fail(e)
                return
            if isinstance(result, GeneratorType):
                # generator body => suspendable frame (segment 0 runs now)
                ctx._in_frame = True
                frame = TaskFrame(task, ctx, result)
                frame.last_worker = w
                self._advance_frame(w, frame)
                return
        finally:
            self._end_unit(w)
        self.recorder.emit(w, EV_TASK_END, "", task.tid)
        with self._results_lock:
            self._results[task.tid] = result
        self._complete(w, task)

    # ------------------------------------------------------------------
    # suspendable frames
    def _run_frame_segment(self, w: int, frame: TaskFrame) -> None:
        """Execute one resume segment of a frame popped off a resume deque
        (possibly stolen — ``w`` need not be ``frame.last_worker``)."""
        frame.resumes += 1
        self.recorder.emit_frame_resume(w, frame)
        if self._recording:
            self._rec_entries[w].append(FrameResume(frame.task.tid, frame.resumes))
        frame.ctx.worker_id = w  # type: ignore[attr-defined]
        frame.last_worker = w
        self._begin_unit(w)
        try:
            self._advance_frame(w, frame)
        finally:
            self._end_unit(w)

    def _advance_frame(self, w: int, frame: TaskFrame) -> None:
        """Drive the generator until it completes or must park.  Without
        recording, immediately satisfiable requests (non-empty channel, set
        event) are consumed inline; with recording on, every request parks
        so the resume segment is observable as a run-list entry."""
        core = self.core
        value = frame.resume_value
        frame.resume_value = None
        while True:
            try:
                status, payload = frame.step(value)
            except BaseException as e:  # noqa: BLE001 - propagate to run()
                core.fail(e)
                return
            if status == "done":
                self.recorder.emit(w, EV_TASK_END, "", frame.task.tid)
                with self._results_lock:
                    self._results[frame.task.tid] = payload
                self._complete(w, frame.task)
                return
            request = payload
            if not self._recording:
                ok, value = request.try_immediate()
                if ok:
                    continue
            self._park_frame(w, frame, request)
            return

    def _park_frame(self, w: int, frame: TaskFrame, request) -> None:
        core = self.core
        frame.last_worker = w

        def waker(value=None, *, _frame=frame):
            self._resume_frame(_frame, value)

        frame.request = request
        frame.waker = waker
        with self._suspend_lock:
            self._suspended[frame.task.tid] = frame
        note_parked(frame)
        core.note_frame_suspended()
        self.run_stats["frame_suspends"] += 1
        self.recorder.emit_frame_suspend(w, frame, request)
        status, value = request.park(waker)
        if status == "ready":
            # the primitive was already satisfied (or this is a plain
            # yield): the frame is immediately resumable, via the queue so
            # other work interleaves — and so recording sees the segment
            waker(value)
        elif core.aborted:
            # the run died while we parked; nobody will drain us later
            self._discard_parked(frame)

    def _resume_frame(self, frame: TaskFrame, value: Any) -> None:
        """Waker target: move a parked frame onto the resume deque of its
        locality worker.  Idempotent against a racing cancel."""
        with self._suspend_lock:
            if self._suspended.pop(frame.task.tid, None) is None:
                return
        note_unparked(frame)
        if self._recording and isinstance(frame.request, WaitAnyRequest):
            # the resume value of a multi-wait is (winner index, payload);
            # record the winner so replay pins the same choice.  (tid, seg)
            # keys are unique, so racing wakers never collide.
            self._rec_wait_choices[(frame.task.tid, frame.resumes + 1)] = \
                int(value[0])
        frame.resume_value = value
        frame.request = None
        frame.waker = None
        self.core.note_frame_resumed()
        # the waker may be any thread (a worker mid-send or an external
        # caller) — worker -1 routes to the recorder's external ring
        self.recorder.emit(self.core.worker_id(default=-1), EV_FRAME_WAKE,
                           "", frame.task.tid, frame.resumes + 1)
        target = frame.last_worker
        with self._resume_locks[target]:
            self._resume_deqs[target].append(frame)
        self._notify_work()

    def _discard_parked(self, frame: TaskFrame) -> None:
        with self._suspend_lock:
            if self._suspended.pop(frame.task.tid, None) is None:
                return
        note_unparked(frame)
        if frame.request is not None:
            frame.request.cancel(frame.waker)
        self.core.note_frame_resumed()   # keep the run's suspend count balanced
        frame.close()

    def drain_frames(self) -> None:
        with self._suspend_lock:
            frames = list(self._suspended.values())
        for frame in frames:
            self._discard_parked(frame)
        # resource grants die with the run: drop every holder and rebalance
        # the suspension accounting of tasks still deferred on the arbiter
        # (the release-on-abort contract the checkpoint writers rely on)
        for _tid in self.arbiter.abort():
            self.core.note_frame_resumed()

    def _complete(self, w: int, task: Task) -> None:
        arbiter = self.arbiter
        if arbiter.active and arbiter.holds(task.tid):
            n_res = len(arbiter.needs(task.tid))
            granted = arbiter.release(task.tid)
            self.run_stats["resource_releases"] += 1
            self.recorder.emit_resource(w, EV_RESOURCE_RELEASE, task, n_res)
            for tid in granted:
                # granted at release time (FIFO-fair): hand the task back to
                # the releasing worker's queue, already holding its grants
                t = self._graph.tasks[tid]
                self.run_stats["resource_acquires"] += 1
                self.recorder.emit_resource(w, EV_RESOURCE_ACQUIRE, t,
                                            len(arbiter.needs(tid)))
                self.core.note_frame_resumed()
                self._push_local(w, t)
            if granted:
                self._notify_work()
        newly_ready: List[Task] = []
        with self._indeg_lock:
            for s in self._graph.successors(task):
                self._indeg[s.tid] -= 1
                if self._indeg[s.tid] == 0:
                    newly_ready.append(s)
        for s in newly_ready:
            self._push_local(w, s)
        if newly_ready:
            self._notify_work()
        with self._remaining_lock:
            self._remaining -= 1
            done = self._remaining <= 0
        if done:
            self.core.signal_done()
            # kick idle workers out of their backoff naps so the core is
            # immediately quiescent for the next run
            self._notify_work()

    # ------------------------------------------------------------------
    # parallel regions (TaskContext.parallel delegates here)
    def parallel(
        self,
        n_threads: int,
        body: Callable[[int, GangRegion], Any],
        *,
        gang: Optional[bool] = None,
        spawn_ctx: Optional[TaskContext] = None,
    ) -> List[Any]:
        """Fork a parallel region of ``n_threads`` ULTs running
        ``body(thread_num, region)``; join and return per-thread results.
        ``region.barrier()`` is the blocking in-region barrier.

        Gang regions (default) are scheduled per Algorithm 1.  Non-gang
        regions push all ULTs to the calling worker's queue — combined with
        blocking barriers this reproduces the Fig. 1 deadlock, which the
        core detects."""
        core = self.core
        w = core.worker_id()
        use_gang = self.gang_default if gang is None else gang
        if use_gang and n_threads > self.n_workers:
            # Blocking synchronization requires every gang member on a
            # distinct kernel thread (no ULT stack switching in Python) —
            # same constraint OpenMP has for its thread teams.
            raise ValueError(
                f"gang region requests {n_threads} ULTs but only "
                f"{self.n_workers} workers exist; blocking barriers would deadlock")
        ctx_stack = self._contexts[w]
        nest_level = (ctx_stack[-1][1] if ctx_stack else 0) + 1

        spawn_task = spawn_ctx.task if spawn_ctx is not None else None
        with self._fork_lock:   # the paper's serialized fork phase
            gang_id = self.gang_state.next_gang_id() if use_gang else -1
            region = GangRegion(
                core, n_threads, gang_id=gang_id, nest_level=nest_level,
                rid=next(self._region_ids), spawn_task=spawn_task, body=body)
            if self._recording and spawn_task is not None:
                # fork lock => globally ordered by gang id (issue order)
                self._rec_forks.append((spawn_task.tid, gang_id, n_threads))
            self.recorder.emit(w, EV_GANG_RESERVE, "", region.rid, n_threads)
            if use_gang:
                reserved = self.gang_state.get_workers(w, n_threads)
                self.gang_state.account_gang(
                    [reserved[i % len(reserved)] for i in range(n_threads)])
                for i in range(n_threads):
                    target = reserved[i % len(reserved)]
                    with self._gang_locks[target]:
                        self._gang_deqs[target].append(_GangULT(region, i))
            else:
                for i in range(n_threads):
                    with self._gang_locks[w]:
                        self._gang_deqs[w].append(_GangULT(region, i))
        with self._region_lock:
            self._live_regions[region.rid] = region
        self._notify_work()

        # join: the spawning worker helps out at this scheduling point —
        # paper: gang ULTs at a join barrier steal (eligible) work.
        try:
            while not region.finished:
                if core.aborted:
                    raise DeadlockError(core.abort_reason())
                progressed = self.schedule_once(w)
                if not progressed and not region.finished:
                    # join-waiters retry stealing, so they are NOT counted as
                    # hard-blocked (only blocking barriers are) — but they do
                    # poll the detector for barrier deadlocks elsewhere.
                    with region.cv:
                        if not region.finished:
                            if not region.cv.wait(timeout=core.block_poll):
                                core.check_deadlock()
        finally:
            with self._region_lock:
                self._live_regions.pop(region.rid, None)
        return list(region.results)

    # ------------------------------------------------------------------
    # plain-body blocking communication (work-conserving kernel-thread wait)
    def ctx_recv(self, channel: Channel, ctx: TaskContext) -> Any:
        return self._blocking_wait(channel.try_recv, "recv", channel.uid)

    def ctx_wait(self, event: TaskEvent, ctx: TaskContext) -> None:
        self._blocking_wait(
            lambda: ((True, None) if event.is_set() else (False, None)),
            "wait", event.uid)

    def ctx_send(self, channel: Channel, value: Any, ctx: TaskContext) -> None:
        """Plain-body backpressured send: block work-conservingly until the
        bounded channel has a slot (unbounded channels succeed at once)."""
        self._blocking_wait(
            lambda: ((True, None) if channel.try_send(value)
                     else (False, None)),
            "send", channel.uid)

    def ctx_wait_any(self, request: WaitAnyRequest, ctx: TaskContext) -> Any:
        """Plain-body select: poll the sources work-conservingly; returns
        ``(index, value)`` of the first satisfied one."""
        return self._blocking_wait(request.try_immediate, "wait_any")

    def ctx_yield(self, ctx: TaskContext) -> None:
        """Plain-body cooperative scheduling point: serve one unit inline."""
        self.schedule_once(self.core.worker_id())

    def _blocking_wait(self, poll: Callable[[], Tuple[bool, Any]],
                       what: str = "", uid: int = -1) -> Any:
        """Block a plain (non-generator) body until ``poll`` succeeds.  The
        worker is NOT hard-blocked: it keeps serving other work at this
        scheduling point (Python cannot switch ULT stacks, so this is the
        strongest preemption a plain body can get — generators suspend for
        real).  While nothing is schedulable the worker is flagged stalled
        and runs the no-progress detector: a wait no remaining work can
        satisfy raises DeadlockError instead of hanging."""
        core = self.core
        w = core.worker_id()
        ok, value = poll()
        if ok:    # satisfied immediately: no block window, no events
            return value
        emit = self.recorder.emit
        emit(w, EV_BLOCK, what, uid)
        try:
            while True:
                ok, value = poll()
                if ok:
                    return value
                if core.aborted:
                    raise DeadlockError(core.abort_reason())
                if self.schedule_once(w):
                    continue
                self._stalled[w] = True
                try:
                    with self._work_available:
                        self._work_available.wait(
                            timeout=self.steal_backoff * 50)
                    ok, value = poll()
                    if ok:
                        return value
                    self._check_no_progress()
                finally:
                    self._stalled[w] = False
        finally:
            emit(w, EV_UNBLOCK, "", uid)

    def _run_gang_ult(self, w: int, ult: _GangULT) -> None:
        region = ult.region
        if self._recording and region.spawn_task is not None:
            self._rec_entries[w].append((region.spawn_tid, ult.thread_num))
        self._contexts[w].append((region.gang_id, region.nest_level))
        self.recorder.emit(w, EV_GANG_ENTER, "", region.rid, ult.thread_num)
        try:
            result = region.body(ult.thread_num, region)
        except BaseException as e:  # noqa: BLE001
            self.core.fail(e)
            return
        finally:
            self.recorder.emit(w, EV_GANG_EXIT, "", region.rid,
                               ult.thread_num)
            self._contexts[w].pop()
            if region.gang_id >= 0:
                with self._fork_lock:
                    self.gang_state.release_gang_thread(w)
        region.thread_done(ult.thread_num, result)

    # ------------------------------------------------------------------
    # flight-recorder assembly + victim-policy feedback (ROADMAP item 4)
    def take_trace(self):
        """Assemble the last run's events into a
        :class:`~repro.obs.trace.RuntimeTrace` (``None`` with tracing off)."""
        if not self.trace_enabled:
            return None
        from ..obs.trace import RuntimeTrace
        return RuntimeTrace.from_recorder(self.recorder)

    def apply_feedback(self, trace) -> None:
        """Feed an assembled trace's metrics (per-victim steal histograms,
        resume latency) to every worker's victim policy — the data plumbing
        stats-driven policies hook via ``VictimPolicy.observe``."""
        if trace is None:
            return
        metrics = trace.metrics()
        for pol in self._policies:
            pol.observe(metrics)

    # ------------------------------------------------------------------
    # recording assembly (record-and-replay, repro.replay)
    def build_recording(self, graph: TaskGraph):
        """Assemble a replay Recording from the instrumentation buffers."""
        from ..replay.graph_key import graph_key
        from ..replay.recording import GangPlacement, Recording

        placements: Dict[int, GangPlacement] = {}
        for spawn_tid, gang_id, n_threads in self._rec_forks:
            if spawn_tid in placements:
                # recordings key regions by spawning task; two forks from one
                # task would be indistinguishable on replay — refuse loudly
                raise ValueError(
                    f"task {spawn_tid} forked more than one parallel region; "
                    "record-and-replay supports one region per task")
            placements[spawn_tid] = GangPlacement(
                spawn_tid, gang_id, [-1] * n_threads)
        for w, entries in enumerate(self._rec_entries):
            for e in entries:
                if isinstance(e, tuple) and e[0] in placements:
                    placements[e[0]].workers[e[1]] = w
        steals = [(w, victim, e)
                  for w, lst in enumerate(self._rec_steals)
                  for victim, e in lst]
        return Recording(
            digest=graph_key(graph).digest,
            graph_name=graph.name,
            n_workers=self.n_workers,
            policy=self.policy_name,
            worker_orders=[list(e) for e in self._rec_entries],
            gang_placements=placements,
            gang_issue_order=[f[0] for f in self._rec_forks],
            steals=steals,
            collective_order=list(self._rec_comms),
            wait_choices=dict(self._rec_wait_choices),
            resource_grants=self.arbiter.grant_log(),
            source="dynamic",
        )
