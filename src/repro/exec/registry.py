"""Process-global registry of shared :class:`ExecutorCore` substrates.

Several :class:`~repro.replay.pool.ReplayPool`\\ s (multi-tenant serving: one
pool per model / per tenant) used to spawn their own cores, so total worker
threads grew with the number of *pools* times worker counts.  The registry
caps that at one core per **worker count per process**: every pool (and any
other facade passing ``core=``) leases the same warm threads.

Leases are refcounted: :func:`shared_core` bumps the count and starts the
core lazily; :func:`release_shared_core` drops it and shuts the core's
threads down when the last lessee leaves — which is what keeps the test
suite's worker-thread leak check meaningful.
"""

from __future__ import annotations

import threading
from typing import Dict, Optional

from .core import ExecutorCore


class CoreRegistry:
    """Refcounted map of ``worker count -> shared ExecutorCore``."""

    def __init__(self, *, name_prefix: str = "exec-core"):
        self._lock = threading.Lock()
        self._cores: Dict[int, ExecutorCore] = {}
        self._refs: Dict[int, int] = {}
        self._name_prefix = name_prefix

    def acquire(self, n_workers: int, *, block_poll: float = 0.05) -> ExecutorCore:
        """Lease the process-wide core for ``n_workers`` (created and
        started on first acquire)."""
        if n_workers < 1:
            raise ValueError(f"cannot share a core of {n_workers} workers")
        with self._lock:
            core = self._cores.get(n_workers)
            if core is None:
                core = ExecutorCore(
                    n_workers, block_poll=block_poll,
                    name=f"{self._name_prefix}{n_workers}")
                self._cores[n_workers] = core
                self._refs[n_workers] = 0
                core.start()
            self._refs[n_workers] += 1
            return core

    def release(self, core: ExecutorCore) -> None:
        """Drop one lease; the last release shuts the core down."""
        to_shutdown: Optional[ExecutorCore] = None
        with self._lock:
            for n, c in self._cores.items():
                if c is core:
                    self._refs[n] -= 1
                    if self._refs[n] <= 0:
                        to_shutdown = self._cores.pop(n)
                        self._refs.pop(n)
                    break
        if to_shutdown is not None:
            to_shutdown.shutdown()

    def refcounts(self) -> Dict[int, int]:
        with self._lock:
            return dict(self._refs)

    def shutdown_all(self) -> None:
        """Force-stop every registered core regardless of refcounts.

        For process teardown paths where no lessee will ever release —
        a :mod:`repro.mp` worker child exiting on parent death must not
        leave worker threads spinning while the interpreter finalizes.
        Leases handed out before this call become dead handles; the
        registry itself stays usable (a later acquire builds fresh cores).
        """
        with self._lock:
            cores = list(self._cores.values())
            self._cores.clear()
            self._refs.clear()
        for core in cores:
            core.shutdown()

    def __len__(self) -> int:
        with self._lock:
            return len(self._cores)


#: The process-global registry every ReplayPool leases from by default.
REGISTRY = CoreRegistry()


def shared_core(n_workers: int) -> ExecutorCore:
    """Lease the process-global shared core for ``n_workers`` workers.
    Pair every call with :func:`release_shared_core`."""
    return REGISTRY.acquire(n_workers)


def release_shared_core(core: ExecutorCore) -> None:
    """Release a lease taken via :func:`shared_core`."""
    REGISTRY.release(core)
