"""Replay dispatch: preallocated run lists + recorded gang placements.

The low-contention scheduling brain of the record-and-replay subsystem,
extracted from the old monolithic ``ReplayExecutor`` so it runs on the
shared :class:`~repro.exec.core.ExecutorCore` substrate.  The dynamic
dispatch pays, per task: a queue push + pop under per-worker locks, a
global indegree-lock critical section, victim selection, and — for gang
regions — a fork-lock critical section running worker reservation.
:class:`ReplayDispatch` re-executes a graph of identical structure from a
:class:`~repro.replay.recording.Recording` with none of those decisions:

* each worker walks its **preallocated run list** (the recorded start order),
* readiness is tracked by **per-task dependency counters** built on
  CPython-atomic ``list.append``/``len`` (no locks at all on the task hot
  path; task claims are atomic ``dict.setdefault`` races, first wins),
* results live in a preallocated list (index = tid; GIL-atomic writes),
* gang regions are forked straight onto their **recorded placement** in the
  recorded gang-id order — no ``GET_WORKERS`` scan, and the fork lock is
  held only to bump the issue cursor.

Deviation handling (cost drift / stale recordings): a worker whose next
recorded entry is not ready within ``stall_timeout`` falls back to *dynamic
stealing* — it scans for any ready-but-unclaimed task (or a published gang
ULT) and executes that instead, then re-checks its list.  Claims are
per-task, so a stolen task's recorded owner simply skips it.  Fallback never
steals a region-forking task whose recorded spawner is someone else: forks
must come from a worker free to join, preserving the gang invariants
(distinct workers per blocking region, monotonic issue order).

Deadlock freedom: run lists are recorded start orders, so dependency and
list-predecessor edges embed in one global time order (acyclic); the
earliest unfinished entry is always runnable by its owner, and the fallback
only adds work, never removes readiness.

Suspendable frames replay deterministically: a recorded run (instrumentation
forces a suspension at every ``yield``) stores each resume segment as a
:class:`~repro.core.taskgraph.FrameResume` run-list entry.  On replay,
generator bodies *always* suspend at their yield points (even when the
channel already has data — the recorded segmentation is reproduced, not
re-decided); a frame becomes *resumable* when its channel send / event set
arrives, and the recorded owner executes segment ``seg`` at its recorded
list position, gated by a per-``(tid, seg)`` claim so fallback helpers
never run a segment twice.  Suspended frames are soft-blocked: their
workers keep walking their lists.

A :class:`ReplayDispatch` is *warm state*: the run lists, placements and
owner map are computed once per recording, and the serving pool keeps one
dispatch per shape while leasing worker time from a shared per-worker-count
core.
"""

from __future__ import annotations

import threading
import time
from types import GeneratorType
from typing import TYPE_CHECKING, Any, Callable, Dict, List, Optional, Tuple

from ..core.simulator import DeadlockError
from ..core.taskgraph import (
    Channel,
    FrameResume,
    Task,
    TaskContext,
    TaskEvent,
    TaskFrame,
    TaskGraph,
    WaitAnyRequest,
    activity_epoch,
    note_parked,
    note_unparked,
)
from ..core.tracing import (
    EV_BLOCK,
    EV_DEADLOCK_POLL,
    EV_FRAME_WAKE,
    EV_GANG_ENTER,
    EV_GANG_EXIT,
    EV_GANG_RESERVE,
    EV_PARK,
    EV_REPLAY_FALLBACK,
    EV_REPLAY_SKIP,
    EV_REPLAY_STALL,
    EV_RESOURCE_ACQUIRE,
    EV_RESOURCE_RELEASE,
    EV_RESOURCE_WAIT,
    EV_RUN_AHEAD,
    EV_TASK_END,
    EV_UNBLOCK,
    EV_WAKE,
)
from ..obs.recorder import NULL_RECORDER, FlightRecorder
from ..resources.arbiter import ResourceArbiter
from .core import DispatchStrategy, ExecutorCore, GangRegion

if TYPE_CHECKING:  # avoid a circular import at load time (exec <-> replay)
    from ..replay.recording import GangPlacement, Recording


class ReplayError(RuntimeError):
    """The recording cannot drive this graph (e.g. an unplaced gang region)."""


class ReplayDispatch(DispatchStrategy):
    """Run-list dispatch driven by a :class:`Recording`."""

    _RUN_AHEAD_WINDOW = 32

    def __init__(self, recording: "Recording", *, stall_timeout: float = 1e-3,
                 trace: bool = False):
        self.core: Optional[ExecutorCore] = None
        self.recording = recording
        self.n_workers = recording.n_workers
        self.stall_timeout = stall_timeout
        self.trace_enabled = trace
        self.recorder = (FlightRecorder(recording.n_workers) if trace
                         else NULL_RECORDER)

        n = self.n_workers
        self._orders = [list(o) for o in recording.worker_orders]
        self._placements: Dict[int, "GangPlacement"] = dict(recording.gang_placements)
        self._issue_order: List[int] = list(recording.gang_issue_order)
        self._issue_set = set(self._issue_order)
        # spawn_tid -> recorded owner worker of every entry, for wakeups
        self._owner: Dict[int, int] = recording.owner_of()
        # (tid, seg) -> recorded owner of each frame-resume entry
        self._resume_owner: Dict[Tuple[int, int], int] = {
            (e.tid, e.seg): w
            for w, order in enumerate(self._orders)
            for e in order if isinstance(e, FrameResume)}
        # (tid, seg) -> recorded wait_any winner index (selects replay as
        # the recorded deterministic choice)
        self._wait_choices: Dict[Tuple[int, int], int] = dict(
            getattr(recording, "wait_choices", {}) or {})

        self._worker_cvs = [threading.Condition() for _ in range(n)]
        self._waiting = [False] * n          # worker w is parked on its cv
        self._fork_lock = threading.Lock()
        self._fork_cv = threading.Condition(self._fork_lock)

        # per-run preallocated state (reset in begin_run)
        self._graph: Optional[TaskGraph] = None
        self._n_tasks = 0
        self._indeg: List[int] = []
        self._ready: List[bool] = []
        self._claims: Dict[int, int] = {}
        self._done: List[bool] = []
        self._dep_seen: List[list] = []
        self._completed: list = []
        self._results: List[Any] = []
        self._regions: Dict[int, GangRegion] = {}
        self._issue_cursor = 0
        # suspendable frames of the current run: tid -> live frame, plus the
        # parked subset (waiting on a channel/event) for abort draining
        self._frames: Dict[int, TaskFrame] = {}
        self._parked: Dict[int, TaskFrame] = {}
        self._park_lock = threading.Lock()
        # serializes the resumable test-and-clear so the recorded owner and
        # a fallback helper can never both take one wakeup
        self._frame_gate = threading.Lock()
        # no-progress detection (mirrors DynamicDispatch): per-worker unit
        # depth + "top of stack blocked in plain-body recv/wait" flags
        self._depth = [0] * n
        self._stalled = [False] * n

        # resource arbiter in *pinned* mode: the recorded grant order is
        # replayed bit-identically (a declaring task runs only when it is
        # head of every relevant recorded per-resource grant queue)
        self.arbiter = ResourceArbiter()

        self.stats: Dict[str, int] = {}
        self.issued_gang_ids: List[int] = []

    # ------------------------------------------------------------------
    # DispatchStrategy interface
    def begin_run(self, graph: TaskGraph) -> None:
        n = len(graph)
        self._graph = graph
        self._n_tasks = n
        # Lock-free bookkeeping, built on CPython-atomic container ops:
        # * claim      = dict.setdefault(tid, w) — first setter wins;
        # * dep count  = list.append + len vs indegree (append is atomic;
        #                over-observing "ready" is idempotent);
        # * completion = append to a global list, drained when len == n.
        self._indeg = graph.indegrees()
        self._ready = [c == 0 for c in self._indeg]
        self._claims = {}
        self._done = [False] * n
        self._dep_seen = [[] for _ in range(n)]
        self._completed = []
        self._results = [None] * n
        self._regions = {}
        self._issue_cursor = 0
        self.drain_frames()                  # cancel a prior aborted run's
        for frame in self._frames.values():  # parked frames; close woken-
            frame.close()                    # but-never-resumed ones (no-op
        self._frames = {}                    # for completed generators)
        self._waiting = [False] * self.n_workers
        self._depth = [0] * self.n_workers
        self._stalled = [False] * self.n_workers
        self.stats = {"fallback_steals": 0, "stalls": 0, "skips": 0,
                      "run_ahead": 0, "frame_suspends": 0,
                      "resource_acquires": 0, "resource_waits": 0,
                      "resource_releases": 0}
        self.issued_gang_ids = []
        # pre-validation recordings may lack a grant order; fall back to
        # dynamic arbitration then (still mutually exclusive, not pinned)
        grants = list(getattr(self.recording, "resource_grants", ()) or ())
        self.arbiter.begin(graph, pinned_order=grants or None)
        self.recorder.begin_run()

    @property
    def drained(self) -> bool:
        return len(self._completed) >= self._n_tasks

    def results(self) -> Dict[int, Any]:
        return {t.tid: self._results[t.tid] for t in self._graph.tasks}

    def pending_units(self) -> int:
        return self._n_tasks - len(self._completed)

    def wake_all(self) -> None:
        for cv in self._worker_cvs:
            with cv:
                cv.notify_all()
        with self._fork_cv:
            self._fork_cv.notify_all()
        # non-blocking: the caller may hold a region cv (a barrier waiter
        # runs the deadlock detector inside `with region.cv`)
        for region in list(self._regions.values()):
            region.notify_nowait()

    # ------------------------------------------------------------------
    # worker loop
    def worker_loop(self, w: int) -> None:
        core = self.core
        order = self._orders[w]
        cv = self._worker_cvs[w]
        emit = self.recorder.emit
        idx = 0
        stalled = False
        idle = False   # park/wake events on transitions only (no flood)
        while idx < len(order):
            if core.aborted:
                return
            entry = order[idx]
            if isinstance(entry, int):
                advanced = self._try_task(w, entry)
            elif isinstance(entry, FrameResume):
                advanced = self._try_resume(w, entry)
            else:
                advanced = self._try_gang(w, entry)
            if advanced:
                idx += 1
                stalled = False
                if idle:
                    idle = False
                    emit(w, EV_WAKE)
                continue
            # next recorded entry not ready: stay work-conserving without
            # parking — run a later ready entry of our *own* list (claims
            # and counters gate correctness; the list order is a schedule
            # hint, not a constraint)
            if self._run_ahead(w, order, idx + 1):
                if idle:
                    idle = False
                    emit(w, EV_WAKE)
                continue
            # nothing of ours is ready: wait one stall window, then start
            # stealing dynamically (cost drift / stale recording)
            if stalled:
                self.stats["stalls"] += 1
                emit(w, EV_REPLAY_STALL, "", idx)
                if self._fallback_once(w):
                    if idle:
                        idle = False
                        emit(w, EV_WAKE)
                    continue
            if not idle:
                idle = True
                emit(w, EV_PARK)
            # Dekker-style handoff with completers: set the waiting flag,
            # THEN re-check readiness.  A completer sets ready, THEN reads
            # the flag — under the GIL one of the two always observes the
            # other, so no wakeup is ever missed.
            self._waiting[w] = True
            try:
                with cv:
                    if not self._entry_ready(entry):
                        cv.wait(timeout=self.stall_timeout)
            finally:
                self._waiting[w] = False
            stalled = True
        # list exhausted: keep serving stalled regions/tasks until the run
        # drains (a stale recording may leave work only this worker can
        # help).  Wait a stall window *before* each scan so recorded owners
        # keep priority over idle helpers on the hot path.
        while not self.drained and not core.aborted:
            with cv:
                if self.drained:
                    break
                if not idle:
                    idle = True
                    emit(w, EV_PARK)
                self._waiting[w] = True
                cv.wait(timeout=self.stall_timeout)
                self._waiting[w] = False
            if not self.drained and not core.aborted:
                if self._fallback_once(w) and idle:
                    idle = False
                    emit(w, EV_WAKE)

    def _run_ahead(self, w: int, order, start: int) -> bool:
        """Execute one ready-but-unclaimed later entry of our own run list
        (bounded scan).  Region-forking tasks are skipped: forks must issue
        in recorded order, and issuing one early from here could wait on a
        fork that sits behind us in this very list."""
        end = min(len(order), start + self._RUN_AHEAD_WINDOW)
        for j in range(start, end):
            e = order[j]
            if not isinstance(e, int):
                continue
            if (self._ready[e] and e not in self._claims
                    and e not in self._placements
                    and self.arbiter.runnable_now(e)):
                if self._claims.setdefault(e, w) != w:
                    continue
                self.recorder.emit(w, EV_RUN_AHEAD, "", e)
                self._execute(w, self._graph.tasks[e])
                self.stats["run_ahead"] += 1
                return True
        return False

    def _entry_ready(self, entry) -> bool:
        """Cheap re-check under the worker cv (pairs with notify ordering:
        state is written before the cv is taken, so no wakeup is missed)."""
        if isinstance(entry, int):
            return ((self._ready[entry] and self.arbiter.runnable_now(entry))
                    or entry in self._claims)
        if isinstance(entry, FrameResume):
            if self._done[entry.tid] or (entry.tid, entry.seg) in self._claims:
                return True
            frame = self._frames.get(entry.tid)
            return (frame is not None and frame.resumable
                    and frame.resumes == entry.seg - 1)
        return entry[0] in self._regions or self._done[entry[0]]

    def _try_task(self, w: int, tid: int) -> bool:
        """Attempt the next recorded task.  True => advance the list."""
        if tid in self._claims:
            # executed (or in flight) elsewhere — a fallback thief claimed
            # it; safe to move on, whoever claimed it completes it
            if not self._done[tid]:
                self.stats["skips"] += 1
                self.recorder.emit(w, EV_REPLAY_SKIP, "", tid)
            return True
        if not self._ready[tid]:
            return False
        if not self.arbiter.runnable_now(tid):
            return False     # not this task's recorded grant turn yet
        if self._claims.setdefault(tid, w) != w:
            return True
        self._execute(w, self._graph.tasks[tid])
        return True

    def _try_resume(self, w: int, entry: FrameResume) -> bool:
        """Attempt the next recorded frame-resume segment.  True => advance
        the list (executed here, already executed elsewhere, or stale)."""
        tid, seg = entry.tid, entry.seg
        key = (tid, seg)
        if key in self._claims:
            if not self._done[tid]:
                self.stats["skips"] += 1     # a fallback helper took our slot
                self.recorder.emit(w, EV_REPLAY_SKIP, "", tid, seg)
            return True
        if self._done[tid]:
            return True                      # frame already ran to completion
        frame = self._frames.get(tid)
        if frame is None:
            return False                     # task not started yet
        if frame.resumes >= seg:
            return True                      # a fallback helper raced past us
        if not self._take_resumable(frame, seg):
            return False                     # wakeup not arrived yet
        self._claims.setdefault(key, w)
        self._resume_segment(w, frame)
        return True

    def _try_gang(self, w: int, entry: Tuple[int, int]) -> bool:
        spawn_tid, thread_num = entry
        region = self._regions.get(spawn_tid)
        if region is None:
            if self._done[spawn_tid]:
                # region already fully joined (e.g. spawner ran ULTs inline
                # after a fallback thief raced us) — nothing left to do
                return True
            return False
        if not region.claim(thread_num):
            return True
        self._run_ult(w, region, thread_num)
        return True

    def _fallback_once(self, w: int) -> bool:
        """Dynamic fallback: serve one gang ULT of a published region (they
        gate everyone behind a blocking barrier) or one ready-but-unclaimed
        task.  Never steals a region-forking task recorded for another
        worker.  Returns True if work was executed."""
        for region in list(self._regions.values()):
            if region.finished:
                continue
            i = region.claim_any()
            if i is not None:
                self.recorder.emit(w, EV_REPLAY_FALLBACK, "gang",
                                   region.spawn_tid, i)
                self._run_ult(w, region, i)
                self.stats["fallback_steals"] += 1
                return True
        # resumable frames gate their successors like barriers do — serve
        # them even off their recorded slot (per-segment claims keep each
        # segment single-shot; the recorded owner just skips it)
        for tid, frame in list(self._frames.items()):
            if self._done[tid] or not frame.resumable:
                continue
            seg = frame.resumes + 1
            if not self._take_resumable(frame, seg):
                continue
            self._claims.setdefault((tid, seg), w)
            self.recorder.emit(w, EV_REPLAY_FALLBACK, "frame", tid, seg)
            self._resume_segment(w, frame)
            self.stats["fallback_steals"] += 1
            return True
        for tid in range(self._n_tasks):
            if self._ready[tid] and tid not in self._claims:
                if not self.arbiter.runnable_now(tid):
                    continue     # held elsewhere or not its grant turn
                if tid in self._placements:
                    if self._owner.get(tid, w) != w:
                        continue
                    # even our own forking task may only go when it is next
                    # in recorded issue order — claiming it early would park
                    # us on the fork cursor behind a fork only we can run
                    cursor = self._issue_cursor
                    if (tid in self._issue_set
                            and (cursor >= len(self._issue_order)
                                 or self._issue_order[cursor] != tid)):
                        continue
                if self._claims.setdefault(tid, w) != w:
                    continue
                self.recorder.emit(w, EV_REPLAY_FALLBACK, "task", tid)
                self._execute(w, self._graph.tasks[tid])
                self.stats["fallback_steals"] += 1
                return True
        return False

    # ------------------------------------------------------------------
    # execution
    def _execute(self, w: int, task: Task) -> None:
        arbiter = self.arbiter
        if arbiter.active and arbiter.needs(task.tid):
            # Gated callers claim only after `runnable_now`, and a pinned
            # head's availability can only improve (competitors sit behind
            # it in the grant queues), so the first acquire succeeds; the
            # loop covers the unpinned degraded mode, where contention
            # defers us onto the FIFO until a release grants us in turn.
            if not arbiter.try_acquire(task.tid):
                self.stats["resource_waits"] += 1
                self.recorder.emit_resource(w, EV_RESOURCE_WAIT, task)
                while not arbiter.try_acquire(task.tid):
                    if self.core.aborted:
                        return
                    time.sleep(0)
            self.stats["resource_acquires"] += 1
            self.recorder.emit_resource(w, EV_RESOURCE_ACQUIRE, task,
                                        len(arbiter.needs(task.tid)))
        self.recorder.emit_task_start(w, task)
        ctx = TaskContext(self._graph, task, self._results, runtime=self)
        ctx.worker_id = w  # type: ignore[attr-defined]
        self._depth[w] += 1
        try:
            result = task.fn(ctx) if task.fn is not None else None
            if isinstance(result, GeneratorType):
                # generator body => suspendable frame.  Replay always
                # suspends at yield points (even with data available) so the
                # recorded segmentation — and the interleaving — is
                # reproduced.
                ctx._in_frame = True
                frame = TaskFrame(task, ctx, result)
                frame.last_worker = w
                self._frames[task.tid] = frame
                self._advance_frame(w, frame)
                return
        finally:
            self._depth[w] -= 1
        self.recorder.emit(w, EV_TASK_END, "", task.tid)
        self._results[task.tid] = result
        self._complete(w, task)

    # ------------------------------------------------------------------
    # suspendable frames
    def _take_resumable(self, frame: TaskFrame, seg: int) -> bool:
        """Atomically consume the frame's wakeup for segment ``seg`` (the
        recorded owner and fallback helpers race here; exactly one wins)."""
        with self._frame_gate:
            if not frame.resumable or frame.resumes != seg - 1:
                return False
            frame.resumable = False
            return True

    def _resume_segment(self, w: int, frame: TaskFrame) -> None:
        frame.resumes += 1
        self.recorder.emit_frame_resume(w, frame)
        frame.ctx.worker_id = w  # type: ignore[attr-defined]
        frame.last_worker = w
        self._depth[w] += 1
        try:
            self._advance_frame(w, frame)
        finally:
            self._depth[w] -= 1

    def _advance_frame(self, w: int, frame: TaskFrame) -> None:
        value = frame.resume_value
        frame.resume_value = None
        status, payload = frame.step(value)
        if status == "done":
            self.recorder.emit(w, EV_TASK_END, "", frame.task.tid)
            self._results[frame.task.tid] = payload
            self._complete(w, frame.task)
            return
        self._park_frame(w, frame, payload)

    def _park_frame(self, w: int, frame: TaskFrame, request) -> None:
        core = self.core
        tid = frame.task.tid
        if isinstance(request, WaitAnyRequest):
            # pin the recorded winner: the select resolves to the same
            # (index, value) choice as the recorded run
            choice = self._wait_choices.get((tid, frame.resumes + 1))
            if choice is not None and 0 <= choice < len(request.requests):
                request = request.pinned(choice)

        def waker(value=None, *, _frame=frame):
            self._wake_frame(_frame, value)

        frame.request = request
        frame.waker = waker
        with self._park_lock:
            self._parked[tid] = frame
        note_parked(frame)
        core.note_frame_suspended()
        self.stats["frame_suspends"] += 1
        self.recorder.emit_frame_suspend(w, frame, request)
        status, value = request.park(waker)
        if status == "ready":
            waker(value)
        elif core.aborted:
            self._discard_parked(frame)

    def _wake_frame(self, frame: TaskFrame, value: Any) -> None:
        """Waker target: mark the frame resumable and nudge the recorded
        owner of its next resume segment."""
        tid = frame.task.tid
        with self._park_lock:
            if self._parked.pop(tid, None) is None:
                return
        note_unparked(frame)
        frame.resume_value = value
        frame.request = None
        frame.waker = None
        with self._frame_gate:
            frame.resumable = True
        self.core.note_frame_resumed()
        # the waker may be any thread (a worker mid-send or an external
        # caller) — worker -1 routes to the recorder's external ring
        self.recorder.emit(self.core.worker_id(default=-1), EV_FRAME_WAKE,
                           "", tid, frame.resumes + 1)
        owner = self._resume_owner.get((tid, frame.resumes + 1))
        if owner == self.core.worker_id(default=-1):
            return     # waking ourselves (send landed while we parked): we
                       # are awake and will hit the resume entry on our walk
        targets = range(self.n_workers) if owner is None else (owner,)
        for t in targets:
            cv = self._worker_cvs[t]
            with cv:
                cv.notify_all()

    def _discard_parked(self, frame: TaskFrame) -> None:
        with self._park_lock:
            if self._parked.pop(frame.task.tid, None) is None:
                return
        note_unparked(frame)
        if frame.request is not None:
            frame.request.cancel(frame.waker)
        self.core.note_frame_resumed()
        frame.close()

    def drain_frames(self) -> None:
        with self._park_lock:
            frames = list(self._parked.values())
        for frame in frames:
            self._discard_parked(frame)
        # an aborted run must not leak grants into the next begin_run
        self.arbiter.abort()

    # ------------------------------------------------------------------
    # plain-body blocking communication (mirrors DynamicDispatch semantics:
    # the worker helps through the fallback path instead of idling)
    def ctx_recv(self, channel: Channel, ctx: TaskContext) -> Any:
        return self._blocking_wait(channel.try_recv, "recv", channel.uid)

    def ctx_wait(self, event: TaskEvent, ctx: TaskContext) -> None:
        self._blocking_wait(
            lambda: ((True, None) if event.is_set() else (False, None)),
            "wait", event.uid)

    def ctx_send(self, channel: Channel, value: Any, ctx: TaskContext) -> None:
        self._blocking_wait(
            lambda: ((True, None) if channel.try_send(value)
                     else (False, None)),
            "send", channel.uid)

    def ctx_wait_any(self, request: WaitAnyRequest, ctx: TaskContext) -> Any:
        return self._blocking_wait(request.try_immediate, "wait_any")

    def ctx_yield(self, ctx: TaskContext) -> None:
        self._fallback_once(self.core.worker_id())

    def _blocking_wait(self, poll, what: str = "", uid: int = -1) -> Any:
        core = self.core
        w = core.worker_id()
        ok, value = poll()
        if ok:    # satisfied immediately: no block window, no events
            return value
        emit = self.recorder.emit
        emit(w, EV_BLOCK, what, uid)
        try:
            while True:
                ok, value = poll()
                if ok:
                    return value
                if core.aborted:
                    raise DeadlockError(core.abort_reason())
                if self._fallback_once(w):
                    continue
                self._stalled[w] = True
                try:
                    time.sleep(self.stall_timeout)
                    ok, value = poll()
                    if ok:
                        return value
                    self._check_no_progress()
                finally:
                    self._stalled[w] = False
        finally:
            emit(w, EV_UNBLOCK, "", uid)

    def _active_workers(self) -> int:
        return sum(1 for w in range(self.n_workers)
                   if self._depth[w] > 0 and not self._stalled[w])

    def _check_no_progress(self) -> None:
        """A plain-body recv/wait no remaining replay work can satisfy:
        nothing executing freely, no completion and no wakeup across a
        confirmation window (completed-count is the progress proxy — any
        runnable run-list entry gets executed by its owner or a fallback
        helper well within ``block_poll``)."""
        core = self.core
        if self.drained or core.aborted or self._active_workers() > 0:
            return
        self.recorder.emit(core.worker_id(default=-1), EV_DEADLOCK_POLL)
        before = (len(self._completed), core.resume_epoch, activity_epoch())
        time.sleep(core.block_poll)
        if (not self.drained and not core.aborted
                and self._active_workers() == 0
                and sum(self._stalled) > 0
                and (len(self._completed), core.resume_epoch,
                     activity_epoch()) == before):
            core.frame_deadlock(
                f"deadlock: {sum(self._stalled)} worker(s) blocked in "
                "task-body recv/wait during replay with no progress left "
                "in the run")

    # ------------------------------------------------------------------
    # flight-recorder assembly
    def take_trace(self):
        """Assemble the last run's events into a
        :class:`~repro.obs.trace.RuntimeTrace` (``None`` with tracing off)."""
        if not self.trace_enabled:
            return None
        from ..obs.trace import RuntimeTrace
        return RuntimeTrace.from_recorder(self.recorder)

    def _complete(self, w: int, task: Task) -> None:
        arbiter = self.arbiter
        if arbiter.active and arbiter.holds(task.tid):
            n_res = len(arbiter.needs(task.tid))
            arbiter.release(task.tid)
            self.stats["resource_releases"] += 1
            self.recorder.emit_resource(w, EV_RESOURCE_RELEASE, task, n_res)
            # nudge the recorded owner of each resource's next grantee
            # (release-then-read pairs with the waiter's set-flag-then-check)
            for nxt in arbiter.pinned_heads():
                owner = self._owner.get(nxt, -1)
                if 0 <= owner != w and self._waiting[owner]:
                    cv = self._worker_cvs[owner]
                    with cv:
                        cv.notify()
        self._done[task.tid] = True
        dep_seen = self._dep_seen
        indeg = self._indeg
        for s in self._graph.successors(task):
            stid = s.tid
            lst = dep_seen[stid]
            lst.append(None)                 # atomic; last appender sees full
            if len(lst) < indeg[stid]:
                continue
            self._ready[stid] = True
            owner = self._owner.get(stid, -1)
            # wake the recorded owner only if it is parked: completers set
            # ready THEN read the flag, waiters set the flag THEN re-check
            # readiness — one side always observes the other (GIL order)
            if 0 <= owner != w and self._waiting[owner]:
                cv = self._worker_cvs[owner]
                with cv:
                    cv.notify()
        self._completed.append(task.tid)     # atomic completion count
        if self.drained:
            self.core.signal_done()
            # kick parked helpers out of their stall windows so the core is
            # immediately idle for the next run() of the sweep
            for cv in self._worker_cvs:
                with cv:
                    cv.notify_all()

    def _run_ult(self, w: int, region: GangRegion, thread_num: int) -> None:
        # replay regions carry no rid; key gang spans by spawning task
        rid = region.rid if region.rid >= 0 else region.spawn_tid
        self.recorder.emit(w, EV_GANG_ENTER, "", rid, thread_num)
        self._depth[w] += 1
        try:
            result = region.body(thread_num, region)
        finally:
            self._depth[w] -= 1
            self.recorder.emit(w, EV_GANG_EXIT, "", rid, thread_num)
        region.thread_done(thread_num, result)

    # ------------------------------------------------------------------
    # parallel regions (TaskContext.parallel delegates here)
    def parallel(
        self,
        n_threads: int,
        body: Callable[[int, GangRegion], Any],
        *,
        gang: Optional[bool] = None,
        spawn_ctx: Optional[TaskContext] = None,
    ) -> List[Any]:
        """Fork/join a region on its recorded placement.  The recorded fork
        (gang-id) order is enforced: a fork waits until every earlier
        recorded fork has been issued."""
        del gang  # the recording already fixed the gang decision
        core = self.core
        spawn_recorded = (spawn_ctx is not None
                          and spawn_ctx.task.tid in self._placements)
        if n_threads == 1 and not spawn_recorded:
            # unrecorded single-ULT region: no barrier partner needed, run
            # inline (recorded ones go through the normal path so the fork
            # still issues in recorded gang-id order)
            region = GangRegion(core, 1, body=body)
            region.started[0] = True
            self._run_ult(core.worker_id(), region, 0)
            return list(region.results)
        if spawn_ctx is None:
            raise ReplayError("replayed regions need a spawning task context")
        if n_threads > self.n_workers:
            raise ReplayError(
                f"region requests {n_threads} ULTs but the replay pool has "
                f"{self.n_workers} workers; blocking barriers would deadlock")
        spawn_tid = spawn_ctx.task.tid
        w = core.worker_id()

        placement = self._placements.get(spawn_tid)
        region = GangRegion(
            core, n_threads,
            gang_id=placement.gang_id if placement else -1,
            spawn_tid=spawn_tid, body=body)
        if placement is not None and len(placement.workers) != n_threads:
            raise ReplayError(
                f"task {spawn_tid} forked {n_threads} ULTs but the recording "
                f"placed {len(placement.workers)}")

        # monotonic issue-order discipline: publish in recorded fork order
        in_issue_order = spawn_tid in self._issue_set
        with self._fork_cv:
            while (in_issue_order
                   and self._issue_cursor < len(self._issue_order)
                   and self._issue_order[self._issue_cursor] != spawn_tid):
                if core.aborted:
                    raise DeadlockError(core.abort_reason())
                self._fork_cv.wait(timeout=core.block_poll)
            if in_issue_order and self._issue_cursor < len(self._issue_order):
                self._issue_cursor += 1
            if spawn_tid in self._regions:
                raise ReplayError(
                    f"task {spawn_tid} forked a second parallel region; "
                    "recordings key regions by spawning task (one per task)")
            self.issued_gang_ids.append(region.gang_id)
            self._regions[spawn_tid] = region
            self.recorder.emit(w, EV_GANG_RESERVE, "", spawn_tid, n_threads)
            self._fork_cv.notify_all()

        # wake recorded members; unplaced regions (static seed) are served by
        # whichever workers stall, so wake everyone
        members = set(placement.workers) if placement is not None \
            else set(range(self.n_workers))
        for member in members:
            if member != w:
                cv = self._worker_cvs[member]
                with cv:
                    cv.notify_all()

        # join: run own recorded ULTs inline (our run-list entries for this
        # region sit *after* the spawning task — we are blocked here), then
        # help via fallback until the region completes
        if placement is not None:
            for i, member in enumerate(placement.workers):
                if member == w and region.claim(i):
                    self._run_ult(w, region, i)
        while not region.finished:
            if core.aborted:
                raise DeadlockError(core.abort_reason())
            i = region.claim_any() if placement is None else None
            if i is not None:
                self._run_ult(w, region, i)
                continue
            with region.cv:
                if not region.finished:
                    region.cv.wait(timeout=core.block_poll)
        return list(region.results)
