"""Unified executor core: one worker substrate under every scheduler.

:class:`ExecutorCore` owns persistent worker threads (park/wake between
runs), unified :class:`GangRegion` parallel regions (blocking barriers with
centralized blocked-thread accounting and Fig.-1 deadlock detection), and a
pluggable :class:`DispatchStrategy`:

* :class:`DynamicDispatch` — per-worker work-stealing deques, Algorithm-2
  victim selection, Algorithm-1 gang reservation (+ record-and-replay
  instrumentation);
* :class:`ReplayDispatch` — preallocated run lists, recorded gang
  placements with monotonic issue order, run-ahead and stall-triggered
  dynamic fallback.

The public entry points remain the facades:
:class:`~repro.core.runtime.Runtime` (dynamic),
:class:`~repro.replay.executor.ReplayExecutor` (replay) and
:class:`~repro.replay.pool.ReplayPool` (serving) — all three lease worker
time from this substrate.
"""

from .core import DispatchStrategy, ExecutorCore, GangRegion
from .dynamic import DynamicDispatch
from .replay import ReplayDispatch, ReplayError

__all__ = [
    "DispatchStrategy",
    "DynamicDispatch",
    "ExecutorCore",
    "GangRegion",
    "ReplayDispatch",
    "ReplayError",
]
