"""Unified executor core: one worker substrate under every scheduler.

:class:`ExecutorCore` owns persistent worker threads (park/wake between
runs), unified :class:`GangRegion` parallel regions (blocking barriers with
centralized blocked-thread accounting and Fig.-1 deadlock detection), and a
pluggable :class:`DispatchStrategy`:

* :class:`DynamicDispatch` — per-worker work-stealing deques, Algorithm-2
  victim selection, Algorithm-1 gang reservation (+ record-and-replay
  instrumentation);
* :class:`ReplayDispatch` — preallocated run lists, recorded gang
  placements with monotonic issue order, run-ahead and stall-triggered
  dynamic fallback.

Both dispatches execute *suspendable task frames*: generator task bodies
yield ``ctx.recv``/``ctx.wait``/``ctx.yield_`` requests and are parked
without occupying their worker (soft-blocked — excluded from Fig.-1
hard-block accounting), then resumed on any worker.  Dynamic treats resumed
frames as locality-preferring stealable work; replay reproduces the
recorded resume segmentation (``FrameResume`` run-list entries).

:class:`CoreRegistry` / :func:`shared_core` add process-global core
sharing: one refcounted core per worker count serves every pool/facade in
the process, capping threads across tenants.

The public entry points remain the facades:
:class:`~repro.core.runtime.Runtime` (dynamic),
:class:`~repro.replay.executor.ReplayExecutor` (replay) and
:class:`~repro.replay.pool.ReplayPool` (serving) — all three lease worker
time from this substrate.
"""

from .. import core as _core  # noqa: F401  (initialize repro.core first:
# repro.core.runtime imports repro.exec.core, so letting the package cycle
# start HERE — instead of inside .core's module body — keeps
# ``import repro.exec`` working as a first import)
from .core import DispatchStrategy, ExecutorCore, GangRegion
from .dynamic import DynamicDispatch
from .registry import REGISTRY, CoreRegistry, release_shared_core, shared_core
from .replay import ReplayDispatch, ReplayError

__all__ = [
    "CoreRegistry",
    "DispatchStrategy",
    "DynamicDispatch",
    "ExecutorCore",
    "GangRegion",
    "REGISTRY",
    "ReplayDispatch",
    "ReplayError",
    "release_shared_core",
    "shared_core",
]
