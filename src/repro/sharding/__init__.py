from .rules import ShardCtx, logical_to_pspec, params_pspecs

__all__ = ["ShardCtx", "logical_to_pspec", "params_pspecs"]
