"""Logical-axis -> mesh-axis sharding rules (DP / TP / EP / SP).

Meshes
------
* single-pod: ``(data=16, model=16)``
* multi-pod:  ``(pod=2, data=16, model=16)`` — ``pod`` is an outer
  data-parallel axis (gradients cross pods once per step).

Rules (Megatron TP + EP + optional SP):

====================  =========================
logical axis          mesh axes
====================  =========================
batch                 ("pod", "data")  /  ("data",)
vocab / heads / ff /
experts / kv_heads*   "model"
embed / seq / state   unsharded (seq shards on "data" for long-context KV)
layers                unsharded (scan axis)
====================  =========================

``kv_heads`` falls back to replication when ``n_kv_heads < |model|`` (GQA
with tp > kv: standard KV replication).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass
class ShardCtx:
    """Everything the model code needs to know about distribution."""

    mesh: Optional[Mesh]
    model_axis: str = "model"
    data_axes: Tuple[str, ...] = ("data",)
    pod_axis: Optional[str] = None
    shard_kv: bool = True            # False => replicate KV heads (GQA tp>kv)
    seq_shard_cache: bool = False    # True => KV cache seq dim on data axes
    fsdp: bool = True                # shard d_model param dims over data axes
                                     # (ZeRO-3-via-GSPMD: per-layer all-gather)
    remat_group: int = 1             # 2-level remat: checkpoint every k layers
    moe_wire_bf16: bool = False      # MoE EP combine (psum) in bf16

    @property
    def batch_axes(self) -> Tuple[str, ...]:
        return ((self.pod_axis,) if self.pod_axis else ()) + tuple(self.data_axes)

    @property
    def model_size(self) -> int:
        return self.mesh.shape[self.model_axis] if self.mesh else 1

    @property
    def dp_size(self) -> int:
        if not self.mesh:
            return 1
        n = 1
        for a in self.batch_axes:
            n *= self.mesh.shape[a]
        return n

    def rules(self) -> Dict[str, Any]:
        batch = self.batch_axes if self.mesh else ()
        return {
            "batch": batch if batch else None,
            "seq": None,
            # param d_model dims shard over the batch axes under FSDP
            # (GSPMD inserts the per-layer all-gather); activations' embed
            # dim stays unsharded (Megatron TP).
            "embed": self.batch_axes if (self.fsdp and self.mesh) else None,
            "heads": self.model_axis,
            "kv_heads": self.model_axis if self.shard_kv else None,
            "ff": self.model_axis,
            "vocab": self.model_axis,
            "experts": self.model_axis,
            "ssm_inner": self.model_axis,
            "state": None,
            "layers": None,
            None: None,
        }


def logical_to_pspec(axes: Tuple[Optional[str], ...], ctx: ShardCtx) -> P:
    r = ctx.rules()
    return P(*[r.get(a) for a in axes])


def params_pspecs(spec_axes_tree, ctx: ShardCtx):
    """Map a logical-axes tree (from layers.spec_axes) to PartitionSpecs."""
    if isinstance(spec_axes_tree, dict):
        return {k: params_pspecs(v, ctx) for k, v in spec_axes_tree.items()}
    return logical_to_pspec(tuple(spec_axes_tree), ctx)


def named(tree_pspecs, mesh: Mesh):
    return jax.tree.map(lambda p: NamedSharding(mesh, p), tree_pspecs,
                        is_leaf=lambda x: isinstance(x, P))


def make_ctx(mesh: Optional[Mesh], cfg=None) -> ShardCtx:
    """Build a ShardCtx from a mesh, adapting rules to the config (KV
    replication when GQA heads < model size)."""
    if mesh is None:
        return ShardCtx(mesh=None)
    axis_names = mesh.axis_names
    pod = "pod" if "pod" in axis_names else None
    shard_kv = True
    if cfg is not None and getattr(cfg, "n_kv_heads", 0):
        shard_kv = cfg.n_kv_heads % mesh.shape["model"] == 0
    return ShardCtx(mesh=mesh, pod_axis=pod, data_axes=("data",), shard_kv=shard_kv)
