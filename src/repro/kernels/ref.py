"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal: bool = True, window: int = 0):
    """q: (B,H,S,d); k/v: (B,H,S,d) (kv heads already repeated)."""
    B, H, S, d = q.shape
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(d)
    qp = jnp.arange(S)[:, None]
    kp = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= qp >= kp
    if window > 0:
        mask &= qp - kp < window
    s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)).astype(q.dtype)


def decode_attention_ref(q, k, v, length: int):
    """q: (B,H,d); k/v: (B,S,H,d); attend to k[:length]."""
    B, S, H, d = k.shape
    s = jnp.einsum("bhd,bshd->bhs", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(d)
    mask = jnp.arange(S)[None, None, :] < length
    s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhs,bshd->bhd", p, v.astype(jnp.float32)).astype(q.dtype)


def tile_matmul_ref(a, b, c: Optional[jnp.ndarray] = None):
    """C (+)= A @ B in f32 accumulation."""
    out = jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32))
    if c is not None:
        out = out + c.astype(jnp.float32)
    return out.astype(a.dtype)


def ssd_chunk_ref(xdt, cs, Bm, Cm, s_in):
    """One SSD chunk (the Pallas kernel's unit of work).

    xdt: (L,H,P) = x*dt; cs: (L,H) cumulative log-decay; Bm/Cm: (L,N);
    s_in: (H,N,P) incoming state.  Returns (y (L,H,P), s_out (H,N,P))."""
    L, H, P = xdt.shape
    cb = Cm.astype(jnp.float32) @ Bm.astype(jnp.float32).T            # (L,L)
    diff = cs[:, None, :] - cs[None, :, :]                            # (L,L,H)
    mask = jnp.tril(jnp.ones((L, L), bool))
    decay = jnp.where(mask[:, :, None], jnp.exp(diff), 0.0)
    y_intra = jnp.einsum("ij,ijh,jhp->ihp", cb, decay, xdt.astype(jnp.float32))
    y_inter = jnp.einsum("in,hnp->ihp", Cm.astype(jnp.float32),
                         s_in.astype(jnp.float32)) * jnp.exp(cs)[:, :, None]
    w_end = jnp.exp(cs[-1][None, :] - cs)                             # (L,H)
    s_out = s_in * jnp.exp(cs[-1])[:, None, None] + jnp.einsum(
        "jn,jh,jhp->hnp", Bm.astype(jnp.float32), w_end,
        xdt.astype(jnp.float32))
    return y_intra + y_inter, s_out
