"""Pallas TPU flash attention (causal + sliding-window), forward.

TPU-native tiling: q blocks live in VMEM, the kernel sweeps kv blocks with
the grid's minor dimension, carrying the (m, l, acc) lazy-softmax state in
VMEM scratch.  Block sizes default to MXU-aligned (128) multiples.

Grid: (B*H, Sq/bq, Sk/bk)  — kv is the innermost (sequential) dimension, so
the scratch carry is valid (TPU grids execute minor-most sequentially).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BQ = 128
DEFAULT_BK = 128
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  causal: bool, window: int, bq: int, bk: int, n_kv: int,
                  scale: float):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        mask &= q_pos >= k_pos
    if window > 0:
        mask &= (q_pos - k_pos) < window

    q = q_ref[0].astype(jnp.float32)                  # (bq, d)
    k = k_ref[0].astype(jnp.float32)                  # (bk, d)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    l_prev = l_ref[...]
    m_cur = jnp.max(s, axis=1)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new[:, None])
    p = jnp.where(mask, p, 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + jnp.sum(p, axis=1)
    v = v_ref[0].astype(jnp.float32)                  # (bk, d)
    pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    acc_ref[...] = acc_ref[...] * corr[:, None] + pv
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(ki == n_kv - 1)
    def _finalize():
        l = l_ref[...]
        o_ref[0, ...] = (acc_ref[...] /
                         jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "bq", "bk",
                                             "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    bq: int = DEFAULT_BQ, bk: int = DEFAULT_BK,
                    interpret: bool = False):
    """q,k,v: (B, H, S, d) with kv heads pre-repeated.  Returns (B,H,S,d)."""
    B, H, S, d = q.shape
    assert S % bq == 0 and S % bk == 0, (S, bq, bk)
    n_q = S // bq
    n_kv = S // bk
    scale = 1.0 / math.sqrt(d)

    qf = q.reshape(B * H, S, d)
    kf = k.reshape(B * H, S, d)
    vf = v.reshape(B * H, S, d)

    kernel = functools.partial(
        _flash_kernel, causal=causal, window=window, bq=bq, bk=bk,
        n_kv=n_kv, scale=scale)

    out = pl.pallas_call(
        kernel,
        grid=(B * H, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),        # m
            pltpu.VMEM((bq,), jnp.float32),        # l
            pltpu.VMEM((bq, d), jnp.float32),      # acc
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, S, d)
