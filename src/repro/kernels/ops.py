"""jit'd public wrappers for the Pallas kernels.

On TPU these dispatch to the kernels; on CPU they run in interpret mode (for
tests) or fall back to the jnp reference path — selected by ``mode``:

* ``auto``      — Pallas on TPU, reference elsewhere (production default)
* ``pallas``    — force the kernel (TPU)
* ``interpret`` — kernel body interpreted in Python (CPU validation)
* ``ref``       — pure-jnp oracle
"""

from __future__ import annotations

import jax

from . import ref as _ref
from .decode_attention import decode_attention as _decode_pallas
from .flash_attention import flash_attention as _flash_pallas
from .ssd_scan import ssd_scan as _ssd_pallas, ssd_scan_ref as _ssd_ref
from .tile_matmul import tile_matmul as _mm_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _resolve(mode: str) -> str:
    if mode != "auto":
        return mode
    return "pallas" if _on_tpu() else "ref"


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    mode: str = "auto", **kw):
    m = _resolve(mode)
    if m == "ref":
        return _ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    return _flash_pallas(q, k, v, causal=causal, window=window,
                         interpret=(m == "interpret"), **kw)


def decode_attention(q, k, v, length, *, mode: str = "auto", **kw):
    m = _resolve(mode)
    if m == "ref":
        return _ref.decode_attention_ref(q, k, v, length)
    return _decode_pallas(q, k, v, length, interpret=(m == "interpret"), **kw)


def ssd_scan(xdt, cs, Bm, Cm, *, mode: str = "auto"):
    m = _resolve(mode)
    if m == "ref":
        return _ssd_ref(xdt, cs, Bm, Cm)
    return _ssd_pallas(xdt, cs, Bm, Cm, interpret=(m == "interpret"))


def tile_matmul(a, b, *, mode: str = "auto", **kw):
    m = _resolve(mode)
    if m == "ref":
        return _ref.tile_matmul_ref(a, b)
    return _mm_pallas(a, b, interpret=(m == "interpret"), **kw)
