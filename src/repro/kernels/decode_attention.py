"""Pallas TPU flash-decoding kernel: one query token against a long KV
cache, split-K over sequence blocks with lazy-softmax carry.

Grid: (B*H, S/bk) — sequence blocks sequential (minor-most), carrying
(m, l, acc) scratch; `length` masks the unfilled cache tail.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                   *, bk: int, n_kv: int):
    ki = pl.program_id(1)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = len_ref[0]
    d = q_ref.shape[-1]
    q = q_ref[0].astype(jnp.float32)                      # (1, d)
    k = k_ref[0].astype(jnp.float32)                      # (bk, d)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (1, bk)
    s = s / math.sqrt(d)
    pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (1, bk), 1)
    mask = pos < length
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s))
    p = jnp.where(mask, jnp.exp(s - m_new), 0.0)          # (1, bk)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p)
    v = v_ref[0].astype(jnp.float32)                      # (bk, d)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ki == n_kv - 1)
    def _finalize():
        o_ref[0, ...] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
                         ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bk", "interpret"))
def decode_attention(q, k, v, length, *, bk: int = 512, interpret: bool = False):
    """q: (B,H,d); k/v: (B,S,H,d) (kv pre-repeated to H); length: int32
    scalar (valid cache entries).  Returns (B,H,d)."""
    B, S, H, d = k.shape
    assert S % bk == 0, (S, bk)
    n_kv = S // bk
    qf = q.reshape(B * H, 1, d)
    kf = jnp.swapaxes(k, 1, 2).reshape(B * H, S, d)
    vf = jnp.swapaxes(v, 1, 2).reshape(B * H, S, d)
    lvec = jnp.full((1,), length, jnp.int32)

    out = pl.pallas_call(
        functools.partial(_decode_kernel, bk=bk, n_kv=n_kv),
        grid=(B * H, n_kv),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, d), lambda b, j: (b, 0, 0)),
            pl.BlockSpec((1, bk, d), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, bk, d), lambda b, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, d), lambda b, j: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, 1, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1, d), jnp.float32),
        ],
        interpret=interpret,
    )(lvec, qf, kf, vf)
    return out.reshape(B, H, d)
