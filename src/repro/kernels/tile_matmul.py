"""Pallas TPU blocked GEMM (the SLATE trailing-update hot spot on TPU).

C = A @ B (+ C_in) with (bm, bk) x (bk, bn) VMEM tiles and f32 accumulation
in VMEM scratch.  Grid: (M/bm, N/bn, K/bk) — K innermost (sequential) so the
accumulator carry is valid.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _mm_kernel(a_ref, b_ref, o_ref, acc_ref, *, n_k: int):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        a_ref[...].astype(jnp.float32), b_ref[...].astype(jnp.float32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(ki == n_k - 1)
    def _finalize():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def tile_matmul(a, b, *, bm: int = 256, bn: int = 256, bk: int = 256,
                interpret: bool = False):
    """a: (M, K); b: (K, N) -> (M, N)."""
    M, K = a.shape
    K2, N = b.shape
    assert K == K2
    bm, bn, bk = min(bm, M), min(bn, N), min(bk, K)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, (M, N, K, bm, bn, bk)

    return pl.pallas_call(
        functools.partial(_mm_kernel, n_k=K // bk),
        grid=(M // bm, N // bn, K // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), a.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(a, b)
