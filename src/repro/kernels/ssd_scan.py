"""Pallas TPU kernel for the Mamba2 SSD chunk step.

One grid step processes one (batch, chunk) pair for a block of heads:
intra-chunk masked-decay attention + inter-chunk state contribution + state
update, with the chunk-to-chunk state recurrence carried in VMEM scratch
(the chunk axis is the grid's minor-most dimension, hence sequential).

This is the TPU adaptation of the SSD algorithm's Triton kernel: the L x L
decay matrix is built in VMEM per (chunk, head-block), never in HBM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(xdt_ref, cs_ref, b_ref, c_ref, y_ref, slast_ref, s_ref, *,
                n_chunks: int, L: int, H: int, N: int, P: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    xdt = xdt_ref[0, 0].astype(jnp.float32)   # (L, H, P)
    cs = cs_ref[0, 0].astype(jnp.float32)      # (L, H)
    Bm = b_ref[0, 0].astype(jnp.float32)       # (L, N)
    Cm = c_ref[0, 0].astype(jnp.float32)       # (L, N)
    s_in = s_ref[...]                         # (H, N, P)

    # intra-chunk: y[i] = sum_{j<=i} (C_i.B_j) exp(cs_i - cs_j) xdt_j
    cb = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (L, L)
    ii = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    tril = ii >= jj
    # per-head decay handled head-by-head to keep the VMEM block 2D-friendly
    y = jnp.zeros((L, H, P), jnp.float32)

    def head_body(h, y):
        csh = cs[:, h]                                     # (L,)
        decay = jnp.where(tril, jnp.exp(csh[:, None] - csh[None, :]), 0.0)
        w = cb * decay                                     # (L, L)
        yh = jax.lax.dot_general(w, xdt[:, h, :], (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)  # (L,P)
        # inter-chunk: C_i . s_in[h] * exp(cs_i)
        yh += jax.lax.dot_general(Cm, s_in[h], (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32) \
            * jnp.exp(csh)[:, None]
        return y.at[:, h, :].set(yh)

    y = jax.lax.fori_loop(0, H, head_body, y)
    y_ref[0, 0, ...] = y.astype(y_ref.dtype)

    # state update: s_out[h] = s_in[h]*exp(cs_L[h]) + sum_j B_j w_end[j,h] xdt[j,h]
    def state_body(h, s):
        csh = cs[:, h]
        w_end = jnp.exp(csh[-1] - csh)                     # (L,)
        bw = Bm * w_end[:, None]                           # (L, N)
        upd = jax.lax.dot_general(bw, xdt[:, h, :], (((0,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)  # (N,P)
        return s.at[h].set(s[h] * jnp.exp(csh[-1]) + upd)

    s_new = jax.lax.fori_loop(0, H, state_body, s_in)
    s_ref[...] = s_new

    @pl.when(ci == n_chunks - 1)
    def _final():
        slast_ref[0, ...] = s_new.astype(slast_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_scan(xdt, cs, Bm, Cm, *, interpret: bool = False):
    """xdt: (B, nc, L, H, P) = x*dt per chunk; cs: (B, nc, L, H) cumulative
    log-decay; Bm/Cm: (B, nc, L, N).  Returns (y (B,nc,L,H,P),
    final_state (B,H,N,P))."""
    B, nc, L, H, P = xdt.shape
    N = Bm.shape[-1]

    y, s_last = pl.pallas_call(
        functools.partial(_ssd_kernel, n_chunks=nc, L=L, H=H, N=N, P=P),
        grid=(B, nc),
        in_specs=[
            pl.BlockSpec((1, 1, L, H, P), lambda b, c: (b, c, 0, 0, 0)),
            pl.BlockSpec((1, 1, L, H), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, L, N), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, L, N), lambda b, c: (b, c, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, L, H, P), lambda b, c: (b, c, 0, 0, 0)),
            pl.BlockSpec((1, H, N, P), lambda b, c: (b, 0, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, nc, L, H, P), xdt.dtype),
            jax.ShapeDtypeStruct((B, H, N, P), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((H, N, P), jnp.float32)],
        interpret=interpret,
    )(xdt, cs, Bm, Cm)
    return y, s_last


def ssd_scan_ref(xdt, cs, Bm, Cm):
    """jnp oracle over the same chunked layout (wraps ref.ssd_chunk_ref)."""
    from .ref import ssd_chunk_ref
    B, nc, L, H, P = xdt.shape
    N = Bm.shape[-1]
    ys = []
    s = jnp.zeros((B, H, N, P), jnp.float32)
    for c in range(nc):
        ych = []
        sch = []
        for b in range(B):
            y, s_b = ssd_chunk_ref(xdt[b, c], cs[b, c], Bm[b, c], Cm[b, c], s[b])
            ych.append(y)
            sch.append(s_b)
        ys.append(jnp.stack(ych))
        s = jnp.stack(sch)
    return jnp.stack(ys, axis=1).astype(xdt.dtype), s
