"""Deterministic sharded synthetic data pipeline with background prefetch.

Production shape: per-host sharded batches (each host materializes only its
slice), deterministic from (seed, step) — so restart/elastic-reshard resumes
produce identical streams — plus a double-buffered prefetch thread so host
data generation overlaps device compute (the paper's comm/compute-overlap
discipline applied to the input pipeline)."""

from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Dict, Iterator, Optional, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_hosts: int = 1
    host_id: int = 0
    extra: Tuple[Tuple[str, Tuple[int, ...]], ...] = ()   # e.g. (("patches",(1600,128)),)

    @property
    def host_batch(self) -> int:
        assert self.global_batch % self.n_hosts == 0
        return self.global_batch // self.n_hosts


class SyntheticLMData:
    """Markov-ish synthetic token stream: next-token structure exists (so
    loss decreases in the e2e example) but generation is pure numpy."""

    def __init__(self, cfg: DataConfig, prefetch: int = 2):
        self.cfg = cfg
        self._q: "queue.Queue" = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._step = 0
        self._thread: Optional[threading.Thread] = None

    # -- deterministic batch synthesis -----------------------------------
    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step, cfg.host_id]))
        b, s, v = cfg.host_batch, cfg.seq_len, cfg.vocab_size
        # structured stream: x_{t+1} = (a * x_t + c) % v with noise
        a = 31, 17
        x0 = rng.integers(0, v, size=(b, 1))
        mult = rng.choice(a, size=(b, 1))
        t = np.arange(s + 1)
        toks = (x0 * np.power(mult, t % 7, dtype=np.int64) + 13 * t) % v
        noise = rng.random((b, s + 1)) < 0.05
        toks = np.where(noise, rng.integers(0, v, size=(b, s + 1)), toks)
        out = {
            "tokens": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32),
        }
        for name, shape in cfg.extra:
            out[name] = rng.standard_normal((b,) + shape).astype(np.float32)
        return out

    # -- prefetching iterator ---------------------------------------------
    def start(self, from_step: int = 0) -> None:
        self._step = from_step
        self._stop.clear()

        def worker():
            step = from_step
            while not self._stop.is_set():
                batch = self.batch_at(step)
                while not self._stop.is_set():
                    try:
                        self._q.put((step, batch), timeout=0.1)
                        break
                    except queue.Full:
                        continue
                step += 1

        self._thread = threading.Thread(target=worker, daemon=True)
        self._thread.start()

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=2.0)
        while not self._q.empty():
            try:
                self._q.get_nowait()
            except queue.Empty:
                break

    def __iter__(self) -> Iterator[Tuple[int, Dict[str, np.ndarray]]]:
        if self._thread is None:
            self.start(self._step)
        while True:
            yield self._q.get()

    def state_dict(self) -> Dict[str, int]:
        return {"step": self._step, "seed": self.cfg.seed}
