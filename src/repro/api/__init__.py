"""API v2: futures-based graph construction, sessions, plans, run reports.

The user-facing layer over the paper's scheduling extensions:

* :class:`Graph` / :class:`TaskHandle` — dataflow construction: ``add``
  returns a future whose value can be passed as an argument to downstream
  tasks (dependencies inferred, composing with explicit ``deps=``);
* :class:`Session` — owns scheduler selection (``dynamic`` / ``replay`` /
  ``pool``), validates the victim policy up front, and leases warm worker
  cores from the process-global registry;
* :class:`Plan` — ``session.plan(graph)``: the warm/record/replay/remap
  decision as inspectable data, replacing the v1 mutually-exclusive
  ``run_graph(record=/replay=/cache=/pool=)`` kwargs;
* :class:`RunReport` — results (``report[handle]``), the recording,
  steal/fallback/suspension statistics and wall clock, replacing the v1
  ``run_graph.last_recording`` module global.

Everything here is re-exported at the package top level (``import repro;
repro.Session``).
"""

from .graph import Graph, TaskHandle
from .session import Plan, PlanError, RunReport, Session

__all__ = [
    "Graph",
    "Plan",
    "PlanError",
    "RunReport",
    "Session",
    "TaskHandle",
]
