"""Sessions, execution plans and run reports (API v2).

One object owns scheduler selection and worker leasing:
:class:`Session` replaces the v1 juggling of
:class:`~repro.core.runtime.Runtime` /
:class:`~repro.replay.executor.ReplayExecutor` /
:class:`~repro.replay.pool.ReplayPool` facades and the mutually-exclusive
``run_graph(record=/replay=/cache=/pool=)`` kwargs:

* ``Session(workers=4, scheduler="dynamic" | "replay" | "pool",
  policy=...)`` — the scheduler is picked once, the victim policy is
  validated once (:func:`repro.core.policies.resolve`), and the session
  *leases* its worker threads from the process-global
  :class:`~repro.exec.registry.CoreRegistry` (one warm core per worker
  count per process; ``shared_cores=False`` opts into a private core);
* :meth:`Session.plan` turns "what will happen to this graph" into
  inspectable data — a :class:`Plan` saying **warm** (dynamic on warm
  workers), **record** (instrumented dynamic run), **replay** (run a
  recording; ``remapped_from`` set when it was re-keyed from another worker
  count) or **pool** (the serving pool decides per shape) and *why*;
* :meth:`Session.run` executes a graph (or a prepared plan) and returns a
  :class:`RunReport` — results, the recording (if any), scheduler
  statistics (steals / fallbacks / frame suspensions) and wall clock.
  Nothing is smuggled through module globals: the v1
  ``run_graph.last_recording`` escape hatch is dead on this path.

Scheduler semantics
-------------------

``dynamic``
    Every run is scheduled dynamically on the leased warm workers.  With a
    ``cache``, a run whose shape misses records and stores; later
    same-shaped runs replay (the v1 ``run_graph(cache=...)`` contract).
``replay``
    Replay-first: cache hits replay on a persistent per-shape executor;
    with ``allow_remap`` a recording at another worker count is re-keyed
    (:func:`~repro.replay.remap.remap_recording`) instead of re-recorded;
    a true miss records this run.  Requires a ``cache`` (it is where
    recordings live).
``pool``
    Requests route through a session-owned
    :class:`~repro.replay.pool.ReplayPool` (warmup → record → replay with
    adaptive re-recording), the steady-state serving path.
``compiled``
    Replay, minus the scheduler: a cache-hit recording is lowered once
    (:func:`repro.compile.compile_recording`) into a fused serial program
    and every later same-shaped run executes on the single-threaded
    :class:`~repro.compile.CompiledExecutor` — no dispatch, no GIL
    contention, bit-identical results.  A true miss records this run and
    compiles the next; a compiled plan that stalls (stale shape) falls
    back to replay for that run.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, Dict, Optional, Union

from ..core.policies import resolve as resolve_policy
from ..core.taskgraph import TaskGraph

__all__ = ["Plan", "PlanError", "RunReport", "Session"]

_SCHEDULERS = ("dynamic", "replay", "pool", "compiled")


class PlanError(RuntimeError):
    """A plan cannot be executed (wrong graph shape, closed session, ...)."""


@dataclasses.dataclass
class Plan:
    """An inspectable execution decision for one graph shape.

    ``mode`` is one of ``"warm"`` (dynamic scheduling on warm leased
    workers), ``"record"`` (dynamic with instrumentation; the recording is
    returned in the report and stored in the session cache), ``"replay"``
    (drive the attached ``recording``; ``remapped_from`` names the worker
    count it was re-keyed from, if any), ``"compiled"`` (lower the attached
    ``recording`` into a fused serial program and run it schedulerless —
    :mod:`repro.compile`) or ``"pool"`` (the serving pool owns the
    per-shape lifecycle).  ``reason`` says why the session chose
    it.  Plans are data: print them, test against them, or pass one back to
    :meth:`Session.run` — including against a *different same-shaped graph*
    (an iterative sweep plans once and executes per iteration).
    """

    mode: str
    n_workers: int
    policy: str
    graph: TaskGraph
    digest: Optional[str] = None
    recording: Optional[Any] = None          # repro.replay.Recording
    remapped_from: Optional[int] = None
    record: bool = False
    reason: str = ""
    #: precomputed structural GraphKey (``Session.run(key=...)``) — lets a
    #: steady-state serving loop skip the per-request hash; safety is not
    #: skipped (replay still enforces the 1:1 task cover, so a wrong key
    #: fails loudly)
    key: Optional[Any] = None                # repro.replay.GraphKey

    def describe(self) -> str:
        extra = ""
        if self.mode == "replay" and self.remapped_from is not None:
            extra = f" (remapped {self.remapped_from}->{self.n_workers})"
        return (f"Plan[{self.mode}{extra}] graph={self.graph.name!r} "
                f"workers={self.n_workers} policy={self.policy}"
                + (f" — {self.reason}" if self.reason else ""))

    def __str__(self) -> str:
        return self.describe()


@dataclasses.dataclass
class RunReport:
    """Everything one execution produced, returned by :meth:`Session.run`.

    ``results`` maps tid -> result; prefer ``report[handle]`` /
    :meth:`result` with the :class:`~repro.api.graph.TaskHandle` the graph
    builder returned.  ``recording`` is the run's
    :class:`~repro.replay.Recording` when one was produced or driven
    (record/replay/pool modes) — the value v1 leaked through
    ``run_graph.last_recording``.  ``stats`` carries scheduler counters:
    dynamic runs report ``steals``/``frame_suspends``; replays report
    ``fallback_steals``/``stalls``/``skips``/``run_ahead``/
    ``frame_suspends``; pool runs add the pool entry's serving counters
    plus ``pool_mode`` and (for replay serves) a ``replay_stats`` snapshot
    explaining fallback-heavy rows.  ``trace`` is the run's assembled
    :class:`~repro.obs.trace.RuntimeTrace` when the session was built with
    ``trace=True`` (None otherwise) — feed it to
    :func:`repro.obs.write_trace` for a Perfetto timeline.
    """

    results: Dict[int, Any]
    plan: Plan
    recording: Optional[Any]
    wall_s: float
    scheduler: str
    n_workers: int
    stats: Dict[str, Any] = dataclasses.field(default_factory=dict)
    trace: Optional[Any] = None              # repro.obs.trace.RuntimeTrace

    def result(self, ref: Any) -> Any:
        """Result of a task, by :class:`~repro.api.graph.TaskHandle`,
        :class:`~repro.core.taskgraph.Task`, or raw tid."""
        tid = getattr(ref, "tid", ref)
        return self.results[tid]

    def __getitem__(self, ref: Any) -> Any:
        return self.result(ref)

    def __contains__(self, ref: Any) -> bool:
        return getattr(ref, "tid", ref) in self.results

    def summary(self) -> str:
        rec = "yes" if self.recording is not None else "no"
        return (f"RunReport[{self.plan.mode}] {len(self.results)} tasks in "
                f"{self.wall_s * 1e3:.2f} ms on {self.n_workers} workers "
                f"({self.scheduler}); recording: {rec}; stats: {self.stats}")


class Session:
    """Owns scheduler selection, policy validation and worker leasing for
    any number of graph executions (see module docstring).

    Use as a context manager (or call :meth:`close`): the session releases
    its core lease — and shuts down its pool/executors — on exit.  Runs on
    one session serialize; use one session per concurrent stream.
    """

    def __init__(
        self,
        workers: int,
        *,
        scheduler: str = "dynamic",
        policy: str = "hybrid",
        gang_default: bool = True,
        seed: int = 0,
        cache: Optional[Any] = None,           # repro.replay.GraphCache
        allow_remap: bool = True,
        record: bool = False,
        trace: bool = False,
        shared_cores: bool = True,
        stall_timeout: float = 1e-3,
        block_poll: float = 0.05,
        pool_kwargs: Optional[Dict[str, Any]] = None,
        procs: Optional[int] = None,
    ):
        if workers < 1:
            raise ValueError(f"a session needs >= 1 worker, got {workers}")
        if procs is not None and procs < 1:
            raise ValueError(f"procs must be >= 1 (or None), got {procs}")
        if scheduler not in _SCHEDULERS:
            raise ValueError(
                f"unknown scheduler {scheduler!r}; valid schedulers: "
                f"{', '.join(_SCHEDULERS)}")
        resolve_policy(policy)       # typos fail HERE, with the valid names
        if scheduler in ("replay", "compiled") and cache is None:
            from ..replay.cache import GraphCache
            cache = GraphCache()     # recordings need a home; private one
        self.workers = workers
        self.scheduler = scheduler
        self.policy = policy
        self.gang_default = gang_default
        self.seed = seed
        self.cache = cache
        self.allow_remap = allow_remap
        self.record_default = record
        self.trace = trace
        self.shared_cores = shared_cores
        self.stall_timeout = stall_timeout
        self.block_poll = block_poll
        self.pool_kwargs = dict(pool_kwargs or {})
        self.procs = procs

        self._lock = threading.RLock()
        self._closed = False
        self._core: Optional[Any] = None                 # ExecutorCore lease
        self._runtime: Optional[Any] = None              # dynamic facade
        self._executors: Dict[str, Any] = {}             # digest -> executor
        self._pool: Optional[Any] = None                 # ReplayPool
        self._compiled: Dict[str, Any] = {}              # digest -> CompiledExecutor
        self._mp_pool: Optional[Any] = None              # repro.mp.ProcessPool
        self._submit_queue: Optional[Any] = None         # queue.Queue
        self._submit_thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------------
    # lifecycle
    def close(self) -> None:
        """Release the core lease and stop session-owned executors.  Shared
        cores stay warm for other lessees; the last lessee's release stops
        the threads (which keeps the suite's thread-leak check honest).
        The async-submit worker is drained first (queued runs complete or
        fail loudly — never silently dropped), then the process pool, then
        the in-process executors."""
        self._drain_submit_thread()
        with self._lock:
            if self._closed:
                return
            self._closed = True
            executors = list(self._executors.values())
            self._executors.clear()
            self._compiled.clear()   # threadless; nothing to shut down
            pool, self._pool = self._pool, None
            runtime, self._runtime = self._runtime, None
            core, self._core = self._core, None
            mp_pool, self._mp_pool = self._mp_pool, None
        if mp_pool is not None:
            mp_pool.shutdown()
        for ex in executors:
            ex.shutdown()
        if pool is not None:
            pool.shutdown()
        if runtime is not None:
            runtime.shutdown()
        if core is not None:
            if self.shared_cores:
                from ..exec.registry import release_shared_core
                release_shared_core(core)
            else:
                core.shutdown()

    def __enter__(self) -> "Session":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def _require_open(self) -> None:
        if self._closed:
            raise PlanError("session is closed")

    # ------------------------------------------------------------------
    # leased substrate (lazy: a session that never runs leases nothing)
    def _leased_core(self):
        with self._lock:
            self._require_open()
            if self._core is None:
                if self.shared_cores:
                    from ..exec.registry import shared_core
                    self._core = shared_core(self.workers)
                else:
                    from ..exec.core import ExecutorCore
                    self._core = ExecutorCore(
                        self.workers, block_poll=self.block_poll,
                        name=f"session{self.workers}-worker")
                    self._core.start()
            return self._core

    def _dynamic_runtime(self):
        with self._lock:
            self._require_open()
            if self._runtime is None:
                from ..core.runtime import Runtime
                self._runtime = Runtime(
                    self.workers, policy=self.policy,
                    gang_default=self.gang_default, seed=self.seed,
                    trace=self.trace, core=self._leased_core())
            return self._runtime

    def _replay_executor(self, recording):
        """Persistent per-shape executor leasing the session core; rebuilt
        when the shape's recording changes (e.g. a re-record)."""
        from ..replay.executor import ReplayExecutor
        with self._lock:
            self._require_open()
            ex = self._executors.get(recording.digest)
            if ex is not None and ex.recording is not recording:
                ex.shutdown()
                ex = None
            if ex is None:
                ex = ReplayExecutor(
                    recording, stall_timeout=self.stall_timeout,
                    check_digest=False, trace=self.trace,
                    core=self._leased_core())
                ex.start()
                self._executors[recording.digest] = ex
            return ex

    def _serving_pool(self):
        with self._lock:
            self._require_open()
            if self._pool is None:
                from ..replay.pool import ReplayPool
                kwargs = dict(self.pool_kwargs)
                kwargs.setdefault("allow_remap", self.allow_remap)
                kwargs.setdefault("stall_timeout", self.stall_timeout)
                kwargs.setdefault("shared_cores", self.shared_cores)
                kwargs.setdefault("trace", self.trace)
                self._pool = ReplayPool(self.cache, **kwargs)
            return self._pool

    @property
    def pool(self):
        """The session's serving pool (``scheduler="pool"`` only) — exposed
        for ``describe()`` / ``register_builder``."""
        if self.scheduler != "pool":
            raise PlanError(
                f"session scheduler is {self.scheduler!r}; no pool exists")
        return self._serving_pool()

    # ------------------------------------------------------------------
    # multi-process substrate (repro.mp)
    def process_pool(self, procs: Optional[int] = None):
        """The session's :class:`~repro.mp.ProcessPool` (built lazily from
        this session's configuration — scheduler, worker count, policy and
        the cache's on-disk path all mirror into each child).  ``procs``
        overrides the count the session was built with; the pool is built
        once and reused, and :meth:`close` shuts it down."""
        with self._lock:
            self._require_open()
            n = procs if procs is not None else self.procs
            if n is None:
                raise PlanError(
                    "session was built without procs=N and none was given")
            if self._mp_pool is None:
                from ..mp import ProcessPool, WorkerSpec
                self._mp_pool = ProcessPool(n, WorkerSpec.from_session(self))
            elif self._mp_pool.n_procs != n:
                raise PlanError(
                    f"session already owns a {self._mp_pool.n_procs}-proc "
                    f"pool; cannot re-size it to {n}")
            return self._mp_pool

    # ------------------------------------------------------------------
    # async submission (graph build overlaps execution)
    def submit(self, graph: TaskGraph, *, record: Optional[bool] = None,
               key: Optional[Any] = None, timeout: float = 300.0):
        """Queue ``graph`` for execution and return a
        :class:`~repro.mp.RunFuture` immediately — the caller keeps
        building the *next* graph while this one runs (task bodies that
        release the GIL genuinely overlap with the build).  Runs submitted
        on one session still execute one at a time, in order; the future
        resolves to the run's :class:`RunReport` (or carries its
        exception).  :meth:`close` drains the queue before shutting
        executors down."""
        from ..mp.futures import RunFuture

        fut = RunFuture()
        with self._lock:
            self._require_open()
            if self._submit_thread is None:
                import queue
                self._submit_queue = queue.Queue()
                self._submit_thread = threading.Thread(
                    target=self._submit_worker, name="session-submit",
                    daemon=True)
                self._submit_thread.start()
            self._submit_queue.put((fut, graph, record, key, timeout))
        return fut

    def _submit_worker(self) -> None:
        while True:
            item = self._submit_queue.get()
            if item is None:
                return
            fut, graph, record, key, timeout = item
            try:
                fut.set_result(
                    self.run(graph, record=record, key=key, timeout=timeout))
            except BaseException as e:       # noqa: BLE001 - via future
                fut.set_exception(e)

    def _drain_submit_thread(self) -> None:
        """Stop the async-submit worker: finish in-flight runs, fail
        anything enqueued after the sentinel (racing a close)."""
        with self._lock:
            thread, self._submit_thread = self._submit_thread, None
            q = self._submit_queue
        if thread is None or q is None:
            return
        q.put(None)
        thread.join()
        while not q.empty():                 # submits that raced the close
            item = q.get()
            if item is not None:
                item[0].set_exception(PlanError("session is closed"))

    # ------------------------------------------------------------------
    # planning
    @staticmethod
    def _as_taskgraph(graph: Union[TaskGraph, Any]) -> TaskGraph:
        if isinstance(graph, TaskGraph):
            return graph
        raise TypeError(f"expected a TaskGraph/Graph, got {type(graph)!r}")

    def plan(self, graph: TaskGraph, *, record: Optional[bool] = None,
             key: Optional[Any] = None) -> Plan:
        """Decide — without executing — how :meth:`run` would serve
        ``graph``; returns the decision as an inspectable :class:`Plan`.
        Side-effect-free: nothing is recorded, stored or leased.  ``key``
        supplies the graph's structural :class:`~repro.replay.GraphKey`
        when the caller already knows it (a serving loop rebuilding one
        shape) so planning skips the per-request hash."""
        self._require_open()
        tg = self._as_taskgraph(graph)
        base = dict(n_workers=self.workers, policy=self.policy, graph=tg,
                    key=key)
        if self.scheduler == "pool":
            return Plan(mode="pool", digest=getattr(key, "digest", None),
                        reason=(
                            "serving pool owns the shape lifecycle "
                            "(warmup -> record -> replay, adaptive "
                            "re-record)"), **base)
        if key is None:
            from ..replay.graph_key import graph_key
            key = graph_key(tg)
            base["key"] = key
        base["digest"] = key.digest
        want_record = self.record_default if record is None else record
        rec = (self.cache.lookup(key, self.workers, self.policy)
               if self.cache is not None else None)
        if rec is not None:
            if self.scheduler == "compiled":
                return Plan(mode="compiled", recording=rec,
                            reason="cache hit — lower the recording to a "
                                   "fused serial program", **base)
            return Plan(mode="replay", recording=rec,
                        reason="cache hit for this shape at this worker "
                               "count", **base)
        if self.scheduler == "compiled":
            if self.allow_remap and self.cache is not None:
                remapped, src = self._try_remap(key)
                if remapped is not None:
                    return Plan(
                        mode="compiled", recording=remapped,
                        remapped_from=src,
                        reason=f"cache held the shape at {src} workers; "
                               f"re-keyed and compiled for {self.workers}",
                        **base)
            return Plan(mode="record", record=True,
                        reason="no recording for this shape — record this "
                               "run, compile the next", **base)
        if self.scheduler == "replay":
            if self.allow_remap and self.cache is not None:
                remapped, src = self._try_remap(key)
                if remapped is not None:
                    return Plan(
                        mode="replay", recording=remapped, remapped_from=src,
                        reason=f"cache held the shape at {src} workers; "
                               f"re-keyed to {self.workers}", **base)
            return Plan(mode="record", record=True,
                        reason="no recording for this shape — record this "
                               "run, replay the next", **base)
        if self.cache is not None:
            return Plan(mode="record", record=True,
                        reason="cache miss — record so later same-shaped "
                               "runs replay", **base)
        if want_record:
            return Plan(mode="record", record=True,
                        reason="recording requested", **base)
        return Plan(mode="warm",
                    reason="dynamic scheduling on warm leased workers",
                    **base)

    def _try_remap(self, key):
        from ..replay.remap import (RemapError, nearest_worker_count,
                                    remap_recording)
        donors = self.cache.candidates(key, self.policy)
        donors.pop(self.workers, None)
        while donors:
            src = nearest_worker_count(list(donors), self.workers)
            try:
                return remap_recording(donors.pop(src), self.workers), src
            except RemapError:
                continue
        return None, None

    # ------------------------------------------------------------------
    # execution
    def run(
        self,
        graph: Optional[TaskGraph] = None,
        *,
        plan: Optional[Plan] = None,
        record: Optional[bool] = None,
        key: Optional[Any] = None,
        timeout: float = 300.0,
    ) -> RunReport:
        """Execute ``graph`` (planned now) or a prepared ``plan`` (against
        ``graph`` when given — a sweep plans once, runs per iteration);
        returns a :class:`RunReport`.  ``key`` forwards a precomputed
        :class:`~repro.replay.GraphKey` to :meth:`plan` (and, for pool
        sessions, to the pool) so steady-state loops skip hashing."""
        if plan is None:
            if graph is None:
                raise TypeError("run() needs a graph or a plan")
            plan = self.plan(graph, record=record, key=key)
        tg = self._as_taskgraph(graph) if graph is not None else plan.graph
        with self._lock:
            self._require_open()
            t0 = time.perf_counter()
            if plan.mode == "pool":
                report = self._run_pool(plan, tg, timeout)
            elif plan.mode == "compiled":
                report = self._run_compiled(plan, tg, timeout)
            elif plan.mode == "replay":
                report = self._run_replay(plan, tg, timeout)
            elif plan.mode in ("warm", "record"):
                report = self._run_dynamic(plan, tg, timeout)
            else:
                raise PlanError(f"unknown plan mode {plan.mode!r}")
            report.wall_s = time.perf_counter() - t0
            return report

    def execute(self, plan: Plan, *, timeout: float = 300.0) -> RunReport:
        """Alias: run a prepared plan against its own graph."""
        return self.run(plan=plan, timeout=timeout)

    def _run_dynamic(self, plan: Plan, tg: TaskGraph,
                     timeout: float) -> RunReport:
        rt = self._dynamic_runtime()
        do_record = plan.mode == "record"
        results = rt.run(tg, timeout=timeout, record=do_record)
        recording = rt.last_recording if do_record else None
        if do_record and recording is not None and self.cache is not None:
            self.cache.store(recording)
        stats = dict(rt.last_stats)
        return RunReport(results=results, plan=plan, recording=recording,
                         wall_s=0.0, scheduler=self.scheduler,
                         n_workers=self.workers, stats=stats,
                         trace=rt.last_trace)

    def _run_replay(self, plan: Plan, tg: TaskGraph,
                    timeout: float) -> RunReport:
        recording = plan.recording
        if recording is None:
            raise PlanError("replay plan carries no recording")
        if tg is not plan.graph:
            # executing a prepared plan against a fresh same-shaped graph:
            # re-key THIS graph (the plan's digest covered the original)
            from ..replay.graph_key import graph_key
            if graph_key(tg).digest != recording.digest:
                raise PlanError(
                    f"plan's recording is for digest "
                    f"{recording.digest[:16]} but the graph hashes "
                    "differently")
        if plan.remapped_from is not None and self.cache is not None:
            # adopt the re-keyed recording so the next plan() is a pure hit
            self.cache.store(recording)
        ex = self._replay_executor(recording)
        results = ex.run(tg, timeout=timeout)
        return RunReport(results=results, plan=plan, recording=recording,
                         wall_s=0.0, scheduler=self.scheduler,
                         n_workers=self.workers, stats=dict(ex.stats),
                         trace=ex.last_trace)

    def _compiled_executor(self, tg: TaskGraph, recording):
        """Get-or-build the per-digest compiled executor (threadless — no
        core lease).  The lowering's :class:`~repro.compile.CompiledPlanMeta`
        is persisted next to the recording in the session cache."""
        from ..compile import CompiledExecutor, compile_recording
        ex = self._compiled.get(recording.digest)
        if ex is not None and ex.plan.recording is not recording:
            ex = None                        # recording swapped (re-record)
        if ex is None:
            cplan = compile_recording(tg, recording)
            ex = CompiledExecutor(tg, cplan)
            self._compiled[recording.digest] = ex
            if self.cache is not None and hasattr(self.cache, "store_plan_meta"):
                self.cache.store_plan_meta(
                    recording.digest, recording.n_workers, self.policy,
                    cplan.meta.to_dict())
        return ex

    def _run_compiled(self, plan: Plan, tg: TaskGraph,
                      timeout: float) -> RunReport:
        from ..compile import CompiledRunError, CompileError
        recording = plan.recording
        if recording is None:
            raise PlanError("compiled plan carries no recording")
        if tg is not plan.graph:
            from ..replay.graph_key import graph_key
            if graph_key(tg).digest != recording.digest:
                raise PlanError(
                    f"plan's recording is for digest "
                    f"{recording.digest[:16]} but the graph hashes "
                    "differently")
        if plan.remapped_from is not None and self.cache is not None:
            self.cache.store(recording)
        try:
            ex = self._compiled_executor(tg, recording)
            results = ex.run(tg, check_digest=False)
            stats = dict(ex.stats)
        except (CompileError, CompiledRunError) as e:
            # stale/unlowerable plan: drop the executable and serve this
            # run on the replay path (dynamic is replay's own fallback)
            self._compiled.pop(recording.digest, None)
            report = self._run_replay(plan, tg, timeout)
            report.stats["compiled_fallback"] = str(e)
            return report
        return RunReport(results=results, plan=plan, recording=recording,
                         wall_s=0.0, scheduler=self.scheduler,
                         n_workers=self.workers, stats=stats, trace=None)

    def map(self, builder, inputs, *, record: Optional[bool] = None,
            key: Optional[Any] = None, timeout: float = 300.0,
            procs: Optional[int] = None):
        """Run a sweep of same-shaped graphs through one plan: ``builder``
        maps each input to a graph; the first graph is planned once and the
        plan is reused for every later input (re-planned a single time when
        the first run records, so the rest of the sweep replays/compiles).
        Returns the per-input :class:`RunReport` list.

        With ``procs`` (or a session built with ``procs=N``) the sweep
        shards across worker *processes*: the first input runs in-process
        (seeding the on-disk cache when one is configured), the rest
        round-robin to pool children that adopt the seeded recording and
        replay warm — no GIL sharing, no per-child recording runs.
        ``builder`` must then be a module-level callable (it ships by
        import reference, see :func:`repro.mp.callable_ref`)."""
        self._require_open()
        n_procs = procs if procs is not None else self.procs
        if n_procs is not None:
            return self._map_mp(builder, inputs, n_procs, record=record,
                                key=key, timeout=timeout)
        reports = []
        plan: Optional[Plan] = None
        for x in inputs:
            g = self._as_taskgraph(builder(x))
            if plan is None:
                plan = self.plan(g, record=record, key=key)
                reports.append(self.run(graph=g, plan=plan, timeout=timeout))
                if plan.mode == "record":
                    plan = None    # re-plan once: the next call hits the cache
            else:
                reports.append(self.run(graph=g, plan=plan, timeout=timeout))
        return reports

    def _map_mp(self, builder, inputs, procs: int, *, record, key,
                timeout: float):
        """Sharded sweep: input 0 in-process (seeds the shared disk cache),
        inputs 1..n round-robin across the process pool."""
        from ..mp import callable_ref
        from ..mp.tasks import run_builder

        try:
            ref = callable_ref(builder)
        except ValueError as e:
            raise PlanError(
                f"map(procs={procs}) ships the builder to worker processes "
                f"by import reference; {e}") from e
        inputs = list(inputs)
        if not inputs:
            return []
        pool = self.process_pool(procs)
        seed_report = self.run(graph=self._as_taskgraph(builder(inputs[0])),
                               record=record, key=key, timeout=timeout)
        futures = [
            pool.submit(run_builder, ref, x, record=record, timeout=timeout)
            for x in inputs[1:]
        ]
        reports = [seed_report]
        for fut in futures:
            out = fut.result(timeout=timeout)
            stats = dict(out["stats"])
            stats["mp_proc"] = out["proc"]
            plan = Plan(
                mode=out["mode"], n_workers=out["n_workers"],
                policy=self.policy, graph=seed_report.plan.graph,
                digest=out["digest"], remapped_from=out["remapped_from"],
                reason=f"executed in worker process {out['proc']}")
            reports.append(RunReport(
                results=out["results"], plan=plan, recording=None,
                wall_s=out["wall_s"], scheduler=out["scheduler"],
                n_workers=out["n_workers"], stats=stats))
        return reports

    def _run_pool(self, plan: Plan, tg: TaskGraph,
                  timeout: float) -> RunReport:
        pool = self._serving_pool()
        outcome = pool.serve(
            tg, self.workers, policy=self.policy,
            gang_default=self.gang_default, seed=self.seed, timeout=timeout,
            key=plan.key)
        stats = dict(outcome.stats)
        stats["pool_mode"] = outcome.mode
        return RunReport(results=outcome.results, plan=plan,
                         recording=outcome.recording, wall_s=0.0,
                         scheduler=self.scheduler, n_workers=self.workers,
                         stats=stats, trace=getattr(outcome, "trace", None))
