"""Futures-based task-graph construction (API v2).

The v1 construction API is positional and stringly wired: ``TaskGraph.add``
returns a :class:`~repro.core.taskgraph.Task`, dependencies are declared by
hand (``deps=[...]``), and results come back as a bare ``{tid: result}``
dict the caller indexes by remembered integer ids.  This module makes the
dataflow explicit:

* :meth:`Graph.add` returns a :class:`TaskHandle` — a *future* for the
  task's result;
* a handle can be passed **as an argument** to a downstream task (including
  inside nested tuples/lists/dicts); the dependency edge is inferred
  automatically and the handle is replaced by the producing task's actual
  result when the consumer runs;
* inferred dependencies compose with explicit ``deps=`` (side-effect
  ordering — tile stores, decode state — still wants explicit edges);
* ``handle.result(report)`` / ``report[handle]`` replaces tid-keyed dict
  indexing on the :class:`~repro.api.session.RunReport`.

:class:`Graph` *is a* :class:`~repro.core.taskgraph.TaskGraph`: every
consumer of the v1 type (``graph_key``, recordings, the executors, the
simulator) accepts it unchanged, and a ``Graph`` built with the same names/
kinds/costs/edges as a v1 ``TaskGraph`` has the identical structural digest
— recordings are interchangeable across the two construction styles.

Body calling convention
-----------------------

``Graph.add(fn, *args)`` calls ``fn`` with ``args`` resolved (handles
replaced by results).  If ``fn``'s first parameter is named ``ctx`` it
additionally receives the :class:`~repro.core.taskgraph.TaskContext` in
front (``fn(ctx, *resolved)``) — which is also how generator bodies get at
the suspension APIs (``yield ctx.recv(...)`` / ``ctx.send`` /
``ctx.wait_any``).  A zero-arg ``fn`` whose first parameter is not ``ctx``
is called as ``fn()``.  v1-style bodies (single ``ctx`` parameter) pass
through unwrapped, byte-for-byte.
"""

from __future__ import annotations

import inspect
from typing import Any, Callable, List, Optional, Sequence

from ..core.taskgraph import ParallelSpec, Task, TaskContext, TaskGraph

__all__ = ["Graph", "TaskHandle"]


class TaskHandle:
    """A future for one task's result, returned by :meth:`Graph.add`.

    Pass it (possibly nested in tuples/lists/dicts) as an argument to a
    later :meth:`Graph.add` call to both declare the dependency and receive
    the producing task's result; read it out of a finished run with
    ``report[handle]`` or ``handle.result(report)``.
    """

    __slots__ = ("_graph", "_task")

    def __init__(self, graph: "Graph", task: Task):
        self._graph = graph
        self._task = task

    @property
    def task(self) -> Task:
        return self._task

    @property
    def tid(self) -> int:
        return self._task.tid

    @property
    def name(self) -> str:
        return self._task.name

    @property
    def graph(self) -> "Graph":
        return self._graph

    def result(self, report: Any) -> Any:
        """This task's result out of a :class:`~repro.api.session.RunReport`
        (or any mapping-like report with a ``result``/``__getitem__``)."""
        if hasattr(report, "result"):
            return report.result(self)
        return report[self.tid]

    def __eq__(self, other: object) -> bool:
        if not isinstance(other, TaskHandle):
            return NotImplemented
        return self._graph is other._graph and self.tid == other.tid

    def __hash__(self) -> int:
        return hash((id(self._graph), self._task.tid))

    def __repr__(self) -> str:
        return f"TaskHandle({self._task.name!r}, tid={self._task.tid})"


def _collect_handles(obj: Any, out: List[TaskHandle]) -> None:
    """Find every :class:`TaskHandle` in a nested argument structure, in
    deterministic (left-to-right, insertion-ordered) discovery order.

    Only tuples, lists and dict values are traversed.  Handles inside
    *sets* are rejected loudly (sets are unordered and results may be
    unhashable — there is no sound way to resolve them); handles buried
    in custom objects are invisible to inference — declare those edges
    with explicit ``deps=``."""
    if isinstance(obj, TaskHandle):
        out.append(obj)
    elif isinstance(obj, (list, tuple)):
        for v in obj:
            _collect_handles(v, out)
    elif isinstance(obj, dict):
        for v in obj.values():
            _collect_handles(v, out)
    elif isinstance(obj, (set, frozenset)):
        if any(isinstance(v, TaskHandle) for v in obj):
            raise TypeError(
                "TaskHandle inside a set cannot be resolved (unordered, "
                "and results may be unhashable) — pass a tuple/list, or "
                "declare the edge with deps=")


def _resolve(obj: Any, ctx: TaskContext) -> Any:
    """Replace handles with their results, preserving the nesting shape."""
    if isinstance(obj, TaskHandle):
        return ctx.result(obj.tid)
    if isinstance(obj, tuple):
        return tuple(_resolve(v, ctx) for v in obj)
    if isinstance(obj, list):
        return [_resolve(v, ctx) for v in obj]
    if isinstance(obj, dict):
        return {k: _resolve(v, ctx) for k, v in obj.items()}
    return obj


def _wants_ctx(fn: Callable[..., Any]) -> bool:
    """Does ``fn``'s first parameter ask for the TaskContext?  Unknowable
    signatures (builtins, some partials) default to the v1 convention."""
    try:
        params = inspect.signature(fn).parameters
    except (TypeError, ValueError):
        return True
    first = next(iter(params), None)
    return first == "ctx"


class Graph(TaskGraph):
    """A :class:`~repro.core.taskgraph.TaskGraph` whose :meth:`add` returns
    :class:`TaskHandle` futures and infers dependencies from handle
    arguments.  Drop-in everywhere a ``TaskGraph`` is accepted."""

    def add(  # type: ignore[override]
        self,
        fn: Optional[Callable[..., Any]] = None,
        *args: Any,
        deps: Sequence[Any] = (),
        name: Optional[str] = None,
        kind: str = "compute",
        cost: float = 1.0,
        priority: int = 0,
        parallel: Optional[ParallelSpec] = None,
        uses: Sequence[Any] = (),
        uses_shared: Sequence[Any] = (),
        **meta: Any,
    ) -> TaskHandle:
        """Add a task; returns its :class:`TaskHandle`.

        ``args`` are passed to ``fn`` at execution time with any contained
        handles resolved to the producing tasks' results; each such handle
        contributes an inferred dependency edge.  Handles are discovered
        through nested tuples/lists/dicts only — a handle hidden inside a
        custom object is NOT seen (declare that edge via ``deps=``), and a
        handle inside a set raises at build time.  ``deps`` accepts
        handles, :class:`~repro.core.taskgraph.Task` objects or raw tids
        and is kept *in front of* the inferred edges (explicit ordering
        intent first).

        ``uses`` / ``uses_shared`` declare
        :class:`~repro.resources.Resource` conflicts (exclusive / shared):
        tasks sharing a resource are mutually excluded at run time without
        any ordering edge between them.
        """
        inferred: List[TaskHandle] = []
        _collect_handles(args, inferred)
        for h in inferred:
            if h._graph is not self:
                raise ValueError(
                    f"argument handle {h!r} belongs to graph "
                    f"{h._graph.name!r}, not {self.name!r}")
        if fn is None and args:
            raise ValueError("dataflow arguments need a callable body")
        explicit = [self._dep_tid(d) for d in deps]
        dep_ids = list(dict.fromkeys(explicit + [h.tid for h in inferred]))
        task = TaskGraph.add(
            self, self._compile_body(fn, args), deps=dep_ids, name=name,
            kind=kind, cost=cost, priority=priority, parallel=parallel,
            uses=uses, uses_shared=uses_shared, **meta)
        return TaskHandle(self, task)

    def handle(self, task_or_tid: Any) -> TaskHandle:
        """Wrap an existing task (or tid) of this graph in a handle."""
        tid = self._dep_tid(task_or_tid)
        return TaskHandle(self, self.tasks[tid])

    @staticmethod
    def _dep_tid(d: Any) -> int:
        if isinstance(d, (TaskHandle, Task)):
            return d.tid
        return int(d)

    @staticmethod
    def _compile_body(
        fn: Optional[Callable[..., Any]], args: Sequence[Any],
    ) -> Optional[Callable[[TaskContext], Any]]:
        if fn is None:
            return None
        wants_ctx = _wants_ctx(fn)
        if not args:
            if wants_ctx:
                return fn           # v1 convention: untouched, zero overhead
            def body(ctx: TaskContext, _fn=fn) -> Any:
                return _fn()
            return body
        if wants_ctx:
            def body(ctx: TaskContext, _fn=fn, _args=tuple(args)) -> Any:
                return _fn(ctx, *_resolve(_args, ctx))
        else:
            def body(ctx: TaskContext, _fn=fn, _args=tuple(args)) -> Any:
                return _fn(*_resolve(_args, ctx))
        return body
