"""llama4-maverick-400b-a17b [moe] — 128 routed experts top-1 + shared
expert, early fusion [hf:meta-llama/Llama-4-Scout-17B-16E; unverified].

Deviation (DESIGN.md section Arch-applicability): the real model interleaves
dense/MoE layers 1:1 (hence ~400B total).  We keep EVERY assigned
hyperparameter exactly (48L, d_model 5120, 40H/kv8, d_ff 8192, vocab
202048, 128 experts top-1) in a homogeneous scan-friendly stack, which
lands at ~770B *stored* params; the *active* params per token (~17B:
shared + top-1 routed + attention) match a17b, so the roofline compute
terms are faithful.  The dry-run proves the stored size still fits.
"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,          # GQA
    head_dim=128,
    d_ff=8192,             # shared-expert width
    vocab_size=202048,
    rope_theta=5e5,
    n_experts=128,
    top_k=1,
    d_expert=8192,
    shared_expert=True,
)
