"""llama-3.2-vision-11b [vlm] — cross-attention image layers every 5th
layer [hf:meta-llama/Llama-3.2-11B-Vision; unverified].  The vision tower is
a stub: input_specs() provides precomputed patch embeddings (B, 1600, D)."""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,          # GQA
    head_dim=128,
    d_ff=14336,
    vocab_size=128256,
    rope_theta=5e5,
    cross_attn_every=5,
    n_patches=1600,
)
