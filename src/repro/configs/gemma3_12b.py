"""gemma3-12b [dense] — 5:1 local:global sliding window, 128k context
[hf:google/gemma-3-1b-pt; unverified]."""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-12b",
    family="dense",
    n_layers=48,
    d_model=3840,
    n_heads=16,
    n_kv_heads=8,          # GQA
    head_dim=256,
    d_ff=15360,
    vocab_size=262144,
    qk_norm=True,
    window=1024,           # local layers: 1024-token sliding window
    local_global_ratio=5,  # 5 local : 1 global
    rope_theta=1e4,        # local theta; global layers use 1e6 (layer_flags)
    tie_embeddings=True,
)
