"""seamless-m4t-medium [audio] — enc-dec transformer backbone
[arXiv:2308.11596; hf].  The audio frontend is a stub: input_specs() provides
precomputed frame embeddings (B, S_src, d_model)."""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="encdec",
    n_layers=12,           # decoder layers
    enc_layers=12,         # encoder layers
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,         # MHA
    head_dim=64,
    d_ff=4096,
    vocab_size=256206,
)
