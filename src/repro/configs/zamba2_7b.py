"""zamba2-7b [hybrid] — Mamba2 backbone + shared attention blocks
[arXiv:2411.15242; unverified].

Deviation (DESIGN.md): one shared attention+MLP block applied every 6th
layer (the real model alternates two shared blocks).
"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,         # MHA shared block
    head_dim=112,
    d_ff=14336,
    vocab_size=32000,
    ssm_state=64,
    ssm_head_dim=64,
    ssm_expand=2,
    attn_every=6,
    ssm_chunk=128,
)
