"""qwen3-moe-235b-a22b [moe] — 128 experts top-8
[hf:Qwen/Qwen3-30B-A3B; hf]."""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,          # GQA
    head_dim=128,
    d_ff=1536,             # (dense d_ff unused; experts below)
    vocab_size=151936,
    qk_norm=True,
    rope_theta=1e6,
    n_experts=128,
    top_k=8,
    d_expert=1536,
)
