"""Assigned-architecture registry: ``get_config(arch_id)`` / ``ARCHS``.

Each module defines ``CONFIG`` with the exact published configuration and
inherits the shape set from the assignment (see repro.launch.shapes).
"""

from importlib import import_module

from ..models.config import ModelConfig

_MODULES = {
    "deepseek-67b": "deepseek_67b",
    "command-r-plus-104b": "command_r_plus_104b",
    "gemma3-12b": "gemma3_12b",
    "qwen3-14b": "qwen3_14b",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b_a17b",
    "zamba2-7b": "zamba2_7b",
    "mamba2-2.7b": "mamba2_2p7b",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "llama-3.2-vision-11b": "llama_3_2_vision_11b",
}

ARCHS = tuple(_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; one of {sorted(_MODULES)}")
    mod = import_module(f".{_MODULES[arch]}", __package__)
    return mod.CONFIG.validate()
