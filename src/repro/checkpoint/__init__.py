from .checkpointer import Checkpointer
from .tasks import (
    CheckpointSink,
    TornWriteError,
    add_checkpoint_tasks,
    checkpoint_resource,
)

__all__ = ["Checkpointer", "CheckpointSink", "TornWriteError",
           "add_checkpoint_tasks", "checkpoint_resource"]
