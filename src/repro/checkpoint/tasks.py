"""Concurrent checkpoint-writer tasks guarded by a declarative resource.

The training-loop consumer of :mod:`repro.resources`: N shard-writer tasks
share one checkpoint *file* resource with **no ordering edges** between
them.  Each writer serializes its shard (the unguarded compute) and then
appends it to the sink while holding the file; the arbiter grants the file
in whatever order the shards finish, so serialization overlaps across
workers while the writes themselves stay mutually exclusive.  Edges would
also pin the write *order* and forbid the overlap — the resource pins
neither (the paper's conflicts-without-dependencies case).

:class:`CheckpointSink` enforces the invariant at the data layer: a second
``begin_shard`` while a write is open raises :class:`TornWriteError`, and
a crash mid-write leaves the sink torn (``complete`` is False) — the
executor surfaces that as an aborted run whose grants the arbiter provably
dropped (``drain_frames`` → ``ResourceArbiter.abort``; asserted in
``tests/test_resources.py``).
"""

from __future__ import annotations

import json
import os
import threading
from typing import Any, Callable, Dict, List, Optional, Sequence

from ..resources import Resource

__all__ = ["CheckpointSink", "TornWriteError", "checkpoint_resource",
           "add_checkpoint_tasks"]


class TornWriteError(RuntimeError):
    """Two writers interleaved inside the sink — the invariant the file
    resource exists to rule out (only reachable if arbitration is off or
    broken, or a writer crashed and left its write open)."""


class CheckpointSink:
    """An append-only sharded checkpoint with torn-write detection.

    ``begin_shard`` / ``commit_shard`` bracket one shard's write; a second
    ``begin_shard`` while one is open raises :class:`TornWriteError`.  The
    checkpoint is ``complete`` once every expected shard committed and no
    write is open.  With a ``path`` the committed shards are also persisted
    as one JSON file per shard plus a manifest on ``finalize`` (dependency-
    free — the array checkpointer is :class:`~repro.checkpoint.Checkpointer`).
    """

    def __init__(self, n_shards: int, path: Optional[str] = None):
        self.n_shards = n_shards
        self.path = path
        self.shards: Dict[int, Any] = {}
        self.write_log: List[int] = []       # commit order (grant order)
        self._open: Optional[int] = None
        self._lock = threading.Lock()
        if path is not None:
            os.makedirs(path, exist_ok=True)

    # -- the guarded critical section ----------------------------------
    def begin_shard(self, shard: int) -> None:
        with self._lock:
            if self._open is not None:
                raise TornWriteError(
                    f"shard {shard} opened while shard {self._open} is "
                    "mid-write: concurrent checkpoint writers")
            self._open = shard

    def commit_shard(self, shard: int, payload: Any) -> None:
        with self._lock:
            if self._open != shard:
                raise TornWriteError(
                    f"commit of shard {shard} but shard {self._open!r} is "
                    "open")
            self.shards[shard] = payload
            self.write_log.append(shard)
            self._open = None
        if self.path is not None:
            with open(os.path.join(self.path, f"shard_{shard:05d}.json"),
                      "w") as f:
                json.dump({"shard": shard, "payload": payload}, f)

    # -- state ----------------------------------------------------------
    @property
    def torn(self) -> bool:
        """A write was begun and never committed (crash mid-write)."""
        return self._open is not None

    @property
    def complete(self) -> bool:
        return not self.torn and len(self.shards) == self.n_shards

    def finalize(self) -> Optional[str]:
        """Write the manifest (requires ``complete``); returns its path."""
        if not self.complete:
            raise TornWriteError(
                f"checkpoint incomplete: {sorted(self.shards)} of "
                f"{self.n_shards} shards, torn={self.torn}")
        if self.path is None:
            return None
        manifest = os.path.join(self.path, "manifest.json")
        with open(manifest, "w") as f:
            json.dump({"n_shards": self.n_shards,
                       "write_log": self.write_log}, f)
        return manifest


def checkpoint_resource(name: str = "checkpoint") -> Resource:
    """The exclusive file resource all writer tasks of one sink share."""
    return Resource(name)


def add_checkpoint_tasks(
    graph,
    sink: CheckpointSink,
    payloads: Sequence[Any],
    *,
    resource: Optional[Resource] = None,
    serialize: Optional[Callable[[int, Any], Any]] = None,
    deps: Sequence[Sequence[Any]] = (),
    crash_on: Optional[int] = None,
) -> List[Any]:
    """Add one writer task per payload shard to ``graph`` (a
    :class:`~repro.api.graph.Graph`), all sharing ``resource`` exclusively
    and with no edges between them.  ``serialize(shard, payload)`` is the
    unguarded per-shard compute (identity by default); ``deps[s]`` are
    optional per-shard upstream edges (the train step that produced the
    shard).  ``crash_on`` makes that shard's writer raise *between*
    ``begin_shard`` and ``commit_shard`` — the torn-write/abort fixture.
    Returns the writer task handles."""
    resource = resource if resource is not None else checkpoint_resource()
    handles = []
    for s, payload in enumerate(payloads):
        def _write(ctx, s=s, payload=payload):
            data = serialize(s, payload) if serialize is not None else payload
            sink.begin_shard(s)
            if crash_on == s:
                raise RuntimeError(f"simulated crash mid-write of shard {s}")
            sink.commit_shard(s, data)
            return s

        shard_deps = deps[s] if s < len(deps) else ()
        handles.append(graph.add(_write, name=f"ckpt_write{s}",
                                 kind="comm", cost=0.2, deps=shard_deps,
                                 uses=[resource]))
    return handles
