"""Sharded, async, elastic checkpointing.

* each leaf is saved as a ``.npy`` under a step directory plus a JSON
  manifest (tree structure, shapes, dtypes, step, data-pipeline state);
* writes go to ``<step>.tmp`` then atomically rename — a preempted save
  never corrupts the latest checkpoint (fault tolerance);
* ``save_async`` runs serialization on a background thread (device->host
  copy is the only sync part), overlapping the next train steps;
* restore is *elastic*: arrays are loaded by tree path and re-sharded onto
  whatever mesh the restoring job uses (different device count / topology),
  so jobs can restart on a resized slice.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree, prefix=()) -> Dict[Tuple[str, ...], Any]:
    if isinstance(tree, dict):
        out = {}
        for k, v in tree.items():
            out.update(_flatten(v, prefix + (str(k),)))
        return out
    return {prefix: tree}


def _unflatten(flat: Dict[Tuple[str, ...], Any]):
    root: Dict = {}
    for path, v in flat.items():
        node = root
        for p in path[:-1]:
            node = node.setdefault(p, {})
        node[path[-1]] = v
    return root


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._pending: Optional[threading.Thread] = None

    # ------------------------------------------------------------------
    def save(self, step: int, tree, extra: Optional[Dict] = None) -> str:
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)
        return self._write(step, host_tree, extra or {})

    def save_async(self, step: int, tree, extra: Optional[Dict] = None) -> None:
        """Device->host copy happens here; file IO on a background thread."""
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)

        def work():
            self._write(step, host_tree, extra or {})

        self._pending = threading.Thread(target=work, daemon=True)
        self._pending.start()

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def _write(self, step: int, host_tree, extra: Dict) -> str:
        final = os.path.join(self.dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        flat = _flatten(host_tree)
        manifest = {"step": step, "extra": extra, "leaves": []}
        for i, (path, arr) in enumerate(sorted(flat.items())):
            fname = f"leaf_{i:05d}.npy"
            np.save(os.path.join(tmp, fname), arr)
            manifest["leaves"].append({
                "path": list(path), "file": fname,
                "shape": list(arr.shape), "dtype": str(arr.dtype),
            })
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()
        return final

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # ------------------------------------------------------------------
    def all_steps(self):
        out = []
        for d in os.listdir(self.dir):
            if d.startswith("step_") and not d.endswith(".tmp"):
                out.append(int(d.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: Optional[int] = None, shardings=None):
        """Load a checkpoint; if ``shardings`` (a pytree of NamedSharding /
        None matching the saved tree) is given, place each leaf accordingly —
        this is the elastic path (works for any mesh shape)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            return None, None
        d = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        flat = {}
        for leaf in manifest["leaves"]:
            arr = np.load(os.path.join(d, leaf["file"]))
            flat[tuple(leaf["path"])] = arr
        tree = _unflatten(flat)
        if shardings is not None:
            flat_sh = _flatten(shardings)

            def place(path, arr):
                sh = flat_sh.get(path)
                if sh is None:
                    return jnp.asarray(arr)
                return jax.device_put(arr, sh)
            tree = _unflatten({p: place(p, a) for p, a in _flatten(tree).items()})
        return tree, manifest
