"""Futures and failure types for the multi-process execution pool.

A :class:`RunFuture` is the parent-side handle for one request shipped to a
worker process (or queued on the session's async submit thread): the
submitting thread gets it back immediately and the dispatcher resolves it
out of order when the child's response arrives.  Deliberately tiny — a
``threading.Event`` plus a result slot — because the pool's dispatcher
resolves futures from its own reader thread and never needs executor
machinery, and because :meth:`RunFuture.result` with a ``timeout`` is the
parent's thread-method watchdog over a child that wedged (the child cannot
be interrupted from here; the *wait* can).
"""

from __future__ import annotations

import threading
from typing import Any, Callable, List, Optional

__all__ = ["FutureTimeout", "RunFuture", "WorkerDied", "WorkerError"]


class FutureTimeout(TimeoutError):
    """``RunFuture.result(timeout=...)`` expired before the worker replied."""


class WorkerDied(RuntimeError):
    """The worker process holding this request died before replying.

    Carries ``proc`` (the pool index of the dead worker) so callers can
    reroute the work — the serving engine re-serves the request in-process.
    """

    def __init__(self, proc: int, detail: str = ""):
        self.proc = proc
        super().__init__(
            f"worker process {proc} died{': ' + detail if detail else ''}")


class WorkerError(RuntimeError):
    """The task raised inside the worker process.

    ``kind`` is the remote exception's type name and ``remote_traceback``
    the formatted child-side traceback (exception *objects* do not cross
    the pipe — task bodies may raise anything, picklable or not).
    """

    def __init__(self, kind: str, message: str, remote_traceback: str = ""):
        self.kind = kind
        self.remote_traceback = remote_traceback
        super().__init__(f"{kind}: {message}")


class RunFuture:
    """One pending result, resolved exactly once by the dispatcher."""

    def __init__(self) -> None:
        self._event = threading.Event()
        self._result: Any = None
        self._exception: Optional[BaseException] = None
        self._lock = threading.Lock()
        self._callbacks: List[Callable[["RunFuture"], None]] = []

    # ------------------------------------------------------------------
    # producer side (dispatcher / submit worker)
    def set_result(self, value: Any) -> None:
        with self._lock:
            if self._event.is_set():
                return                      # first resolution wins
            self._result = value
            self._event.set()
            callbacks, self._callbacks = self._callbacks, []
        for cb in callbacks:
            cb(self)

    def set_exception(self, exc: BaseException) -> None:
        with self._lock:
            if self._event.is_set():
                return
            self._exception = exc
            self._event.set()
            callbacks, self._callbacks = self._callbacks, []
        for cb in callbacks:
            cb(self)

    # ------------------------------------------------------------------
    # consumer side
    def done(self) -> bool:
        return self._event.is_set()

    def result(self, timeout: Optional[float] = None) -> Any:
        """Block until resolved; raises the worker's failure, or
        :class:`FutureTimeout` when ``timeout`` seconds pass first."""
        if not self._event.wait(timeout):
            raise FutureTimeout(
                f"no result within {timeout}s (worker busy, wedged, or "
                "starved — the request itself is still outstanding)")
        if self._exception is not None:
            raise self._exception
        return self._result

    def exception(self, timeout: Optional[float] = None) -> Optional[BaseException]:
        if not self._event.wait(timeout):
            raise FutureTimeout(f"no result within {timeout}s")
        return self._exception

    def add_done_callback(self, fn: Callable[["RunFuture"], None]) -> None:
        """Run ``fn(self)`` on resolution (immediately if already done);
        called from the resolving thread."""
        with self._lock:
            if not self._event.is_set():
                self._callbacks.append(fn)
                return
        fn(self)
