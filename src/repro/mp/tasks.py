"""Module-level task bodies shipped to pool workers by reference.

``session.map(builder, inputs)`` with ``procs=N`` round-robins inputs to
worker processes as ``run_builder`` calls: the child resolves the builder
ref, builds its own graph from the input, runs it through the child
session (adopting the parent's recordings from the shared on-disk cache
when one is configured) and sends back a compact, picklable outcome —
results, plan mode, scheduler stats, wall clock.  Jax arrays in the
results are converted to numpy so the payload pickles without a device
runtime on the parent's unpickling path.
"""

from __future__ import annotations

from typing import Any, Dict

from .pool import resolve_ref

__all__ = ["run_builder"]


def _portable(value: Any) -> Any:
    """Best-effort conversion of array-likes (jax) to plain numpy so the
    result pickles cheaply across the pipe; everything else passes
    through."""
    try:
        import numpy as np
        if hasattr(value, "__array__") and not isinstance(value, np.ndarray):
            return np.asarray(value)
    except Exception:
        pass
    return value


def _portable_stats(stats: Dict[str, Any]) -> Dict[str, Any]:
    out: Dict[str, Any] = {}
    for k, v in stats.items():
        if isinstance(v, (int, float, str, bool, type(None))):
            out[k] = v
        elif isinstance(v, dict):
            out[k] = _portable_stats(v)
        else:
            out[k] = repr(v)
    return out


def run_builder(ctx: Any, ref: str, value: Any, *,
                record: Any = None, timeout: float = 300.0) -> Dict[str, Any]:
    """Build ``resolve_ref(ref)(value)`` and run it on the child session.

    Returns a plain dict (never a live RunReport — graphs, recordings and
    traces stay in the child): ``results`` keyed by tid, the executed plan
    ``mode`` (``replay``/``pool``/... — ``pool_mode`` distinguishes adopt
    vs record for pool sessions), the run ``stats`` and ``wall_s``.
    """
    builder = resolve_ref(ref)
    graph = builder(value)
    report = ctx.session.run(graph, record=record, timeout=timeout)
    return {
        "results": {tid: _portable(v) for tid, v in report.results.items()},
        "mode": report.plan.mode,
        "remapped_from": report.plan.remapped_from,
        "digest": report.plan.digest,
        "stats": _portable_stats(report.stats),
        "wall_s": report.wall_s,
        "n_workers": report.n_workers,
        "scheduler": report.scheduler,
        "proc": ctx.index,
    }
