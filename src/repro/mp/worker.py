"""Child-process side of the :class:`~repro.mp.pool.ProcessPool` protocol.

``worker_main`` is the spawn target: it sends the ready handshake, then
loops on ``conn.recv()`` dispatching ``(seq, op, payload)`` requests into
a :class:`WorkerContext` — a lazily built
:class:`~repro.api.session.Session` (with its own shared
:class:`~repro.exec.core.ExecutorCore` / :class:`~repro.replay.ReplayPool`)
plus any serving streams the parent opened.  **Pipe EOF is the
parent-death sentinel**: the recv loop exits, the context tears the
session down and force-stops the shared-core registry, and the (daemonic)
process ends — children never outlive the parent.

Serving streams (``serve_open`` / ``serve_submit`` / ``serve_close``) run
a child-local :class:`~repro.serving.engine.ContinuousBatchingEngine` on a
driver thread; a ``serve_submit`` is answered *when the request finishes*
(with its :class:`~repro.serving.metrics.RequestRecord`), which is how
per-request completion crosses the pipe without any polling protocol on
top.  A submit that hits the child's bounded admission queue answers
immediately with an ``AdmissionFull`` error — backpressure propagates to
the parent as a failed future it can retry, on top of its own
outstanding-cap throttling.
"""

from __future__ import annotations

import os
import threading
import time
import traceback
from typing import Any, Dict, Optional, Tuple

from .pool import WorkerSpec, _split_fns_ref, resolve_ref

__all__ = ["WorkerContext", "worker_main"]


class WorkerContext:
    """Per-process service state handed to every shipped callable."""

    def __init__(self, conn: Any, spec: WorkerSpec, index: int):
        self.conn = conn
        self.spec = spec
        self.index = index
        self.state: Any = None               # spec.init's return value
        self._send_lock = threading.Lock()
        self._session: Optional[Any] = None
        self._streams: Dict[int, _ServeStream] = {}

    # ------------------------------------------------------------------
    # replies (recv loop + serve driver threads both send)
    def reply(self, seq: int, status: str, payload: Any) -> None:
        try:
            with self._send_lock:
                self.conn.send((seq, status, payload))
        except (BrokenPipeError, OSError):
            pass                             # parent is gone; we exit soon
        except Exception as e:               # unpicklable payload
            self.reply_err(seq, TypeError(
                f"worker reply for seq {seq} is not picklable: {e!r}"))

    def reply_err(self, seq: int, exc: BaseException) -> None:
        payload = (type(exc).__name__, str(exc),
                   "".join(traceback.format_exception(
                       type(exc), exc, exc.__traceback__)))
        try:
            with self._send_lock:
                self.conn.send((seq, "err", payload))
        except (BrokenPipeError, OSError):
            pass

    # ------------------------------------------------------------------
    @property
    def session(self) -> Any:
        """The child's session, built on first use from the spec (the
        on-disk cache directory is the recording-shipment channel)."""
        if self._session is None:
            from ..api.session import Session
            from ..replay.cache import GraphCache

            spec = self.spec
            cache = (GraphCache(spec.cache_path)
                     if spec.cache_path else None)
            self._session = Session(
                spec.workers, scheduler=spec.scheduler, policy=spec.policy,
                gang_default=spec.gang_default, seed=spec.seed, cache=cache,
                allow_remap=spec.allow_remap, trace=spec.trace,
                shared_cores=spec.shared_cores,
                stall_timeout=spec.stall_timeout,
                block_poll=spec.block_poll,
                pool_kwargs=dict(spec.pool_kwargs))
            if spec.init is not None:
                self.state = resolve_ref(spec.init)(self)
        return self._session

    # ------------------------------------------------------------------
    def dispatch(self, seq: int, op: str, payload: Any) -> None:
        if op == "ping":
            self.reply(seq, "ok", payload)
        elif op == "call":
            ref, args, kwargs = payload
            fn = resolve_ref(ref)
            self.reply(seq, "ok", fn(self, *args, **(kwargs or {})))
        elif op == "serve_open":
            sid = int(payload["stream"])
            if sid in self._streams:
                raise ValueError(f"serve stream {sid} is already open")
            self._streams[sid] = _ServeStream(
                self, payload["fns_ref"], dict(payload.get("engine") or {}))
            self.reply(seq, "ok", None)
        elif op == "serve_submit":
            stream = self._streams[int(payload["stream"])]
            stream.submit(seq, payload["request"])   # answered at finish
        elif op == "serve_close":
            stream = self._streams.pop(int(payload["stream"]))
            stream.close(seq)                        # answered at drain
        else:
            raise ValueError(f"unknown worker op {op!r}")

    def teardown(self) -> None:
        for stream in list(self._streams.values()):
            stream.abort()
        self._streams.clear()
        if self._session is not None:
            try:
                self._session.close()
            except Exception:
                pass
            self._session = None
        # a worker process hosts exactly one tenant: force-stop whatever
        # shared cores are still registered so the interpreter exits with
        # no live worker threads (daemon or not, a clean exit beats a reap)
        try:
            from ..exec.registry import REGISTRY
            REGISTRY.shutdown_all()
        except Exception:
            pass


class _ServeStream:
    """One continuous-batching engine driven by pipe submits.

    The driver thread owns every engine mutation except
    :meth:`ContinuousBatchingEngine.submit` (documented thread-safe); the
    stream lock only guards the seq bookkeeping (`_pending`, the close
    seq).  Completion detection reuses the engine's own semantics — token
    budget reached or EOS drawn — instead of ``done_s``, which is a valid
    0.0 under the virtual clock.
    """

    def __init__(self, ctx: WorkerContext, fns_ref: Any,
                 engine_kwargs: Dict[str, Any]):
        from ..serving.engine import ContinuousBatchingEngine

        ref, factory_kwargs = _split_fns_ref(fns_ref)
        fns = resolve_ref(ref)(**factory_kwargs)
        decode_fn, prefill_fn, sample_fn = (tuple(fns) + (None,))[:3]
        self.ctx = ctx
        self.engine = ContinuousBatchingEngine(
            ctx.session, decode_fn, prefill_fn, sample_fn=sample_fn,
            **engine_kwargs)
        self._lock = threading.Lock()
        self._pending: Dict[int, Tuple[int, Any]] = {}   # rid -> (seq, req)
        self._close_seq: Optional[int] = None
        self._aborted = False
        self._wake = threading.Event()
        self._t0 = time.perf_counter()
        self._thread = threading.Thread(
            target=self._drive, name=f"mp-serve-drive-{ctx.index}",
            daemon=True)
        self._thread.start()

    # ------------------------------------------------------------------
    # recv-loop side
    def submit(self, seq: int, request: Any) -> None:
        # AdmissionFull propagates to the dispatcher, which answers the
        # seq with an err the parent can retry on
        self.engine.submit(request)
        with self._lock:
            self._pending[request.rid] = (seq, request)
        self._wake.set()

    def close(self, seq: int) -> None:
        with self._lock:
            self._close_seq = seq
        self._wake.set()

    def abort(self) -> None:
        self._aborted = True
        self._wake.set()
        self._thread.join(timeout=5.0)

    # ------------------------------------------------------------------
    @staticmethod
    def _finished(rec: Any, req: Any) -> bool:
        if len(rec.tokens) >= req.max_new_tokens:
            return True
        eos = req.eos_token
        return (eos is not None and bool(rec.tokens)
                and rec.tokens[-1] == eos)

    def _drive(self) -> None:
        engine = self.engine
        while not self._aborted:
            worked = engine.step()
            done = []
            with self._lock:
                for rid, (seq, req) in list(self._pending.items()):
                    rec = engine._records.get(rid)
                    if rec is not None and self._finished(rec, req):
                        done.append((seq, rec))
                        del self._pending[rid]
                idle = (not self._pending and not engine.in_flight()
                        and not engine.queue_depth())
                close_seq = self._close_seq if idle else None
            for seq, rec in done:
                self.ctx.reply(seq, "ok", rec)
            if close_seq is not None:
                self.ctx.reply(close_seq, "ok", self.summary())
                return
            if not worked and not done:
                self._wake.wait(1e-3)
                self._wake.clear()

    def summary(self) -> Dict[str, Any]:
        """The child-side counters the parent folds into its merged
        :class:`~repro.serving.metrics.ServingReport` — including the
        pool's per-shape record/adopt counters, which is how "children
        replay warm without re-recording" becomes assertable."""
        e = self.engine
        pool_stats: Dict[str, Any] = {}
        records = rerecords = 0
        sess = self.ctx._session
        if (sess is not None and sess.scheduler == "pool"
                and sess._pool is not None):
            pool_stats = sess._pool.describe()
            for st in pool_stats.values():
                records += int(st.get("records", 0))
                rerecords += int(st.get("rerecords", 0))
        return {
            "pid": os.getpid(),
            "proc": self.ctx.index,
            "steps": e._steps,
            "warm_steps": e._warm_steps,
            "lane_steps": e._lane_steps,
            "shape_counts": dict(e._shape_counts),
            "completed": e._done,
            "records": records,
            "rerecords": rerecords,
            "pool": pool_stats,
            "wall_s": time.perf_counter() - self._t0,
        }


def worker_main(conn: Any, spec: WorkerSpec, index: int) -> None:
    """Spawn target: handshake, serve the pipe, die with the parent."""
    ctx = WorkerContext(conn, spec, index)
    ctx.reply(0, "ok", ("ready", os.getpid()))
    try:
        while True:
            try:
                msg = conn.recv()
            except (EOFError, OSError):
                break                        # parent died: EOF sentinel
            seq, op, payload = msg
            if op == "shutdown":
                ctx.reply(seq, "ok", None)
                break
            try:
                ctx.dispatch(seq, op, payload)
            except BaseException as e:       # noqa: BLE001 - shipped back
                ctx.reply_err(seq, e)
    finally:
        ctx.teardown()
        try:
            conn.close()
        except OSError:
            pass
