"""Multi-process execution pool: processes for parallelism, recordings
for dispatch.

The in-process executors hit a single-interpreter ceiling: every dispatch
path contends on the GIL, so adding worker *threads* stops buying
parallelism (the flight recorder measured dispatch overhead growing from
3% to 59% of worker time between 1 and 4 workers).  This package shards
work across worker *processes* instead — each child hosts its own shared
:class:`~repro.exec.core.ExecutorCore` + serving pool — while recordings
and compiled-plan metadata ship through the existing on-disk
:class:`~repro.replay.cache.GraphCache`, so children replay warm without
paying their own recording runs.

Entry points:

* :class:`ProcessPool` / :class:`WorkerSpec` — the raw pool (spawn-safe
  request pipe, seq-matched :class:`RunFuture` results, daemon children
  that die with the parent);
* ``Session(procs=N)`` routes :meth:`~repro.api.session.Session.map`
  through the pool and exposes :meth:`Session.process_pool`;
* ``ContinuousBatchingEngine(procs=N, fns_ref=...)`` shards serving
  requests by rid across child engines with bit-identical per-request
  streams;
* :func:`callable_ref` / :func:`resolve_ref` — the "code ships by import
  reference, never by pickle" contract.
"""

from .futures import FutureTimeout, RunFuture, WorkerDied, WorkerError
from .pool import ProcessPool, WorkerSpec, callable_ref, resolve_ref

__all__ = [
    "FutureTimeout",
    "ProcessPool",
    "RunFuture",
    "WorkerDied",
    "WorkerError",
    "WorkerSpec",
    "callable_ref",
    "resolve_ref",
]
