"""Spawn-safe worker-process pool with a pipe request protocol.

A :class:`ProcessPool` holds N spawned child processes, each hosting its
own :class:`~repro.api.session.Session` (shared
:class:`~repro.exec.core.ExecutorCore` + :class:`~repro.replay.ReplayPool`)
built from a picklable :class:`WorkerSpec`.  Work crosses a per-child
duplex pipe as ``(seq, op, payload)`` tuples; a per-child reader thread
resolves the matching :class:`~repro.mp.futures.RunFuture` when the
child's ``(seq, status, payload)`` response lands — responses may arrive
out of order (a serving stream answers a submit only when the request
*finishes*), which is the whole point of the seq-matched futures.

Code never crosses the pipe: callables ship as ``"module:qualname"``
references (:func:`callable_ref`) resolved by import inside the child, and
recordings/compiled-plan meta ship through the on-disk
:class:`~repro.replay.cache.GraphCache` named by ``WorkerSpec.cache_path``
— the children adopt the parent's recordings from disk instead of paying
their own recording runs.

Death handling is symmetric:

* children are **daemonic** and treat pipe EOF as the parent-death
  sentinel (their recv loop exits, the worker tears its session down), so
  a dying parent never strands grandchildren;
* the parent's reader thread treats pipe EOF as child death: every
  outstanding future on that worker fails with
  :class:`~repro.mp.futures.WorkerDied` (carrying the worker index), which
  is what lets the serving engine re-route a dead child's requests instead
  of hanging on them.
"""

from __future__ import annotations

import dataclasses
import importlib
import itertools
import multiprocessing
import pickle
import threading
from typing import Any, Dict, List, Optional, Tuple

from .futures import RunFuture, WorkerDied

__all__ = ["ProcessPool", "WorkerSpec", "callable_ref", "resolve_ref"]


# ----------------------------------------------------------------------
# shipping callables by reference
def callable_ref(fn: Any) -> str:
    """``fn`` -> ``"module:qualname"``, verified to round-trip.

    Only module-level callables can cross a spawn boundary (the child
    re-imports them); closures, lambdas and locals raise ``ValueError`` so
    callers can fail fast (or fall back) instead of shipping a ref the
    child cannot resolve.
    """
    if isinstance(fn, str):
        resolve_ref(fn)                     # validate early, parent-side
        return fn
    mod = getattr(fn, "__module__", None)
    qual = getattr(fn, "__qualname__", None)
    if not mod or not qual or "<" in qual:
        raise ValueError(
            f"{fn!r} is not shippable to a worker process: only "
            "module-level callables resolve across spawn "
            "(got module={mod!r}, qualname={qual!r})".format(
                fn=fn, mod=mod, qual=qual))
    ref = f"{mod}:{qual}"
    if resolve_ref(ref) is not fn:
        raise ValueError(
            f"{fn!r} does not round-trip through {ref!r} (decorated or "
            "shadowed?); workers would resolve a different object")
    return ref


def resolve_ref(ref: str) -> Any:
    """``"module:qualname"`` -> the callable (child-side import)."""
    mod_name, _, qual = ref.partition(":")
    if not mod_name or not qual:
        raise ValueError(f"malformed callable ref {ref!r} "
                         "(want 'module:qualname')")
    obj: Any = importlib.import_module(mod_name)
    for part in qual.split("."):
        obj = getattr(obj, part)
    return obj


# ----------------------------------------------------------------------
@dataclasses.dataclass
class WorkerSpec:
    """Everything a child needs to build its session — plain picklable
    data.  ``cache_path`` (a directory) is the recording-shipment channel:
    every child opens its own :class:`~repro.replay.cache.GraphCache` over
    the same directory, so parent-seeded recordings are adopted via
    ``GraphCache.candidates`` + ``remap_recording`` with no child-side
    recording run.  ``init`` names a module-level ``fn(ctx)`` run once at
    session build time; its return value becomes ``ctx.state`` (model
    set-up, RNG seeding — anything every later task on that worker needs).
    """

    workers: int = 1
    scheduler: str = "dynamic"
    policy: str = "hybrid"
    gang_default: bool = True
    seed: int = 0
    cache_path: Optional[str] = None
    allow_remap: bool = True
    trace: bool = False
    shared_cores: bool = True
    stall_timeout: float = 1e-3
    block_poll: float = 0.05
    pool_kwargs: Dict[str, Any] = dataclasses.field(default_factory=dict)
    init: Optional[str] = None               # "module:qualname" -> fn(ctx)

    @classmethod
    def from_session(cls, session: Any) -> "WorkerSpec":
        """Mirror a parent session's configuration into child processes
        (cache shipment rides the session cache's on-disk path, when it
        has one)."""
        return cls(
            workers=session.workers,
            scheduler=session.scheduler,
            policy=session.policy,
            gang_default=session.gang_default,
            seed=session.seed,
            cache_path=getattr(session.cache, "path", None),
            allow_remap=session.allow_remap,
            trace=False,     # traces are parent-side observability; child
                             # ring buffers would never be shipped back
            shared_cores=session.shared_cores,
            stall_timeout=session.stall_timeout,
            block_poll=session.block_poll,
            pool_kwargs=dict(session.pool_kwargs),
        )


class _Worker:
    """Parent-side handle for one child process."""

    __slots__ = ("index", "process", "conn", "send_lock", "pending",
                 "pending_lock", "alive", "reader", "ready")

    def __init__(self, index: int) -> None:
        self.index = index
        self.process: Any = None
        self.conn: Any = None
        self.send_lock = threading.Lock()
        self.pending: Dict[int, RunFuture] = {}
        self.pending_lock = threading.Lock()
        self.alive = False
        self.reader: Optional[threading.Thread] = None
        self.ready = RunFuture()


class ProcessPool:
    """N spawned worker processes behind seq-matched pipe futures.

    ``request(proc, op, payload)`` is the raw protocol primitive;
    ``submit(fn, *args)`` ships a module-level callable as a ``call`` op
    (round-robin across workers unless ``proc`` pins one).  Use as a
    context manager, or call :meth:`shutdown`.
    """

    #: seq 0 is reserved for the child's ready handshake
    _READY_SEQ = 0

    def __init__(self, procs: int, spec: Optional[WorkerSpec] = None, *,
                 name: str = "repro-mp", start_timeout: float = 120.0):
        if procs < 1:
            raise ValueError(f"a process pool needs >= 1 worker, got {procs}")
        self.spec = spec if spec is not None else WorkerSpec()
        self.n_procs = procs
        self.name = name
        self._ctx = multiprocessing.get_context("spawn")
        self._seq = itertools.count(self._READY_SEQ + 1)
        self._rr = itertools.count()
        self._closed = False
        self._workers: List[_Worker] = [self._spawn(i) for i in range(procs)]
        try:
            for w in self._workers:
                got = w.ready.result(timeout=start_timeout)
                if got[0] != "ready":
                    raise RuntimeError(
                        f"worker {w.index} sent {got!r} instead of the "
                        "ready handshake")
        except BaseException:
            self.shutdown()
            raise

    # ------------------------------------------------------------------
    # lifecycle
    def _spawn(self, index: int) -> _Worker:
        from .worker import worker_main

        w = _Worker(index)
        parent_conn, child_conn = self._ctx.Pipe(duplex=True)
        w.conn = parent_conn
        w.pending[self._READY_SEQ] = w.ready
        # daemon: the OS reaps the child if the parent dies without a clean
        # shutdown; the child's own recv loop exits on pipe EOF first
        w.process = self._ctx.Process(
            target=worker_main, args=(child_conn, self.spec, index),
            name=f"{self.name}-{index}", daemon=True)
        w.alive = True
        w.process.start()
        child_conn.close()       # the child owns its end now; EOF works
        w.reader = threading.Thread(
            target=self._read_loop, args=(w,),
            name=f"mp-reader-{index}", daemon=True)
        w.reader.start()
        return w

    def _read_loop(self, w: _Worker) -> None:
        while True:
            try:
                # bounded poll instead of a bare recv: a read blocked in
                # the kernel pins the connection's file description open,
                # so a conn.close() from another thread (the simulated
                # parent-death path, or kill()) could never deliver EOF to
                # the child; polling re-checks the handle a few times a
                # second so a close takes effect promptly
                if not w.conn.poll(0.2):
                    continue
                seq, status, payload = w.conn.recv()
            except (EOFError, OSError):
                break
            except (pickle.UnpicklingError, AttributeError, ImportError,
                    IndexError):
                # a reply we cannot decode poisons only itself, not the
                # worker; there is no seq to resolve, so drop it
                continue
            with w.pending_lock:
                fut = w.pending.pop(seq, None)
            if fut is None:
                continue                     # cancelled/unknown seq
            if status == "ok":
                fut.set_result(payload)
            else:
                from .futures import WorkerError
                kind, msg, tb = payload
                fut.set_exception(WorkerError(kind, msg, tb))
        self._mark_dead(w, "pipe closed")

    def _mark_dead(self, w: _Worker, detail: str) -> None:
        w.alive = False
        with w.pending_lock:
            pending, w.pending = dict(w.pending), {}
        for fut in pending.values():
            fut.set_exception(WorkerDied(w.index, detail))

    def alive(self, proc: int) -> bool:
        w = self._workers[proc]
        return w.alive and w.process.is_alive()

    def kill(self, proc: int) -> None:
        """Hard-kill one worker (chaos/testing helper).  Outstanding
        futures on it fail with :class:`WorkerDied` via the reader's EOF."""
        w = self._workers[proc]
        if w.process.is_alive():
            w.process.terminate()
        w.process.join(timeout=5.0)
        try:
            w.conn.close()
        except OSError:
            pass

    def shutdown(self) -> None:
        """Orderly stop: ask every live child to exit, then escalate
        (terminate -> kill) so no child ever outlives the pool."""
        if self._closed:
            return
        self._closed = True
        for w in self._workers:
            if w.alive:
                try:
                    seq = next(self._seq)
                    with w.send_lock:
                        w.conn.send((seq, "shutdown", None))
                except (OSError, ValueError):
                    pass
        for w in self._workers:
            w.process.join(timeout=3.0)
            if w.process.is_alive():
                w.process.terminate()
                w.process.join(timeout=2.0)
            if w.process.is_alive():      # pragma: no cover - last resort
                w.process.kill()
                w.process.join(timeout=2.0)
            try:
                w.conn.close()
            except OSError:
                pass
            self._mark_dead(w, "pool shut down")
            if w.reader is not None:
                w.reader.join(timeout=2.0)
            # release the multiprocessing bookkeeping entry so the suite's
            # orphaned-child check (multiprocessing.active_children) stays
            # clean even right after a shutdown
            w.process.close()

    def __enter__(self) -> "ProcessPool":
        return self

    def __exit__(self, *exc) -> None:
        self.shutdown()

    # ------------------------------------------------------------------
    # the protocol primitive
    def request(self, proc: int, op: str, payload: Any = None) -> RunFuture:
        """Send one op to worker ``proc``; the returned future resolves
        when (and only when) the child answers its seq."""
        if self._closed:
            raise RuntimeError("ProcessPool is shut down")
        w = self._workers[proc]
        fut = RunFuture()
        if not w.alive:
            fut.set_exception(WorkerDied(proc, "worker is not running"))
            return fut
        seq = next(self._seq)
        with w.pending_lock:
            w.pending[seq] = fut
        try:
            with w.send_lock:
                w.conn.send((seq, op, payload))
        except (pickle.PicklingError, TypeError) as e:
            # unpicklable payload: this request fails, the worker lives
            with w.pending_lock:
                w.pending.pop(seq, None)
            fut.set_exception(e)
        except (BrokenPipeError, EOFError, OSError) as e:
            with w.pending_lock:
                w.pending.pop(seq, None)
            self._mark_dead(w, f"send failed: {e}")
            fut.set_exception(WorkerDied(proc, f"send failed: {e}"))
        return fut

    def broadcast(self, op: str, payload: Any = None) -> List[RunFuture]:
        return [self.request(p, op, payload) for p in range(self.n_procs)]

    # ------------------------------------------------------------------
    # conveniences over the protocol
    def ping(self, proc: int, token: Any = None,
             timeout: float = 30.0) -> Any:
        return self.request(proc, "ping", token).result(timeout=timeout)

    def submit(self, fn: Any, *args: Any, proc: Optional[int] = None,
               **kwargs: Any) -> RunFuture:
        """Ship ``fn(ctx, *args, **kwargs)`` to a worker (round-robin when
        ``proc`` is None).  ``fn`` must be a module-level callable (or an
        explicit ``"module:qualname"`` string); inside the child it
        receives the :class:`~repro.mp.worker.WorkerContext` first."""
        ref = callable_ref(fn)
        if proc is None:
            proc = next(self._rr) % self.n_procs
        return self.request(proc, "call", (ref, args, kwargs))

    def map(self, fn: Any, values: Any, timeout: float = 300.0) -> List[Any]:
        """Round-robin ``fn`` over ``values``; blocks for all results (in
        input order)."""
        futs = [self.submit(fn, v) for v in values]
        return [f.result(timeout=timeout) for f in futs]

    def describe(self) -> Dict[str, Any]:
        return {
            "procs": self.n_procs,
            "alive": [self.alive(p) for p in range(self.n_procs)],
            "pids": [w.process.pid if w.process is not None else None
                     for w in self._workers],
            "spec": dataclasses.asdict(self.spec),
        }


def _split_fns_ref(fns_ref: Any) -> Tuple[str, Dict[str, Any]]:
    """Normalize an engine ``fns_ref`` — ``"mod:qual"`` or
    ``("mod:qual", kwargs)`` — to ``(ref, kwargs)``."""
    if isinstance(fns_ref, (tuple, list)):
        ref, kw = fns_ref
        return str(ref), dict(kw or {})
    return str(fns_ref), {}
