"""Recordings of task-graph executions.

A :class:`Recording` captures everything the replay executor needs to re-run
a graph of the same shape without making any scheduling decisions:

* ``worker_orders`` — for each worker, the entries it executed in start
  order.  An entry is a task id (``int``), a gang ULT
  ``(spawn_tid, thread_num)`` pair (stored as a 2-list in JSON), or a
  :class:`~repro.core.taskgraph.FrameResume` — resume segment ``seg`` of a
  suspended task frame (stored as ``["r", tid, seg]``), which is what lets
  replay reproduce a run's frame interleaving bit-identically;
* ``gang_placements`` — for each region-forking task, the recorded gang id
  and the worker that ran each ULT (index = ``thread_num``);
* ``gang_issue_order`` — spawn-task ids in fork (gang-id) order: the
  monotonic-gang-id discipline replay must reproduce;
* ``steals`` — the dynamic run's successful steal decisions
  ``(thief, victim, entry)``, kept for analysis (the run lists already
  incorporate their effect);
* ``collective_order`` — comm-task ids in issue order (from the static
  schedule's total order when seeded from one, from completion order when
  recorded dynamically).

Recordings are plain data (ints/floats/strings) — JSON round-trippable for
the on-disk :class:`~repro.replay.cache.GraphCache`.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, List, Optional, Tuple, Union

from ..core.static_schedule import StaticSchedule
from ..core.taskgraph import FrameResume, TaskGraph
from .graph_key import GraphKey, graph_key

# an executed unit: a task id, (spawn_tid, thread_num) for a gang ULT, or
# FrameResume(tid, seg) for a suspended frame's resume segment
Entry = Union[int, Tuple[int, int], FrameResume]


@dataclasses.dataclass
class GangPlacement:
    spawn_tid: int
    gang_id: int
    workers: List[int]          # workers[i] ran thread_num i


class RecordingError(ValueError):
    """A recording does not match the graph it is being replayed against."""


@dataclasses.dataclass
class Recording:
    digest: str                                  # GraphKey digest recorded for
    graph_name: str
    n_workers: int
    policy: str
    worker_orders: List[List[Entry]]
    gang_placements: Dict[int, GangPlacement] = dataclasses.field(default_factory=dict)
    gang_issue_order: List[int] = dataclasses.field(default_factory=list)
    steals: List[Tuple[int, int, Entry]] = dataclasses.field(default_factory=list)
    collective_order: List[int] = dataclasses.field(default_factory=list)
    # (tid, seg) -> winning source index of a ctx.wait_any select resolved
    # at that resume segment; replay pins the recorded choice
    wait_choices: Dict[Tuple[int, int], int] = dataclasses.field(default_factory=dict)
    # global resource-grant order: tids of resource-declaring tasks in the
    # order the arbiter granted them (each exactly once — acquisition is
    # all-or-nothing per task).  Replay derives per-resource queues from
    # this and re-grants bit-identically; worker-slot independent, so
    # remapping across worker counts preserves it verbatim.
    resource_grants: List[int] = dataclasses.field(default_factory=list)
    source: str = "dynamic"                      # "dynamic" | "static"

    # ------------------------------------------------------------------
    def owner_of(self) -> Dict[int, int]:
        """tid -> recorded worker, for plain task entries."""
        out: Dict[int, int] = {}
        for w, order in enumerate(self.worker_orders):
            for e in order:
                if isinstance(e, int):
                    out[e] = w
        return out

    def n_tasks(self) -> int:
        """Number of distinct tasks the recording covers (plain entries;
        frame-resume segments belong to an already-counted task)."""
        return sum(1 for order in self.worker_orders
                   for e in order if isinstance(e, int))

    def validate_against(self, graph: TaskGraph, *, check_digest: bool = True) -> None:
        """Raise :class:`RecordingError` unless this recording covers exactly
        the tasks of ``graph`` (each tid once) and — when ``check_digest`` —
        was recorded for a graph of identical structure."""
        if check_digest:
            key = graph_key(graph)
            if key.digest != self.digest:
                raise RecordingError(
                    f"recording is for graph {self.graph_name!r} "
                    f"(digest {self.digest[:16]}) but got {key}")
        seen: Dict[int, int] = {}
        resumes: Dict[Tuple[int, int], int] = {}
        for order in self.worker_orders:
            for e in order:
                if isinstance(e, int):
                    seen[e] = seen.get(e, 0) + 1
                elif isinstance(e, FrameResume):
                    resumes[(e.tid, e.seg)] = resumes.get((e.tid, e.seg), 0) + 1
        n = len(graph)
        missing = [t for t in range(n) if seen.get(t, 0) != 1]
        extra = [t for t in seen if t >= n]
        if missing or extra:
            raise RecordingError(
                "recording does not cover graph 1:1 "
                f"(bad/missing tids {missing[:8]}, out-of-range {extra[:8]})")
        bad_resumes = [k for k, c in resumes.items()
                       if c != 1 or k[0] >= n or k[1] < 1]
        if bad_resumes:
            raise RecordingError(
                f"bad frame-resume entries {bad_resumes[:8]} (each (tid, seg) "
                "must appear once, for an in-range task, with seg >= 1)")
        bad_choices = [(k, i) for k, i in self.wait_choices.items()
                       if k[0] >= n or k[1] < 1 or i < 0]
        if bad_choices:
            raise RecordingError(
                f"bad wait_any choices {bad_choices[:8]} (keys must be "
                "in-range (tid, seg >= 1) with a non-negative winner index)")
        declaring = {t.tid for t in graph.tasks if t.uses or t.uses_shared}
        granted = list(self.resource_grants)
        if declaring or granted:
            counts: Dict[int, int] = {}
            for tid in granted:
                counts[tid] = counts.get(tid, 0) + 1
            bad_grants = sorted(
                (set(counts) ^ declaring)
                | {t for t, c in counts.items() if c != 1})
            if bad_grants:
                raise RecordingError(
                    f"resource_grants does not cover the graph's resource-"
                    f"declaring tasks 1:1 (bad tids {bad_grants[:8]})")

    # ------------------------------------------------------------------
    # serialization (plain data; gang entries become 2-lists)
    def to_dict(self) -> Dict[str, Any]:
        def enc(e: Entry):
            if isinstance(e, int):
                return e
            if isinstance(e, FrameResume):
                return ["r", int(e.tid), int(e.seg)]
            return [int(e[0]), int(e[1])]
        return {
            "digest": self.digest,
            "graph_name": self.graph_name,
            "n_workers": self.n_workers,
            "policy": self.policy,
            "worker_orders": [[enc(e) for e in o] for o in self.worker_orders],
            "gang_placements": {
                str(tid): {"spawn_tid": p.spawn_tid, "gang_id": p.gang_id,
                           "workers": list(p.workers)}
                for tid, p in self.gang_placements.items()},
            "gang_issue_order": list(self.gang_issue_order),
            "steals": [[t, v, enc(e)] for t, v, e in self.steals],
            "collective_order": list(self.collective_order),
            "wait_choices": [[tid, seg, idx] for (tid, seg), idx
                             in sorted(self.wait_choices.items())],
            "resource_grants": list(self.resource_grants),
            "source": self.source,
        }

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "Recording":
        def dec(e) -> Entry:
            if isinstance(e, int):
                return e
            if len(e) == 3 and e[0] == "r":
                return FrameResume(int(e[1]), int(e[2]))
            return (int(e[0]), int(e[1]))
        return cls(
            digest=d["digest"],
            graph_name=d.get("graph_name", ""),
            n_workers=int(d["n_workers"]),
            policy=d.get("policy", "hybrid"),
            worker_orders=[[dec(e) for e in o] for o in d["worker_orders"]],
            gang_placements={
                int(tid): GangPlacement(p["spawn_tid"], p["gang_id"],
                                        list(p["workers"]))
                for tid, p in d.get("gang_placements", {}).items()},
            gang_issue_order=list(d.get("gang_issue_order", [])),
            steals=[(s[0], s[1], dec(s[2])) for s in d.get("steals", [])],
            collective_order=list(d.get("collective_order", [])),
            wait_choices={(int(c[0]), int(c[1])): int(c[2])
                          for c in d.get("wait_choices", [])},
            resource_grants=[int(t) for t in d.get("resource_grants", [])],
            source=d.get("source", "dynamic"),
        )

    def to_json(self) -> str:
        return json.dumps(self.to_dict())

    @classmethod
    def from_json(cls, s: str) -> "Recording":
        return cls.from_dict(json.loads(s))

    # ------------------------------------------------------------------
    @classmethod
    def from_static_schedule(
        cls,
        sched: StaticSchedule,
        graph: TaskGraph,
        key: Optional[GraphKey] = None,
        *,
        gangs: bool = True,
    ) -> "Recording":
        """Seed a recording from a frozen :class:`StaticSchedule`: slot i's
        item order (by frozen start time) becomes worker i's run list, and
        the schedule's collective total order is carried over.

        With ``gangs`` (default) the simulator's gang reservations
        (``sched.gangs``) are synthesized into recorded placements: each
        region-forking task gets a :class:`GangPlacement` on the reserved
        slots, its ULT entries are inserted into those slots' run lists at
        the fork's virtual time, and the fork order becomes the recording's
        monotonic gang-id issue order — so e.g. numeric LU/QR panel forks
        replay *placed* instead of hitting the dynamic fallback.  Pass
        ``key`` explicitly when the recording should drive a same-shaped
        twin of ``graph`` (the numeric build of a cost-model schedule)."""
        if key is None:
            key = graph_key(graph)
        # (slot, sort-key, end-time) per scheduled task
        place: Dict[int, Tuple[int, float, float]] = {}
        for slot, items in sched.order.items():
            for i, it in enumerate(items):
                place[it.tid] = (slot, float(i), it.t1)
        # Tasks missing from the frozen trace (zero-cost joins filtered from
        # sim events) go immediately after their latest-finishing dependency
        # on that dependency's slot: at that point every dep has completed,
        # so the recorded start order stays dependency-consistent.
        eps = 1.0 / (len(graph) + 2)
        for t in graph.topological_order():
            if t.tid in place:
                continue
            best: Optional[Tuple[float, int, float]] = None   # (t1, slot, seq)
            for d in t.deps:
                slot_d, seq_d, t1_d = place[d]
                if best is None or t1_d > best[0]:
                    best = (t1_d, slot_d, seq_d)
            if best is None:                                   # root task
                place[t.tid] = (0, -1.0 + eps * t.tid, 0.0)
            else:
                place[t.tid] = (best[1], best[2] + eps * (t.tid + 1), best[0])
        rows: List[Tuple[int, float, int, Entry]] = [
            (slot, seq, 0, tid) for tid, (slot, seq, _) in place.items()]

        # gang reservations -> recorded placements + slot-ordered ULT entries
        placements: Dict[int, GangPlacement] = {}
        issue_order: List[int] = []
        if gangs and sched.gangs:
            import bisect

            slot_starts: List[List[float]] = [[] for _ in range(sched.n_slots)]
            for it in sched.items:
                slot_starts[it.slot].append(it.t0)
            for s in slot_starts:
                s.sort()
            for g in sorted(sched.gangs, key=lambda g: (g.t, g.gang_id)):
                placements[g.spawn_tid] = GangPlacement(
                    g.spawn_tid, g.gang_id, list(g.workers))
                issue_order.append(g.spawn_tid)
                for i, wk in enumerate(g.workers):
                    # fractional seq: after every item starting at or before
                    # the fork, before the next one (ULTs run right after
                    # their fork on the reserved slot)
                    seq = bisect.bisect_right(slot_starts[wk], g.t) - 0.5
                    rows.append((wk, seq, 1, (g.spawn_tid, i)))

        orders: List[List[Entry]] = [[] for _ in range(sched.n_slots)]
        for slot, _, _, entry in sorted(rows, key=lambda r: (r[0], r[1], r[2])):
            orders[slot].append(entry)
        # synthesize the resource-grant order from the frozen start times
        # (the simulator grants at task start; ties break by tid, matching
        # its deterministic event order)
        t0_of: Dict[int, float] = {it.tid: it.t0 for it in sched.items}
        resource_grants = sorted(
            (t.tid for t in graph.tasks if t.uses or t.uses_shared),
            key=lambda tid: (t0_of.get(tid, place[tid][2]), tid))
        return cls(
            digest=key.digest,
            graph_name=graph.name,
            n_workers=sched.n_slots,
            policy=sched.policy,
            worker_orders=orders,
            gang_placements=placements,
            gang_issue_order=issue_order,
            collective_order=sched.collective_order(),
            resource_grants=resource_grants,
            source="static",
        )
