"""Canonical structural hashing of task graphs.

A :class:`GraphKey` identifies a :class:`~repro.core.taskgraph.TaskGraph` by
*shape*: topology (dependency edges), task kinds, analytical costs,
priorities, names, and parallel-region specs.  Callables are deliberately
excluded — two builds of the same tiled factorization over different tile
stores close over different data but produce the same key, which is exactly
what lets an iterative sweep reuse one recording for every iteration.

Floats are canonicalized with ``float.hex()`` (exact, no repr drift);
the digest is SHA-256 over a line-per-task serialization.
"""

from __future__ import annotations

import dataclasses
import hashlib
from typing import Optional

from ..core.taskgraph import ParallelSpec, TaskGraph


@dataclasses.dataclass(frozen=True, eq=False)
class GraphKey:
    """Structural identity of a task graph.  Equality and hashing use only
    the digest; ``name``/``n_tasks`` are carried for diagnostics."""

    digest: str
    n_tasks: int
    name: str = ""

    def __eq__(self, other: object) -> bool:
        if isinstance(other, GraphKey):
            return self.digest == other.digest
        if isinstance(other, str):
            return self.digest == other
        return NotImplemented

    def __hash__(self) -> int:
        return hash(self.digest)

    def short(self) -> str:
        return self.digest[:16]

    def __str__(self) -> str:
        return f"GraphKey({self.name or '?'}, {self.short()}, n={self.n_tasks})"


def _canon_float(x: float) -> str:
    return float(x).hex()


def _canon_parallel(spec: Optional[ParallelSpec]) -> str:
    if spec is None:
        return "-"
    return "|".join((
        str(spec.n_threads),
        "B" if spec.blocking else "n",
        {None: "?", True: "G", False: "g"}[spec.gang],
        _canon_float(spec.cost_per_thread),
        str(spec.n_barriers),
    ))


def _canon_resources(graph: TaskGraph, t) -> str:
    """Resource declarations by structural identity: rindex (first-use
    order), name and capacity — not the process-wide uid, so two builds of
    the same graph over fresh handles share a key.  Empty for tasks with no
    declarations, which keeps resource-free digests byte-identical to the
    pre-resource format."""
    if not t.uses and not t.uses_shared:
        return ""
    index = graph.resource_index()
    def enc(r, tag):
        return f"{tag}{index[id(r)]}:{r.name}:{r.capacity}"
    parts = sorted(
        [enc(r, "x") for r in t.uses] + [enc(r, "s") for r in t.uses_shared])
    return ";" + ",".join(parts)


def graph_key(graph: TaskGraph) -> GraphKey:
    """Compute the structural key of ``graph`` (O(tasks + edges))."""
    h = hashlib.sha256()
    h.update(graph.name.encode())
    for t in graph.tasks:
        line = ";".join((
            str(t.tid),
            t.name,
            t.kind,
            _canon_float(t.cost),
            str(t.priority),
            ",".join(map(str, t.deps)),
            _canon_parallel(t.parallel),
        )) + _canon_resources(graph, t)
        h.update(line.encode())
        h.update(b"\n")
    return GraphKey(digest=h.hexdigest(), n_tasks=len(graph), name=graph.name)
