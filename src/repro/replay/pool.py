"""Replay-serving pool: persistent executors with adaptive re-recording.

A steady-state serving loop (``examples/serve_lm.py``: one decode-step graph
per request) re-executes the same graph *shape* indefinitely.  Running each
request through :func:`~repro.core.runtime.run_graph` pays per-request
runtime construction — thread spawn, queue allocation — on top of dynamic
scheduling; even ``run_graph(cache=...)`` builds a fresh
:class:`~repro.replay.executor.ReplayExecutor` (and its worker threads) per
call.  :class:`ReplayPool` keeps one long-lived executor per
``(GraphKey digest, n_workers, policy)`` and serves repeated executions on
warm threads:

* **first requests** for a shape run dynamically: ``warmup_runs`` requests
  unrecorded (so jit compiles / cold caches do not skew the recorded
  placement), then one recording run — or the pool adopts a recording
  already in the :class:`~repro.replay.cache.GraphCache` (e.g. shipped from
  a profiling run) with no dynamic run at all — and parks a started
  executor;
* **worker-count remapping** — when the cache holds the shape only at a
  different worker count, the pool re-keys it via
  :func:`~repro.replay.remap.remap_recording` instead of paying a fresh
  recording run;
* **adaptive re-recording** — after every replay the pool reads
  ``ReplayExecutor.stats``; when the drift rate ``(fallback_steals +
  skips) / n_entries`` stays above ``drift_threshold`` for
  ``drift_patience`` consecutive runs, the recording is declared stale.
  (Fallback steals and skips are *plan deviations* — work executed off its
  recorded slot.  Raw stall counts are deliberately excluded: a worker
  legitimately idles through many stall windows while a long task body it
  depends on runs to completion.)
  The next request then re-records: inline (that request runs dynamically
  with instrumentation on — it is served normally, its recording is the
  fresh one) or, when a side-effect-free graph *builder* was registered via
  :meth:`register_builder`, in a **background thread** that records the
  builder's twin graph while requests keep replaying the stale recording.
  Either way the new recording is hot-swapped into the ``GraphCache``
  (:meth:`GraphCache.swap`) and the entry's executor is rebuilt.

Thread safety: requests for *different* shapes run concurrently on their
own executors; requests for the same shape serialize on the entry lock (one
executor replays one graph at a time by construction).
"""

from __future__ import annotations

import dataclasses
import threading
from typing import Any, Callable, Dict, Optional, Tuple, Union

from ..core.taskgraph import TaskGraph
from .cache import GraphCache, cache_key
from .executor import ReplayExecutor
from .graph_key import GraphKey, graph_key
from .recording import Recording
from .remap import RemapError, nearest_worker_count, remap_recording


@dataclasses.dataclass
class PoolEntryStats:
    """Per-(shape, workers, policy) serving counters."""

    requests: int = 0
    replays: int = 0
    warmups: int = 0          # unrecorded dynamic runs before recording
    records: int = 0          # cold dynamic recording runs
    remaps: int = 0           # recordings adopted via worker-count remap
    rerecords: int = 0        # adaptive re-recording swaps
    drift: float = 0.0        # last observed drift rate
    drift_strikes: int = 0    # consecutive runs past the threshold

    def as_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


class _PoolEntry:
    """One persistent executor + its recording + drift bookkeeping."""

    __slots__ = ("executor", "recording", "n_entries", "lock", "stats",
                 "needs_rerecord", "rerecord_inflight", "last_error")

    def __init__(self) -> None:
        self.executor: Optional[ReplayExecutor] = None
        self.recording: Optional[Recording] = None
        self.n_entries = 1
        self.lock = threading.Lock()
        self.stats = PoolEntryStats()
        self.needs_rerecord = False
        self.rerecord_inflight = False
        self.last_error: Optional[BaseException] = None


class ReplayPool:
    """Persistent replay-serving pool (see module docstring).

    Parameters
    ----------
    cache:
        Backing :class:`GraphCache` (fresh in-memory one by default).  Give
        it a ``path`` to adopt recordings shipped from other processes and
        to persist re-recordings.
    drift_threshold / drift_patience:
        A replay whose ``(fallback steals + skips) / entries`` rate exceeds
        ``drift_threshold`` counts one strike; ``drift_patience`` strikes in
        a row trigger re-recording.
    allow_remap:
        On a cache miss for the exact worker count, remap the nearest
        recorded worker count instead of recording from scratch.
    warmup_runs:
        Dynamic *unrecorded* requests served before the recording run when
        no cached recording exists.  The first execution of a shape
        typically pays one-off costs (jit compilation, cold allocator) that
        would bake a skewed task placement into the recording; recording a
        warm run captures the steady-state schedule.  Adopted/remapped
        recordings skip warmup entirely.
    stall_timeout:
        Forwarded to each :class:`ReplayExecutor`.
    """

    def __init__(
        self,
        cache: Optional[GraphCache] = None,
        *,
        drift_threshold: float = 0.25,
        drift_patience: int = 3,
        allow_remap: bool = True,
        warmup_runs: int = 1,
        stall_timeout: float = 1e-3,
    ):
        self.cache = cache if cache is not None else GraphCache()
        self.drift_threshold = drift_threshold
        self.drift_patience = drift_patience
        self.allow_remap = allow_remap
        self.warmup_runs = warmup_runs
        self.stall_timeout = stall_timeout
        self.last_recording: Optional[Recording] = None

        self._entries: Dict[str, _PoolEntry] = {}
        self._builders: Dict[str, Callable[[], TaskGraph]] = {}
        self._lock = threading.Lock()
        self._closed = False

    # ------------------------------------------------------------------
    # lifecycle
    def shutdown(self) -> None:
        """Stop every executor.  Terminal: later :meth:`run` calls raise
        (a request racing shutdown either completes first — shutdown waits
        on its entry lock — or observes the closed flag before it can
        install an executor nobody could ever stop)."""
        with self._lock:
            self._closed = True
            entries = list(self._entries.values())
            self._entries.clear()
        for entry in entries:
            with entry.lock:
                if entry.executor is not None:
                    entry.executor.shutdown()
                    entry.executor = None

    def __enter__(self) -> "ReplayPool":
        return self

    def __exit__(self, *exc) -> None:
        self.shutdown()

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    # ------------------------------------------------------------------
    # introspection
    def describe(self) -> Dict[str, Dict[str, Any]]:
        """{cache key: stats dict} for every shape the pool has served."""
        with self._lock:
            entries = dict(self._entries)
        return {ckey: e.stats.as_dict() for ckey, e in entries.items()}

    def register_builder(
        self,
        key: Union[TaskGraph, GraphKey, str],
        builder: Callable[[], TaskGraph],
    ) -> None:
        """Register a zero-arg factory producing a fresh, *side-effect-free*
        graph of this shape (e.g. a decode step over scratch state).  With a
        builder registered, adaptive re-recording runs in a background
        thread on the builder's twin graph instead of making a request pay
        the dynamic run."""
        digest = self._digest_of(key)
        with self._lock:
            self._builders[digest] = builder

    @staticmethod
    def _digest_of(key: Union[TaskGraph, GraphKey, str]) -> str:
        if isinstance(key, TaskGraph):
            return graph_key(key).digest
        return key.digest if isinstance(key, GraphKey) else str(key)

    # ------------------------------------------------------------------
    # serving
    def run(
        self,
        graph: TaskGraph,
        n_workers: int,
        *,
        policy: str = "hybrid",
        gang_default: bool = True,
        seed: int = 0,
        timeout: float = 300.0,
        key: Optional[GraphKey] = None,
    ) -> Dict[int, Any]:
        """Serve one execution of ``graph``; returns ``{tid: result}``.

        ``gang_default`` / ``seed`` configure the dynamic runtime used for
        warmup, recording, and re-recording runs (replays are driven purely
        by the recording).  They are not part of the entry key: one shape
        should be served under one scheduling configuration.

        ``key`` skips the per-request structural hash when the caller
        already knows it (e.g. a decode loop rebuilding one shape — see
        :func:`repro.models.decode_graph_key`); the executor still enforces
        the 1:1 task cover, so a wrong key fails loudly, not silently."""
        if key is None:
            key = graph_key(graph)
        ckey = cache_key(key, n_workers, policy)
        with self._lock:
            if self._closed:
                raise RuntimeError("ReplayPool is shut down")
            entry = self._entries.get(ckey)
            if entry is None:
                entry = self._entries[ckey] = _PoolEntry()
            builder = self._builders.get(key.digest)

        rt_kwargs = {"policy": policy, "gang_default": gang_default,
                     "seed": seed}
        with entry.lock:
            if self._closed:
                raise RuntimeError("ReplayPool is shut down")
            entry.stats.requests += 1
            if entry.executor is None:
                results = self._materialize(entry, key, graph, n_workers,
                                            rt_kwargs, timeout)
                self.last_recording = entry.recording
                return results
            if entry.needs_rerecord:
                if builder is None:
                    results = self._rerecord_inline(entry, graph, n_workers,
                                                    rt_kwargs, timeout)
                    self.last_recording = entry.recording
                    return results
                if not entry.rerecord_inflight:
                    entry.rerecord_inflight = True
                    threading.Thread(
                        target=self._rerecord_background,
                        args=(entry, builder, n_workers, rt_kwargs, timeout),
                        daemon=True,
                        name=f"replay-pool-rerecord-{ckey[:12]}",
                    ).start()
            results = entry.executor.run(graph, timeout=timeout)
            entry.stats.replays += 1
            self._observe_drift(entry)
            self.last_recording = entry.recording
            return results

    # ------------------------------------------------------------------
    # entry construction paths
    def _materialize(
        self,
        entry: _PoolEntry,
        key: GraphKey,
        graph: TaskGraph,
        n_workers: int,
        rt_kwargs: Dict[str, Any],
        timeout: float,
    ) -> Dict[int, Any]:
        """Cold path: adopt / remap / record, park the executor, serve."""
        policy = rt_kwargs["policy"]
        rec = self.cache.lookup(key, n_workers, policy)
        if rec is None and self.allow_remap:
            rec = self._remap_from_cache(entry, key, n_workers, policy)
        if rec is not None:
            self._install(entry, rec)
            results = entry.executor.run(graph, timeout=timeout)
            entry.stats.replays += 1
            self._observe_drift(entry)
            return results
        if entry.stats.warmups < self.warmup_runs:
            # serve cold requests dynamically without recording: the first
            # executions pay one-off costs (jit compiles) whose skew would
            # otherwise be baked into the recorded placement
            entry.stats.warmups += 1
            from ..core.runtime import Runtime

            rt = Runtime(n_workers, **rt_kwargs)
            with rt:
                return rt.run(graph, timeout=timeout)
        results, recording = self._record_dynamic(graph, n_workers, rt_kwargs,
                                                  timeout)
        entry.stats.records += 1
        self.cache.store(recording)
        self._install(entry, recording)
        return results

    def _remap_from_cache(
        self,
        entry: _PoolEntry,
        key: GraphKey,
        n_workers: int,
        policy: str,
    ) -> Optional[Recording]:
        donors = self.cache.candidates(key, policy)
        donors.pop(n_workers, None)          # exact hits were already tried
        while donors:
            src = nearest_worker_count(list(donors), n_workers)
            try:
                rec = remap_recording(donors.pop(src), n_workers)
            except RemapError:
                continue                     # e.g. a gang too wide — next donor
            self.cache.store(rec)
            entry.stats.remaps += 1
            return rec
        return None

    def _record_dynamic(
        self,
        graph: TaskGraph,
        n_workers: int,
        rt_kwargs: Dict[str, Any],
        timeout: float,
    ) -> Tuple[Dict[int, Any], Recording]:
        from ..core.runtime import Runtime

        rt = Runtime(n_workers, **rt_kwargs)
        with rt:
            results = rt.run(graph, timeout=timeout, record=True)
        return results, rt.last_recording

    def _install(self, entry: _PoolEntry, recording: Recording) -> None:
        """(Re)build the entry's persistent executor around ``recording``."""
        if entry.executor is not None:
            entry.executor.shutdown()
        entry.recording = recording
        entry.n_entries = max(
            1, sum(len(o) for o in recording.worker_orders))
        entry.executor = ReplayExecutor(
            recording, stall_timeout=self.stall_timeout, check_digest=False)
        entry.executor.start()
        entry.needs_rerecord = False
        entry.stats.drift_strikes = 0

    # ------------------------------------------------------------------
    # adaptive re-recording
    def _observe_drift(self, entry: _PoolEntry) -> None:
        stats = entry.executor.stats
        drift = (stats.get("fallback_steals", 0)
                 + stats.get("skips", 0)) / entry.n_entries
        entry.stats.drift = drift
        if drift > self.drift_threshold:
            entry.stats.drift_strikes += 1
        else:
            entry.stats.drift_strikes = 0
        if entry.stats.drift_strikes >= self.drift_patience:
            entry.needs_rerecord = True

    def _rerecord_inline(
        self,
        entry: _PoolEntry,
        graph: TaskGraph,
        n_workers: int,
        rt_kwargs: Dict[str, Any],
        timeout: float,
    ) -> Dict[int, Any]:
        """Serve this request dynamically with instrumentation on; its
        recording replaces the stale one (the request itself is the
        re-record — no double execution of side-effecting task bodies)."""
        results, recording = self._record_dynamic(graph, n_workers, rt_kwargs,
                                                  timeout)
        entry.stats.rerecords += 1
        self.cache.swap(recording)
        self._install(entry, recording)
        return results

    def _rerecord_background(
        self,
        entry: _PoolEntry,
        builder: Callable[[], TaskGraph],
        n_workers: int,
        rt_kwargs: Dict[str, Any],
        timeout: float,
    ) -> None:
        """Record the builder's twin graph off the request path, then
        hot-swap recording + executor under the entry lock."""
        try:
            twin = builder()
            _, recording = self._record_dynamic(twin, n_workers, rt_kwargs,
                                                timeout)
            with entry.lock:
                with self._lock:
                    live = any(e is entry for e in self._entries.values())
                if not live:
                    # the pool was shut down (or the entry evicted) while we
                    # recorded: installing would leak an unreachable
                    # executor's worker threads — drop the recording
                    return
                entry.stats.rerecords += 1
                self.cache.swap(recording)
                self._install(entry, recording)
        except BaseException as e:  # noqa: BLE001 - surfaced via last_error
            entry.last_error = e
            with entry.lock:
                entry.needs_rerecord = False   # do not spin on a broken twin
        finally:
            entry.rerecord_inflight = False
