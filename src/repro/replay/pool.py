"""Replay-serving pool: warm leased workers with adaptive re-recording.

A steady-state serving loop (``examples/serve_lm.py``: one decode-step graph
per request) re-executes the same graph *shape* indefinitely.  Running each
request through :func:`~repro.core.runtime.run_graph` pays per-request
runtime construction — thread spawn, queue allocation — on top of dynamic
scheduling.  :class:`ReplayPool` keeps one warm
:class:`~repro.exec.core.ExecutorCore` per **worker count** and, per
``(GraphKey digest, n_workers, policy)``, a prepared replay dispatch
(:class:`~repro.replay.executor.ReplayExecutor` leasing the shared core).
Total threads are capped by the set of distinct worker counts — not by the
number of shapes — and every path (warmup, recording, replay) runs on the
same warm substrate:

* **first requests** for a shape run dynamically *on the shared core*:
  ``warmup_runs`` requests unrecorded (so jit compiles / cold caches do not
  skew the recorded placement), then one recording run — or the pool adopts
  a recording already in the :class:`~repro.replay.cache.GraphCache` (e.g.
  shipped from a profiling run) with no dynamic run at all;
* **worker-count remapping** — when the cache holds the shape only at a
  different worker count, the pool re-keys it via
  :func:`~repro.replay.remap.remap_recording` instead of paying a fresh
  recording run;
* **adaptive re-recording** — after every replay the pool reads
  ``ReplayExecutor.stats``; when the drift rate ``(fallback_steals +
  skips) / n_entries`` stays above ``drift_threshold`` for
  ``drift_patience`` consecutive runs, the recording is declared stale.
  (Fallback steals and skips are *plan deviations* — work executed off its
  recorded slot.  Raw stall counts are deliberately excluded: a worker
  legitimately idles through many stall windows while a long task body it
  depends on runs to completion.)
  The next request then re-records: inline (that request runs dynamically
  with instrumentation on — it is served normally, its recording is the
  fresh one) or, when a side-effect-free graph *builder* was registered via
  :meth:`register_builder`, in a **background thread** that records the
  builder's twin graph on transient workers while requests keep replaying
  the stale recording.  Either way the new recording is hot-swapped into
  the ``GraphCache`` (:meth:`GraphCache.swap`) and the entry's executor is
  rebuilt;
* **latency-aware drift** — deviation-rate triggers miss recordings that
  are *consistently imbalanced* (zero steals, long stalls baked into the
  placement).  With ``latency_drift_factor`` set, the pool tracks an EWMA
  of per-run replay wall clock against an EWMA of the entry's dynamic runs
  (warmups, recordings, re-recordings); a replay EWMA above ``factor ×``
  the dynamic baseline for ``drift_patience`` consecutive runs also
  triggers re-recording — even at zero fallback steals;
* **warm → compiled promotion** — with ``compile_after`` set, an entry
  whose last ``compile_after`` replays were *deviation-free* (zero fallback
  steals / skips, no pending re-record) is promoted: the recording is
  lowered via :func:`~repro.compile.compile_recording` into a fused serial
  plan and later requests are served by a
  :class:`~repro.compile.CompiledExecutor` (mode ``compiled``) — no worker
  dispatch at all.  The lowering's shape is persisted next to the recording
  (:meth:`GraphCache.store_plan_meta`).  A compiled serve that fails
  (:class:`~repro.compile.CompiledRunError` — the plan no longer matches
  the graph's behavior) demotes the entry back to replay, where the drift
  machinery takes over; a re-record (:meth:`_install`) always drops the
  compiled plan, and clean replays must be re-earned;
* **multi-tenant cap** — ``max_shapes`` bounds the number of resident
  entries; inserting past the cap evicts the least-recently-used
  ``(GraphKey, workers, policy)`` entry, releasing its core lease (cheap:
  no threads die — the shared cores stay warm).  A request racing its own
  entry's eviction completes normally on a fresh lease.

Thread safety: requests for the same shape serialize on the entry lock;
requests for different shapes at the same worker count serialize on the
shared core (one run at a time per core); different worker counts run
concurrently on their own cores.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Tuple, Union

from ..core.policies import resolve as resolve_policy
from ..core.taskgraph import TaskGraph
from ..exec.core import ExecutorCore
from ..exec.registry import release_shared_core, shared_core
from .cache import GraphCache, cache_key
from .executor import ReplayExecutor
from .graph_key import GraphKey, graph_key
from .recording import Recording, RecordingError
from .remap import RemapError, nearest_worker_count, remap_recording


@dataclasses.dataclass
class PoolRun:
    """One served request, structured: results, the recording that is (or
    just became) live for the shape, how the request was served (``mode``:
    ``warmup`` / ``record`` / ``adopt`` / ``remap`` / ``rerecord`` /
    ``replay`` / ``compiled``) and a snapshot of the entry's serving
    counters.  For compiled serves ``stats["compiled_stats"]`` carries the
    driver's counters (``dispatch_overhead_fraction``, segments, fused
    tasks).  For
    replay serves ``stats["replay_stats"]`` carries the executor's raw
    deviation counters (``fallback_steals`` / ``stalls`` / ``skips`` /
    ``run_ahead``) so a slow row is explainable from the outcome alone.
    ``trace`` is the run's :class:`~repro.obs.trace.RuntimeTrace` when the
    pool was built with ``trace=True``.  The session API wraps this into a
    :class:`~repro.api.session.RunReport`; the legacy
    :meth:`ReplayPool.run` returns just ``results``."""

    results: Dict[int, Any]
    recording: Optional[Recording]
    mode: str
    stats: Dict[str, Any]
    trace: Optional[Any] = None              # repro.obs.trace.RuntimeTrace


@dataclasses.dataclass
class PoolEntryStats:
    """Per-(shape, workers, policy) serving counters."""

    requests: int = 0
    replays: int = 0
    warmups: int = 0          # unrecorded dynamic runs before recording
    records: int = 0          # cold dynamic recording runs
    remaps: int = 0           # recordings adopted via worker-count remap
    rerecords: int = 0        # adaptive re-recording swaps
    drift: float = 0.0        # last observed plan-deviation rate
    drift_strikes: int = 0    # consecutive runs past the threshold
    replay_ms: float = 0.0    # EWMA of replay wall clock
    dynamic_ms: float = 0.0   # EWMA of dynamic-run wall clock (baseline)
    latency_strikes: int = 0  # consecutive replays past the latency factor
    clean_replays: int = 0    # consecutive deviation-free replays
    compiles: int = 0         # warm -> compiled promotions
    compiled_serves: int = 0  # serves run on the compiled executor
    compile_failures: int = 0  # lowering/compiled-run failures (fell back)
    compiled_ms: float = 0.0  # EWMA of compiled-serve wall clock
    #: rolling (EWMA) flight-recorder metrics for this shape — populated
    #: only when the pool traces (steal_success_rate,
    #: dispatch_overhead_fraction, utilization, resume_latency_mean_s,
    #: replay_fallback_rate)
    trace_metrics: Dict[str, float] = dataclasses.field(default_factory=dict)

    def as_dict(self) -> Dict[str, Any]:
        # hand-rolled: this runs on EVERY serve (the outcome snapshot), and
        # dataclasses.asdict deep-copies recursively — including
        # trace_metrics — which showed up on the smoke-bench serve path
        return {
            "requests": self.requests,
            "replays": self.replays,
            "warmups": self.warmups,
            "records": self.records,
            "remaps": self.remaps,
            "rerecords": self.rerecords,
            "drift": self.drift,
            "drift_strikes": self.drift_strikes,
            "replay_ms": self.replay_ms,
            "dynamic_ms": self.dynamic_ms,
            "latency_strikes": self.latency_strikes,
            "clean_replays": self.clean_replays,
            "compiles": self.compiles,
            "compiled_serves": self.compiled_serves,
            "compile_failures": self.compile_failures,
            "compiled_ms": self.compiled_ms,
            "trace_metrics": dict(self.trace_metrics),
        }


class _PoolEntry:
    """One per-shape lease (executor + recording) + drift bookkeeping."""

    __slots__ = ("executor", "recording", "compiled", "n_entries", "lock",
                 "stats", "needs_rerecord", "rerecord_inflight", "last_error")

    def __init__(self) -> None:
        self.executor: Optional[ReplayExecutor] = None
        self.recording: Optional[Recording] = None
        self.compiled: Optional[Any] = None   # repro.compile.CompiledExecutor
        self.n_entries = 1
        self.lock = threading.Lock()
        self.stats = PoolEntryStats()
        self.needs_rerecord = False
        self.rerecord_inflight = False
        self.last_error: Optional[BaseException] = None


class ReplayPool:
    """Persistent replay-serving pool (see module docstring).

    Parameters
    ----------
    cache:
        Backing :class:`GraphCache` (fresh in-memory one by default).  Give
        it a ``path`` to adopt recordings shipped from other processes and
        to persist re-recordings.
    drift_threshold / drift_patience:
        A replay whose ``(fallback steals + skips) / entries`` rate exceeds
        ``drift_threshold`` counts one strike; ``drift_patience`` strikes in
        a row trigger re-recording.
    latency_drift_factor:
        When set, a replay wall-clock EWMA above ``factor ×`` the entry's
        dynamic-baseline EWMA counts a latency strike; ``drift_patience``
        strikes in a row trigger re-recording even at zero plan deviation.
        ``None`` (default) disables the latency trigger.
    latency_alpha:
        EWMA smoothing for the wall-clock trackers.
    allow_remap:
        On a cache miss for the exact worker count, remap the nearest
        recorded worker count instead of recording from scratch.
    warmup_runs:
        Dynamic *unrecorded* requests served before the recording run when
        no cached recording exists.  The first execution of a shape
        typically pays one-off costs (jit compilation, cold allocator) that
        would bake a skewed task placement into the recording; recording a
        warm run captures the steady-state schedule.  Adopted/remapped
        recordings skip warmup entirely.
    max_shapes:
        Cap on resident ``(GraphKey, workers, policy)`` entries; the
        least-recently-used entry past the cap is evicted and its core
        lease released.  ``None`` (default) keeps every shape.
    compile_after:
        Promote an entry to a fused compiled plan after this many
        *consecutive deviation-free* replays (see module docstring).
        ``None`` (default) disables promotion.
    stall_timeout:
        Forwarded to each :class:`ReplayExecutor`.
    trace:
        Run every serve (replay *and* the dynamic warmup/record paths) with
        the flight recorder on.  Each :class:`PoolRun` then carries the
        run's :class:`~repro.obs.trace.RuntimeTrace` and the entry keeps
        rolling per-shape trace metrics (``PoolEntryStats.trace_metrics``).
    shared_cores:
        Lease worker cores from the process-global
        :class:`~repro.exec.registry.CoreRegistry` (default): several pools
        in one process share one core per worker count, so total threads
        are capped across tenants.  ``False`` gives this pool private
        cores (the pre-registry behavior — full isolation).
    """

    def __init__(
        self,
        cache: Optional[GraphCache] = None,
        *,
        drift_threshold: float = 0.25,
        drift_patience: int = 3,
        latency_drift_factor: Optional[float] = None,
        latency_alpha: float = 0.3,
        allow_remap: bool = True,
        warmup_runs: int = 1,
        compile_after: Optional[int] = None,
        max_shapes: Optional[int] = None,
        stall_timeout: float = 1e-3,
        trace: bool = False,
        shared_cores: bool = True,
    ):
        if max_shapes is not None and max_shapes < 1:
            raise ValueError("max_shapes must be >= 1 (or None for no cap)")
        if compile_after is not None and compile_after < 1:
            raise ValueError(
                "compile_after must be >= 1 (or None to disable promotion)")
        self.cache = cache if cache is not None else GraphCache()
        self.drift_threshold = drift_threshold
        self.drift_patience = drift_patience
        self.latency_drift_factor = latency_drift_factor
        self.latency_alpha = latency_alpha
        self.allow_remap = allow_remap
        self.warmup_runs = warmup_runs
        self.compile_after = compile_after
        self.max_shapes = max_shapes
        self.stall_timeout = stall_timeout
        self.trace = trace
        self.shared_cores = shared_cores
        self.last_recording: Optional[Recording] = None
        self.evictions = 0

        self._entries: Dict[str, _PoolEntry] = {}   # insertion order = LRU
        self._cores: Dict[int, ExecutorCore] = {}   # one per worker count
        self._builders: Dict[str, Callable[[], TaskGraph]] = {}
        self._lock = threading.Lock()
        self._closed = False

    # ------------------------------------------------------------------
    # lifecycle
    def shutdown(self) -> None:
        """Release every lease and stop the shared cores.  Terminal: later
        :meth:`run` calls raise (a request racing shutdown either completes
        first — shutdown waits on its entry lock — or observes the closed
        flag before it can install an executor nobody could ever stop)."""
        with self._lock:
            self._closed = True
            entries = list(self._entries.values())
            self._entries.clear()
            cores = list(self._cores.values())
            self._cores.clear()
        for entry in entries:
            self._release_entry(entry)
        for core in cores:
            if self.shared_cores:
                release_shared_core(core)   # last lessee stops the threads
            else:
                core.shutdown()

    def _release_entry(self, entry: _PoolEntry) -> None:
        """Shut an evicted/closed entry's lease down cleanly: waits for any
        in-flight request (the entry lock) before dropping the executor."""
        with entry.lock:
            if entry.executor is not None:
                entry.executor.shutdown()
                entry.executor = None
            entry.compiled = None   # threadless — just drop the reference
            entry.needs_rerecord = False

    def __enter__(self) -> "ReplayPool":
        return self

    def __exit__(self, *exc) -> None:
        self.shutdown()

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    # ------------------------------------------------------------------
    # shared worker substrate
    def _core_for(self, n_workers: int) -> ExecutorCore:
        """The warm core for this worker count (leased lazily).  Every shape
        at this count — and its warmup/recording dynamic runs — shares these
        threads; with ``shared_cores`` (default) the lease comes from the
        process-global registry, so other pools share them too."""
        with self._lock:
            if self._closed:
                raise RuntimeError("ReplayPool is shut down")
            core = self._cores.get(n_workers)
            if core is None:
                if self.shared_cores:
                    core = shared_core(n_workers)
                else:
                    core = ExecutorCore(
                        n_workers, name=f"pool{n_workers}-worker")
                    core.start()
                self._cores[n_workers] = core
            return core

    # ------------------------------------------------------------------
    # introspection
    def describe(self) -> Dict[str, Dict[str, Any]]:
        """{cache key: stats dict} for every shape the pool has served."""
        with self._lock:
            entries = dict(self._entries)
        return {ckey: e.stats.as_dict() for ckey, e in entries.items()}

    def register_builder(
        self,
        key: Union[TaskGraph, GraphKey, str],
        builder: Callable[[], TaskGraph],
    ) -> None:
        """Register a zero-arg factory producing a fresh, *side-effect-free*
        graph of this shape (e.g. a decode step over scratch state).  With a
        builder registered, adaptive re-recording runs in a background
        thread on the builder's twin graph instead of making a request pay
        the dynamic run."""
        digest = self._digest_of(key)
        with self._lock:
            self._builders[digest] = builder

    @staticmethod
    def _digest_of(key: Union[TaskGraph, GraphKey, str]) -> str:
        if isinstance(key, TaskGraph):
            return graph_key(key).digest
        return key.digest if isinstance(key, GraphKey) else str(key)

    # ------------------------------------------------------------------
    # serving
    def serve(
        self,
        graph: TaskGraph,
        n_workers: int,
        *,
        policy: str = "hybrid",
        gang_default: bool = True,
        seed: int = 0,
        timeout: float = 300.0,
        key: Optional[GraphKey] = None,
    ) -> PoolRun:
        """Serve one execution of ``graph``; returns a :class:`PoolRun`
        (results + recording + how the request was served) — no state is
        smuggled through pool attributes.

        ``gang_default`` / ``seed`` configure the dynamic dispatch used for
        warmup, recording, and re-recording runs (replays are driven purely
        by the recording).  They are not part of the entry key: one shape
        should be served under one scheduling configuration.

        ``key`` skips the per-request structural hash when the caller
        already knows it (e.g. a decode loop rebuilding one shape — see
        :func:`repro.models.decode_graph_key`); the executor still enforces
        the 1:1 task cover, so a wrong key fails loudly, not silently."""
        resolve_policy(policy)
        if key is None:
            key = graph_key(graph)
        ckey = cache_key(key, n_workers, policy)
        evicted: List[_PoolEntry] = []
        with self._lock:
            if self._closed:
                raise RuntimeError("ReplayPool is shut down")
            entry = self._entries.pop(ckey, None)
            if entry is None:
                entry = _PoolEntry()
            self._entries[ckey] = entry          # (re)insert: most recent
            if self.max_shapes is not None:
                while len(self._entries) > self.max_shapes:
                    oldest = next(iter(self._entries))
                    evicted.append(self._entries.pop(oldest))
                    self.evictions += 1
            builder = self._builders.get(key.digest)
        for old in evicted:
            self._release_entry(old)

        rt_kwargs = {"policy": policy, "gang_default": gang_default,
                     "seed": seed}
        with entry.lock:
            if self._closed:
                raise RuntimeError("ReplayPool is shut down")
            entry.stats.requests += 1
            if entry.executor is None:
                results, mode, trace, replayed = self._materialize(
                    entry, key, graph, n_workers, rt_kwargs, timeout)
                return self._outcome(entry, results, mode, trace,
                                     replayed=replayed)
            if entry.needs_rerecord:
                if builder is None:
                    results, trace = self._rerecord_inline(
                        entry, graph, n_workers, rt_kwargs, timeout)
                    return self._outcome(entry, results, "rerecord", trace)
                if not entry.rerecord_inflight:
                    entry.rerecord_inflight = True
                    threading.Thread(
                        target=self._rerecord_background,
                        args=(entry, builder, n_workers, rt_kwargs, timeout),
                        daemon=True,
                        name=f"replay-pool-rerecord-{ckey[:12]}",
                    ).start()
            if entry.compiled is not None and not entry.needs_rerecord:
                from ..compile import CompiledRunError

                try:
                    results = self._serve_compiled(entry, graph, timeout)
                    return self._outcome(entry, results, "compiled")
                except CompiledRunError as e:
                    # the plan no longer matches the graph's behavior —
                    # demote to replay and let the drift machinery decide
                    # whether the recording itself has gone stale
                    entry.compiled = None
                    entry.stats.compile_failures += 1
                    entry.stats.clean_replays = 0
                    entry.last_error = e
            results = self._replay(entry, graph, timeout)
            return self._outcome(entry, results, "replay", replayed=True)

    @staticmethod
    def _outcome(entry: _PoolEntry, results: Dict[int, Any], mode: str,
                 trace: Optional[Any] = None, *,
                 replayed: bool = False) -> PoolRun:
        stats = entry.stats.as_dict()
        if mode == "compiled" and entry.compiled is not None:
            stats["compiled_stats"] = dict(entry.compiled.stats)
        if replayed and entry.executor is not None:
            # raw deviation counters of THIS replay — a speedup<1 row is
            # explainable from the outcome alone (fallback steals, stalls,
            # skips), without cross-referencing pool.describe()
            stats["replay_stats"] = dict(entry.executor.stats)
            if trace is None:
                trace = entry.executor.last_trace
        return PoolRun(results=results, recording=entry.recording,
                       mode=mode, stats=stats, trace=trace)

    def run(
        self,
        graph: TaskGraph,
        n_workers: int,
        *,
        policy: str = "hybrid",
        gang_default: bool = True,
        seed: int = 0,
        timeout: float = 300.0,
        key: Optional[GraphKey] = None,
    ) -> Dict[int, Any]:
        """Legacy entry point: serve and return the bare ``{tid: result}``
        dict.  ``self.last_recording`` is refreshed for old callers; new
        code should use :meth:`serve` (or a ``Session(scheduler="pool")``)
        and read the recording off the returned :class:`PoolRun`."""
        out = self.serve(graph, n_workers, policy=policy,
                         gang_default=gang_default, seed=seed,
                         timeout=timeout, key=key)
        self.last_recording = out.recording
        return out.results

    def _replay(self, entry: _PoolEntry, graph: TaskGraph,
                timeout: float) -> Dict[int, Any]:
        t0 = time.perf_counter()
        results = entry.executor.run(graph, timeout=timeout)
        elapsed = time.perf_counter() - t0
        entry.stats.replays += 1
        self._observe_drift(entry, elapsed)
        self._note_trace(entry, entry.executor.last_trace)
        if (self.compile_after is not None and entry.compiled is None
                and not entry.needs_rerecord
                and entry.stats.clean_replays >= self.compile_after):
            self._promote(entry, graph)
        return results

    def _serve_compiled(self, entry: _PoolEntry, graph: TaskGraph,
                        timeout: float) -> Dict[int, Any]:
        """One serve on the entry's compiled plan: single-threaded fused
        dispatch — no worker hand-off, no queues.  ``timeout`` is unused
        (the driver is synchronous); kept for signature symmetry."""
        t0 = time.perf_counter()
        results = entry.compiled.run(graph, check_digest=False)
        elapsed = time.perf_counter() - t0
        st = entry.stats
        st.compiled_serves += 1
        st.compiled_ms = self._ewma(st.compiled_ms, elapsed * 1e3)
        return results

    def _promote(self, entry: _PoolEntry, graph: TaskGraph) -> None:
        """Lower the entry's (stable) recording into a fused compiled plan
        and persist the lowering's shape next to the recording.  A failed
        lowering resets the clean-replay streak — the entry keeps replaying
        and must re-earn promotion before the pool tries again."""
        from ..compile import CompiledExecutor, CompileError, compile_recording

        rec = entry.recording
        try:
            plan = compile_recording(graph, rec)
            entry.compiled = CompiledExecutor(graph, plan)
        except CompileError as e:
            entry.stats.compile_failures += 1
            entry.stats.clean_replays = 0
            entry.last_error = e
            return
        entry.stats.compiles += 1
        self.cache.store_plan_meta(rec.digest, rec.n_workers, rec.policy,
                                   plan.meta.to_dict())

    # ------------------------------------------------------------------
    # entry construction paths
    def _materialize(
        self,
        entry: _PoolEntry,
        key: GraphKey,
        graph: TaskGraph,
        n_workers: int,
        rt_kwargs: Dict[str, Any],
        timeout: float,
    ) -> Tuple[Dict[int, Any], str, Optional[Any], bool]:
        """Cold path: adopt / remap / record, install the lease, serve.
        Returns ``(results, mode, trace, replayed)`` — ``replayed`` says the
        serve itself was driven by the installed executor (adopt/remap),
        not a dynamic run."""
        policy = rt_kwargs["policy"]
        mode = "adopt"
        rec = self.cache.lookup(key, n_workers, policy)
        if rec is None and self.allow_remap:
            rec = self._remap_from_cache(entry, key, n_workers, policy)
            mode = "remap"
        if rec is not None:
            self._install(entry, rec)
            if (self.latency_drift_factor is not None
                    and entry.stats.dynamic_ms == 0.0):
                # adopted/remapped recordings arrive with no dynamic runs:
                # without a baseline the latency trigger could never fire —
                # precisely for the shipped recordings most likely to be
                # imbalanced.  One dynamic probe seeds the EWMA.
                entry.stats.warmups += 1
                results, _, elapsed, trace = self._run_dynamic(
                    graph, n_workers, rt_kwargs, timeout, record=False)
                self._note_dynamic(entry, elapsed)
                self._note_trace(entry, trace)
                return results, mode, trace, False
            return self._replay(entry, graph, timeout), mode, None, True
        if entry.stats.warmups < self.warmup_runs:
            # serve cold requests dynamically without recording: the first
            # executions pay one-off costs (jit compiles) whose skew would
            # otherwise be baked into the recorded placement
            entry.stats.warmups += 1
            results, _, elapsed, trace = self._run_dynamic(
                graph, n_workers, rt_kwargs, timeout, record=False)
            self._note_dynamic(entry, elapsed)
            self._note_trace(entry, trace)
            return results, "warmup", trace, False
        results, recording, elapsed, trace = self._run_dynamic(
            graph, n_workers, rt_kwargs, timeout, record=True)
        entry.stats.records += 1
        self._note_dynamic(entry, elapsed)
        self._note_trace(entry, trace)
        self.cache.store(recording)
        self._install(entry, recording)
        return results, "record", trace, False

    def _remap_from_cache(
        self,
        entry: _PoolEntry,
        key: GraphKey,
        n_workers: int,
        policy: str,
    ) -> Optional[Recording]:
        donors = self.cache.candidates(key, policy)
        donors.pop(n_workers, None)          # exact hits were already tried
        while donors:
            src = nearest_worker_count(list(donors), n_workers)
            try:
                rec = remap_recording(donors.pop(src), n_workers)
            except RemapError:
                continue                     # e.g. a gang too wide — next donor
            self.cache.store(rec)
            entry.stats.remaps += 1
            return rec
        return None

    def _run_dynamic(
        self,
        graph: TaskGraph,
        n_workers: int,
        rt_kwargs: Dict[str, Any],
        timeout: float,
        *,
        record: bool,
        transient: bool = False,
    ) -> Tuple[Dict[int, Any], Optional[Recording], float, Optional[Any]]:
        """One dynamic run on the shared warm core (or on transient private
        threads when ``transient`` — the background re-record path, which
        must not occupy the serving core)."""
        from ..core.runtime import Runtime

        core = None if transient else self._core_for(n_workers)
        rt = Runtime(n_workers, core=core, trace=self.trace, **rt_kwargs)
        with rt:
            t0 = time.perf_counter()
            results = rt.run(graph, timeout=timeout, record=record)
            elapsed = time.perf_counter() - t0
        return results, rt.last_recording, elapsed, rt.last_trace

    def _install(self, entry: _PoolEntry, recording: Recording) -> None:
        """(Re)build the entry's executor lease around ``recording``."""
        if entry.executor is not None:
            entry.executor.shutdown()
        entry.recording = recording
        entry.n_entries = max(
            1, sum(len(o) for o in recording.worker_orders))
        entry.executor = ReplayExecutor(
            recording, stall_timeout=self.stall_timeout, check_digest=False,
            trace=self.trace, core=self._core_for(recording.n_workers))
        entry.executor.start()
        entry.needs_rerecord = False
        entry.stats.drift_strikes = 0
        entry.stats.latency_strikes = 0
        # a new recording stales any lowering (the cache drops the plan
        # meta on swap for the same reason); promotion must be re-earned
        entry.compiled = None
        entry.stats.clean_replays = 0

    # ------------------------------------------------------------------
    # adaptive re-recording (plan deviation + latency regression)
    def _ewma(self, old: float, sample_ms: float) -> float:
        if old <= 0.0:
            return sample_ms
        return old + self.latency_alpha * (sample_ms - old)

    def _note_dynamic(self, entry: _PoolEntry, elapsed_s: float) -> None:
        entry.stats.dynamic_ms = self._ewma(entry.stats.dynamic_ms,
                                            elapsed_s * 1e3)

    #: flight-recorder metrics rolled per shape (ROADMAP item 4: the data
    #: the victim-policy layer consumes)
    _TRACE_KEYS = ("steal_success_rate", "dispatch_overhead_fraction",
                   "utilization", "replay_fallback_rate")

    def _note_trace(self, entry: _PoolEntry, trace: Optional[Any]) -> None:
        """Roll a traced run's metrics into the entry's EWMA trackers."""
        if trace is None:
            return
        metrics = trace.metrics()
        tm = entry.stats.trace_metrics
        for key in self._TRACE_KEYS:
            tm[key] = self._ewma(tm.get(key, 0.0), float(metrics[key]))
        resume_mean = float(metrics["resume_latency"]["mean_s"])
        tm["resume_latency_mean_s"] = self._ewma(
            tm.get("resume_latency_mean_s", 0.0), resume_mean)

    def _observe_drift(self, entry: _PoolEntry, elapsed_s: float) -> None:
        stats = entry.executor.stats
        st = entry.stats
        drift = (stats.get("fallback_steals", 0)
                 + stats.get("skips", 0)) / entry.n_entries
        st.drift = drift
        if drift > self.drift_threshold:
            st.drift_strikes += 1
        else:
            st.drift_strikes = 0
        # latency-aware drift: a consistently imbalanced recording can
        # replay deviation-free yet much slower than dynamic scheduling
        st.replay_ms = self._ewma(st.replay_ms, elapsed_s * 1e3)
        if (self.latency_drift_factor is not None and st.dynamic_ms > 0.0
                and st.replay_ms > st.dynamic_ms * self.latency_drift_factor):
            st.latency_strikes += 1
        else:
            st.latency_strikes = 0
        if (st.drift_strikes >= self.drift_patience
                or st.latency_strikes >= self.drift_patience):
            entry.needs_rerecord = True
        # a replay that earned no strike of either kind is "clean" — the
        # streak that earns warm -> compiled promotion (compile_after)
        if (st.drift_strikes == 0 and st.latency_strikes == 0
                and not entry.needs_rerecord):
            st.clean_replays += 1
        else:
            st.clean_replays = 0

    def _rerecord_inline(
        self,
        entry: _PoolEntry,
        graph: TaskGraph,
        n_workers: int,
        rt_kwargs: Dict[str, Any],
        timeout: float,
    ) -> Tuple[Dict[int, Any], Optional[Any]]:
        """Serve this request dynamically with instrumentation on; its
        recording replaces the stale one (the request itself is the
        re-record — no double execution of side-effecting task bodies)."""
        rec = entry.recording
        if rec is not None and len(graph) != rec.n_tasks():
            # the replay path would catch a wrong-shaped graph at the 1:1
            # cover check; a drift-triggered re-record must not silently
            # adopt it instead (the precomputed-key safety contract)
            raise RecordingError(
                f"graph has {len(graph)} tasks but the entry's recording "
                f"covers {rec.n_tasks()}: wrong graph for this pool key")
        results, recording, elapsed, trace = self._run_dynamic(
            graph, n_workers, rt_kwargs, timeout, record=True)
        entry.stats.rerecords += 1
        self._note_dynamic(entry, elapsed)
        self._note_trace(entry, trace)
        self.cache.swap(recording)
        self._install(entry, recording)
        return results, trace

    def _rerecord_background(
        self,
        entry: _PoolEntry,
        builder: Callable[[], TaskGraph],
        n_workers: int,
        rt_kwargs: Dict[str, Any],
        timeout: float,
    ) -> None:
        """Record the builder's twin graph off the request path — on
        transient threads, so the serving core stays free for replays —
        then hot-swap recording + executor under the entry lock."""
        try:
            twin = builder()
            _, recording, elapsed, trace = self._run_dynamic(
                twin, n_workers, rt_kwargs, timeout, record=True,
                transient=True)
            with entry.lock:
                with self._lock:
                    live = any(e is entry for e in self._entries.values())
                if not live:
                    # the pool was shut down (or the entry evicted) while we
                    # recorded: installing would resurrect a lease nobody
                    # can reach — drop the recording
                    return
                entry.stats.rerecords += 1
                self._note_dynamic(entry, elapsed)
                self._note_trace(entry, trace)
                self.cache.swap(recording)
                self._install(entry, recording)
        except BaseException as e:  # noqa: BLE001 - surfaced via last_error
            entry.last_error = e
            with entry.lock:
                entry.needs_rerecord = False   # do not spin on a broken twin
        finally:
            entry.rerecord_inflight = False
