"""Recording cache keyed on graph structure.

A :class:`GraphCache` maps ``(GraphKey digest, n_workers, policy)`` to a
:class:`~repro.replay.recording.Recording`.  The key is purely structural
(see :mod:`~repro.replay.graph_key`), so each iteration of a sweep that
rebuilds the same-shaped graph over fresh data hits the cache after the
first (recording) iteration.

With ``path`` set, recordings persist as one JSON file per cache key under
that directory and survive the process — a second sweep skips the recording
iteration entirely.
"""

from __future__ import annotations

import json
import os
import threading
from typing import Dict, Optional, Union

from ..core.taskgraph import TaskGraph
from .graph_key import GraphKey, graph_key
from .recording import Recording


def cache_key(key: Union[GraphKey, str], n_workers: int, policy: str) -> str:
    digest = key.digest if isinstance(key, GraphKey) else str(key)
    return f"{digest[:32]}_w{n_workers}_{policy}"


class GraphCache:
    """In-memory (and optionally on-disk) recording store."""

    def __init__(self, path: Optional[Union[str, os.PathLike]] = None):
        self.path = os.fspath(path) if path is not None else None
        self._mem: Dict[str, Recording] = {}
        self._lock = threading.Lock()
        if self.path is not None:
            os.makedirs(self.path, exist_ok=True)

    # ------------------------------------------------------------------
    def _file_for(self, ckey: str) -> Optional[str]:
        if self.path is None:
            return None
        return os.path.join(self.path, f"{ckey}.json")

    def lookup(
        self,
        graph_or_key: Union[TaskGraph, GraphKey, str],
        n_workers: int,
        policy: str = "hybrid",
    ) -> Optional[Recording]:
        """Return the cached recording for this shape/config, or None."""
        key = (graph_key(graph_or_key) if isinstance(graph_or_key, TaskGraph)
               else graph_or_key)
        ckey = cache_key(key, n_workers, policy)
        with self._lock:
            rec = self._mem.get(ckey)
        if rec is not None:
            return rec
        f = self._file_for(ckey)
        if f is not None and os.path.exists(f):
            with open(f) as fh:
                rec = Recording.from_dict(json.load(fh))
            with self._lock:
                self._mem[ckey] = rec
            return rec
        return None

    def store(self, recording: Recording) -> str:
        """Cache ``recording`` (and persist it when on-disk).  Returns the
        cache key."""
        ckey = cache_key(recording.digest, recording.n_workers, recording.policy)
        with self._lock:
            self._mem[ckey] = recording
        f = self._file_for(ckey)
        if f is not None:
            tmp = f + ".tmp"
            with open(tmp, "w") as fh:
                json.dump(recording.to_dict(), fh)
            os.replace(tmp, f)
        return ckey

    def __len__(self) -> int:
        with self._lock:
            return len(self._mem)

    def clear(self) -> None:
        with self._lock:
            self._mem.clear()
