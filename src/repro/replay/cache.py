"""Recording cache keyed on graph structure.

A :class:`GraphCache` maps ``(GraphKey digest, n_workers, policy)`` to a
:class:`~repro.replay.recording.Recording`.  The key is purely structural
(see :mod:`~repro.replay.graph_key`), so each iteration of a sweep that
rebuilds the same-shaped graph over fresh data hits the cache after the
first (recording) iteration.

With ``path`` set, recordings persist as one JSON file per cache key under
that directory and survive the process — a second sweep skips the recording
iteration entirely.  A truncated or corrupt cache file is *ignored* (and
quarantined as ``<file>.corrupt``), never fatal: the caller simply misses
and re-records, overwriting the bad entry.

:meth:`GraphCache.swap` atomically replaces an entry (returning the old
recording) — the hot-swap primitive the replay pool uses for adaptive
re-recording — and :meth:`GraphCache.candidates` enumerates every worker
count a digest has been recorded at, which is what worker-count remapping
(:mod:`~repro.replay.remap`) feeds on.

Compiled-plan metadata (:class:`~repro.compile.CompiledPlanMeta` dicts)
rides alongside recordings under the same cache key as ``<ckey>.plan.json``
(:meth:`store_plan_meta` / :meth:`lookup_plan_meta`): the lowering's shape
— segment counts, fusion coverage, boundary reasons — survives the process
while the executable itself stays memory-only.  Swapping or invalidating a
recording drops its plan metadata too (a new recording means a stale
lowering).

Cross-process safety: the cache directory is the :mod:`repro.mp` shipment
channel, so several *processes* write it concurrently.  Every disk write
goes to a per-writer unique temp file (pid + counter — two writers can
never interleave bytes in one temp path) followed by an atomic
``os.replace``, under an advisory ``fcntl`` lock on ``<file>.lock`` that
serializes writer pairs (and the unlink paths).  Readers never lock:
rename atomicity guarantees they see a complete old or complete new file,
and anything torn by a crashed writer is quarantined as usual.  Note the
*in-memory* layer is per-instance: a long-lived ``GraphCache`` does not
see another process's swap/invalidate until the key misses in memory —
cross-process consumers (pool worker children) open their own instance
per adoption, which reads through to disk.
"""

from __future__ import annotations

import contextlib
import itertools
import json
import os
import re
import threading
from typing import Dict, Iterator, List, Optional, Union

try:                                     # POSIX advisory locks; the cache
    import fcntl                         # degrades to rename-only atomicity
except ImportError:                      # on platforms without fcntl
    fcntl = None                         # type: ignore[assignment]

from ..core.taskgraph import TaskGraph
from .graph_key import GraphKey, graph_key
from .recording import Recording


def cache_key(key: Union[GraphKey, str], n_workers: int, policy: str) -> str:
    digest = key.digest if isinstance(key, GraphKey) else str(key)
    return f"{digest[:32]}_w{n_workers}_{policy}"


_CKEY_RE = re.compile(r"^(?P<digest>[0-9a-f]{32})_w(?P<workers>\d+)_(?P<policy>.+)$")

#: per-process unique temp-file suffixes: concurrent writers (threads in
#: one process, or several processes via the pid component) never share a
#: temp path, so a torn interleaved write is structurally impossible
_TMP_COUNTER = itertools.count()


@contextlib.contextmanager
def _file_lock(target: str) -> Iterator[None]:
    """Advisory exclusive lock on ``target + ".lock"`` (no-op without
    fcntl).  The lock file deliberately does not end in ``.json`` so the
    :meth:`GraphCache.candidates` directory scan never sees it."""
    if fcntl is None:
        yield
        return
    fd = os.open(target + ".lock", os.O_CREAT | os.O_RDWR, 0o644)
    try:
        fcntl.flock(fd, fcntl.LOCK_EX)
        try:
            yield
        finally:
            fcntl.flock(fd, fcntl.LOCK_UN)
    finally:
        os.close(fd)


def _atomic_write_json(target: str, payload: dict) -> None:
    """Write ``payload`` to ``target`` so that no reader — same process or
    another — can ever observe torn JSON: unique temp file, fsync-free
    atomic rename, advisory lock across the pair."""
    tmp = f"{target}.{os.getpid()}.{next(_TMP_COUNTER)}.tmp"
    with _file_lock(target):
        try:
            with open(tmp, "w") as fh:
                json.dump(payload, fh)
            os.replace(tmp, target)
        finally:
            if os.path.exists(tmp):      # failed mid-write: never leak tmps
                try:
                    os.remove(tmp)
                except OSError:
                    pass


class GraphCache:
    """In-memory (and optionally on-disk) recording store."""

    def __init__(self, path: Optional[Union[str, os.PathLike]] = None):
        self.path = os.fspath(path) if path is not None else None
        self._mem: Dict[str, Recording] = {}
        self._plan_meta: Dict[str, dict] = {}
        self._lock = threading.Lock()
        if self.path is not None:
            os.makedirs(self.path, exist_ok=True)

    # ------------------------------------------------------------------
    def _file_for(self, ckey: str) -> Optional[str]:
        if self.path is None:
            return None
        return os.path.join(self.path, f"{ckey}.json")

    def _load_file(self, f: str) -> Optional[Recording]:
        """Parse one on-disk recording; quarantine and miss on corruption."""
        try:
            with open(f) as fh:
                return Recording.from_dict(json.load(fh))
        except FileNotFoundError:
            return None
        except (OSError, ValueError, KeyError, TypeError):
            # truncated write, corrupt JSON, or a schema from another era:
            # move it aside (best effort) so we stop re-parsing it, and let
            # the caller re-record over the key
            try:
                os.replace(f, f + ".corrupt")
            except OSError:
                pass
            return None

    def lookup(
        self,
        graph_or_key: Union[TaskGraph, GraphKey, str],
        n_workers: int,
        policy: str = "hybrid",
    ) -> Optional[Recording]:
        """Return the cached recording for this shape/config, or None."""
        key = (graph_key(graph_or_key) if isinstance(graph_or_key, TaskGraph)
               else graph_or_key)
        ckey = cache_key(key, n_workers, policy)
        with self._lock:
            rec = self._mem.get(ckey)
        if rec is not None:
            return rec
        f = self._file_for(ckey)
        if f is not None and os.path.exists(f):
            rec = self._load_file(f)
            if rec is not None:
                with self._lock:
                    self._mem[ckey] = rec
            return rec
        return None

    def _write(self, ckey: str, recording: Recording) -> None:
        f = self._file_for(ckey)
        if f is not None:
            _atomic_write_json(f, recording.to_dict())

    def store(self, recording: Recording) -> str:
        """Cache ``recording`` (and persist it when on-disk).  Returns the
        cache key."""
        ckey = cache_key(recording.digest, recording.n_workers, recording.policy)
        with self._lock:
            self._mem[ckey] = recording
        self._write(ckey, recording)
        return ckey

    # ------------------------------------------------------------------
    # compiled-plan metadata (rides the recording's cache key)
    def _plan_file_for(self, ckey: str) -> Optional[str]:
        if self.path is None:
            return None
        return os.path.join(self.path, f"{ckey}.plan.json")

    def store_plan_meta(self, key: Union[GraphKey, str], n_workers: int,
                        policy: str, meta: dict) -> str:
        """Persist a compiled plan's descriptive metadata next to the
        recording it was lowered from.  Returns the cache key."""
        ckey = cache_key(key, n_workers, policy)
        with self._lock:
            self._plan_meta[ckey] = dict(meta)
        f = self._plan_file_for(ckey)
        if f is not None:
            _atomic_write_json(f, meta)
        return ckey

    def lookup_plan_meta(self, key: Union[GraphKey, str], n_workers: int,
                         policy: str = "hybrid") -> Optional[dict]:
        """The stored compiled-plan metadata for this shape/config, or
        None (corrupt files miss, like recordings)."""
        ckey = cache_key(key, n_workers, policy)
        with self._lock:
            meta = self._plan_meta.get(ckey)
        if meta is not None:
            return dict(meta)
        f = self._plan_file_for(ckey)
        if f is not None and os.path.exists(f):
            try:
                with open(f) as fh:
                    meta = json.load(fh)
            except (OSError, ValueError):
                return None
            with self._lock:
                self._plan_meta[ckey] = dict(meta)
            return meta
        return None

    def _drop_plan_meta(self, ckey: str) -> None:
        with self._lock:
            self._plan_meta.pop(ckey, None)
        f = self._plan_file_for(ckey)
        if f is not None and os.path.exists(f):
            try:
                with _file_lock(f):
                    os.remove(f)
            except OSError:
                pass

    def swap(self, recording: Recording) -> Optional[Recording]:
        """Hot-swap ``recording`` over whatever the cache held for its key
        and return the replaced recording (None when the slot was empty).
        The in-memory exchange is atomic — concurrent swappers see each
        other's recordings as ``old``, never the same one twice.  On-disk,
        last writer wins (each write is an atomic file replace)."""
        # populate _mem from disk first so a disk-only entry surfaces as old
        self.lookup(recording.digest, recording.n_workers, recording.policy)
        ckey = cache_key(recording.digest, recording.n_workers, recording.policy)
        with self._lock:
            old = self._mem.get(ckey)
            self._mem[ckey] = recording
        self._write(ckey, recording)
        self._drop_plan_meta(ckey)   # a new recording stales any lowering
        return old

    def invalidate(
        self,
        key: Union[GraphKey, str],
        n_workers: int,
        policy: str = "hybrid",
    ) -> bool:
        """Drop an entry from memory and disk.  Returns True if anything
        was removed."""
        ckey = cache_key(key, n_workers, policy)
        with self._lock:
            dropped = self._mem.pop(ckey, None) is not None
        f = self._file_for(ckey)
        if f is not None and os.path.exists(f):
            try:
                with _file_lock(f):
                    os.remove(f)
                dropped = True
            except OSError:
                pass
        self._drop_plan_meta(ckey)
        return dropped

    def candidates(
        self,
        key: Union[GraphKey, str],
        policy: str = "hybrid",
    ) -> Dict[int, Recording]:
        """All recordings of this digest+policy, keyed by worker count —
        the feedstock for worker-count remapping when the exact count
        misses."""
        digest = (key.digest if isinstance(key, GraphKey) else str(key))[:32]
        out: Dict[int, Recording] = {}
        if self.path is not None and os.path.isdir(self.path):
            for fname in os.listdir(self.path):
                if not fname.endswith(".json"):
                    continue
                m = _CKEY_RE.match(fname[:-len(".json")])
                if not m or m.group("digest") != digest or m.group("policy") != policy:
                    continue
                rec = self.lookup(digest, int(m.group("workers")), policy)
                if rec is not None:
                    out[rec.n_workers] = rec
        with self._lock:
            mem = list(self._mem.items())
        for ckey, rec in mem:
            m = _CKEY_RE.match(ckey)
            if m and m.group("digest") == digest and m.group("policy") == policy:
                out[rec.n_workers] = rec
        return out

    def keys(self) -> List[str]:
        with self._lock:
            return sorted(self._mem)

    def __len__(self) -> int:
        with self._lock:
            return len(self._mem)

    def clear(self) -> None:
        with self._lock:
            self._mem.clear()
            self._plan_meta.clear()
