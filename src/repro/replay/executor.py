"""Low-contention replay of a recorded task-graph execution.

Since the unified-executor refactor this module is a thin facade: the
scheduling logic (preallocated run lists, atomic claims and dep counters,
recorded gang placements with monotonic issue order, run-ahead,
stall-triggered dynamic fallback) lives in
:class:`~repro.exec.replay.ReplayDispatch`, and the worker substrate
(persistent threads, park/wake, deadlock detection) is the shared
:class:`~repro.exec.core.ExecutorCore` — the same substrate the dynamic
:class:`~repro.core.runtime.Runtime` runs on.

One executor owns (or leases) a worker pool sized to the recording; call
:meth:`ReplayExecutor.run` once per graph instance (same structure, e.g.
each iteration of a factorization sweep).  With ``core=`` the executor
leases warm workers from a shared core (the serving pool keeps one core
per worker count and any number of per-shape executors on top of it);
without, it owns a private core.
"""

from __future__ import annotations

from typing import Any, Dict, Optional

from ..exec.core import ExecutorCore
from ..exec.replay import ReplayDispatch, ReplayError
from ..core.taskgraph import TaskGraph
from .recording import Recording

__all__ = ["ReplayError", "ReplayExecutor", "replay_graph"]


class ReplayExecutor:
    """Re-execute task graphs from a :class:`Recording`.

    Use as a context manager or call :meth:`shutdown`.  ``shutdown`` on an
    executor leasing a shared ``core`` releases the lease but leaves the
    core's threads warm for other lessees.
    """

    def __init__(
        self,
        recording: Recording,
        *,
        stall_timeout: float = 1e-3,
        block_poll: float = 0.05,
        check_digest: bool = True,
        trace: bool = False,
        core: Optional[ExecutorCore] = None,
    ):
        if core is not None and core.n_workers != recording.n_workers:
            raise ValueError(
                f"shared core has {core.n_workers} workers but the recording "
                f"was made at {recording.n_workers}")
        self.recording = recording
        self.n_workers = recording.n_workers
        self.stall_timeout = stall_timeout
        self.block_poll = block_poll
        self.check_digest = check_digest
        self.trace_enabled = trace
        #: assembled :class:`~repro.obs.trace.RuntimeTrace` of the most
        #: recent traced replay (None with ``trace=False``)
        self.last_trace = None

        self._core = core if core is not None else ExecutorCore(
            recording.n_workers, block_poll=block_poll, name="replay-worker")
        self._owns_core = core is None
        self._dispatch = ReplayDispatch(recording, stall_timeout=stall_timeout,
                                        trace=trace)

    # ------------------------------------------------------------------
    # lifecycle
    @property
    def core(self) -> ExecutorCore:
        return self._core

    def start(self) -> None:
        self._core.start()

    def shutdown(self) -> None:
        if self._owns_core:
            self._core.shutdown()

    def __enter__(self) -> "ReplayExecutor":
        self.start()
        return self

    def __exit__(self, *exc) -> None:
        self.shutdown()

    # ------------------------------------------------------------------
    # introspection (deviation stats drive the pool's adaptive re-recording)
    @property
    def stats(self) -> Dict[str, int]:
        return self._dispatch.stats

    @property
    def issued_gang_ids(self):
        return self._dispatch.issued_gang_ids

    # ------------------------------------------------------------------
    def run(self, graph: TaskGraph, timeout: float = 300.0) -> Dict[int, Any]:
        """Execute ``graph`` following the recording; returns {tid: result}."""
        self.recording.validate_against(graph, check_digest=self.check_digest)
        try:
            return self._core.run(self._dispatch, graph, timeout=timeout)
        finally:
            if self.trace_enabled:
                # assemble in the finally so stalled/failed replays still
                # leave their flight-recorder evidence behind
                self.last_trace = self._dispatch.take_trace()


def replay_graph(
    graph: TaskGraph,
    recording: Recording,
    *,
    timeout: float = 300.0,
    stall_timeout: float = 1e-3,
    check_digest: bool = True,
) -> Dict[int, Any]:
    """Convenience: replay ``graph`` from ``recording`` on a fresh executor."""
    ex = ReplayExecutor(recording, stall_timeout=stall_timeout,
                        check_digest=check_digest)
    with ex:
        return ex.run(graph, timeout=timeout)
