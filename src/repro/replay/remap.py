"""Worker-count remapping of recordings.

Recordings are JSON-serializable and the on-disk
:class:`~repro.replay.cache.GraphCache` persists them, so a recording made
on a profiling run can be shipped to serving replicas — which rarely run
the same worker count.  :func:`remap_recording` re-keys a recording from
``rec.n_workers`` to any ``new_workers`` so the replay executor can use it
directly:

* **fold / expand** — old worker ``w`` maps to new worker ``w %
  new_workers`` (round-robin).  Folded lists are merged by original list
  position (a stable proxy for recorded start time), so each old worker's
  entries keep their relative order — the executor's invariant that a run
  list is *some* dependency-consistent start order degrades gracefully:
  cross-list inversions introduced by the fold are served by the executor's
  run-ahead window and dynamic fallback, never deadlock.
* **frame adjacency** — a suspended frame's
  :class:`~repro.core.taskgraph.FrameResume` entries are routed to the list
  where the frame's *start* entry lands (its home list), and re-ordered
  start-first / segments-ascending, so one worker owns a frame's whole
  lifecycle after the remap.
* **expansion rebalancing** — expanding to *more* workers would leave the
  extra workers with empty run lists (fallback-only helpers that idle
  through stall windows before stealing).  Instead, each empty worker is
  seeded with the tail half of the currently longest run list's plain-task
  entries (gang entries stay pinned to their placement worker).  Relative
  order within the moved tail and within the donor's remainder is
  preserved, so both remain dependency-consistent start orders; per-task
  claims keep the split correct regardless of how costs shift.
* **gang co-placement** — a placement's workers are folded with the same
  rule, then repaired to stay *distinct* (blocking in-region barriers need
  every ULT on its own kernel thread): colliding threads are reassigned
  round-robin to the nearest free worker, and their run-list entries move
  with them.  A recording whose largest gang exceeds ``new_workers`` cannot
  be remapped (:class:`RemapError`) — replaying it would deadlock.

The remapped recording keeps the original digest (the *graph* is unchanged,
only the slot keying), so it drops into the same :class:`GraphCache` under
the new ``(digest, new_workers, policy)`` key.  Steal decisions are purely
diagnostic and stale after a remap; they are dropped.
"""

from __future__ import annotations

from typing import Dict, List, Tuple

from ..core.taskgraph import FrameResume
from .recording import Entry, GangPlacement, Recording, RecordingError


class RemapError(RecordingError):
    """The recording cannot be re-keyed to the requested worker count."""


def remap_recording(rec: Recording, new_workers: int) -> Recording:
    """Return a copy of ``rec`` re-keyed for ``new_workers`` workers."""
    old = rec.n_workers
    if new_workers < 1:
        raise RemapError(f"cannot remap to {new_workers} workers")
    if new_workers == old:
        return Recording.from_dict(rec.to_dict())
    for p in rec.gang_placements.values():
        if len(p.workers) > new_workers:
            raise RemapError(
                f"recording places a {len(p.workers)}-ULT gang (task "
                f"{p.spawn_tid}); {new_workers} workers cannot host its "
                "blocking barriers")

    # 1. gang placements: fold, then repair collisions so each blocking
    # region keeps distinct workers (reassign round-robin to the next free).
    placements: Dict[int, GangPlacement] = {}
    for tid, p in rec.gang_placements.items():
        used: set = set()
        workers: List[int] = []
        for w in p.workers:
            nw = w % new_workers
            while nw in used:
                nw = (nw + 1) % new_workers
            workers.append(nw)
            used.add(nw)
        placements[tid] = GangPlacement(p.spawn_tid, p.gang_id, workers)
    # every gang entry's target worker under the repaired placements
    gang_target: Dict[Tuple[int, int], int] = {
        (tid, i): w
        for tid, p in placements.items() for i, w in enumerate(p.workers)}

    # 2. run lists: route each entry to its new worker, then merge folded
    # lists stably by (original position, old worker) — original position is
    # the recorded start-order proxy, so intra-worker order is preserved and
    # cross-list interleaving approximates the recorded global order.
    # Frame-resume entries follow their frame's *home list* (wherever the
    # task's start entry lands): a frame recorded as stolen across workers
    # still keeps all of its segments adjacent to its start after the fold,
    # so the remapped owner both starts and resumes it.
    task_target: Dict[int, int] = {}
    for ow, order in enumerate(rec.worker_orders):
        for e in order:
            if isinstance(e, int):
                task_target[e] = ow % new_workers
    buckets: List[List[Tuple[int, int, Entry]]] = [[] for _ in range(new_workers)]
    for ow, order in enumerate(rec.worker_orders):
        for idx, e in enumerate(order):
            if isinstance(e, int):
                target = ow % new_workers
            elif isinstance(e, FrameResume):
                target = task_target.get(e.tid, ow % new_workers)
            else:
                target = gang_target.get((e[0], e[1]), ow % new_workers)
            buckets[target].append((idx, ow, e))
    orders = [[e for _, _, e in sorted(b, key=lambda t: (t[0], t[1]))]
              for b in buckets]
    for order in orders:
        _fix_frame_segment_order(order)
    if new_workers > old:
        _seed_expansion_workers(orders)

    return Recording(
        digest=rec.digest,
        graph_name=rec.graph_name,
        n_workers=new_workers,
        policy=rec.policy,
        worker_orders=orders,
        gang_placements=placements,
        gang_issue_order=list(rec.gang_issue_order),
        steals=[],
        collective_order=list(rec.collective_order),
        # wait_any winners are keyed by (tid, seg) and the resource-grant
        # order is a tid sequence — both slot-independent, so the recorded
        # deterministic choices survive the remap untouched
        wait_choices=dict(rec.wait_choices),
        resource_grants=list(rec.resource_grants),
        source=f"remap[{old}->{new_workers}]:{rec.source}",
    )


def _fix_frame_segment_order(order: List[Entry]) -> None:
    """Restore each task's frame entries to causal order in place: start
    entry first, then resume segments ascending.  A fold can interleave
    source lists such that a stolen frame's segment 2 (recorded on another
    worker, small list index) sorts before segment 1."""
    positions: Dict[int, List[int]] = {}
    for i, e in enumerate(order):
        if isinstance(e, FrameResume):
            positions.setdefault(e.tid, []).append(i)
        elif isinstance(e, int):
            positions.setdefault(e, []).append(i)
    for tid, pos in positions.items():
        if len(pos) < 2:
            continue
        entries = [order[i] for i in pos]
        entries.sort(key=lambda e: 0 if isinstance(e, int) else e.seg)
        for i, e in zip(pos, entries):
            order[i] = e


def _seed_expansion_workers(orders: List[List[Entry]]) -> None:
    """Seed each empty run list with the tail half of the longest list's
    plain-task entries (in place), pulling each moved task's frame-resume
    entries along so a frame's segments stay on its home list.  Gang
    entries never move — their worker is fixed by the (already repaired)
    placement; a donor with fewer than two movable entries leaves the
    target as a fallback-only helper."""
    for w, order in enumerate(orders):
        if order:
            continue
        donor = max(range(len(orders)),
                    key=lambda i: sum(1 for e in orders[i] if isinstance(e, int)))
        movable = [i for i, e in enumerate(orders[donor]) if isinstance(e, int)]
        if len(movable) < 2:
            continue
        tail = movable[len(movable) // 2:]
        moved_tids = {orders[donor][i] for i in tail}
        move_set = set(tail) | {
            i for i, e in enumerate(orders[donor])
            if isinstance(e, FrameResume) and e.tid in moved_tids}
        orders[w] = [orders[donor][i] for i in sorted(move_set)]
        orders[donor] = [e for i, e in enumerate(orders[donor])
                         if i not in move_set]
        _fix_frame_segment_order(orders[w])


def nearest_worker_count(available: List[int], wanted: int) -> int:
    """Pick the best source worker count to remap from: prefer the closest,
    break ties toward the larger recording (folding loses less order
    information than expanding gains)."""
    if not available:
        raise ValueError("no candidate recordings to remap from")
    return min(available, key=lambda w: (abs(w - wanted), -w))
