"""Record-and-replay subsystem: graph cache + low-contention replay executor.

The repo's flagship workloads — tiled Cholesky/LU/QR sweeps, training steps,
repeated serving requests — execute the same task-graph *shape* over and
over, yet the dynamic runtime re-makes every scheduling decision (indegree
bookkeeping, victim selection, gang-worker reservation) on every run.  This
package records a graph's execution once and replays it with preallocated,
contention-free structures (the Taskgraph/QuickSched record-and-replay
idea):

* :func:`graph_key` / :class:`GraphKey` — canonical structural hash of a
  :class:`~repro.core.taskgraph.TaskGraph` (topology, kinds, costs,
  priorities, parallel specs — **not** callables), so rebuilds of the same
  shape over fresh data share one identity;
* :class:`GraphCache` — recordings keyed on ``(GraphKey, n_workers,
  policy)`` with optional on-disk persistence;
* :class:`Recording` — per-worker execution order, steal decisions, gang
  placements and gang-id issue order, captured from an instrumented dynamic
  run (``Runtime.run(graph, record=True)``) or seeded from a frozen
  :class:`~repro.core.static_schedule.StaticSchedule`
  (:meth:`Recording.from_static_schedule`);
* :class:`ReplayExecutor` — re-executes the graph from the recording with
  preallocated per-worker run lists, per-task dependency counters under
  per-task locks, and recorded gang placements: no victim selection, no
  ``GET_WORKERS`` scan, near-zero fork-lock work.  A facade over the
  unified executor core (:mod:`repro.exec`) — pass ``core=`` to lease warm
  workers shared with other executors;
* :class:`ReplayPool` — persistent per-``(GraphKey, n_workers, policy)``
  leases over one shared worker core per worker count — leased from the
  process-global :class:`~repro.exec.registry.CoreRegistry` by default, so
  several pools in one process share threads — for steady-state serving
  loops: adaptive re-recording on sustained plan deviation or wall-clock
  regression (``latency_drift_factor``), LRU shape eviction
  (``max_shapes``), and worker-count remapping (:func:`remap_recording`)
  of recordings shipped at a different worker count.

The record/replay contract
--------------------------

A recording drives any graph whose :func:`graph_key` digest matches the one
it was recorded for (enforced by :meth:`Recording.validate_against`; opt out
with ``check_digest=False`` for deliberately perturbed graphs, where the
executor still requires a 1:1 task-id cover).  Replay preserves execution
*semantics*, not timing: task results are bit-identical to a dynamic run
because the dependency edges — not the recorded interleaving — gate every
task, and tile-store writes are ordered by those same edges.

Suspendable frames replay deterministically: a recorded run stores every
frame suspension as a :class:`~repro.core.taskgraph.FrameResume` run-list
entry (recording forces a suspension at each ``yield``), and replay
re-suspends at the same points — reproducing the recorded frame
interleaving bit-identically, with per-segment claims keeping fallback
helpers single-shot.  Worker-count remapping keeps a frame's resume entries
adjacent to its start entry on one list.

Deviation limits: when real costs drift from the recorded ones, a worker
whose next recorded entry is not ready within ``stall_timeout`` falls back
to dynamic stealing of ready-but-unclaimed work, so a stale recording
degrades toward dynamic-scheduling performance instead of stalling — but a
recording for a *different structure* (changed nb/b/panel_threads) is
rejected, and region-forking tasks are never stolen from their recorded
spawner.  Recordings key parallel regions by their spawning task, so a task
may fork at most one region per execution (recording and replay both refuse
a second fork loudly).  Gang invariants survive replay: blocking regions run on the
recorded distinct workers and forks are published in recorded (monotonic
gang-id) issue order.
"""

from .cache import GraphCache, cache_key
from .executor import ReplayError, ReplayExecutor, replay_graph
from .graph_key import GraphKey, graph_key
from .pool import PoolEntryStats, PoolRun, ReplayPool
from .recording import GangPlacement, Recording, RecordingError
from .remap import RemapError, remap_recording

__all__ = [
    "GangPlacement",
    "GraphCache",
    "GraphKey",
    "PoolEntryStats",
    "PoolRun",
    "Recording",
    "RecordingError",
    "RemapError",
    "ReplayError",
    "ReplayExecutor",
    "ReplayPool",
    "cache_key",
    "graph_key",
    "remap_recording",
    "replay_graph",
]
