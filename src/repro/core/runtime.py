"""Multi-threaded work-stealing + gang-scheduling runtime (faithful repro).

Executes a :class:`~repro.core.taskgraph.TaskGraph` whose tasks are real
Python/JAX callables on a pool of pinned worker threads.  JAX CPU ops release
the GIL, so tile GEMMs genuinely run in parallel and communication thunks
(sleeps / device transfers) genuinely overlap compute — the wall-clock
speedups of the hybrid victim policy are measurable, not simulated.

Since the unified-executor refactor, :class:`Runtime` is a thin facade: the
worker substrate (persistent threads, park/wake, blocked-thread accounting,
deadlock detection) is :class:`~repro.exec.core.ExecutorCore`, and the
scheduling logic (per-worker deques, Algorithm-2 victim selection,
Algorithm-1 gang reservation, record instrumentation) is
:class:`~repro.exec.dynamic.DynamicDispatch`.  The replay executor and the
serving pool run different dispatch strategies on the *same* substrate —
one runtime, as the paper argues.  A ``Runtime`` is reusable: repeated
:meth:`run` calls execute on the same warm parked workers with no thread
respawn, and passing ``core=`` lets several facades share one thread set.

Faithfulness to the paper:

* per-worker work-stealing deques; ready tasks are pushed to the queue of
  the worker that resolved their last dependency (paper §2.1);
* Algorithm 2 victim selection (``history`` / ``random`` / ``hybrid``);
* Algorithm 1 gang scheduling: parallel regions spawned by tasks are
  gang-scheduled onto reserved workers under the fork lock with a monotonic
  gang id; gang ULTs are stealable subject to ``is_eligible_to_sched``;
* region barriers: gang regions may use *blocking* barriers safely (all
  members are guaranteed distinct workers); at the *join* barrier a gang ULT
  steals eligible work instead of idling (the paper's scheduling point);
* non-gang regions with blocking barriers reproduce the Fig. 1 deadlock —
  the core detects the all-workers-blocked state and raises
  :class:`DeadlockError` instead of hanging.

Python threads cannot switch ULT stacks, so *internal* barriers of a gang
region block the kernel thread (safe under gang reservation) instead of
being cooperative scheduling points — the one deviation from HClib,
documented in DESIGN.md §2.
"""

from __future__ import annotations

import threading
import warnings
from typing import Any, Callable, Dict, List, Optional

from ..exec.core import ExecutorCore, GangRegion
from ..exec.dynamic import DynamicDispatch
from .taskgraph import TaskContext, TaskGraph

__all__ = ["Runtime", "run_graph"]


class Runtime:
    """The integrated runtime (HClib-OMP analogue) — dynamic-dispatch facade
    over the shared :class:`~repro.exec.core.ExecutorCore`.

    ``core=`` injects a shared substrate (e.g. the serving pool's
    per-worker-count core); the runtime then *leases* those warm workers and
    :meth:`shutdown` leaves them running for the next lessee.  Without it
    the runtime owns a private core, shut down with the facade.
    """

    def __init__(
        self,
        n_workers: int,
        *,
        policy: str = "hybrid",
        gang_default: bool = True,
        seed: int = 0,
        steal_backoff: float = 20e-6,
        block_poll: float = 0.05,
        trace: bool = False,
        core: Optional[ExecutorCore] = None,
    ):
        if core is not None and core.n_workers != n_workers:
            raise ValueError(
                f"shared core has {core.n_workers} workers, runtime wants "
                f"{n_workers}")
        self.n_workers = n_workers
        self.policy_name = policy
        self.gang_default = gang_default
        self.seed = seed
        self.steal_backoff = steal_backoff
        self.block_poll = block_poll
        self.trace_enabled = trace

        self._core = core if core is not None else ExecutorCore(
            n_workers, block_poll=block_poll, name="repro-worker")
        self._owns_core = core is None
        self._dispatch = DynamicDispatch(
            n_workers, policy=policy, gang_default=gang_default, seed=seed,
            steal_backoff=steal_backoff, trace=trace)
        #: assembled :class:`~repro.obs.trace.RuntimeTrace` of the most
        #: recent traced run (None with ``trace=False``)
        self.last_trace = None
        self.last_recording = None

    # ------------------------------------------------------------------
    # lifecycle
    @property
    def core(self) -> ExecutorCore:
        return self._core

    @property
    def gang_state(self):
        return self._dispatch.gang_state

    @property
    def last_stats(self) -> Dict[str, int]:
        """Lightweight counters of the most recent run (steals, frame
        suspensions) — surfaced by :class:`repro.api.RunReport`."""
        return dict(self._dispatch.run_stats)

    def start(self) -> None:
        self._core.start()

    def shutdown(self) -> None:
        if self._owns_core:
            self._core.shutdown()

    def __enter__(self) -> "Runtime":
        self.start()
        return self

    def __exit__(self, *exc) -> None:
        self.shutdown()

    # ------------------------------------------------------------------
    # graph execution
    def run(self, graph: TaskGraph, timeout: float = 300.0, *,
            record: bool = False) -> Dict[int, Any]:
        """Execute the graph; returns {tid: result}.  Raises DeadlockError if
        the Fig. 1 state is reached, or re-raises the first task failure.
        Repeated calls reuse the same warm worker threads.

        With ``record=True`` the run is instrumented (per-worker execution
        order, steals, gang placements and fork order) and a
        :class:`repro.replay.Recording` is left in ``self.last_recording``
        for the replay executor / graph cache."""
        graph.validate()
        self._dispatch.set_recording(record)
        try:
            results = self._core.run(self._dispatch, graph, timeout=timeout)
            if record:
                self.last_recording = self._dispatch.build_recording(graph)
            return results
        finally:
            self._dispatch.set_recording(False)
            if self.trace_enabled:
                # assemble in the finally so deadlocked/failed runs still
                # leave their flight-recorder evidence behind
                self.last_trace = self._dispatch.take_trace()
                self._dispatch.apply_feedback(self.last_trace)

    # ------------------------------------------------------------------
    # parallel regions (called from task bodies via ctx.parallel)
    def parallel(
        self,
        n_threads: int,
        body: Callable[[int, GangRegion], Any],
        *,
        gang: Optional[bool] = None,
        spawn_ctx: Optional[TaskContext] = None,
    ) -> List[Any]:
        """Fork a parallel region of ``n_threads`` ULTs running
        ``body(thread_num, region)``; join and return per-thread results.
        Delegates to the dynamic dispatch (Algorithm 1)."""
        return self._dispatch.parallel(n_threads, body, gang=gang,
                                       spawn_ctx=spawn_ctx)


class _RunGraphShim:
    """The v1 convenience entry point, now a thin shim over the v2 session
    API (:mod:`repro.api`).

    ``run_graph(graph, n)`` runs one dynamic execution on a short-lived
    :class:`~repro.api.Session` lease.  The old mutually-exclusive mode
    kwargs map onto :class:`~repro.api.Plan` decisions:

    * ``record=True``  -> ``Session.run(graph, record=True)``;
    * ``replay=rec``   -> a ``Plan(mode="replay", recording=rec)``;
    * ``cache=c``      -> ``Session(cache=c)`` (record on miss, replay on
      hit);
    * ``pool=p``       -> ``p.serve(...)`` (``record``/``replay``/
      ``cache``/``trace`` are the pool's own business and rejected when
      combined with it).

    The v1 ``run_graph.last_recording`` module global is **gone from the
    library path**; this shim keeps a deprecation-warned, read-only,
    *thread-local* alias for old callers.  New code reads the recording off
    the :class:`~repro.api.RunReport` a session returns.
    """

    def __init__(self) -> None:
        self._tls = threading.local()

    # -- the deprecated alias -------------------------------------------
    @property
    def last_recording(self):
        """Deprecated: the recording involved in this thread's most recent
        ``run_graph`` call.  Use ``Session.run(...).recording``."""
        warnings.warn(
            "run_graph.last_recording is deprecated; use the RunReport "
            "returned by repro.Session.run (report.recording)",
            DeprecationWarning, stacklevel=2)
        return getattr(self._tls, "recording", None)

    def _note(self, recording: Any) -> None:
        self._tls.recording = recording

    # -- the call --------------------------------------------------------
    def __call__(
        self,
        graph: TaskGraph,
        n_workers: int,
        *,
        policy: str = "hybrid",
        gang_default: bool = True,
        seed: int = 0,
        trace: bool = False,
        timeout: float = 300.0,
        record: bool = False,
        replay: Any = None,
        cache: Any = None,
        pool: Any = None,
    ) -> Dict[int, Any]:
        from ..api.session import Plan, Session
        from .policies import resolve as resolve_policy

        resolve_policy(policy)            # typos fail here, with valid names
        if pool is not None:
            if record or replay is not None or cache is not None or trace:
                raise ValueError(
                    "run_graph(pool=...) owns recording/replay/caching "
                    "itself; record/replay/cache/trace cannot be combined "
                    "with a pool")
            out = pool.serve(graph, n_workers, policy=policy,
                             gang_default=gang_default, seed=seed,
                             timeout=timeout)
            # v1 callers also read pool.last_recording after the call
            pool.last_recording = out.recording
            self._note(out.recording)
            return out.results
        if replay is not None:
            if record or cache is not None:
                warnings.warn(
                    "run_graph(replay=...) ignores record/cache; use a "
                    "Session with a Plan instead", DeprecationWarning,
                    stacklevel=2)
            replay.validate_against(graph)     # v1 checked the digest here
            session = Session(replay.n_workers, scheduler="replay",
                              policy=policy, gang_default=gang_default,
                              seed=seed)
            try:
                plan = Plan(mode="replay", n_workers=replay.n_workers,
                            policy=policy, graph=graph, digest=replay.digest,
                            recording=replay,
                            reason="run_graph(replay=...) shim")
                report = session.run(plan=plan, timeout=timeout)
            finally:
                session.close()
            self._note(report.recording)
            return report.results
        session = Session(n_workers, scheduler="dynamic", policy=policy,
                          gang_default=gang_default, seed=seed, cache=cache,
                          trace=trace)
        try:
            report = session.run(graph, record=record or None,
                                 timeout=timeout)
        finally:
            session.close()
        self._note(report.recording)
        return report.results


#: v1 entry point (shim; see :class:`_RunGraphShim`).
run_graph = _RunGraphShim()
