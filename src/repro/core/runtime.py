"""Multi-threaded work-stealing + gang-scheduling runtime (faithful repro).

Executes a :class:`~repro.core.taskgraph.TaskGraph` whose tasks are real
Python/JAX callables on a pool of pinned worker threads.  JAX CPU ops release
the GIL, so tile GEMMs genuinely run in parallel and communication thunks
(sleeps / device transfers) genuinely overlap compute — the wall-clock
speedups of the hybrid victim policy are measurable, not simulated.

Faithfulness to the paper:

* per-worker work-stealing deques; ready tasks are pushed to the queue of
  the worker that resolved their last dependency (paper §2.1);
* Algorithm 2 victim selection (``history`` / ``random`` / ``hybrid``);
* Algorithm 1 gang scheduling: parallel regions spawned by tasks are
  gang-scheduled onto reserved workers under the fork lock with a monotonic
  gang id; gang ULTs are stealable subject to ``is_eligible_to_sched``;
* region barriers: gang regions may use *blocking* barriers safely (all
  members are guaranteed distinct workers); at the *join* barrier a gang ULT
  steals eligible work instead of idling (the paper's scheduling point);
* non-gang regions with blocking barriers reproduce the Fig. 1 deadlock —
  the runtime detects the all-workers-blocked state and raises
  :class:`DeadlockError` instead of hanging.

Python threads cannot switch ULT stacks, so *internal* barriers of a gang
region block the kernel thread (safe under gang reservation) instead of
being cooperative scheduling points — the one deviation from HClib,
documented in DESIGN.md §2.
"""

from __future__ import annotations

import itertools
import threading
import time
from collections import deque
from typing import Any, Callable, Deque, Dict, List, Optional, Sequence, Tuple

from .gang import GangState, is_eligible_to_sched
from .policies import make_policy
from .simulator import DeadlockError
from .taskgraph import ParallelSpec, Task, TaskContext, TaskGraph
from .tracing import Trace


class _Region:
    """A running parallel region (one gang)."""

    def __init__(self, rid: int, gang_id: int, nest_level: int, spec: ParallelSpec,
                 runtime: "Runtime", spawn_task: Optional[Task]):
        self.rid = rid
        self.gang_id = gang_id
        self.nest_level = nest_level
        self.spec = spec
        self.runtime = runtime
        self.spawn_task = spawn_task
        self.lock = threading.Lock()
        self.cv = threading.Condition(self.lock)
        self.barrier_round = 0
        self.arrived = 0
        self.done = 0
        self.results: List[Any] = [None] * spec.n_threads

    # -- the custom in-region barrier (paper: blocking sync inside tasks) ---
    def barrier(self) -> None:
        rt = self.runtime
        with self.cv:
            my_round = self.barrier_round
            self.arrived += 1
            if self.arrived == self.spec.n_threads:
                self.arrived = 0
                self.barrier_round += 1
                self.cv.notify_all()
                return
            rt._enter_blocked()
            try:
                while self.barrier_round == my_round:
                    if rt._shutdown or rt._deadlock or rt._failure:
                        raise DeadlockError(rt._deadlock or "runtime aborted during barrier")
                    if not self.cv.wait(timeout=rt.block_poll):
                        rt._check_deadlock()
            finally:
                rt._exit_blocked()

    def thread_done(self, tid: int, result: Any) -> bool:
        with self.cv:
            self.results[tid] = result
            self.done += 1
            finished = self.done == self.spec.n_threads
            if finished:
                self.cv.notify_all()
            return finished

    @property
    def finished(self) -> bool:
        return self.done == self.spec.n_threads


class _GangULT:
    __slots__ = ("region", "thread_num")

    def __init__(self, region: _Region, thread_num: int):
        self.region = region
        self.thread_num = thread_num

    @property
    def gang_id(self) -> int:
        return self.region.gang_id

    @property
    def nest_level(self) -> int:
        return self.region.nest_level


class _WorkerState(threading.local):
    pass


class Runtime:
    """The integrated runtime (HClib-OMP analogue)."""

    def __init__(
        self,
        n_workers: int,
        *,
        policy: str = "hybrid",
        gang_default: bool = True,
        seed: int = 0,
        steal_backoff: float = 20e-6,
        block_poll: float = 0.05,
        trace: bool = False,
    ):
        self.n_workers = n_workers
        self.policy_name = policy
        self.gang_default = gang_default
        self.seed = seed
        self.steal_backoff = steal_backoff
        self.block_poll = block_poll
        self.trace_enabled = trace
        self.trace = Trace(n_workers)

        self._fork_lock = threading.Lock()          # the paper's fork-phase lock
        self.gang_state = GangState(n_workers)
        self._region_ids = itertools.count()

        self._locals: List[Deque[Task]] = [deque() for _ in range(n_workers)]
        self._local_locks = [threading.Lock() for _ in range(n_workers)]
        self._gang_deqs: List[Deque[_GangULT]] = [deque() for _ in range(n_workers)]
        self._gang_locks = [threading.Lock() for _ in range(n_workers)]
        self._policies = [make_policy(policy, w, n_workers, seed) for w in range(n_workers)]

        # worker context stacks: list of (gang_id, nest_level)
        self._contexts: List[List[Tuple[int, int]]] = [[] for _ in range(n_workers)]

        self._results: Dict[int, Any] = {}
        self._results_lock = threading.Lock()
        self._graph: Optional[TaskGraph] = None
        self._indeg: List[int] = []
        self._indeg_lock = threading.Lock()
        self._remaining = 0
        self._done_cv = threading.Condition()

        self._blocked_count = 0
        self._blocked_lock = threading.Lock()
        self._shutdown = False
        self._deadlock: Optional[str] = None
        self._failure: Optional[BaseException] = None

        self._threads: List[threading.Thread] = []
        self._tls = _WorkerState()
        self._started = False
        self._work_available = threading.Condition()

        # record-and-replay instrumentation (repro.replay); populated by
        # run(record=True) — cold path, None otherwise
        self._recording = False
        self._rec_entries: List[List[Any]] = []
        self._rec_steals: List[List[Tuple[int, Any]]] = []
        self._rec_forks: List[Tuple[int, int, int]] = []
        self._rec_comms: List[int] = []
        self._rec_comm_lock = threading.Lock()
        self.last_recording = None

    # ------------------------------------------------------------------
    # lifecycle
    def start(self) -> None:
        if self._started:
            return
        self._started = True
        for w in range(self.n_workers):
            th = threading.Thread(target=self._worker_main, args=(w,), daemon=True,
                                  name=f"repro-worker-{w}")
            self._threads.append(th)
            th.start()

    def shutdown(self) -> None:
        self._shutdown = True
        with self._work_available:
            self._work_available.notify_all()
        for th in self._threads:
            th.join(timeout=5.0)
        self._threads.clear()
        self._started = False
        self._shutdown = False

    def __enter__(self) -> "Runtime":
        self.start()
        return self

    def __exit__(self, *exc) -> None:
        self.shutdown()

    # ------------------------------------------------------------------
    # graph execution
    def run(self, graph: TaskGraph, timeout: float = 300.0, *,
            record: bool = False) -> Dict[int, Any]:
        """Execute the graph; returns {tid: result}.  Raises DeadlockError if
        the Fig. 1 state is reached, or re-raises the first task failure.

        With ``record=True`` the run is instrumented (per-worker execution
        order, steals, gang placements and fork order) and a
        :class:`repro.replay.Recording` is left in ``self.last_recording``
        for the replay executor / graph cache."""
        graph.validate()
        if not self._started:
            self.start()
        self._graph = graph
        self._indeg = graph.indegrees()
        self._results = {}
        self._deadlock = None
        self._failure = None
        self._recording = record
        if record:
            self._rec_entries = [[] for _ in range(self.n_workers)]
            self._rec_steals = [[] for _ in range(self.n_workers)]
            self._rec_forks = []
            self._rec_comms = []
        with self._done_cv:
            self._remaining = len(graph)
        # master thread (worker 0's queue) receives the roots
        for t in graph.roots():
            self._push_local(0, t)
        self._notify_work()

        deadline = time.monotonic() + timeout
        with self._done_cv:
            while self._remaining > 0:
                if self._deadlock:
                    raise DeadlockError(self._deadlock)
                if self._failure:
                    raise self._failure
                if not self._done_cv.wait(timeout=0.05):
                    if time.monotonic() > deadline:
                        raise TimeoutError(
                            f"graph {graph.name!r} did not finish within {timeout}s "
                            f"({self._remaining} tasks left)")
        if self._failure:
            raise self._failure
        if record:
            self.last_recording = self._build_recording(graph)
            self._recording = False
        return dict(self._results)

    def _build_recording(self, graph: TaskGraph):
        """Assemble a replay Recording from the instrumentation buffers."""
        from ..replay.recording import GangPlacement, Recording
        from ..replay.graph_key import graph_key

        placements: Dict[int, GangPlacement] = {}
        for spawn_tid, gang_id, n_threads in self._rec_forks:
            if spawn_tid in placements:
                # recordings key regions by spawning task; two forks from one
                # task would be indistinguishable on replay — refuse loudly
                raise ValueError(
                    f"task {spawn_tid} forked more than one parallel region; "
                    "record-and-replay supports one region per task")
            placements[spawn_tid] = GangPlacement(
                spawn_tid, gang_id, [-1] * n_threads)
        for w, entries in enumerate(self._rec_entries):
            for e in entries:
                if isinstance(e, tuple) and e[0] in placements:
                    placements[e[0]].workers[e[1]] = w
        steals = [(w, victim, e)
                  for w, lst in enumerate(self._rec_steals)
                  for victim, e in lst]
        return Recording(
            digest=graph_key(graph).digest,
            graph_name=graph.name,
            n_workers=self.n_workers,
            policy=self.policy_name,
            worker_orders=[list(e) for e in self._rec_entries],
            gang_placements=placements,
            gang_issue_order=[f[0] for f in self._rec_forks],
            steals=steals,
            collective_order=list(self._rec_comms),
            source="dynamic",
        )

    # ------------------------------------------------------------------
    # queues
    def _push_local(self, w: int, task: Task) -> None:
        with self._local_locks[w]:
            self._locals[w].append(task)

    def _pop_local(self, w: int) -> Optional[Task]:
        with self._local_locks[w]:
            dq = self._locals[w]
            if not dq:
                return None
            # priority-aware LIFO pop (bounded scan, paper's priority clause)
            best_i, best_p = len(dq) - 1, dq[-1].priority
            for i in range(len(dq) - 1, max(-1, len(dq) - 9), -1):
                if dq[i].priority > best_p:
                    best_i, best_p = i, dq[i].priority
            t = dq[best_i]
            del dq[best_i]
            return t

    def _steal_local(self, victim: int) -> Optional[Task]:
        with self._local_locks[victim]:
            dq = self._locals[victim]
            return dq.popleft() if dq else None

    def _pop_gang(self, thief: int, victim: int) -> Optional[_GangULT]:
        ctx = self._contexts[thief]
        cur_gang, cur_nest = (ctx[-1] if ctx else (-1, 0))
        with self._gang_locks[victim]:
            dq = self._gang_deqs[victim]
            if not dq:
                return None
            head = dq[0]
            if is_eligible_to_sched(head.gang_id, head.nest_level, cur_gang, cur_nest):
                return dq.popleft()
            return None

    def _notify_work(self) -> None:
        with self._work_available:
            self._work_available.notify_all()

    # ------------------------------------------------------------------
    # worker loop
    def _worker_main(self, w: int) -> None:
        self._tls.wid = w
        while not self._shutdown:
            progressed = self._schedule_once(w)
            if not progressed:
                with self._work_available:
                    self._work_available.wait(timeout=self.steal_backoff * 50)

    def _schedule_once(self, w: int, eligible_only: bool = True) -> bool:
        """One scheduling point: gang deque > local deque > steal.  Returns
        True if a unit of work was executed."""
        if self._failure is not None or self._deadlock is not None:
            return False
        ult = self._pop_gang(w, w)
        if ult is not None:
            self._run_gang_ult(w, ult)
            return True
        task = self._pop_local(w)
        if task is not None:
            self._run_task(w, task)
            return True
        # work stealing (Algorithm 2 policy)
        pol = self._policies[w]
        victim = pol.select()
        got: Any = None
        if victim != w:
            got = self._pop_gang(w, victim)
            if got is None:
                got = self._steal_local(victim)
        pol.record(victim, got is not None)
        if got is None:
            return False
        if self._recording:
            entry = (got.region.spawn_task.tid, got.thread_num) \
                if isinstance(got, _GangULT) and got.region.spawn_task is not None \
                else (got.tid if not isinstance(got, _GangULT) else None)
            if entry is not None:
                self._rec_steals[w].append((victim, entry))
        if isinstance(got, _GangULT):
            self._run_gang_ult(w, got)
        else:
            self._run_task(w, got)
        return True

    # ------------------------------------------------------------------
    # task execution
    def _run_task(self, w: int, task: Task) -> None:
        t0 = time.perf_counter()
        if self._recording:
            # per-worker list, appended only by worker w: start order, no lock
            self._rec_entries[w].append(task.tid)
            if task.kind == "comm":
                with self._rec_comm_lock:
                    self._rec_comms.append(task.tid)
        ctx = TaskContext(self._graph, task, self._results, runtime=self)
        ctx.worker_id = w  # type: ignore[attr-defined]
        try:
            result = task.fn(ctx) if task.fn is not None else None
        except BaseException as e:  # noqa: BLE001 - propagate to run()
            self._failure = e
            with self._done_cv:
                self._done_cv.notify_all()
            return
        t1 = time.perf_counter()
        if self.trace_enabled:
            self.trace.record(w, t0, t1, task.kind, task.name)
        with self._results_lock:
            self._results[task.tid] = result
        self._complete(w, task)

    def _complete(self, w: int, task: Task) -> None:
        newly_ready: List[Task] = []
        with self._indeg_lock:
            for s in self._graph.successors(task):
                self._indeg[s.tid] -= 1
                if self._indeg[s.tid] == 0:
                    newly_ready.append(s)
        for s in newly_ready:
            self._push_local(w, s)
        if newly_ready:
            self._notify_work()
        with self._done_cv:
            self._remaining -= 1
            if self._remaining <= 0:
                self._done_cv.notify_all()

    # ------------------------------------------------------------------
    # parallel regions (called from task bodies via ctx.parallel)
    def parallel(
        self,
        n_threads: int,
        body: Callable[[int, "_Region"], Any],
        *,
        gang: Optional[bool] = None,
        spawn_ctx: Optional[TaskContext] = None,
    ) -> List[Any]:
        """Fork a parallel region of ``n_threads`` ULTs running
        ``body(thread_num, region)``; join and return per-thread results.
        ``region.barrier()`` is the blocking in-region barrier.

        Gang regions (default) are scheduled per Algorithm 1.  Non-gang
        regions push all ULTs to the calling worker's queue — combined with
        blocking barriers this reproduces the Fig. 1 deadlock, which the
        runtime detects."""
        w = getattr(self._tls, "wid", 0)
        use_gang = self.gang_default if gang is None else gang
        if use_gang and n_threads > self.n_workers:
            # Blocking synchronization requires every gang member on a
            # distinct kernel thread (no ULT stack switching in Python) —
            # same constraint OpenMP has for its thread teams.
            raise ValueError(
                f"gang region requests {n_threads} ULTs but only "
                f"{self.n_workers} workers exist; blocking barriers would deadlock")
        ctx_stack = self._contexts[w]
        nest_level = (ctx_stack[-1][1] if ctx_stack else 0) + 1
        spec = ParallelSpec(n_threads=n_threads, body=body, gang=use_gang)

        spawn_task = spawn_ctx.task if spawn_ctx is not None else None
        with self._fork_lock:   # the paper's serialized fork phase
            gang_id = self.gang_state.next_gang_id() if use_gang else -1
            region = _Region(next(self._region_ids), gang_id, nest_level, spec, self,
                             spawn_task=spawn_task)
            if self._recording and spawn_task is not None:
                # fork lock => globally ordered by gang id (issue order)
                self._rec_forks.append((spawn_task.tid, gang_id, n_threads))
            if use_gang:
                reserved = self.gang_state.get_workers(w, n_threads)
                self.gang_state.account_gang([reserved[i % len(reserved)] for i in range(n_threads)])
                for i in range(n_threads):
                    target = reserved[i % len(reserved)]
                    with self._gang_locks[target]:
                        self._gang_deqs[target].append(_GangULT(region, i))
            else:
                for i in range(n_threads):
                    with self._gang_locks[w]:
                        self._gang_deqs[w].append(_GangULT(region, i))
        self._notify_work()

        # join: the spawning worker helps out at this scheduling point —
        # paper: gang ULTs at a join barrier steal (eligible) work.
        while not region.finished:
            if self._shutdown or self._deadlock or self._failure:
                raise DeadlockError(self._deadlock or "runtime aborted during join")
            progressed = self._schedule_once(w)
            if not progressed and not region.finished:
                # join-waiters retry stealing, so they are NOT counted as
                # hard-blocked (only blocking barriers are) — but they do
                # poll the detector for barrier deadlocks elsewhere.
                with region.cv:
                    if not region.finished:
                        if not region.cv.wait(timeout=self.block_poll):
                            self._check_deadlock()
        return list(region.results)

    def _run_gang_ult(self, w: int, ult: _GangULT) -> None:
        region = ult.region
        if self._recording and region.spawn_task is not None:
            self._rec_entries[w].append((region.spawn_task.tid, ult.thread_num))
        self._contexts[w].append((region.gang_id, region.nest_level))
        t0 = time.perf_counter()
        try:
            result = region.spec.body(ult.thread_num, region)
        except BaseException as e:  # noqa: BLE001
            self._failure = e
            with self._done_cv:
                self._done_cv.notify_all()
            return
        finally:
            self._contexts[w].pop()
            if region.gang_id >= 0:
                with self._fork_lock:
                    self.gang_state.release_gang_thread(w)
        t1 = time.perf_counter()
        if self.trace_enabled:
            self.trace.record(w, t0, t1, "panel", f"r{region.rid}.t{ult.thread_num}")
        region.thread_done(ult.thread_num, result)

    # ------------------------------------------------------------------
    # deadlock detection: all workers blocked on barriers/joins while work
    # remains that only they could run
    def _enter_blocked(self) -> None:
        with self._blocked_lock:
            self._blocked_count += 1

    def _exit_blocked(self) -> None:
        with self._blocked_lock:
            self._blocked_count -= 1

    def _check_deadlock(self) -> None:
        """The Fig. 1 state: every worker is stuck inside a *blocking*
        barrier (kernel-thread semantics — cannot schedule anything) while
        the ULTs that would satisfy those barriers sit starved in queues."""
        with self._blocked_lock:
            blocked = self._blocked_count
        if blocked < self.n_workers:
            return
        queued = sum(len(d) for d in self._gang_deqs) + sum(len(d) for d in self._locals)
        msg = (f"deadlock: all {blocked} workers blocked at blocking barriers; "
               f"{queued} ULT(s)/task(s) starved")
        self._deadlock = msg
        with self._done_cv:
            self._done_cv.notify_all()
        raise DeadlockError(msg)


def run_graph(
    graph: TaskGraph,
    n_workers: int,
    *,
    policy: str = "hybrid",
    gang_default: bool = True,
    seed: int = 0,
    trace: bool = False,
    timeout: float = 300.0,
    record: bool = False,
    replay: Any = None,
    cache: Any = None,
    pool: Any = None,
) -> Dict[int, Any]:
    """Convenience: run a graph on a fresh runtime and shut it down.

    Record-and-replay hooks (see :mod:`repro.replay`):

    * ``pool`` — a :class:`~repro.replay.ReplayPool`: serve the execution
      from a persistent per-shape executor (records on first sight, replays
      after, adaptively re-records on drift).  The serving-loop path: no
      per-request runtime or executor construction.  ``gang_default`` and
      ``seed`` are forwarded to the pool's dynamic warmup/recording runs;
      ``record``/``replay``/``cache``/``trace`` are the pool's own business
      and rejected when combined with it;
    * ``replay`` — a :class:`~repro.replay.Recording`: skip the dynamic
      scheduler entirely and replay the graph on a
      :class:`~repro.replay.ReplayExecutor`;
    * ``cache`` — a :class:`~repro.replay.GraphCache`: replay on a cache hit
      for this (structure, ``n_workers``, ``policy``); on a miss, run
      dynamically with recording on and store the recording, so the next
      same-shaped call replays;
    * ``record`` — instrument the dynamic run; the recording is returned via
      ``run_graph.last_recording`` (also stored in ``cache`` when given).
    """
    if pool is not None:
        if record or replay is not None or cache is not None or trace:
            raise ValueError(
                "run_graph(pool=...) owns recording/replay/caching itself; "
                "record/replay/cache/trace cannot be combined with a pool")
        results = pool.run(graph, n_workers, policy=policy,
                           gang_default=gang_default, seed=seed,
                           timeout=timeout)
        run_graph.last_recording = pool.last_recording
        return results
    if replay is not None:
        from ..replay.executor import replay_graph
        run_graph.last_recording = replay
        return replay_graph(graph, replay, timeout=timeout)
    if cache is not None:
        rec = cache.lookup(graph, n_workers, policy)
        if rec is not None:
            from ..replay.executor import replay_graph
            run_graph.last_recording = rec
            # lookup already matched this graph's digest — skip re-hashing
            # the structure on the hot path
            return replay_graph(graph, rec, timeout=timeout,
                                check_digest=False)
        record = True
    rt = Runtime(n_workers, policy=policy, gang_default=gang_default, seed=seed, trace=trace)
    with rt:
        results = rt.run(graph, timeout=timeout, record=record)
    run_graph.last_recording = rt.last_recording
    if cache is not None and rt.last_recording is not None:
        cache.store(rt.last_recording)
    return results


run_graph.last_recording = None
