"""Gang scheduling (paper Algorithm 1) — pure logic shared by the threaded
runtime and the discrete-event simulator.

A *gang* is the set of ULTs of one data-parallel region that must be able to
synchronize with blocking operations.  ``gang_sched`` assigns the region a
monotonically increasing ``gang_id`` (under the runtime's fork lock, so ids
are a global total order on region forks), reserves ``n_request`` workers
chosen close to the spawner and below average gang load, and pushes ULT *i*
onto reserved worker *i*'s ``gang_deq``.

Deadlock freedom comes from two properties implemented here:

* gang deques are FIFO and pushes are globally ordered by ``gang_id``
  (fork lock), so every worker drains gang ULTs in gang-id order — the
  incomplete gang with the smallest id always has all of its reserved
  workers reach its ULTs, so its (blocking) barrier is satisfied; induction
  does the rest;
* ``is_eligible_to_sched`` restricts which gang ULTs a worker may *steal*:
  a worker currently inside gang G at nest level L may only take ULTs from
  strictly deeper regions or from G itself — earlier/outer gangs take
  precedence and no cycle of workers mutually blocked on each other's
  barriers can form.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Any, List, Sequence


@dataclasses.dataclass
class ULT:
    """A user-level thread of a parallel region."""

    gang_id: int            # id of the region (monotonic); -1 => not a gang ULT
    nest_level: int         # nest level of the *spawning* worker
    region: Any             # the ParallelRegion this ULT belongs to
    thread_num: int         # omp_get_thread_num() within the region
    cost: float = 0.0       # simulator cost per barrier phase

    @property
    def name(self) -> str:
        return f"gang{self.gang_id}.t{self.thread_num}"


class GangState:
    """Global gang bookkeeping (the runtime holds one, protected by its fork
    lock; the simulator holds one, single-threaded)."""

    def __init__(self, n_workers: int):
        self.n_workers = n_workers
        self._next_gang_id = itertools.count()
        # paper: per-worker count of gang ULTs ever assigned minus completed;
        # used by get_workers' load balancing.
        self.worker_gang_load: List[int] = [0] * n_workers
        self.n_gang_threads = 0

    def next_gang_id(self) -> int:
        return next(self._next_gang_id)

    # -- Algorithm 1, GET_WORKERS ------------------------------------------
    def get_workers(self, cur_worker_id: int, n_request: int) -> List[int]:
        """Reserve ``n_request`` workers: start adjacent to the spawner
        (wrapping back by ``n_request/2`` near the top of the worker range so
        the reservation stays contiguous), skip workers whose gang load is
        above average.  Mirrors the paper's pseudo-code, with the guarantee
        of termination even when every worker is above-average loaded (second
        sweep ignores the load filter — the paper implicitly relies on loads
        draining; a bounded scan keeps the runtime lock-step finite)."""
        n = self.n_workers
        n_request = min(n_request, n)
        avg_load = self.n_gang_threads / n
        if cur_worker_id + n_request >= n:
            start = (cur_worker_id - n_request // 2) % n
        else:
            start = (cur_worker_id + 1) % n
        reserved: List[int] = []
        idx = start
        scanned = 0
        while len(reserved) < n_request and scanned < n:
            if self.worker_gang_load[idx] <= avg_load:
                reserved.append(idx)
            idx = (idx + 1) % n
            scanned += 1
        # fallback sweep: take least-loaded remaining workers
        if len(reserved) < n_request:
            remaining = sorted(
                (w for w in range(n) if w not in reserved),
                key=lambda w: (self.worker_gang_load[w], (w - start) % n),
            )
            reserved.extend(remaining[: n_request - len(reserved)])
        return reserved

    def account_gang(self, workers: Sequence[int]) -> None:
        for w in workers:
            self.worker_gang_load[w] += 1
        self.n_gang_threads += len(workers)

    def release_gang_thread(self, worker: int) -> None:
        self.worker_gang_load[worker] -= 1
        self.n_gang_threads -= 1


# -- Algorithm 1, IS_ELIGIBLE_TO_SCHED --------------------------------------
def is_eligible_to_sched(
    ult_gang_id: int,
    ult_nest_level: int,
    worker_cur_gang_id: int,
    worker_nest_level: int,
) -> bool:
    """May a worker (currently executing inside gang ``worker_cur_gang_id``
    at ``worker_nest_level``, or idle if ``worker_cur_gang_id < 0``) start or
    steal the given gang ULT?"""
    if worker_cur_gang_id < 0:
        return True
    if ult_nest_level > worker_nest_level:
        return True
    if ult_nest_level == worker_nest_level and ult_gang_id == worker_cur_gang_id:
        return True
    return False
