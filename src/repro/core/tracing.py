"""Execution tracing for the runtime and simulator: one event vocabulary.

Traces are lists of ``(worker, t0, t1, kind, label)`` events.  ``kind`` is
one of ``compute / comm / panel / idle / steal / barrier / switch`` — the
categories the paper's Fig. 8 (critical path) and Fig. 11d (idle/compute/
MPI breakdown) are built from.  The same :class:`Event` schema and kind
vocabulary are shared by the offline :class:`~repro.core.simulator.Simulator`
(:class:`Trace`) and the live executor's flight recorder
(:class:`~repro.obs.trace.RuntimeTrace`), so ``breakdown()`` /
``utilization()`` / per-worker tables read identically on both.

The flight recorder additionally emits *point* events (``EV_*`` below):
raw timestamped markers (task start/end, steal attempt/hit, gang
reserve/enter/exit, frame suspend/wake/resume, plain-body block/unblock,
deadlock polls, worker park/wake, replay deviations) that
:mod:`repro.obs.trace` assembles into the span kinds above.
"""

from __future__ import annotations

import dataclasses
from collections import defaultdict
from typing import Dict, List

# ---------------------------------------------------------------------------
# span kinds (simulator Trace + assembled RuntimeTrace share these)
KIND_COMPUTE = "compute"
KIND_COMM = "comm"
KIND_PANEL = "panel"
KIND_IDLE = "idle"
KIND_STEAL = "steal"
KIND_BARRIER = "barrier"
KIND_SWITCH = "switch"

#: every span kind a Trace/RuntimeTrace event may carry
SPAN_KINDS = frozenset({KIND_COMPUTE, KIND_COMM, KIND_PANEL, KIND_IDLE,
                        KIND_STEAL, KIND_BARRIER, KIND_SWITCH})
#: kinds that count as useful work in utilization()/busy_time()
BUSY_KINDS = (KIND_COMPUTE, KIND_COMM, KIND_PANEL)

# ---------------------------------------------------------------------------
# point-event kinds emitted by the live executors' flight recorder
EV_TASK_START = "task_start"          # a=tid                label="kind|name"
EV_TASK_END = "task_end"              # a=tid
EV_STEAL_ATTEMPT = "steal_attempt"    # a=victim
EV_STEAL_HIT = "steal_hit"            # a=victim             label=unit kind
EV_GANG_RESERVE = "gang_reserve"      # a=spawn_tid, b=n     label="g<gang_id>"
EV_GANG_ENTER = "gang_enter"          # a=rid, b=thread_num
EV_GANG_EXIT = "gang_exit"            # a=rid, b=thread_num
EV_BARRIER_WAIT = "barrier_wait"      # a=rid
EV_BARRIER_DONE = "barrier_done"      # a=rid
EV_FRAME_SUSPEND = "frame_suspend"    # a=tid, b=seg   label="req(chan)@uid"
EV_FRAME_WAKE = "frame_wake"          # a=tid, b=seg (emitted on waker thread)
EV_FRAME_RESUME = "frame_resume"      # a=tid, b=seg         label="kind|name"
EV_BLOCK = "block"                    # a=tid                label=what
EV_UNBLOCK = "unblock"                # a=tid
EV_DEADLOCK_POLL = "deadlock_poll"
EV_PARK = "park"                      # worker went idle (no schedulable work)
EV_WAKE = "wake"                      # worker found work after idling
EV_REPLAY_FALLBACK = "replay_fallback"  # a=tid or -1        label=unit kind
EV_REPLAY_STALL = "replay_stall"
EV_REPLAY_SKIP = "replay_skip"        # a=tid
EV_RUN_AHEAD = "run_ahead"            # a=tid
EV_RESOURCE_ACQUIRE = "resource_acquire"  # a=tid, b=n_res   label=task name
EV_RESOURCE_WAIT = "resource_wait"    # a=tid (task deferred on contention)
EV_RESOURCE_RELEASE = "resource_release"  # a=tid, b=n_res

EVENT_KINDS = frozenset({
    EV_TASK_START, EV_TASK_END, EV_STEAL_ATTEMPT, EV_STEAL_HIT,
    EV_GANG_RESERVE, EV_GANG_ENTER, EV_GANG_EXIT, EV_BARRIER_WAIT,
    EV_BARRIER_DONE, EV_FRAME_SUSPEND, EV_FRAME_WAKE, EV_FRAME_RESUME,
    EV_BLOCK, EV_UNBLOCK, EV_DEADLOCK_POLL, EV_PARK, EV_WAKE,
    EV_REPLAY_FALLBACK, EV_REPLAY_STALL, EV_REPLAY_SKIP, EV_RUN_AHEAD,
    EV_RESOURCE_ACQUIRE, EV_RESOURCE_WAIT, EV_RESOURCE_RELEASE,
})


@dataclasses.dataclass
class Event:
    worker: int
    t0: float
    t1: float
    kind: str
    label: str = ""

    @property
    def dt(self) -> float:
        return self.t1 - self.t0


class Trace:
    def __init__(self, n_workers: int):
        self.n_workers = n_workers
        self.events: List[Event] = []

    def record(self, worker: int, t0: float, t1: float, kind: str, label: str = "") -> None:
        self.events.append(Event(worker, t0, t1, kind, label))

    @property
    def makespan(self) -> float:
        return max((e.t1 for e in self.events), default=0.0)

    def busy_time(self, kinds=BUSY_KINDS) -> float:
        return sum(e.dt for e in self.events if e.kind in kinds)

    def breakdown(self) -> Dict[str, float]:
        """Total seconds per event kind, plus derived idle time
        (makespan * workers - busy)."""
        out: Dict[str, float] = defaultdict(float)
        for e in self.events:
            out[e.kind] += e.dt
        accounted = sum(out.values())
        out[KIND_IDLE] += max(0.0, self.makespan * self.n_workers - accounted)
        return dict(out)

    def breakdown_fraction(self) -> Dict[str, float]:
        b = self.breakdown()
        total = self.makespan * self.n_workers
        return {k: (v / total if total else 0.0) for k, v in b.items()}

    def per_worker_breakdown(self) -> List[Dict[str, float]]:
        outs: List[Dict[str, float]] = [defaultdict(float) for _ in range(self.n_workers)]
        for e in self.events:
            outs[e.worker][e.kind] += e.dt
        res = []
        for w, o in enumerate(outs):
            busy = sum(o.values())
            o = dict(o)
            o[KIND_IDLE] = max(0.0, self.makespan - busy)
            res.append(o)
        return res

    def utilization(self) -> float:
        if not self.events:
            return 0.0
        return self.busy_time() / (self.makespan * self.n_workers)

    def count(self, kind: str) -> int:
        return sum(1 for e in self.events if e.kind == kind)
