"""Execution tracing for the runtime and simulator.

Traces are lists of ``(worker, t0, t1, kind, label)`` events.  ``kind`` is
one of ``compute / comm / panel / idle / steal / barrier / switch`` — the
categories the paper's Fig. 8 (critical path) and Fig. 11d (idle/compute/
MPI breakdown) are built from.
"""

from __future__ import annotations

import dataclasses
from collections import defaultdict
from typing import Dict, List, Tuple


@dataclasses.dataclass
class Event:
    worker: int
    t0: float
    t1: float
    kind: str
    label: str = ""

    @property
    def dt(self) -> float:
        return self.t1 - self.t0


class Trace:
    def __init__(self, n_workers: int):
        self.n_workers = n_workers
        self.events: List[Event] = []

    def record(self, worker: int, t0: float, t1: float, kind: str, label: str = "") -> None:
        self.events.append(Event(worker, t0, t1, kind, label))

    @property
    def makespan(self) -> float:
        return max((e.t1 for e in self.events), default=0.0)

    def busy_time(self, kinds=("compute", "comm", "panel")) -> float:
        return sum(e.dt for e in self.events if e.kind in kinds)

    def breakdown(self) -> Dict[str, float]:
        """Total seconds per event kind, plus derived idle time
        (makespan * workers - busy)."""
        out: Dict[str, float] = defaultdict(float)
        for e in self.events:
            out[e.kind] += e.dt
        accounted = sum(out.values())
        out["idle"] += max(0.0, self.makespan * self.n_workers - accounted)
        return dict(out)

    def breakdown_fraction(self) -> Dict[str, float]:
        b = self.breakdown()
        total = self.makespan * self.n_workers
        return {k: (v / total if total else 0.0) for k, v in b.items()}

    def per_worker_breakdown(self) -> List[Dict[str, float]]:
        outs: List[Dict[str, float]] = [defaultdict(float) for _ in range(self.n_workers)]
        for e in self.events:
            outs[e.worker][e.kind] += e.dt
        res = []
        for w, o in enumerate(outs):
            busy = sum(o.values())
            o = dict(o)
            o["idle"] = max(0.0, self.makespan - busy)
            res.append(o)
        return res

    def utilization(self) -> float:
        if not self.events:
            return 0.0
        return self.busy_time() / (self.makespan * self.n_workers)

    def count(self, kind: str) -> int:
        return sum(1 for e in self.events if e.kind == kind)
