"""Static schedule extraction — the TPU-native realization of the paper.

TPUs execute statically compiled SPMD programs: there is no on-device work
stealing.  What *can* be controlled ahead of time is (a) the order in which
independent tasks (tile ops, microbatch steps) are placed into the program,
and (b) the order in which collective-bearing regions ("gangs") issue their
collectives — which must be a global total order across participants or the
fabric deadlocks, exactly the paper's monotonic-gang-id discipline.

:class:`ListScheduler` therefore runs the *deterministic* discrete-event
scheduler (same Algorithm 1/2 implementation as the dynamic runtime) against
the task graph's cost model and freezes the resulting per-worker execution
order into a :class:`StaticSchedule`:

* ``order[slot]``      — the frozen task order for each of the P slots
                         (device groups / host executor lanes),
* ``waves()``          — a barrier-free wave decomposition (tasks grouped by
                         frozen start time) used by the distributed tiled
                         factorization executor (`repro.linalg.dist`),
* ``collective_order`` — gang-id-ordered list of collective-bearing tasks;
                         every participant must issue these in this order,
* ``makespan``         — the cost-model makespan (the hillclimbing metric).

The victim policy changes the frozen interleaving — ``history`` reproduces
the locality-first serialization, ``hybrid`` the paper's overlapped order —
so the paper's scheduling effect survives compilation.
"""

from __future__ import annotations

import dataclasses
from collections import defaultdict
from typing import Dict, List

from .simulator import Simulator
from .taskgraph import TaskGraph
from .tracing import Trace


@dataclasses.dataclass
class ScheduledItem:
    tid: int
    name: str
    kind: str
    slot: int
    t0: float
    t1: float


@dataclasses.dataclass
class GangReservation:
    """A gang-scheduled region's frozen reservation: the simulator reserved
    ``workers[i]`` for ULT ``i`` of the region forked by ``spawn_tid`` at
    virtual time ``t``.  Carried into static-seeded replay recordings so
    panel forks replay *placed* instead of falling back to dynamic."""

    spawn_tid: int
    gang_id: int
    workers: List[int]
    t: float


@dataclasses.dataclass
class StaticSchedule:
    n_slots: int
    items: List[ScheduledItem]
    makespan: float
    policy: str
    gangs: List[GangReservation] = dataclasses.field(default_factory=list)

    @property
    def order(self) -> Dict[int, List[ScheduledItem]]:
        out: Dict[int, List[ScheduledItem]] = defaultdict(list)
        for it in sorted(self.items, key=lambda i: (i.slot, i.t0)):
            out[it.slot].append(it)
        return dict(out)

    def waves(self) -> List[List[int]]:
        """Group task ids into execution waves: tasks whose frozen intervals
        overlap the same wave window run concurrently.  Greedy sweep by start
        time; a new wave opens when a task starts after the current wave's
        minimum end time (so within a wave, no task depends on another)."""
        items = sorted(self.items, key=lambda i: (i.t0, i.t1))
        waves: List[List[int]] = []
        wave_end = -1.0
        for it in items:
            if not waves or it.t0 >= wave_end - 1e-12:
                waves.append([it.tid])
                wave_end = it.t1
            else:
                waves[-1].append(it.tid)
                wave_end = min(wave_end, it.t1)
        return waves

    def collective_order(self) -> List[int]:
        """Task ids of comm-kind tasks in frozen issue order — the gang-id
        total order every SPMD participant must respect."""
        return [it.tid for it in sorted(self.items, key=lambda i: (i.t0, i.tid))
                if it.kind == "comm"]

    def slot_utilization(self) -> List[float]:
        busy = [0.0] * self.n_slots
        for it in self.items:
            busy[it.slot] += it.t1 - it.t0
        return [b / self.makespan if self.makespan else 0.0 for b in busy]

    def overlap_fraction(self) -> float:
        """Fraction of total comm time that is hidden under concurrently
        running compute on other slots — the paper's Fig. 2 metric."""
        comm = [(it.t0, it.t1) for it in self.items if it.kind == "comm"]
        compute = [(it.t0, it.t1) for it in self.items if it.kind != "comm"]
        total = sum(t1 - t0 for t0, t1 in comm)
        if total == 0:
            return 0.0
        # sweep: time where >=1 comm and >=1 compute are simultaneously active
        points = sorted({t for iv in comm + compute for t in iv})
        hidden = 0.0
        for a, b in zip(points[:-1], points[1:]):
            mid = (a + b) / 2
            if any(t0 <= mid < t1 for t0, t1 in comm) and any(t0 <= mid < t1 for t0, t1 in compute):
                hidden += b - a
        return hidden / total


class ListScheduler:
    """Freeze a dynamic-scheduler run into a static schedule."""

    def __init__(self, n_slots: int, *, policy: str = "hybrid", seed: int = 0,
                 mode: str = "gang"):
        self.n_slots = n_slots
        self.policy = policy
        self.seed = seed
        self.mode = mode

    def schedule(self, graph: TaskGraph) -> StaticSchedule:
        sim = Simulator(self.n_slots, policy=self.policy, mode=self.mode,
                        seed=self.seed, trace=True)
        trace: Trace = sim.run(graph)
        by_name = {t.name: t for t in graph}
        items: List[ScheduledItem] = []
        for e in trace.events:
            task = by_name.get(e.label)
            if task is None or e.kind in ("barrier", "idle"):
                continue
            items.append(ScheduledItem(task.tid, task.name, task.kind, e.worker, e.t0, e.t1))
        gangs = [GangReservation(tid, gid, list(workers), t)
                 for tid, gid, workers, t in sim.gang_log]
        return StaticSchedule(self.n_slots, items, trace.makespan, self.policy,
                              gangs=gangs)


def microbatch_overlap_graph(
    n_microbatches: int,
    *,
    compute_cost: float = 1.0,
    comm_cost: float = 0.4,
    name: str = "grad-accum",
) -> TaskGraph:
    """The paper's Fig. 2 scenario rendered as gradient accumulation: each
    microbatch has a compute task (fwd+bwd) and a comm task (its gradient
    bucket's DP all-reduce).  Compute tasks chain (sequential on the device);
    comm_i depends on compute_i; the optimizer update depends on all comms.
    Under ``history`` scheduling the comms serialize after the computes;
    under ``hybrid`` each comm overlaps the next microbatch's compute."""
    g = TaskGraph(name)
    prev = None
    comms = []
    for i in range(n_microbatches):
        deps = [prev] if prev is not None else []
        c = g.add(name=f"mb{i}.compute", kind="compute", cost=compute_cost, deps=deps)
        r = g.add(name=f"mb{i}.allreduce", kind="comm", cost=comm_cost, deps=[c])
        comms.append(r)
        prev = c
    g.add(name="optimizer.update", kind="compute", cost=compute_cost * 0.1, deps=comms)
    return g


def issue_offsets_from_schedule(sched: StaticSchedule, n_microbatches: int) -> List[int]:
    """Derive, for each microbatch's gradient bucket, how many microbatches
    later its all-reduce is issued (0 = immediately).  Consumed by the train
    step's bucketed grad-accumulation loop to realize the frozen overlap in
    XLA (the collective for bucket i is embedded in iteration i+offset)."""
    comm_start = {}
    compute_end = {}
    for it in sched.items:
        if it.name.endswith(".allreduce"):
            comm_start[int(it.name.split(".")[0][2:])] = it.t0
        elif it.name.endswith(".compute"):
            compute_end[int(it.name.split(".")[0][2:])] = it.t1
    offsets = []
    for i in range(n_microbatches):
        off = 0
        for j in range(i, n_microbatches):
            if comm_start.get(i, 0.0) <= compute_end.get(j, float("inf")) + 1e-12:
                off = j - i
                break
        else:
            off = n_microbatches - 1 - i
        offsets.append(off)
    return offsets
