"""Core: the paper's task-graph scheduling extensions.

Public API:

* :class:`TaskGraph` / :class:`Task` / :class:`ParallelSpec` — task graphs
  with nested data-parallel regions.
* :class:`Channel` / :class:`TaskEvent` / :class:`TaskFrame` — blocking
  communication primitives and suspendable task frames: generator task
  bodies suspend on ``yield ctx.recv(ch)`` / ``ctx.wait(ev)`` /
  ``ctx.yield_()`` without occupying a worker, and resume on any worker.
* :class:`Runtime` / :func:`run_graph` — the threaded gang-scheduling +
  work-stealing runtime (Algorithms 1 & 2, faithful reproduction).
* :class:`Simulator` / :func:`simulate` — deterministic discrete-event
  simulator of the same scheduler (oversubscription / gang / naive-ULT
  modes) for controlled experiments at scale.
* :class:`ListScheduler` / :class:`StaticSchedule` — frozen schedules for
  the SPMD/TPU execution path (wave decomposition, collective total order).
* victim policies: ``history`` / ``random`` / ``hybrid`` (Algorithm 2).
"""

from .gang import GangState, is_eligible_to_sched
from .policies import (
    HistoryPolicy,
    HybridPolicy,
    PolicyError,
    RandomPolicy,
    available_policies,
    make_policy,
    register_policy,
    resolve_policy,
)
from .runtime import Runtime, run_graph
from .simulator import DeadlockError, Simulator, simulate
from .static_schedule import (
    GangReservation,
    ListScheduler,
    StaticSchedule,
    issue_offsets_from_schedule,
    microbatch_overlap_graph,
)
from .taskgraph import (
    Channel,
    ChannelEmpty,
    ChannelFull,
    FrameResume,
    ParallelSpec,
    Task,
    TaskContext,
    TaskEvent,
    TaskFrame,
    TaskGraph,
    WaitAnyRequest,
)
from .tracing import Trace

__all__ = [
    "Channel",
    "ChannelEmpty",
    "ChannelFull",
    "DeadlockError",
    "FrameResume",
    "GangReservation",
    "GangState",
    "HistoryPolicy",
    "HybridPolicy",
    "ListScheduler",
    "ParallelSpec",
    "PolicyError",
    "RandomPolicy",
    "Runtime",
    "Simulator",
    "StaticSchedule",
    "Task",
    "TaskContext",
    "TaskEvent",
    "TaskFrame",
    "TaskGraph",
    "Trace",
    "WaitAnyRequest",
    "available_policies",
    "is_eligible_to_sched",
    "issue_offsets_from_schedule",
    "make_policy",
    "microbatch_overlap_graph",
    "register_policy",
    "resolve_policy",
    "run_graph",
    "simulate",
]
