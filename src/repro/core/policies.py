"""Victim-selection policies (paper Algorithm 2 and baselines).

The paper's hybrid policy keeps, per worker, a fixed-size circular *history
array* ``prev_victim_id`` and a cursor ``history_idx``:

* ``select_victim``: if the entry under the cursor holds a valid victim id,
  steal from it (history); otherwise pick a uniformly random victim.
* after a **successful** steal the entry is set to the victim and the cursor
  advances — the next attempt lands on a (typically empty ⇒ random) slot, so
  a success is followed by a random probe;
* after a **failed** steal the entry is invalidated and the cursor moves
  back — landing on the slot of the latest success, so failures retry the
  last productive victim.

The alternation is what creates communication/computation overlap across
sibling subtrees (paper Fig. 2) while the retreat-on-failure preserves
locality.  ``HistoryPolicy`` is the classical steal-from-last-success
baseline (what LLVM OMP effectively does); ``RandomPolicy`` is the pure
random baseline.  All policies are deterministic given their ``seed`` so the
simulator and the benchmarks are reproducible.
"""

from __future__ import annotations

import random
from typing import Callable, Dict, List, Optional, Type


class PolicyError(ValueError):
    """An unknown victim-policy name (carries the valid options)."""


class VictimPolicy:
    """Per-worker victim selection state machine."""

    name = "base"

    def __init__(self, worker_id: int, n_workers: int, seed: int = 0):
        self.worker_id = worker_id
        self.n_workers = n_workers
        self.rng = random.Random((seed << 20) ^ (worker_id * 0x9E3779B1))

    def _rand_victim(self) -> int:
        """Random victim excluding self (a worker never steals from itself)."""
        if self.n_workers <= 1:
            return self.worker_id
        v = self.rng.randrange(self.n_workers - 1)
        return v if v < self.worker_id else v + 1

    def select(self) -> int:
        raise NotImplementedError

    def record(self, victim: int, success: bool) -> None:
        raise NotImplementedError

    def observe(self, metrics: dict) -> None:
        """Cross-run feedback hook (flight-recorder data plumbing).

        After every traced run the dispatch feeds each worker's policy the
        assembled :meth:`repro.obs.RuntimeTrace.metrics` dict — notably
        ``steal_by_victim`` (per-victim ``[attempts, hits]`` histograms)
        and ``resume_latency`` — so a stats-driven policy can adapt across
        a session's (or a :class:`~repro.replay.pool.ReplayPool` entry's)
        lifetime.  The built-in paper policies ignore it; custom policies
        registered via :func:`register_policy` override this."""

    def clone_for(self, worker_id: int) -> "VictimPolicy":
        return type(self)(worker_id, self.n_workers, self._seed)


class RandomPolicy(VictimPolicy):
    name = "random"

    def __init__(self, worker_id: int, n_workers: int, seed: int = 0):
        super().__init__(worker_id, n_workers, seed)
        self._seed = seed

    def select(self) -> int:
        return self._rand_victim()

    def record(self, victim: int, success: bool) -> None:
        pass


class HistoryPolicy(VictimPolicy):
    """Classical history heuristic: keep stealing from the last successful
    victim until a steal from it fails, then probe randomly."""

    name = "history"

    def __init__(self, worker_id: int, n_workers: int, seed: int = 0):
        super().__init__(worker_id, n_workers, seed)
        self._seed = seed
        self.last_victim: int = -1

    def select(self) -> int:
        if self.last_victim >= 0:
            return self.last_victim
        return self._rand_victim()

    def record(self, victim: int, success: bool) -> None:
        self.last_victim = victim if success else -1


class HybridPolicy(VictimPolicy):
    """Paper Algorithm 2 — alternating history / random within a fixed
    circular window."""

    name = "hybrid"

    def __init__(self, worker_id: int, n_workers: int, seed: int = 0, window: int = 8):
        super().__init__(worker_id, n_workers, seed)
        self._seed = seed
        self.window = window
        self.prev_victim_id: List[int] = [-1] * window
        self.history_idx = 0

    def select(self) -> int:
        cur = self.prev_victim_id[self.history_idx % self.window]
        if cur >= 0:
            return cur
        return self._rand_victim()

    def record(self, victim: int, success: bool) -> None:
        cur_idx = self.history_idx % self.window
        if success:
            self.prev_victim_id[cur_idx] = victim
            self.history_idx = (self.history_idx + 1) % self.window
        else:
            self.prev_victim_id[cur_idx] = -1
            self.history_idx = (self.history_idx - 1) % self.window

    def clone_for(self, worker_id: int) -> "HybridPolicy":
        return HybridPolicy(worker_id, self.n_workers, self._seed, self.window)


class FrameAwarePolicy(HybridPolicy):
    """Stats-driven hybrid: the paper's alternating history/random machine,
    with the *random* probe replaced by a deterministic walk over victims
    ranked from flight-recorder feedback.

    :meth:`observe` (fed each traced run's
    :meth:`~repro.obs.RuntimeTrace.metrics`) ranks the other workers by

    * ``frame_resumes_by_worker`` — a worker that executes many frame
      resume segments hosts suspended continuations: its queue refills as
      channels are fed, so it is a durable steal target even when a random
      probe of it once failed;
    * per-victim steal hit rate (``steal_by_victim``) as the tie-break.

    Until the first observation (or when the trace saw no resumes and no
    steals) it behaves exactly like :class:`HybridPolicy`.  The walk is
    round-robin over the ranked list, so successive probes spread over the
    productive victims instead of hammering one — and the policy stays
    deterministic given its seed and its observation history.
    """

    name = "frame_hybrid"

    def __init__(self, worker_id: int, n_workers: int, seed: int = 0,
                 window: int = 8):
        super().__init__(worker_id, n_workers, seed, window)
        self._pref: List[int] = []
        self._pref_idx = 0

    def observe(self, metrics: dict) -> None:
        resumes = metrics.get("frame_resumes_by_worker") or {}
        by_victim = metrics.get("steal_by_victim") or {}
        ranked: List[tuple] = []
        for v in range(self.n_workers):
            if v == self.worker_id:
                continue
            # trace metrics carry int keys; JSON round-trips stringify them
            res = int(resumes.get(v, resumes.get(str(v), 0)))
            att, hits = by_victim.get(v, by_victim.get(str(v), (0, 0)))
            rate = (hits / att) if att else 0.0
            if res > 0 or hits > 0:
                ranked.append((-res, -rate, v))
        self._pref = [v for _, _, v in sorted(ranked)]
        self._pref_idx = 0

    def _rand_victim(self) -> int:
        if self._pref:
            v = self._pref[self._pref_idx % len(self._pref)]
            self._pref_idx += 1
            return v
        return super()._rand_victim()

    def clone_for(self, worker_id: int) -> "FrameAwarePolicy":
        return FrameAwarePolicy(worker_id, self.n_workers, self._seed,
                                self.window)


#: The validated policy registry.  Every entry point that accepts a
#: ``policy: str`` (``Session``, ``run_graph``, ``Runtime``, ``ReplayPool``,
#: the simulator) resolves the name here, so a typo fails at the API
#: boundary with the list of valid names instead of deep in dispatch.
POLICIES: Dict[str, Type[VictimPolicy]] = {
    "random": RandomPolicy,
    "history": HistoryPolicy,
    "hybrid": HybridPolicy,
    "frame_hybrid": FrameAwarePolicy,
}


def available_policies() -> List[str]:
    """Sorted names of every registered victim policy."""
    return sorted(POLICIES)


def register_policy(
    name: str, cls: Optional[Type[VictimPolicy]] = None,
) -> Callable[[Type[VictimPolicy]], Type[VictimPolicy]]:
    """Register a :class:`VictimPolicy` subclass under ``name`` (usable as a
    decorator).  Registered policies become valid ``policy=`` arguments
    everywhere a built-in name is."""
    def _register(c: Type[VictimPolicy]) -> Type[VictimPolicy]:
        if not (isinstance(c, type) and issubclass(c, VictimPolicy)):
            raise TypeError(f"{c!r} is not a VictimPolicy subclass")
        POLICIES[name] = c
        return c
    return _register(cls) if cls is not None else _register


def resolve(name: str) -> Type[VictimPolicy]:
    """Resolve a policy name to its class, or raise :class:`PolicyError`
    naming the valid choices.  The single validation point the session API
    and the legacy entry points share."""
    try:
        return POLICIES[name]
    except (KeyError, TypeError):
        raise PolicyError(
            f"unknown victim policy {name!r}; valid policies: "
            f"{', '.join(available_policies())}") from None


#: Package-level alias (``repro.core.resolve_policy``): ``resolve`` reads
#: naturally as ``policies.resolve`` at the module level.
resolve_policy = resolve


def make_policy(name: str, worker_id: int, n_workers: int, seed: int = 0) -> VictimPolicy:
    return resolve(name)(worker_id, n_workers, seed)
