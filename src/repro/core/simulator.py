"""Discrete-event simulator of the paper's scheduler.

Runs a :class:`~repro.core.taskgraph.TaskGraph` on ``n_workers`` virtual
workers under a victim-selection policy (Algorithm 2) and one of three
nested-parallel-region modes:

* ``gang``          — the paper: regions are gang-scheduled onto reserved
                      workers (Algorithm 1); gang ULTs are stealable by
                      eligible workers; barriers are safe by construction.
* ``oversubscribe`` — the LLVM-OMP baseline: each nested region brings its
                      own thread pool; its threads timeshare the cores near
                      the spawner (processor-sharing approximation plus a
                      per-phase context-switch penalty).
* ``ult_naive``     — ULTs multiplexed on workers with *blocking* barriers
                      and no gang coordination (paper Fig. 1a): the sim
                      detects the resulting deadlock and raises
                      :class:`DeadlockError`.

Virtual time is event-driven; all randomness comes from the policy seeds, so
runs are reproducible.  The output is a :class:`~repro.core.tracing.Trace`
(makespan, per-kind breakdowns) — the substrate for the Fig. 7/8/9/11
benchmark analogues.
"""

from __future__ import annotations

import dataclasses
import heapq
import itertools
from collections import deque
from typing import Any, Deque, Dict, List, Optional, Tuple

from .gang import GangState, is_eligible_to_sched
from .policies import VictimPolicy, make_policy
from .taskgraph import ParallelSpec, Task, TaskGraph
from .tracing import KIND_BARRIER, KIND_COMM, Trace


class DeadlockError(RuntimeError):
    """All workers are blocked on barriers while runnable ULTs remain —
    the paper's Fig. 1 scenario."""


@dataclasses.dataclass
class _Region:
    rid: int
    gang_id: int          # -1 when not gang-scheduled
    nest_level: int
    spec: ParallelSpec
    spawn_task: Optional[Task]
    spawn_worker: int
    kind: str
    arrived: List[int] = dataclasses.field(default_factory=list)
    parked: List[List["_ULTJob"]] = dataclasses.field(default_factory=list)
    done_threads: int = 0

    def __post_init__(self):
        n_phases = max(1, self.spec.n_barriers)
        self.arrived = [0] * n_phases
        self.parked = [[] for _ in range(n_phases)]

    @property
    def n_phases(self) -> int:
        return max(1, self.spec.n_barriers)


@dataclasses.dataclass
class _ULTJob:
    region: _Region
    thread_num: int
    phase: int = 0
    worker: int = -1        # worker currently running / last ran this ULT
    park_t: float = 0.0

    @property
    def gang_id(self) -> int:
        return self.region.gang_id

    @property
    def nest_level(self) -> int:
        return self.region.nest_level

    @property
    def name(self) -> str:
        return f"r{self.region.rid}.t{self.thread_num}.p{self.phase}"


class _Worker:
    __slots__ = ("wid", "local", "gang_deq", "suspended", "policy", "context",
                 "blocked", "co_resident", "fail_streak", "busy_until",
                 "last_family")

    def __init__(self, wid: int, policy: VictimPolicy):
        self.wid = wid
        self.local: Deque[Task] = deque()
        self.gang_deq: Deque[_ULTJob] = deque()
        self.suspended: Deque[Task] = deque()
        self.policy = policy
        self.context: List[Tuple[int, int]] = []   # (gang_id, nest_level) stack
        self.blocked = False
        self.co_resident = 0
        self.fail_streak = 0
        self.busy_until = 0.0
        self.last_family = None

    @property
    def cur_gang_id(self) -> int:
        return self.context[-1][0] if self.context else -1

    @property
    def nest_level(self) -> int:
        return self.context[-1][1] if self.context else 0

    def has_queued(self) -> bool:
        return bool(self.local or self.gang_deq or self.suspended)


# event kinds in the heap: ("w", worker_id) dispatch, ("c", cont_id) continuation
class Simulator:
    def __init__(
        self,
        n_workers: int,
        *,
        ranks: int = 1,
        policy: str = "hybrid",
        mode: str = "gang",
        seed: int = 0,
        steal_latency: float = 2e-6,
        ctx_switch: float = 5e-6,
        fork_overhead: float = 2e-6,
        respect_priority: bool = False,
        locality_penalty: float = 0.10,
        trace: bool = True,
    ):
        if mode not in ("gang", "oversubscribe", "ult_naive"):
            raise ValueError(f"unknown mode {mode!r}")
        if n_workers % ranks != 0:
            raise ValueError(f"n_workers={n_workers} not divisible by ranks={ranks}")
        self.n_workers = n_workers
        # MPI-rank partitioning: workers are split into `ranks` pools; work
        # stealing and gang reservation stay within a pool, and tasks pinned
        # via meta['rank'] are enqueued on their rank's pool (the paper's
        # multi-rank SLATE runs: 2-4 ranks/node x 10-20 threads/rank).
        self.ranks = ranks
        self.rank_width = n_workers // ranks
        self.mode = mode
        self.policy_name = policy
        self.seed = seed
        self.steal_latency = steal_latency
        self.ctx_switch = ctx_switch
        self.fork_overhead = fork_overhead
        # LLVM OMP (the paper's baseline) ignores the OpenMP `priority`
        # clause — "supported by only a few OpenMP runtime systems such as
        # GNU OpenMP" (paper §5.1) — so plain LIFO is the default.
        self.respect_priority = respect_priority
        # data-locality model: sibling tasks of one family (same kind+step,
        # e.g. trailing children of one step sharing the panel column in
        # cache) run at full speed back-to-back; switching families on a
        # worker pays a cold-cache penalty.  This is the locality term that
        # makes pure-random stealing lose (paper §3.2: "random stealing,
        # however, suffers from a loss of data locality").
        self.locality_penalty = locality_penalty
        self.trace_enabled = trace

    # ------------------------------------------------------------------
    def run(self, graph: TaskGraph) -> Trace:
        graph.validate()
        self.graph = graph
        self.trace = Trace(self.n_workers)
        # victim policies operate on local (within-rank) worker ids
        self.workers = [
            _Worker(w, make_policy(self.policy_name, w % self.rank_width,
                                   self.rank_width, self.seed + 1000 * (w // self.rank_width)))
            for w in range(self.n_workers)
        ]
        # per-rank gang state: reservations never cross rank pools
        self.gang_states = [GangState(self.rank_width) for _ in range(self.ranks)]
        self.gang_state = self.gang_states[0]  # back-compat alias (ranks=1)
        self.indeg = graph.indegrees()
        self.remaining = len(graph)
        # declarative resources: virtual holder counters + a FIFO of
        # deferred (task, worker, defer_t) waiters.  A deferred task costs
        # the *task* time, not the worker (the worker moves on — the
        # arbiter's work-conserving contract); the wait surfaces in the
        # trace as a barrier-kind span labelled "res:<task>".
        from ..resources.arbiter import task_needs
        self._res_needs = {
            t.tid: task_needs(graph, t.tid) for t in graph.tasks
            if getattr(t, "uses", ()) or getattr(t, "uses_shared", ())}
        n_res = len(getattr(graph, "resources", ()))
        self._res_excl = [0] * n_res
        self._res_shared = [0] * n_res
        self._res_caps = [r.capacity for r in getattr(graph, "resources", ())]
        self._res_held: Dict[int, Any] = {}
        self._res_wait: List[Tuple[Task, int, float]] = []
        # gang reservations in fork order: (spawn_tid, gang_id, workers, t)
        # — consumed by ListScheduler to synthesize replayable placements
        self.gang_log: List[Tuple[int, int, List[int], float]] = []
        self._region_ids = itertools.count()
        self._seq = itertools.count()
        self._heap: List[Tuple[float, int, Tuple[str, int]]] = []
        self._conts: Dict[int, Tuple[_Worker, _ULTJob]] = {}
        self._next_cont = itertools.count()

        # Roots are created by each rank's master thread => lead worker's
        # local queue (this is what makes history serialization observable).
        for t in graph.roots():
            r = t.meta.get("rank") or 0
            self.workers[r * self.rank_width].local.append(t)

        self._actions: Dict[int, Any] = {}
        self._next_action = itertools.count()

        now = 0.0
        for w in range(self.n_workers):
            self._event(0.0, ("w", w))

        guard, max_events = 0, 500 * (len(graph) + 8) * max(1, self.n_workers) + 500_000
        while self._heap and self.remaining > 0:
            guard += 1
            if guard > max_events:
                raise RuntimeError("simulator exceeded event budget (livelock?)")
            now, _, (ekind, arg) = heapq.heappop(self._heap)
            if ekind == "w":
                self._dispatch(self.workers[arg], now)
            elif ekind == "a":
                self._actions.pop(arg)(now)
            else:
                w, ult = self._conts.pop(arg)
                self._arrive_barrier(w, ult, now)
            if self.remaining > 0 and not self._heap:
                self._deadlock_check(now, final=True)
        if self.remaining > 0:
            self._deadlock_check(now, final=True)
        return self.trace

    # ------------------------------------------------------------------
    def _event(self, t: float, payload: Tuple[str, int]) -> None:
        heapq.heappush(self._heap, (t, next(self._seq), payload))

    def _cont(self, t: float, w: _Worker, ult: _ULTJob) -> None:
        cid = next(self._next_cont)
        self._conts[cid] = (w, ult)
        self._event(t, ("c", cid))

    def _action(self, t: float, fn) -> None:
        aid = next(self._next_action)
        self._actions[aid] = fn
        self._event(t, ("a", aid))

    def _record(self, w: int, t0: float, t1: float, kind: str, label: str = "") -> None:
        if self.trace_enabled and t1 > t0:
            self.trace.record(w, t0, t1, kind, label)

    # -- dispatch: the scheduling-point logic ---------------------------
    def _dispatch(self, w: _Worker, now: float) -> None:
        if w.blocked or self.remaining == 0:
            return
        if now < w.busy_until - 1e-15:
            return  # stale wake-up while executing; completion event follows
        job = self._next_job(w)
        if job is None:
            w.fail_streak += 1
            backoff = self.steal_latency * min(64, w.fail_streak)
            self._event(now + backoff, ("w", w.wid))
            return
        w.fail_streak = 0
        if isinstance(job, Task):
            self._run_task(w, job, now)
        else:
            self._run_ult_phase(w, job, now)

    def _next_job(self, w: _Worker):
        # priority: suspended > own gang deque (eligible) > local > steal
        if w.suspended:
            return w.suspended.popleft()
        g = self._pop_gang(w, w)
        if g is not None:
            return g
        if w.local:
            return self._pop_local(w)
        return self._steal(w)

    def _pop_local(self, w: _Worker) -> Task:
        if not self.respect_priority:
            return w.local.pop()        # plain LIFO (LLVM OMP semantics)
        # priority-clause support: scan a bounded window from the newest end
        best_i, best_p = len(w.local) - 1, w.local[-1].priority
        for i in range(len(w.local) - 1, max(-1, len(w.local) - 9), -1):
            if w.local[i].priority > best_p:
                best_i, best_p = i, w.local[i].priority
        t = w.local[best_i]
        del w.local[best_i]
        return t

    def _pop_gang(self, thief: _Worker, victim: _Worker) -> Optional[_ULTJob]:
        """FIFO pop of the victim's gang deque, subject to Algorithm 1's
        eligibility predicate evaluated against the *thief*."""
        if not victim.gang_deq:
            return None
        head = victim.gang_deq[0]
        if is_eligible_to_sched(head.gang_id, head.nest_level, thief.cur_gang_id, thief.nest_level):
            return victim.gang_deq.popleft()
        return None

    def _steal(self, w: _Worker):
        local_victim = w.policy.select()
        victim_id = (w.wid // self.rank_width) * self.rank_width + local_victim
        victim = self.workers[victim_id]
        job: Any = None
        if victim_id != w.wid:
            job = self._pop_gang(w, victim)       # gang ULTs: highest steal priority
            if job is None and victim.local:
                job = victim.local.popleft()      # FIFO side (oldest = biggest subtree)
        w.policy.record(local_victim, job is not None)
        return job

    def _deadlock_check(self, now: float, final: bool = False) -> None:
        blocked = sum(1 for w in self.workers if w.blocked)
        queued = sum(len(w.local) + len(w.gang_deq) + len(w.suspended) for w in self.workers)
        if self.remaining > 0 and blocked > 0 and blocked == self.n_workers:
            raise DeadlockError(
                f"t={now:.6f}: all {blocked} workers blocked at barriers, "
                f"{queued} runnable ULTs/tasks starved, {self.remaining} tasks unfinished"
            )
        if final and self.remaining > 0:
            if blocked > 0:
                raise DeadlockError(
                    f"t={now:.6f}: {blocked}/{self.n_workers} workers blocked at barriers "
                    f"with no waking event; {self.remaining} tasks unfinished"
                )
            raise RuntimeError(
                f"simulation stalled at t={now:.6f} with {self.remaining} tasks unfinished"
            )

    # -- declarative resources -------------------------------------------
    def _res_available(self, needs) -> bool:
        for rindex, shared in needs:
            if shared:
                if self._res_excl[rindex] > 0:
                    return False
            elif (self._res_shared[rindex] > 0
                    or self._res_excl[rindex] >= self._res_caps[rindex]):
                return False
        return True

    def _res_grant(self, tid: int, needs) -> None:
        for rindex, shared in needs:
            if shared:
                self._res_shared[rindex] += 1
            else:
                self._res_excl[rindex] += 1
        self._res_held[tid] = needs

    def _res_release(self, task: Task, t: float) -> None:
        """Free a completing holder's resources and grant deferred waiters
        in FIFO order (a blocked earlier waiter shadows later overlapping
        ones — the arbiter's fairness rule), re-queueing each granted task
        on its deferring worker."""
        needs = self._res_held.pop(task.tid, None)
        if needs is None:
            return
        for rindex, shared in needs:
            if shared:
                self._res_shared[rindex] -= 1
            else:
                self._res_excl[rindex] -= 1
        if not self._res_wait:
            return
        shadow: set = set()
        still: List[Tuple[Task, int, float]] = []
        for waiter, wid, t0 in self._res_wait:
            wneeds = self._res_needs[waiter.tid]
            if (not any(r in shadow for r, _ in wneeds)
                    and self._res_available(wneeds)):
                self._res_grant(waiter.tid, wneeds)
                self._record(wid, t0, t, KIND_BARRIER, f"res:{waiter.name}")
                self.workers[wid].local.append(waiter)
                self._event(t, ("w", wid))
            else:
                still.append((waiter, wid, t0))
                shadow.update(r for r, _ in wneeds)
        self._res_wait = still

    # -- graph tasks ------------------------------------------------------
    def _run_task(self, w: _Worker, task: Task, now: float) -> None:
        needs = self._res_needs.get(task.tid)
        if needs is not None and task.tid not in self._res_held:
            mine = {r for r, _ in needs}
            overtakes = any(         # FIFO fairness: no overtaking an
                r in mine            # earlier waiter on a shared resource
                for wt, _, _ in self._res_wait
                for r, _ in self._res_needs[wt.tid])
            if overtakes or not self._res_available(needs):
                self._res_wait.append((task, w.wid, now))
                self._event(now, ("w", w.wid))   # worker stays work-conserving
                return
            self._res_grant(task.tid, needs)
        dur = task.cost
        if self.mode == "oversubscribe" and w.co_resident > 0:
            dur = dur * (1 + w.co_resident) + self.ctx_switch * w.co_resident
        if self.locality_penalty and task.kind != KIND_COMM:
            family = (task.kind, task.meta.get("step"))
            if w.last_family is not None and family != w.last_family:
                dur *= 1.0 + self.locality_penalty
            w.last_family = family
        end = now + dur
        self._record(w.wid, now, end, task.kind, task.name)
        w.busy_until = end

        def _finish(t: float, w=w, task=task) -> None:
            if task.parallel is not None and task.parallel.n_threads > 0:
                self._fork_region(w, task, t)
            else:
                self._complete_task(w, task, t)
            self._event(t, ("w", w.wid))

        self._action(end, _finish)

    def _complete_task(self, w: _Worker, task: Task, t: float) -> None:
        self.remaining -= 1
        self._res_release(task, t)
        my_rank = w.wid // self.rank_width
        for s in self.graph.successors(task):
            self.indeg[s.tid] -= 1
            if self.indeg[s.tid] == 0:
                r = s.meta.get("rank")
                if r is None or r == my_rank:
                    w.local.append(s)   # ready tasks go to the resolving worker
                else:
                    # cross-rank readiness (an MPI message landing): enqueue
                    # on the destination rank's lead worker
                    dst = self.workers[r * self.rank_width]
                    dst.local.append(s)
                    self._event(t, ("w", dst.wid))

    # -- nested parallel regions -----------------------------------------
    def _fork_region(self, w: _Worker, task: Task, t: float) -> None:
        spec = task.parallel
        assert spec is not None
        gang = spec.gang if spec.gang is not None else (self.mode == "gang")
        region = _Region(
            rid=next(self._region_ids),
            gang_id=-1,
            nest_level=w.nest_level + 1,
            spec=spec,
            spawn_task=task,
            spawn_worker=w.wid,
            kind=task.kind,
        )
        n = spec.n_threads
        if self.mode == "gang" and gang:
            # Algorithm 1: GANG_SCHED under the fork lock (per-rank pool)
            rank = w.wid // self.rank_width
            gs = self.gang_states[rank]
            region.gang_id = gs.next_gang_id() + rank * 1_000_000
            reserved = gs.get_workers(w.wid % self.rank_width, n)
            gs.account_gang([reserved[i % len(reserved)] for i in range(n)])
            base = rank * self.rank_width
            members = [base + reserved[i % len(reserved)] for i in range(n)]
            self.gang_log.append((task.tid, region.gang_id, members, t))
            for i in range(n):
                target = self.workers[members[i]]
                target.gang_deq.append(_ULTJob(region, i))
                self._event(t + self.fork_overhead, ("w", target.wid))
        elif self.mode == "oversubscribe":
            # fresh thread pool co-resident on cores near the spawner
            for i in range(n):
                core = self.workers[(w.wid + i) % self.n_workers]
                core.co_resident += 1
                ult = _ULTJob(region, i, worker=core.wid)
                self._start_oversubscribed_phase(core, ult, t + self.fork_overhead)
        else:
            # ult_naive (or explicitly non-gang regions): ULTs queue on the
            # spawner as stealable work — Fig. 1 hazard if blocking.
            for i in range(n):
                w.gang_deq.append(_ULTJob(region, i))
            self._event(t, ("w", w.wid))

    def _phase_cost(self, region: _Region) -> float:
        return region.spec.cost_per_thread / region.n_phases

    # -- ULT execution: gang / ult_naive paths -----------------------------
    def _run_ult_phase(self, w: _Worker, ult: _ULTJob, now: float) -> None:
        region = ult.region
        ult.worker = w.wid
        w.context.append((region.gang_id, region.nest_level))
        end = now + self._phase_cost(region)
        self._record(w.wid, now, end, region.kind, ult.name)
        w.busy_until = end
        w.context.pop()
        self._cont(end, w, ult)

    def _arrive_barrier(self, w: _Worker, ult: _ULTJob, t: float) -> None:
        region = ult.region
        phase = ult.phase
        region.arrived[phase] += 1
        if region.arrived[phase] == region.spec.n_threads:
            parked = region.parked[phase]
            region.parked[phase] = []
            for p in parked:
                self._wake_parked(p, t)
            self._advance_ult(self.workers[ult.worker], ult, t)
        else:
            region.parked[phase].append(ult)
            ult.park_t = t
            if self.mode == "ult_naive" and region.spec.blocking:
                # blocking barrier on a kernel thread: the worker spins
                w.blocked = True
                self._deadlock_check(t)
            else:
                # cooperative barrier / gang join point: worker schedules
                # other eligible work (paper's scheduling point)
                self._event(t, ("w", w.wid))

    def _wake_parked(self, ult: _ULTJob, t: float) -> None:
        region = ult.region
        w = self.workers[ult.worker]
        self._record(w.wid, ult.park_t, t, KIND_BARRIER, ult.name)
        if self.mode == "ult_naive" and region.spec.blocking:
            w.blocked = False
        self._advance_ult(w, ult, t)

    def _advance_ult(self, w: _Worker, ult: _ULTJob, t: float) -> None:
        region = ult.region
        ult.phase += 1
        if ult.phase >= region.n_phases:
            self._finish_ult(w, ult, t)
            self._event(t, ("w", w.wid))
            return
        if self.mode == "oversubscribe":
            self._start_oversubscribed_phase(w, ult, t)
        elif self.mode == "ult_naive" and region.spec.blocking:
            # continue next phase in place on the (just-woken) worker
            end = t + self._phase_cost(region)
            self._record(w.wid, t, end, region.kind, ult.name)
            self._cont(end, w, ult)
        else:
            # gang / cooperative: re-enqueue at the front of this worker's
            # gang deque (locality); eligible workers may steal it.
            w.gang_deq.appendleft(ult)
            self._event(t, ("w", w.wid))

    def _finish_ult(self, w: _Worker, ult: _ULTJob, t: float) -> None:
        region = ult.region
        region.done_threads += 1
        if self.mode == "oversubscribe":
            core = self.workers[ult.worker]
            core.co_resident = max(0, core.co_resident - 1)
        if region.gang_id >= 0:
            rank = w.wid // self.rank_width
            self.gang_states[rank].release_gang_thread(w.wid % self.rank_width)
        if region.done_threads == region.spec.n_threads:
            if region.spawn_task is not None:
                self._complete_task(self.workers[region.spawn_worker], region.spawn_task, t)
                self._event(t, ("w", region.spawn_worker))

    # -- oversubscribe path -------------------------------------------------
    def _start_oversubscribed_phase(self, core: _Worker, ult: _ULTJob, t: float) -> None:
        region = ult.region
        share = max(1, core.co_resident)
        busy_now = 1 if core.busy_until > t else 0
        dur = self._phase_cost(region) * (share + busy_now) \
            + self.ctx_switch * max(0, share + busy_now - 1)
        end = t + dur
        self._record(core.wid, t, end, region.kind, ult.name)
        self._cont(end, core, ult)


def simulate(
    graph: TaskGraph,
    n_workers: int,
    *,
    policy: str = "hybrid",
    mode: str = "gang",
    seed: int = 0,
    **kw: Any,
) -> Trace:
    """One-shot convenience wrapper."""
    return Simulator(n_workers, policy=policy, mode=mode, seed=seed, **kw).run(graph)
