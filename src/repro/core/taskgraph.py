"""Task-graph representation.

A :class:`TaskGraph` is a DAG of :class:`Task` nodes.  Tasks carry

* an optional callable ``fn(ctx)`` executed by the runtime (``ctx`` is a
  :class:`TaskContext` giving access to predecessor results and to the
  runtime's parallel-region primitives),
* an analytical ``cost`` (seconds) used by the discrete-event simulator and
  the static list scheduler,
* a ``kind`` tag (``compute`` / ``comm`` / ``panel`` / ...) used by cost
  models and by the critical-path breakdown figures,
* an optional ``parallel`` spec describing a nested data-parallel region the
  task spawns (the gang-scheduling target of the paper).

Dependencies are explicit (OpenMP ``depend``-style, resolved by the runtime)
— the graph is static; readiness is dynamic.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Callable, Dict, Iterable, List, Optional, Sequence, Tuple


@dataclasses.dataclass
class ParallelSpec:
    """A nested data-parallel region spawned by a task.

    ``n_threads`` ULTs run ``body(tid, ctx)``.  ``blocking`` marks regions
    whose internal synchronization is *blocking* (the paper's Fig. 1 hazard:
    a custom library barrier that does not yield to the scheduler).  ``gang``
    requests gang scheduling for this region (the paper's
    ``ompx_set_gang_sched`` scope); ``None`` defers to the runtime default.
    ``cost_per_thread`` is the per-ULT cost for the simulator; ``n_barriers``
    is how many internal barrier rounds the region performs.
    """

    n_threads: int
    body: Optional[Callable[[int, "TaskContext"], Any]] = None
    blocking: bool = True
    gang: Optional[bool] = None
    cost_per_thread: float = 0.0
    n_barriers: int = 1


@dataclasses.dataclass
class Task:
    tid: int
    name: str
    fn: Optional[Callable[["TaskContext"], Any]] = None
    deps: Tuple[int, ...] = ()
    kind: str = "compute"
    cost: float = 1.0
    priority: int = 0
    parallel: Optional[ParallelSpec] = None
    meta: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def __hash__(self) -> int:  # identity by tid within a graph
        return hash(self.tid)


class TaskContext:
    """Handed to task bodies at execution time.

    Provides predecessor results (``ctx[dep_task]`` / ``ctx.result(tid)``)
    and, when run under the threaded runtime, the parallel-region primitives
    (``ctx.parallel`` / ``ctx.barrier``) used by gang-scheduled regions.
    """

    def __init__(self, graph: "TaskGraph", task: Task, results: Dict[int, Any], runtime: Any = None):
        self.graph = graph
        self.task = task
        self._results = results
        self.runtime = runtime

    def result(self, tid: int) -> Any:
        return self._results[tid]

    def parallel(self, n_threads: int, body, *, gang=None):
        """Fork/join a nested parallel region (delegates to the runtime;
        gang-scheduled by default — the paper's `ompx_set_gang_sched`)."""
        if self.runtime is None:
            # degenerate serial execution (no runtime): run inline
            class _SerialRegion:
                def barrier(self_inner):
                    pass
            region = _SerialRegion()
            return [body(i, region) for i in range(n_threads)]
        return self.runtime.parallel(n_threads, body, gang=gang, spawn_ctx=self)

    def __getitem__(self, task_or_tid) -> Any:
        tid = task_or_tid.tid if isinstance(task_or_tid, Task) else task_or_tid
        return self._results[tid]

    def dep_results(self) -> List[Any]:
        return [self._results[d] for d in self.task.deps]


class TaskGraph:
    """A static DAG of tasks with dependency bookkeeping."""

    def __init__(self, name: str = "graph"):
        self.name = name
        self.tasks: List[Task] = []
        self._succ: Dict[int, List[int]] = {}

    # -- construction -----------------------------------------------------
    def add(
        self,
        fn: Optional[Callable[[TaskContext], Any]] = None,
        *,
        deps: Sequence[Task] = (),
        name: Optional[str] = None,
        kind: str = "compute",
        cost: float = 1.0,
        priority: int = 0,
        parallel: Optional[ParallelSpec] = None,
        **meta: Any,
    ) -> Task:
        tid = len(self.tasks)
        dep_ids = tuple(d.tid if isinstance(d, Task) else int(d) for d in deps)
        for d in dep_ids:
            if d >= tid or d < 0:
                raise ValueError(f"dependency {d} of task {tid} is not an existing task")
        t = Task(
            tid=tid,
            name=name or f"{kind}:{tid}",
            fn=fn,
            deps=dep_ids,
            kind=kind,
            cost=float(cost),
            priority=priority,
            parallel=parallel,
            meta=dict(meta),
        )
        self.tasks.append(t)
        self._succ[tid] = []
        for d in dep_ids:
            self._succ[d].append(tid)
        return t

    # -- queries ----------------------------------------------------------
    def __len__(self) -> int:
        return len(self.tasks)

    def __iter__(self):
        return iter(self.tasks)

    def successors(self, task_or_tid) -> List[Task]:
        tid = task_or_tid.tid if isinstance(task_or_tid, Task) else task_or_tid
        return [self.tasks[s] for s in self._succ[tid]]

    def indegrees(self) -> List[int]:
        return [len(t.deps) for t in self.tasks]

    def roots(self) -> List[Task]:
        return [t for t in self.tasks if not t.deps]

    def topological_order(self) -> List[Task]:
        """Kahn topological order; raises on cycles (construction forbids
        them, this is a safety net for hand-built graphs)."""
        indeg = self.indegrees()
        frontier = [t.tid for t in self.tasks if indeg[t.tid] == 0]
        order: List[int] = []
        while frontier:
            tid = frontier.pop()
            order.append(tid)
            for s in self._succ[tid]:
                indeg[s] -= 1
                if indeg[s] == 0:
                    frontier.append(s)
        if len(order) != len(self.tasks):
            raise ValueError("task graph has a cycle")
        return [self.tasks[t] for t in order]

    def critical_path(self) -> Tuple[float, List[Task]]:
        """Longest path through the graph by task ``cost`` (a task spawning a
        parallel region contributes ``cost + cost_per_thread`` — the region
        runs to completion within the task from the graph's point of view).
        Returns ``(length_seconds, path_tasks)``."""
        order = self.topological_order()
        dist: Dict[int, float] = {}
        prev: Dict[int, Optional[int]] = {}
        for t in order:
            c = t.cost + (t.parallel.cost_per_thread if t.parallel else 0.0)
            best, arg = 0.0, None
            for d in t.deps:
                if dist[d] > best:
                    best, arg = dist[d], d
            dist[t.tid] = best + c
            prev[t.tid] = arg
        end = max(dist, key=lambda k: dist[k])
        path: List[Task] = []
        cur: Optional[int] = end
        while cur is not None:
            path.append(self.tasks[cur])
            cur = prev[cur]
        return dist[end], list(reversed(path))

    def total_work(self) -> float:
        return sum(
            t.cost + (t.parallel.n_threads * t.parallel.cost_per_thread if t.parallel else 0.0)
            for t in self.tasks
        )

    def validate(self) -> None:
        self.topological_order()

    def subgraph_kinds(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for t in self.tasks:
            out[t.kind] = out.get(t.kind, 0) + 1
        return out
