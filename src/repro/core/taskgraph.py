"""Task-graph representation.

A :class:`TaskGraph` is a DAG of :class:`Task` nodes.  Tasks carry

* an optional callable ``fn(ctx)`` executed by the runtime (``ctx`` is a
  :class:`TaskContext` giving access to predecessor results and to the
  runtime's parallel-region primitives),
* an analytical ``cost`` (seconds) used by the discrete-event simulator and
  the static list scheduler,
* a ``kind`` tag (``compute`` / ``comm`` / ``panel`` / ...) used by cost
  models and by the critical-path breakdown figures,
* an optional ``parallel`` spec describing a nested data-parallel region the
  task spawns (the gang-scheduling target of the paper).

Dependencies are explicit (OpenMP ``depend``-style, resolved by the runtime)
— the graph is static; readiness is dynamic.

Suspendable task frames
-----------------------

Task bodies may be written as *generators*; the runtime then compiles them
into resumable :class:`TaskFrame`\\ s (the paper's ULT-style suspension,
§III): yielding one of the :class:`TaskContext` communication requests —
``yield ctx.recv(channel)`` / ``yield ctx.wait(event)`` /
``yield ctx.yield_()`` — parks the frame on a waitlist *without occupying a
worker thread*, and a matching :meth:`Channel.send` / :meth:`TaskEvent.set`
makes it resumable on any worker.  Plain (non-generator) bodies may call the
same APIs; they block their kernel thread work-conservingly (the worker
keeps scheduling other tasks at the blocking point) since Python cannot
switch ULT stacks.  :class:`Channel` and :class:`TaskEvent` are the
communication primitives; :class:`FrameResume` is the run-list entry type
the record-and-replay subsystem uses to reproduce frame interleavings.
"""

from __future__ import annotations

import dataclasses
import itertools
import threading
import weakref
from collections import deque
from typing import Any, Callable, Deque, Dict, List, Optional, Sequence, Tuple

from ..resources.handle import Resource


# ---------------------------------------------------------------------------
# communication primitives + suspendable frames
# ---------------------------------------------------------------------------

# Global activity epoch: bumped by every Channel.send / TaskEvent.set so the
# runtime's suspension-deadlock detectors can confirm "nothing changed" across
# their confirmation window even for sends that found no parked waiter (e.g. a
# send racing a plain-body ctx.recv poll loop).  This makes detection safe
# against senders racing the window — not against senders that stay silent
# past it: wakeups are expected to come from the run's own work.
_epoch_lock = threading.Lock()
_activity_epoch = 0

# Process-wide monotonic ids for communication primitives: names are user-
# chosen and may collide, so the flight recorder tags suspend/block events
# with the uid (``recv(chan)@c7``) to tell same-named channels apart.
_prim_uids = itertools.count()


def _bump_activity() -> None:
    global _activity_epoch
    with _epoch_lock:
        _activity_epoch += 1


def activity_epoch() -> int:
    with _epoch_lock:
        return _activity_epoch


class ChannelEmpty(Exception):
    """:meth:`Channel.recv_nowait` on an empty channel."""


class ChannelFull(Exception):
    """:meth:`Channel.send` on a full *bounded* channel.  Use
    :meth:`TaskContext.send` for backpressure: a frame body parks, a plain
    body blocks work-conservingly, until a receiver frees space."""


class Channel:
    """A multi-producer multi-consumer FIFO for task-internal communication.

    ``send`` on the default *unbounded* channel never blocks.  With
    ``capacity=N`` the channel is *bounded*: senders must pace themselves —
    ``ctx.send(ch, v)`` suspends a frame body (``yield ctx.send(ch, v)``)
    or blocks a plain body work-conservingly until a receiver frees a slot,
    and the raw :meth:`send` raises :class:`ChannelFull` instead of
    silently growing the buffer.

    Receiving goes through :meth:`TaskContext.recv`: a generator body
    suspends its frame until an item arrives (the worker keeps scheduling);
    a plain body blocks its kernel thread work-conservingly.  Delivery to
    parked frames happens under the channel lock, so a ``send`` racing a
    frame park can never be lost: either the parking side sees the item, or
    the sender sees the waiter.  On a bounded channel, a receive that frees
    a slot promotes the oldest parked *sender* (its value enters the buffer
    in park order); plain-body senders polling :meth:`try_send` may
    interleave with parked frame senders — FIFO fairness is per mechanism,
    not global.
    """

    __slots__ = ("name", "capacity", "uid", "_lock", "_items", "_waiters",
                 "_send_waiters")

    def __init__(self, name: str = "channel", capacity: Optional[int] = None):
        if capacity is not None and capacity < 1:
            raise ValueError(f"channel capacity must be >= 1, got {capacity}")
        self.name = name
        self.capacity = capacity
        self.uid = next(_prim_uids)
        self._lock = threading.Lock()
        self._items: Deque[Any] = deque()
        self._waiters: Deque[Callable[[Any], None]] = deque()
        # parked frame senders of a bounded channel: (waker, value) pairs
        self._send_waiters: Deque[Tuple[Callable[[Any], None], Any]] = deque()

    def send(self, value: Any) -> None:
        """Non-suspending send.  Bounded channels raise :class:`ChannelFull`
        when no slot (and no parked receiver) is available — backpressure
        needs the scheduler, so it lives in :meth:`TaskContext.send`."""
        if not self.try_send(value):
            raise ChannelFull(
                f"channel {self.name!r} is full (capacity {self.capacity}); "
                "use ctx.send(channel, value) so the sender can suspend")

    def try_send(self, value: Any) -> bool:
        """Attempt a send without waiting; False when the channel is full."""
        with self._lock:
            waiter = self._waiters.popleft() if self._waiters else None
            if waiter is None:
                if (self.capacity is not None
                        and len(self._items) >= self.capacity):
                    return False
                self._items.append(value)
        _bump_activity()
        if waiter is not None:
            waiter(value)
        return True

    def _pop_item(self) -> Any:
        """Take the head item and promote the oldest parked sender into the
        freed slot.  Caller holds ``_lock``; returns ``(value, promoted)``
        where ``promoted`` must be called outside the lock (or None)."""
        value = self._items.popleft()
        promoted = None
        if self._send_waiters:
            waker, pending = self._send_waiters.popleft()
            self._items.append(pending)
            promoted = waker
        return value, promoted

    def try_recv(self) -> Tuple[bool, Any]:
        with self._lock:
            if not self._items:
                return False, None
            value, promoted = self._pop_item()
        if self.capacity is not None:
            # blocked senders poll/confirm on the activity epoch: a consumed
            # slot is the progress they are waiting for
            _bump_activity()
        if promoted is not None:
            promoted(None)
        return True, value

    def recv_nowait(self) -> Any:
        ok, value = self.try_recv()
        if not ok:
            raise ChannelEmpty(f"channel {self.name!r} is empty")
        return value

    def __len__(self) -> int:
        with self._lock:
            return len(self._items)

    # -- park/cancel protocol (used by the dispatch strategies) -------------
    def _park(self, waiter: Callable[[Any], None]) -> Tuple[str, Any]:
        """Atomically take an item or register ``waiter``.  Returns
        ``("ready", item)`` or ``("parked", None)``."""
        with self._lock:
            if self._items:
                value, promoted = self._pop_item()
            else:
                self._waiters.append(waiter)
                return "parked", None
        if self.capacity is not None:
            _bump_activity()
        if promoted is not None:
            promoted(None)
        return "ready", value

    def _cancel(self, waiter: Callable[[Any], None]) -> bool:
        """Remove a registered waiter; False if it already fired."""
        with self._lock:
            try:
                self._waiters.remove(waiter)
                return True
            except ValueError:
                return False

    def _park_send(self, waiter: Callable[[Any], None],
                   value: Any) -> Tuple[str, Any]:
        """Atomically deliver/enqueue ``value`` or register the sender
        ``waiter`` for the next freed slot (bounded channels)."""
        with self._lock:
            recv_waiter = self._waiters.popleft() if self._waiters else None
            if recv_waiter is None:
                if (self.capacity is not None
                        and len(self._items) >= self.capacity):
                    self._send_waiters.append((waiter, value))
                    return "parked", None
                self._items.append(value)
        _bump_activity()
        if recv_waiter is not None:
            recv_waiter(value)
        return "ready", None

    def _cancel_send(self, waiter: Callable[[Any], None]) -> bool:
        with self._lock:
            for i, (w, _) in enumerate(self._send_waiters):
                if w is waiter:
                    del self._send_waiters[i]
                    return True
            return False

    def _requeue(self, value: Any) -> None:
        """Hand back an item a losing multi-wait racer consumed.  Delivers
        to a parked receiver if one exists, else re-enters the buffer —
        *bypassing* the capacity check: the item was already admitted once,
        so bouncing it off a refilled bounded channel would drop it (or
        blow up in an unrelated sender's callback)."""
        with self._lock:
            waiter = self._waiters.popleft() if self._waiters else None
            if waiter is None:
                self._items.append(value)
        _bump_activity()
        if waiter is not None:
            waiter(value)


class TaskEvent:
    """A one-shot event tasks can :meth:`TaskContext.wait` on.

    ``set()`` is sticky; frames parked on the event become resumable, later
    waits return immediately.
    """

    __slots__ = ("name", "uid", "_lock", "_set", "_waiters")

    def __init__(self, name: str = "event"):
        self.name = name
        self.uid = next(_prim_uids)
        self._lock = threading.Lock()
        self._set = False
        self._waiters: Deque[Callable[[Any], None]] = deque()

    def is_set(self) -> bool:
        with self._lock:
            return self._set

    def set(self) -> None:
        with self._lock:
            if self._set:
                return
            self._set = True
            waiters = list(self._waiters)
            self._waiters.clear()
        _bump_activity()
        for waiter in waiters:
            waiter(None)

    def _park(self, waiter: Callable[[Any], None]) -> Tuple[str, Any]:
        with self._lock:
            if self._set:
                return "ready", None
            self._waiters.append(waiter)
            return "parked", None

    def _cancel(self, waiter: Callable[[Any], None]) -> bool:
        with self._lock:
            try:
                self._waiters.remove(waiter)
                return True
            except ValueError:
                return False


class FrameRequest:
    """What a suspended generator body is waiting for (yielded to the
    worker loop).  ``try_immediate`` is the eager fast path (consume inline
    without suspending); ``park`` registers a waker under the primitive's
    lock so no wakeup can be lost."""

    kind = "?"
    __slots__ = ()

    def try_immediate(self) -> Tuple[bool, Any]:
        return False, None

    def park(self, waiter: Callable[[Any], None]) -> Tuple[str, Any]:
        raise NotImplementedError

    def cancel(self, waiter: Callable[[Any], None]) -> bool:
        return False

    def describe(self) -> str:
        return self.kind

    def source_uid(self) -> int:
        """Uid of the primitive this request waits on (-1 when it has none
        or several) — the flight recorder's channel-identity tag."""
        return -1


class RecvRequest(FrameRequest):
    kind = "recv"
    __slots__ = ("channel",)

    def __init__(self, channel: Channel):
        self.channel = channel

    def try_immediate(self) -> Tuple[bool, Any]:
        return self.channel.try_recv()

    def park(self, waiter):
        return self.channel._park(waiter)

    def cancel(self, waiter):
        return self.channel._cancel(waiter)

    def describe(self) -> str:
        return f"recv({self.channel.name})"

    def source_uid(self) -> int:
        return self.channel.uid


class WaitRequest(FrameRequest):
    kind = "wait"
    __slots__ = ("event",)

    def __init__(self, event: TaskEvent):
        self.event = event

    def try_immediate(self) -> Tuple[bool, Any]:
        return (True, None) if self.event.is_set() else (False, None)

    def park(self, waiter):
        return self.event._park(waiter)

    def cancel(self, waiter):
        return self.event._cancel(waiter)

    def describe(self) -> str:
        return f"wait({self.event.name})"

    def source_uid(self) -> int:
        return self.event.uid


class SendRequest(FrameRequest):
    """A bounded-channel send: the *sender* suspends until a slot frees
    (the backpressure half of the paper's blocking communication)."""

    kind = "send"
    __slots__ = ("channel", "value")

    def __init__(self, channel: Channel, value: Any):
        self.channel = channel
        self.value = value

    def try_immediate(self) -> Tuple[bool, Any]:
        return (self.channel.try_send(self.value), None)

    def park(self, waiter):
        return self.channel._park_send(waiter, self.value)

    def cancel(self, waiter):
        return self.channel._cancel_send(waiter)

    def describe(self) -> str:
        return f"send({self.channel.name})"

    def source_uid(self) -> int:
        return self.channel.uid


class WaitAnyRequest(FrameRequest):
    """Select-style multi-wait: satisfied by whichever of its sub-requests
    (``recv`` on a channel / ``wait`` on an event) becomes ready first.

    The resume value is ``(index, value)``: the position of the winning
    source in the argument list plus that source's payload.  Exactly one
    source is consumed — a channel item claimed by a losing racer is
    re-queued, never dropped.  The winning index is instrumented by the
    recording dynamic dispatch and pinned on replay
    (:meth:`pinned`), so a replayed select is a deterministic choice.
    """

    kind = "wait_any"
    __slots__ = ("requests", "_lock", "_fired", "_children")

    def __init__(self, requests: Sequence[FrameRequest]):
        reqs = tuple(requests)
        if not reqs:
            raise ValueError("wait_any needs at least one channel/event")
        for r in reqs:
            if not isinstance(r, (RecvRequest, WaitRequest)):
                raise TypeError(
                    "wait_any sources must be channels or events "
                    f"(recv/wait), got {getattr(r, 'kind', r)!r}")
        self.requests = reqs
        self._lock = threading.Lock()
        self._fired = False
        # (index, child_waiter) pairs registered with the sub-requests
        self._children: List[Tuple[int, Callable[[Any], None]]] = []

    def try_immediate(self) -> Tuple[bool, Any]:
        for i, r in enumerate(self.requests):
            ok, v = r.try_immediate()
            if ok:
                return True, (i, v)
        return False, None

    def _claim(self) -> bool:
        with self._lock:
            if self._fired:
                return False
            self._fired = True
            return True

    def _cancel_children(self, except_waiter=None) -> None:
        for j, c in self._children:
            if c is not except_waiter:
                self.requests[j].cancel(c)

    def park(self, waiter: Callable[[Any], None]) -> Tuple[str, Any]:
        # children append incrementally so a child that fires mid-loop can
        # cancel every sibling parked so far; the post-loop sweep catches
        # any parked after the winner (cancel is a no-op on consumed ones)
        self._children = children = []
        for i, r in enumerate(self.requests):
            with self._lock:
                if self._fired:
                    break           # a parked child already won
            child = self._make_child(i, r, waiter)
            status, v = r.park(child)
            if status == "ready":
                if self._claim():
                    for j, c in children:
                        self.requests[j].cancel(c)
                    return "ready", (i, v)
                # a previously-parked child fired concurrently and owns the
                # delivery; this ready value must not drop
                if isinstance(r, RecvRequest):
                    r.channel._requeue(v)
                break
            children.append((i, child))
        with self._lock:
            fired = self._fired
        if fired:
            for j, c in children:
                self.requests[j].cancel(c)
            return "parked", None   # the winner child calls ``waiter``
        return "parked", None

    def _make_child(self, i: int, r: FrameRequest,
                    waiter: Callable[[Any], None]) -> Callable[[Any], None]:
        def child(value: Any = None, *, _i=i, _r=r) -> None:
            if not self._claim():
                # lost the race: hand a consumed channel item back (events
                # are sticky — nothing to return).  _requeue bypasses the
                # capacity check: a full bounded channel must not drop the
                # item or raise inside the producing sender's callback.
                if isinstance(_r, RecvRequest):
                    _r.channel._requeue(value)
                return
            self._cancel_children(except_waiter=child)
            waiter((_i, value))
        return child

    def cancel(self, waiter: Callable[[Any], None]) -> bool:
        if not self._claim():
            return False
        self._cancel_children()
        return True

    def pinned(self, index: int) -> "FrameRequest":
        """The replay form: wait only on the recorded winner, delivering the
        same ``(index, value)`` shape."""
        return _PinnedChoice(self.requests[index], index)

    def describe(self) -> str:
        return ("wait_any("
                + ", ".join(r.describe() for r in self.requests) + ")")


class _PinnedChoice(FrameRequest):
    """A :class:`WaitAnyRequest` whose winning index was recorded: replay
    parks only on that source, making the select deterministic."""

    kind = "wait_any"
    __slots__ = ("request", "index", "_wrapped")

    def __init__(self, request: FrameRequest, index: int):
        self.request = request
        self.index = index
        self._wrapped: Optional[Callable[[Any], None]] = None

    def try_immediate(self) -> Tuple[bool, Any]:
        ok, v = self.request.try_immediate()
        return (True, (self.index, v)) if ok else (False, None)

    def park(self, waiter):
        def wrapped(value: Any = None) -> None:
            waiter((self.index, value))
        self._wrapped = wrapped
        status, v = self.request.park(wrapped)
        if status == "ready":
            return "ready", (self.index, v)
        return status, None

    def cancel(self, waiter):
        if self._wrapped is None:
            return False
        return self.request.cancel(self._wrapped)

    def describe(self) -> str:
        return f"wait_any[{self.index}]({self.request.describe()})"

    def source_uid(self) -> int:
        return self.request.source_uid()


class YieldRequest(FrameRequest):
    """A cooperative yield: the frame goes to the back of the resume queue
    so the worker can schedule other work; it is immediately resumable."""

    kind = "yield"
    __slots__ = ()

    def park(self, waiter):
        return "ready", None


@dataclasses.dataclass(frozen=True)
class FrameResume:
    """A run-list entry: resume segment ``seg`` (1-based) of task ``tid``'s
    suspended frame.  Recorded by the dynamic dispatch, reproduced by
    replay (JSON-encoded as ``["r", tid, seg]``)."""

    tid: int
    seg: int


class TaskFrame:
    """A resumable execution of one task whose body is a generator.

    The worker loop drives the generator via :meth:`step`; each yielded
    :class:`FrameRequest` either completes inline (eager mode) or parks the
    frame.  ``resumes`` counts executed resume segments (segment 0 is the
    initial run), ``last_worker`` is the resume-locality hint, and
    ``resumable``/``resume_value`` carry the wakeup handshake.
    """

    __slots__ = ("task", "ctx", "gen", "resumes", "resume_value",
                 "last_worker", "resumable", "request", "waker",
                 "__weakref__")

    def __init__(self, task: "Task", ctx: "TaskContext", gen: Any):
        self.task = task
        self.ctx = ctx
        self.gen = gen
        self.resumes = 0
        self.resume_value: Any = None
        self.last_worker = 0
        self.resumable = False
        self.request: Optional[FrameRequest] = None
        self.waker: Optional[Callable[[Any], None]] = None

    def step(self, value: Any = None) -> Tuple[str, Any]:
        """Advance the generator once.  Returns ``("done", result)`` or
        ``("suspend", request)``."""
        try:
            req = self.gen.send(value)
        except StopIteration as stop:
            return "done", stop.value
        if not isinstance(req, FrameRequest):
            raise TypeError(
                f"task {self.task.name!r} yielded {req!r}; generator task "
                "bodies must yield ctx.recv(channel) / ctx.wait(event) / "
                "ctx.yield_()")
        return "suspend", req

    def close(self) -> None:
        self.gen.close()


# Every parked frame is registered here (and removed on wake/cancel) so the
# test suite can assert no frame is orphaned after aborts — the frame
# analogue of the worker-thread leak check.
_parked_frames: "weakref.WeakSet[TaskFrame]" = weakref.WeakSet()


def note_parked(frame: TaskFrame) -> None:
    _parked_frames.add(frame)


def note_unparked(frame: TaskFrame) -> None:
    _parked_frames.discard(frame)


def live_parked_frames() -> List[TaskFrame]:
    return list(_parked_frames)


@dataclasses.dataclass
class ParallelSpec:
    """A nested data-parallel region spawned by a task.

    ``n_threads`` ULTs run ``body(tid, ctx)``.  ``blocking`` marks regions
    whose internal synchronization is *blocking* (the paper's Fig. 1 hazard:
    a custom library barrier that does not yield to the scheduler).  ``gang``
    requests gang scheduling for this region (the paper's
    ``ompx_set_gang_sched`` scope); ``None`` defers to the runtime default.
    ``cost_per_thread`` is the per-ULT cost for the simulator; ``n_barriers``
    is how many internal barrier rounds the region performs.
    """

    n_threads: int
    body: Optional[Callable[[int, "TaskContext"], Any]] = None
    blocking: bool = True
    gang: Optional[bool] = None
    cost_per_thread: float = 0.0
    n_barriers: int = 1


@dataclasses.dataclass
class Task:
    tid: int
    name: str
    fn: Optional[Callable[["TaskContext"], Any]] = None
    deps: Tuple[int, ...] = ()
    kind: str = "compute"
    cost: float = 1.0
    priority: int = 0
    parallel: Optional[ParallelSpec] = None
    meta: Dict[str, Any] = dataclasses.field(default_factory=dict)
    # declarative conflicts (QuickSched): resources this task must hold for
    # its whole execution — exclusively (``uses``) or reader-shared
    # (``uses_shared``).  No ordering is implied; the arbiter picks one.
    uses: Tuple[Resource, ...] = ()
    uses_shared: Tuple[Resource, ...] = ()

    def __hash__(self) -> int:  # identity by tid within a graph
        return hash(self.tid)


class TaskContext:
    """Handed to task bodies at execution time.

    Provides predecessor results (``ctx[dep_task]`` / ``ctx.result(tid)``),
    the parallel-region primitives (``ctx.parallel`` / ``ctx.barrier``) used
    by gang-scheduled regions, and the suspension APIs (``ctx.recv`` /
    ``ctx.wait`` / ``ctx.yield_``).  In a generator body these return
    :class:`FrameRequest` objects that MUST be yielded (``value = yield
    ctx.recv(ch)``); in a plain body they block the worker
    work-conservingly.
    """

    _in_frame = False           # set by the frame driver for generator bodies

    def __init__(self, graph: "TaskGraph", task: Task, results: Dict[int, Any],
                 runtime: Any = None):
        self.graph = graph
        self.task = task
        self._results = results
        self.runtime = runtime

    def result(self, tid: int) -> Any:
        return self._results[tid]

    # -- suspension / communication (the paper's blocking extensions) -------
    def recv(self, channel: Channel) -> Any:
        """Receive from ``channel``.  Generator body: ``value = yield
        ctx.recv(ch)`` suspends the frame until an item arrives.  Plain
        body: blocks this worker (which keeps scheduling other work)."""
        if self._in_frame:
            return RecvRequest(channel)
        rt = self.runtime
        if rt is None or not hasattr(rt, "ctx_recv"):
            return channel.recv_nowait()        # serial context: no waiting
        return rt.ctx_recv(channel, self)

    def wait(self, event: TaskEvent) -> Any:
        """Wait for ``event``; same generator/plain split as :meth:`recv`."""
        if self._in_frame:
            return WaitRequest(event)
        rt = self.runtime
        if rt is None or not hasattr(rt, "ctx_wait"):
            if not event.is_set():
                raise RuntimeError(
                    f"wait on unset event {event.name!r} outside a runtime")
            return None
        return rt.ctx_wait(event, self)

    def send(self, channel: Channel, value: Any) -> Any:
        """Send with backpressure.  Generator body: ``yield ctx.send(ch,
        v)`` suspends the frame while a bounded channel is full.  Plain
        body: blocks this worker work-conservingly until a slot frees.
        Unbounded channels never wait (equivalent to ``channel.send``)."""
        if self._in_frame:
            return SendRequest(channel, value)
        rt = self.runtime
        if rt is None or not hasattr(rt, "ctx_send"):
            channel.send(value)             # serial context: no waiting
            return None
        return rt.ctx_send(channel, value, self)

    def wait_any(self, *sources: Any) -> Any:
        """Select-style multi-wait over channels and/or events: returns
        ``(index, value)`` for whichever source is satisfied first.
        Generator body: ``idx, v = yield ctx.wait_any(ch_a, ch_b, ev)``
        suspends until one fires.  Plain body: blocks work-conservingly.
        Recording captures the winning index; replay pins it, so the
        choice is deterministic."""
        request = WaitAnyRequest([self._as_request(s) for s in sources])
        if self._in_frame:
            return request
        rt = self.runtime
        if rt is None or not hasattr(rt, "ctx_wait_any"):
            ok, result = request.try_immediate()
            if not ok:
                raise RuntimeError(
                    "wait_any with no source ready outside a runtime")
            return result
        return rt.ctx_wait_any(request, self)

    @staticmethod
    def _as_request(source: Any) -> FrameRequest:
        if isinstance(source, Channel):
            return RecvRequest(source)
        if isinstance(source, TaskEvent):
            return WaitRequest(source)
        if isinstance(source, (RecvRequest, WaitRequest)):
            return source
        raise TypeError(
            f"wait_any sources must be Channel/TaskEvent, got {source!r}")

    def yield_(self) -> Any:
        """A cooperative scheduling point.  Generator body: ``yield
        ctx.yield_()`` parks the frame at the back of the resume queue.
        Plain body: the worker serves one unit of other work inline."""
        if self._in_frame:
            return YieldRequest()
        rt = self.runtime
        if rt is None or not hasattr(rt, "ctx_yield"):
            return None
        return rt.ctx_yield(self)

    def parallel(self, n_threads: int, body, *, gang=None):
        """Fork/join a nested parallel region (delegates to the runtime;
        gang-scheduled by default — the paper's `ompx_set_gang_sched`)."""
        if self.runtime is None:
            # degenerate serial execution (no runtime): run inline
            class _SerialRegion:
                def barrier(self_inner):
                    pass
            region = _SerialRegion()
            return [body(i, region) for i in range(n_threads)]
        return self.runtime.parallel(n_threads, body, gang=gang, spawn_ctx=self)

    def __getitem__(self, task_or_tid) -> Any:
        tid = task_or_tid.tid if isinstance(task_or_tid, Task) else task_or_tid
        return self._results[tid]

    def dep_results(self) -> List[Any]:
        return [self._results[d] for d in self.task.deps]


class TaskGraph:
    """A static DAG of tasks with dependency bookkeeping."""

    def __init__(self, name: str = "graph"):
        self.name = name
        self.tasks: List[Task] = []
        self._succ: Dict[int, List[int]] = {}
        # declared resources in first-use order; recordings and the flight
        # recorder refer to them by index in this list (the "rindex")
        self.resources: List[Resource] = []
        self._resource_index: Dict[int, int] = {}   # id(resource) -> rindex

    # -- construction -----------------------------------------------------
    def add(
        self,
        fn: Optional[Callable[[TaskContext], Any]] = None,
        *,
        deps: Sequence[Task] = (),
        name: Optional[str] = None,
        kind: str = "compute",
        cost: float = 1.0,
        priority: int = 0,
        parallel: Optional[ParallelSpec] = None,
        uses: Sequence[Resource] = (),
        uses_shared: Sequence[Resource] = (),
        **meta: Any,
    ) -> Task:
        tid = len(self.tasks)
        dep_ids = tuple(d.tid if isinstance(d, Task) else int(d) for d in deps)
        for d in dep_ids:
            if d >= tid or d < 0:
                raise ValueError(f"dependency {d} of task {tid} is not an existing task")
        for r in tuple(uses) + tuple(uses_shared):
            if not isinstance(r, Resource):
                raise TypeError(
                    f"uses/uses_shared entries must be Resource, got {r!r}")
            self.register_resource(r)
        t = Task(
            tid=tid,
            name=name or f"{kind}:{tid}",
            fn=fn,
            deps=dep_ids,
            kind=kind,
            cost=float(cost),
            priority=priority,
            parallel=parallel,
            meta=dict(meta),
            uses=tuple(uses),
            uses_shared=tuple(uses_shared),
        )
        self.tasks.append(t)
        self._succ[tid] = []
        for d in dep_ids:
            self._succ[d].append(tid)
        return t

    def register_resource(self, resource: Resource) -> int:
        """Intern ``resource`` into this graph's rindex space (idempotent;
        identity-keyed — two same-named handles are two resources)."""
        rindex = self._resource_index.get(id(resource))
        if rindex is None:
            rindex = len(self.resources)
            self._resource_index[id(resource)] = rindex
            self.resources.append(resource)
        return rindex

    def resource_index(self) -> Dict[int, int]:
        """id(resource) -> rindex for every declared resource."""
        return self._resource_index

    # -- queries ----------------------------------------------------------
    def __len__(self) -> int:
        return len(self.tasks)

    def __iter__(self):
        return iter(self.tasks)

    def successors(self, task_or_tid) -> List[Task]:
        tid = task_or_tid.tid if isinstance(task_or_tid, Task) else task_or_tid
        return [self.tasks[s] for s in self._succ[tid]]

    def indegrees(self) -> List[int]:
        return [len(t.deps) for t in self.tasks]

    def roots(self) -> List[Task]:
        return [t for t in self.tasks if not t.deps]

    def topological_order(self) -> List[Task]:
        """Kahn topological order; raises on cycles (construction forbids
        them, this is a safety net for hand-built graphs)."""
        indeg = self.indegrees()
        frontier = [t.tid for t in self.tasks if indeg[t.tid] == 0]
        order: List[int] = []
        while frontier:
            tid = frontier.pop()
            order.append(tid)
            for s in self._succ[tid]:
                indeg[s] -= 1
                if indeg[s] == 0:
                    frontier.append(s)
        if len(order) != len(self.tasks):
            raise ValueError("task graph has a cycle")
        return [self.tasks[t] for t in order]

    def critical_path(self) -> Tuple[float, List[Task]]:
        """Longest path through the graph by task ``cost`` (a task spawning a
        parallel region contributes ``cost + cost_per_thread`` — the region
        runs to completion within the task from the graph's point of view).
        Returns ``(length_seconds, path_tasks)``."""
        order = self.topological_order()
        dist: Dict[int, float] = {}
        prev: Dict[int, Optional[int]] = {}
        for t in order:
            c = t.cost + (t.parallel.cost_per_thread if t.parallel else 0.0)
            best, arg = 0.0, None
            for d in t.deps:
                if dist[d] > best:
                    best, arg = dist[d], d
            dist[t.tid] = best + c
            prev[t.tid] = arg
        end = max(dist, key=lambda k: dist[k])
        path: List[Task] = []
        cur: Optional[int] = end
        while cur is not None:
            path.append(self.tasks[cur])
            cur = prev[cur]
        return dist[end], list(reversed(path))

    def total_work(self) -> float:
        return sum(
            t.cost + (t.parallel.n_threads * t.parallel.cost_per_thread if t.parallel else 0.0)
            for t in self.tasks
        )

    def validate(self) -> None:
        self.topological_order()

    def subgraph_kinds(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for t in self.tasks:
            out[t.kind] = out.get(t.kind, 0) + 1
        return out
