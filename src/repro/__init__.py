"""Task-graph scheduling extensions — reproduction package.

Top-level exports are the **API v2** surface (:mod:`repro.api`): build
graphs with :class:`Graph` (futures-based, dependencies inferred from
:class:`TaskHandle` arguments), execute them through a :class:`Session`
(scheduler selection + warm worker leasing), inspect decisions as
:class:`Plan` objects and read results from :class:`RunReport`\\ s::

    import repro

    g = repro.Graph("pipeline")
    a = g.add(lambda: 2, name="a")
    b = g.add(lambda x: x * 21, a, name="b")      # dep inferred from `a`
    with repro.Session(workers=2) as s:
        report = s.run(g)
    assert report[b] == 42

The v1 surface (:func:`run_graph`, :class:`Runtime`, tid-keyed result
dicts) remains available from :mod:`repro.core` as thin shims over the
session layer; see README "API v2" for the migration table.  Heavyweight
subsystems (models, kernels, linalg) stay behind their subpackages —
``import repro`` pulls no JAX/numpy.
"""

from .api import Graph, Plan, PlanError, RunReport, Session, TaskHandle
from .core import (
    Channel,
    ChannelEmpty,
    ChannelFull,
    DeadlockError,
    ParallelSpec,
    Runtime,
    Task,
    TaskContext,
    TaskEvent,
    TaskGraph,
    run_graph,
)
from .core.policies import PolicyError, available_policies, register_policy

__all__ = [
    "Channel",
    "ChannelEmpty",
    "ChannelFull",
    "DeadlockError",
    "Graph",
    "ParallelSpec",
    "Plan",
    "PlanError",
    "PolicyError",
    "Runtime",
    "RunReport",
    "Session",
    "Task",
    "TaskContext",
    "TaskEvent",
    "TaskGraph",
    "TaskHandle",
    "available_policies",
    "register_policy",
    "run_graph",
]
