"""Chrome/Perfetto ``trace_event`` JSON export for :class:`RuntimeTrace`.

Open the exported file in https://ui.perfetto.dev or ``chrome://tracing``:

* one named row (thread) per worker, plus an ``external`` row for events
  emitted off the worker pool (e.g. a send from the caller's thread);
* every span is a complete (``ph: "X"``) slice with ``cat`` = its kind
  (``compute``/``comm``/``panel``/``barrier``/``idle``...), frame resume
  segments named ``task#sN``;
* flow arrows (``ph: "s"``/``"f"``) connect steal victims to thieves and
  channel sends to the frame resume segment they woke;
* frame suspensions are instant markers (``ph: "i"``) labelled with the
  suspended request (``recv(chan)@uid``).

Exact round-trip: Perfetto wants integer-ish microseconds in ``ts``/
``dur``, which does not survive ``*1e6 / 1e6`` float trips — so every
event also carries the raw second-resolution floats in ``args`` and
:func:`load_trace` rebuilds a :class:`RuntimeTrace` equal (``==``) to the
exported one.  ``otherData`` carries the schema tag, counters and the
aggregated metrics, which makes the file self-describing for CI
validation (:func:`validate_trace_json`).
"""

from __future__ import annotations

import json
import os
from typing import Any, Dict, List, Optional

from ..core.tracing import SPAN_KINDS
from .trace import RuntimeTrace

__all__ = ["to_perfetto", "write_trace", "load_trace", "validate_trace_json"]

SCHEMA = "repro.obs/1"
_PID = 0


def _us(t: float) -> float:
    return round(t * 1e6, 3)


def to_perfetto(trace: RuntimeTrace, *,
                extra: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
    """Serialize a :class:`RuntimeTrace` to a ``trace_event`` JSON object."""
    tev: List[Dict[str, Any]] = []
    rows = list(range(trace.n_workers)) + [-1]
    for w in rows:
        name = f"worker {w}" if w >= 0 else "external"
        tid = w if w >= 0 else trace.n_workers
        tev.append({"ph": "M", "pid": _PID, "tid": tid, "name": "thread_name",
                    "args": {"name": name}})
        tev.append({"ph": "M", "pid": _PID, "tid": tid,
                    "name": "thread_sort_index", "args": {"sort_index": tid}})

    for e in trace.events:
        ev = {"ph": "X", "pid": _PID, "tid": e.worker, "ts": _us(e.t0),
              "dur": _us(e.t1 - e.t0), "name": e.label or e.kind,
              "cat": e.kind, "args": {"t0": e.t0, "t1": e.t1,
                                      "kind": e.kind, "label": e.label}}
        if e.kind == "switch" and e.t0 == e.t1:
            # suspension points read better as instants than 0-dur slices
            ev = {"ph": "i", "s": "t", "pid": _PID, "tid": e.worker,
                  "ts": _us(e.t0), "name": e.label or "suspend",
                  "cat": e.kind, "args": {"t0": e.t0, "t1": e.t1,
                                          "kind": e.kind, "label": e.label}}
        tev.append(ev)

    flow_id = 0
    for (victim, thief, t, label) in trace.steal_flows:
        flow_id += 1
        args = {"victim": victim, "thief": thief, "t": t, "label": label}
        tev.append({"ph": "s", "id": flow_id, "pid": _PID, "tid": victim,
                    "ts": _us(t), "name": "steal", "cat": "steal",
                    "args": args})
        tev.append({"ph": "f", "bp": "e", "id": flow_id, "pid": _PID,
                    "tid": thief, "ts": _us(t), "name": "steal",
                    "cat": "steal", "args": args})
    for (src_w, t0, dst_w, t1, label) in trace.frame_flows:
        flow_id += 1
        src_tid = src_w if src_w >= 0 else trace.n_workers
        args = {"src": src_w, "dst": dst_w, "t0": t0, "t1": t1,
                "label": label}
        tev.append({"ph": "s", "id": flow_id, "pid": _PID, "tid": src_tid,
                    "ts": _us(t0), "name": label or "wake", "cat": "frame",
                    "args": args})
        tev.append({"ph": "f", "bp": "e", "id": flow_id, "pid": _PID,
                    "tid": dst_w, "ts": _us(t1), "name": label or "wake",
                    "cat": "frame", "args": args})

    other: Dict[str, Any] = {
        "schema": SCHEMA,
        "n_workers": trace.n_workers,
        "counters": dict(trace.counters),
        "dropped": trace.dropped,
        "metrics": trace.metrics(),
    }
    if extra:
        other.update(extra)
    return {"traceEvents": tev, "displayTimeUnit": "ms", "otherData": other}


def write_trace(trace: RuntimeTrace, path: str, *,
                extra: Optional[Dict[str, Any]] = None) -> str:
    with open(path, "w") as f:
        json.dump(to_perfetto(trace, extra=extra), f)
    return path


def _as_trace_dict(obj: Any) -> Any:
    """Accept a dict, a JSON string, or a file path (str / PathLike)."""
    if isinstance(obj, os.PathLike):
        obj = os.fspath(obj)
    if isinstance(obj, str):
        if obj.lstrip().startswith("{"):
            return json.loads(obj)
        with open(obj) as f:
            return json.load(f)
    return obj


def load_trace(obj: Any) -> RuntimeTrace:
    """Rebuild a :class:`RuntimeTrace` from exported JSON (a dict, a JSON
    string, or a file path).  Uses the exact raw floats stored in each
    event's ``args``, so ``load_trace(to_perfetto(t)) == t``."""
    obj = _as_trace_dict(obj)
    other = obj.get("otherData", {})
    rt = RuntimeTrace(int(other.get("n_workers", 1)))
    rt.counters = {k: int(v) for k, v in other.get("counters", {}).items()}
    rt.dropped = int(other.get("dropped", 0))
    metrics = other.get("metrics")
    if isinstance(metrics, dict):
        # JSON stringifies the per-worker histograms' int keys
        if isinstance(metrics.get("steal_by_victim"), dict):
            metrics["steal_by_victim"] = {
                int(v): hits for v, hits in metrics["steal_by_victim"].items()}
        if isinstance(metrics.get("frame_resumes_by_worker"), dict):
            metrics["frame_resumes_by_worker"] = {
                int(w): n
                for w, n in metrics["frame_resumes_by_worker"].items()}
        rt._metrics_cache = metrics
    flows: Dict[int, Dict[str, Any]] = {}
    for ev in obj.get("traceEvents", []):
        ph = ev.get("ph")
        args = ev.get("args", {})
        if ph in ("X", "i") and "kind" in args:
            rt.record(int(ev["tid"]), float(args["t0"]), float(args["t1"]),
                      str(args["kind"]), str(args.get("label", "")))
        elif ph == "s":
            flows[ev["id"]] = {"cat": ev.get("cat"), **args}
    for fl in flows.values():
        if fl.get("cat") == "steal":
            rt.steal_flows.append((int(fl["victim"]), int(fl["thief"]),
                                   float(fl["t"]), str(fl.get("label", ""))))
        elif fl.get("cat") == "frame":
            rt.frame_flows.append((int(fl["src"]), float(fl["t0"]),
                                   int(fl["dst"]), float(fl["t1"]),
                                   str(fl.get("label", ""))))
            rt.resume_latencies.append(
                max(0.0, float(fl["t1"]) - float(fl["t0"])))
    rt.events.sort(key=lambda e: (e.t0, e.worker, e.t1))
    return rt


def validate_trace_json(obj: Any) -> Dict[str, Any]:
    """Validate an exported trace against the ``repro.obs/1`` schema.
    Returns a summary dict; raises ``ValueError`` with every violation
    found (used by the CI bench-smoke job on the uploaded artifact)."""
    obj = _as_trace_dict(obj)
    errors: List[str] = []
    if not isinstance(obj, dict):
        raise ValueError(f"trace must be a JSON object, got {type(obj)!r}")
    other = obj.get("otherData")
    if not isinstance(other, dict) or other.get("schema") != SCHEMA:
        errors.append(f"otherData.schema must be {SCHEMA!r}")
    tev = obj.get("traceEvents")
    if not isinstance(tev, list) or not tev:
        raise ValueError("traceEvents must be a non-empty list")
    n_workers = int(other.get("n_workers", 0)) if isinstance(other, dict) else 0
    named_rows = set()
    slices = 0
    opens: Dict[Any, str] = {}
    closes: Dict[Any, str] = {}
    for i, ev in enumerate(tev):
        ph = ev.get("ph")
        if ph is None:
            errors.append(f"traceEvents[{i}]: missing ph")
            continue
        if ph == "M":
            if ev.get("name") == "thread_name":
                named_rows.add(ev.get("tid"))
            continue
        if "tid" not in ev or "ts" not in ev:
            errors.append(f"traceEvents[{i}] (ph={ph}): missing tid/ts")
            continue
        if ph == "X":
            slices += 1
            if "dur" not in ev or "name" not in ev:
                errors.append(f"traceEvents[{i}]: X slice needs dur+name")
            if ev.get("cat") not in SPAN_KINDS:
                errors.append(
                    f"traceEvents[{i}]: unknown slice kind {ev.get('cat')!r}")
        elif ph == "s":
            opens[ev.get("id")] = ev.get("cat")
        elif ph == "f":
            closes[ev.get("id")] = ev.get("cat")
    for fid, cat in opens.items():
        if fid not in closes:
            errors.append(f"flow {fid} ({cat}): start without finish")
    for fid, cat in closes.items():
        if fid not in opens:
            errors.append(f"flow {fid} ({cat}): finish without start")
    missing = [w for w in range(n_workers) if w not in named_rows]
    if missing:
        errors.append(f"workers without a named row: {missing}")
    if slices == 0:
        errors.append("no X slices (empty trace?)")
    if errors:
        raise ValueError("invalid trace JSON:\n  " + "\n  ".join(errors))
    return {
        "schema": SCHEMA,
        "n_workers": n_workers,
        "slices": slices,
        "flows": len(opens),
        "rows": len(named_rows),
        "counters": dict(other.get("counters", {})),
    }
