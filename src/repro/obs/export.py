"""CLI: export, validate and summarize flight-recorder traces.

Usage (``PYTHONPATH=src``)::

    # run the built-in synthetic demo workload traced, write Perfetto JSON
    python -m repro.obs.export --out trace.json

    # steer the demo: workers / steps / scheduler
    python -m repro.obs.export --out trace.json --workers 4 --scheduler pool

    # validate an exported file against the repro.obs/1 schema (CI)
    python -m repro.obs.export --validate trace.json

    # print the breakdown / metrics tables of an exported file
    python -m repro.obs.export --summarize trace.json

The demo workload is jax-free on purpose — a fan-out/fan-in graph with a
producer→consumer channel pair (suspendable frames) and enough imbalance
to show steals — so the CLI works on any box the repo imports on.  Open
the result in https://ui.perfetto.dev or ``chrome://tracing``.
"""

from __future__ import annotations

import argparse
import json
import sys
import time
from typing import Optional

from .perfetto import load_trace, validate_trace_json, write_trace
from .trace import RuntimeTrace


def _demo_graph(fanout: int = 8, spin_s: float = 2e-4):
    """A traced-demo graph: root -> fanout spinners + a channel-coupled
    producer/consumer frame pair -> join."""
    import repro

    g = repro.Graph("obs-demo")
    ch = repro.Channel("demo-ch", capacity=2)

    def spin(_=None):
        t_end = time.perf_counter() + spin_s
        x = 0
        while time.perf_counter() < t_end:
            x += 1
        return x

    def producer(ctx):
        for i in range(4):
            spin()
            yield ctx.send(ch, i)
        return "sent"

    def consumer(ctx):
        total = 0
        for _ in range(4):
            v = yield ctx.recv(ch)
            total += v
        return total

    root = g.add(spin, name="root")
    mids = [g.add(spin, root, name=f"spin{i}") for i in range(fanout)]
    p = g.add(producer, deps=[root], name="producer")
    c = g.add(consumer, deps=[root], name="consumer")
    g.add(lambda *xs: len(xs), *mids, p, c, name="join")
    return g


def run_demo(workers: int, scheduler: str, steps: int,
             fanout: int = 8) -> RuntimeTrace:
    """Run the demo workload ``steps`` times on a traced session and
    return the last run's :class:`RuntimeTrace`."""
    import repro

    trace: Optional[RuntimeTrace] = None
    with repro.Session(workers, scheduler=scheduler, trace=True) as s:
        for _ in range(max(1, steps)):
            report = s.run(_demo_graph(fanout=fanout))
            trace = report.trace
    if trace is None:
        raise RuntimeError("traced session produced no RuntimeTrace")
    return trace


def summarize(trace: RuntimeTrace) -> str:
    lines = [f"workers: {trace.n_workers}   events: {len(trace.events)}   "
             f"makespan: {trace.makespan * 1e3:.3f} ms   "
             f"dropped: {trace.dropped}"]
    lines.append("breakdown (fraction of worker-time):")
    for kind, frac in sorted(trace.breakdown_fraction().items(),
                             key=lambda kv: -kv[1]):
        lines.append(f"  {kind:<10s} {frac * 100:6.2f} %")
    m = trace.metrics()
    lines.append(f"steal success: {m['steal_hits']}/{m['steal_attempts']} "
                 f"({m['steal_success_rate'] * 100:.1f} %)")
    rl = m["resume_latency"]
    lines.append(f"resume latency: n={rl['count']} "
                 f"mean={rl['mean_s'] * 1e6:.1f} us "
                 f"p95={rl['p95_s'] * 1e6:.1f} us")
    lines.append(f"dispatch overhead fraction: "
                 f"{m['dispatch_overhead_fraction']:.3f}")
    lines.append("counters: " + json.dumps(trace.counters, sort_keys=True))
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.export",
        description="flight-recorder trace export / validation")
    ap.add_argument("--out", default=None,
                    help="run the demo workload traced and write Perfetto "
                         "JSON here")
    ap.add_argument("--validate", default=None, metavar="PATH",
                    help="validate an exported trace file; exit non-zero "
                         "on schema violations")
    ap.add_argument("--summarize", default=None, metavar="PATH",
                    help="print breakdown/metrics tables of an exported file")
    ap.add_argument("--workers", type=int, default=2)
    ap.add_argument("--scheduler", choices=("dynamic", "pool"),
                    default="dynamic")
    ap.add_argument("--steps", type=int, default=3,
                    help="demo iterations (last one is exported)")
    args = ap.parse_args(argv)

    did = False
    if args.validate:
        try:
            info = validate_trace_json(args.validate)
        except ValueError as e:
            print(e, file=sys.stderr)
            return 1
        print(f"OK {args.validate}: {info['slices']} slices, "
              f"{info['flows']} flows, {info['rows']} rows, "
              f"schema {info['schema']}")
        did = True
    if args.summarize:
        print(summarize(load_trace(args.summarize)))
        did = True
    if args.out:
        trace = run_demo(args.workers, args.scheduler, args.steps)
        write_trace(trace, args.out)
        print(f"wrote {args.out}")
        print(summarize(trace))
        did = True
    if not did:
        ap.error("nothing to do: pass --out, --validate or --summarize")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
