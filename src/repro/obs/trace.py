"""Assemble flight-recorder point events into a simulator-schema trace.

:class:`RuntimeTrace` extends :class:`~repro.core.tracing.Trace`, so every
analysis written for the offline simulator — ``breakdown()``,
``breakdown_fraction()``, ``per_worker_breakdown()``, ``utilization()``,
``count()`` — reads a live run identically (the paper's Fig. 11d tables
for the *real* executor).

Assembly walks each worker's event stream in time order keeping a stack of
open units: task bodies, frame resume segments and gang ULTs open/close
spans (``compute``/``comm``/``panel`` per the task kind); plain-body
blocks and blocking barriers open ``barrier`` spans; explicit worker
park/wake windows open ``idle`` spans.  Inline nesting (a join-waiter
serving other work, a ``ctx.recv`` poll loop stealing) *splits* the outer
span instead of double-counting it, so per-worker busy time never exceeds
wall clock.  Steals, replay fallbacks and frame suspensions additionally
land as zero-length ``steal``/``switch`` marker events so ``count()``
reconciles exactly with ``RunReport.stats``.
"""

from __future__ import annotations

from collections import defaultdict
from typing import Any, Dict, List, Optional, Tuple

from ..core.tracing import (
    EV_BARRIER_DONE,
    EV_BARRIER_WAIT,
    EV_BLOCK,
    EV_DEADLOCK_POLL,
    EV_FRAME_RESUME,
    EV_FRAME_SUSPEND,
    EV_FRAME_WAKE,
    EV_GANG_ENTER,
    EV_GANG_EXIT,
    EV_GANG_RESERVE,
    EV_PARK,
    EV_REPLAY_FALLBACK,
    EV_REPLAY_SKIP,
    EV_REPLAY_STALL,
    EV_RESOURCE_ACQUIRE,
    EV_RESOURCE_RELEASE,
    EV_RESOURCE_WAIT,
    EV_RUN_AHEAD,
    EV_STEAL_ATTEMPT,
    EV_STEAL_HIT,
    EV_TASK_END,
    EV_TASK_START,
    EV_UNBLOCK,
    EV_WAKE,
    KIND_BARRIER,
    KIND_COMPUTE,
    KIND_IDLE,
    KIND_PANEL,
    KIND_STEAL,
    KIND_SWITCH,
    Event,
    Trace,
)

__all__ = ["RuntimeTrace", "assemble"]

#: counter name -> point-event kind it mirrors (RunReport.stats parity)
_COUNTER_EVENTS = {
    "steals": EV_STEAL_HIT,
    "steal_attempts": EV_STEAL_ATTEMPT,
    "frame_suspends": EV_FRAME_SUSPEND,
    "frame_resumes": EV_FRAME_RESUME,
    "fallback_steals": EV_REPLAY_FALLBACK,
    "stalls": EV_REPLAY_STALL,
    "skips": EV_REPLAY_SKIP,
    "run_ahead": EV_RUN_AHEAD,
    "gang_regions": EV_GANG_RESERVE,
    "deadlock_polls": EV_DEADLOCK_POLL,
    "blocks": EV_BLOCK,
    "tasks": EV_TASK_END,
    "resource_acquires": EV_RESOURCE_ACQUIRE,
    "resource_waits": EV_RESOURCE_WAIT,
    "resource_releases": EV_RESOURCE_RELEASE,
}


def _split_label(label: str) -> Tuple[str, str]:
    """``"kind|name"`` -> (span kind, display name)."""
    if "|" in label:
        kind, name = label.split("|", 1)
        return (kind or KIND_COMPUTE), name
    return KIND_COMPUTE, label


class RuntimeTrace(Trace):
    """A live-executor trace in the simulator's ``Event`` schema, plus the
    runtime-only extras: exact point-event ``counters`` (reconciling with
    ``RunReport.stats``), steal / frame-wake flow edges (Perfetto arrows),
    ring-overflow ``dropped`` count, and multi-run :meth:`metrics`."""

    def __init__(self, n_workers: int):
        super().__init__(n_workers)
        self.counters: Dict[str, int] = {}
        self.dropped = 0
        #: (victim worker, thief worker, t, unit label) per successful steal
        self.steal_flows: List[Tuple[int, int, float, str]] = []
        #: (waker worker, t_wake, resume worker, t_resume, label) per
        #: frame wakeup that reached its resume segment (channel send→recv)
        self.frame_flows: List[Tuple[int, float, int, float, str]] = []
        #: resume-latency samples (s): frame wake -> segment start
        self.resume_latencies: List[float] = []
        #: per-victim steal histogram: victim -> [attempts, hits]
        self.steal_victims: Dict[int, List[int]] = {}
        #: frame resume segments executed per worker — the workers that
        #: host suspended continuations (frame-aware victim selection)
        self.frame_resumes_by_worker: Dict[int, int] = {}
        #: (tid, t_deferred, t_granted) per resource-contended task — the
        #: arbiter defer window (task time, not worker time: the deferring
        #: worker moves on)
        self.resource_waits: List[Tuple[int, float, float]] = []
        self._metrics_cache: Optional[Dict[str, Any]] = None

    # -- equality is exact: events, counters and flow edges round-trip ----
    def __eq__(self, other: object) -> bool:
        if not isinstance(other, RuntimeTrace):
            return NotImplemented
        return (self.n_workers == other.n_workers
                and self.events == other.events
                and self.counters == other.counters
                and self.dropped == other.dropped
                and self.steal_flows == other.steal_flows
                and self.frame_flows == other.frame_flows)

    __hash__ = None  # type: ignore[assignment]

    # ------------------------------------------------------------------
    def reconcile(self, stats: Dict[str, Any]) -> Dict[str, Tuple[int, int]]:
        """Compare this trace's exact event counters against a
        ``RunReport.stats`` dict; returns ``{key: (stats value, trace
        value)}`` for every shared counter that disagrees (empty == the
        trace accounts for every counted event)."""
        out: Dict[str, Tuple[int, int]] = {}
        for key in _COUNTER_EVENTS:
            if key in stats and key in self.counters:
                if int(stats[key]) != self.counters[key]:
                    out[key] = (int(stats[key]), self.counters[key])
        return out

    def dispatch_overhead_fraction(self) -> float:
        """Fraction of total worker-time NOT spent in task/ULT bodies —
        scheduling, steal scans, GIL waits, blocked communication, idle.
        ``1 - utilization()``; the per-phase number behind the serving
        bench's dispatch-collapse row."""
        if not self.events:
            return 0.0
        return max(0.0, 1.0 - self.utilization())

    def metrics(self) -> Dict[str, Any]:
        """Aggregate run metrics: steal success rate and per-victim
        histogram, resume-latency stats, per-worker idle fractions,
        barrier/blocked wait time, replay fallback rate."""
        if self._metrics_cache is not None:
            return dict(self._metrics_cache)
        c = self.counters
        attempts = c.get("steal_attempts", 0)
        hits = c.get("steals", 0)
        lat = sorted(self.resume_latencies)
        n_tasks = max(1, c.get("tasks", 0))
        per_worker = self.per_worker_breakdown()
        mk = self.makespan
        idle_frac = [
            (w.get(KIND_IDLE, 0.0) / mk if mk else 0.0) for w in per_worker]
        metrics: Dict[str, Any] = {
            "steal_attempts": attempts,
            "steal_hits": hits,
            "steal_success_rate": (hits / attempts) if attempts else 0.0,
            "steal_by_victim": {v: list(ah)
                                for v, ah in sorted(self.steal_victims.items())},
            "frame_resumes_by_worker": dict(
                sorted(self.frame_resumes_by_worker.items())),
            "resume_latency": {
                "count": len(lat),
                "mean_s": (sum(lat) / len(lat)) if lat else 0.0,
                # upper nearest-rank percentile (rounds up on small n)
                "p95_s": lat[-max(1, len(lat) - int(0.95 * len(lat)))]
                if lat else 0.0,
                "max_s": lat[-1] if lat else 0.0,
            },
            "per_worker_idle_fraction": idle_frac,
            "barrier_wait_s": self.breakdown().get(KIND_BARRIER, 0.0),
            "resource_waits": c.get("resource_waits", 0),
            "resource_wait_s": sum(t1 - t0
                                   for _, t0, t1 in self.resource_waits),
            "resource_wait_fraction":
                (sum(t1 - t0 for _, t0, t1 in self.resource_waits)
                 / (mk * self.n_workers)) if mk else 0.0,
            "replay_fallback_rate": c.get("fallback_steals", 0) / n_tasks,
            "dispatch_overhead_fraction": self.dispatch_overhead_fraction(),
            "utilization": self.utilization(),
            "makespan_s": mk,
            "dropped_events": self.dropped,
        }
        self._metrics_cache = metrics
        return dict(metrics)

    @classmethod
    def from_recorder(cls, recorder, n_workers: Optional[int] = None
                      ) -> "RuntimeTrace":
        return assemble(recorder.snapshot(),
                        n_workers if n_workers is not None
                        else recorder.n_workers,
                        dropped=recorder.dropped)


# boundary events: these open/close the per-worker unit stack
_OPENERS = {EV_TASK_START, EV_FRAME_RESUME, EV_GANG_ENTER, EV_BLOCK,
            EV_BARRIER_WAIT, EV_PARK}
_CLOSERS = {EV_TASK_END: EV_TASK_START, EV_FRAME_SUSPEND: EV_FRAME_RESUME,
            EV_GANG_EXIT: EV_GANG_ENTER, EV_UNBLOCK: EV_BLOCK,
            EV_BARRIER_DONE: EV_BARRIER_WAIT, EV_WAKE: EV_PARK}


def _unit_for(ev: str, label: str, a: int, b: int) -> Tuple[Any, str, str]:
    """(match key, span kind, span label) of an opening boundary event."""
    if ev == EV_TASK_START:
        kind, name = _split_label(label)
        return ("t", a), kind, name
    if ev == EV_FRAME_RESUME:
        kind, name = _split_label(label)
        return ("t", a), kind, f"{name}#s{b}"
    if ev == EV_GANG_ENTER:
        return ("g", a, b), KIND_PANEL, label or f"r{a}.t{b}"
    if ev == EV_BLOCK:
        return ("blk", a), KIND_BARRIER, label
    if ev == EV_BARRIER_WAIT:
        return ("bar", a), KIND_BARRIER, label or f"barrier r{a}"
    return ("idle",), KIND_IDLE, ""


def _close_key(ev: str, a: int, b: int) -> Any:
    if ev == EV_TASK_END or ev == EV_FRAME_SUSPEND:
        return ("t", a)
    if ev == EV_GANG_EXIT:
        return ("g", a, b)
    if ev == EV_UNBLOCK:
        return ("blk", a)
    if ev == EV_BARRIER_DONE:
        return ("bar", a)
    return ("idle",)


def assemble(snapshot: List[Tuple[int, float, str, str, int, int]],
             n_workers: int, *, dropped: int = 0) -> RuntimeTrace:
    """Build a :class:`RuntimeTrace` from a recorder snapshot (``(worker,
    t, kind, label, a, b)`` tuples, any order).  Timestamps are shifted so
    the earliest event is ``t=0`` (simulator convention; keeps
    ``makespan`` meaningful)."""
    rt = RuntimeTrace(n_workers)
    rt.dropped = dropped
    if not snapshot:
        rt.counters = {k: 0 for k in _COUNTER_EVENTS}
        return rt
    events = sorted(snapshot, key=lambda e: e[1])
    t_base = events[0][1]
    t_end = events[-1][1] - t_base

    counters: Dict[str, int] = defaultdict(int)
    victims: Dict[int, List[int]] = {}
    # frame flow matching: (tid, seg) -> pending suspend/wake timestamps
    suspends: Dict[Tuple[int, int], Tuple[int, float, str]] = {}
    wakes: Dict[Tuple[int, int], Tuple[int, float]] = {}
    # resource wait matching: tid -> defer timestamp (closed by the grant)
    res_pending: Dict[int, float] = {}

    per_worker: Dict[int, List[Tuple[float, str, str, int, int]]] = \
        defaultdict(list)
    for (w, t, ev, label, a, b) in events:
        per_worker[w].append((t - t_base, ev, label, a, b))

    spans: List[Event] = []
    for w in range(n_workers):
        stack: List[Tuple[Any, str, str]] = []
        cur_t = 0.0
        for (t, ev, label, a, b) in per_worker.get(w, ()):
            if ev in _OPENERS:
                if stack and t > cur_t:
                    _, k, lbl = stack[-1]
                    spans.append(Event(w, cur_t, t, k, lbl))
                stack.append(_unit_for(ev, label, a, b))
                cur_t = t
            elif ev in _CLOSERS:
                if stack and t > cur_t:
                    _, k, lbl = stack[-1]
                    spans.append(Event(w, cur_t, t, k, lbl))
                key = _close_key(ev, a, b)
                for i in range(len(stack) - 1, -1, -1):
                    if stack[i][0] == key:
                        del stack[i]
                        break
                cur_t = t
                if ev == EV_FRAME_SUSPEND:
                    spans.append(Event(w, t, t, KIND_SWITCH, label))
                    suspends[(a, b)] = (w, t, label)
            elif ev == EV_STEAL_HIT:
                spans.append(Event(w, t, t, KIND_STEAL, label))
                rt.steal_flows.append((a, w, t, label))
            elif ev == EV_REPLAY_FALLBACK:
                spans.append(Event(w, t, t, KIND_STEAL, f"fallback:{label}"))
            elif ev == EV_RESOURCE_WAIT:
                spans.append(Event(w, t, t, KIND_SWITCH, f"res-wait:{label}"))
            elif ev == EV_RESOURCE_ACQUIRE:
                spans.append(Event(w, t, t, KIND_SWITCH,
                                   f"res-acquire:{label}"))
            elif ev == EV_RESOURCE_RELEASE:
                spans.append(Event(w, t, t, KIND_SWITCH,
                                   f"res-release:{label}"))
        # close dangling units (aborted runs / ring truncation) at trace end
        while stack:
            _, k, lbl = stack.pop()
            if t_end > cur_t:
                spans.append(Event(w, cur_t, t_end, k, lbl))
                cur_t = t_end

    # flows + counters need the global stream (wakes land on other workers)
    for (w, t, ev, label, a, b) in events:
        t -= t_base
        for cname, ckind in _COUNTER_EVENTS.items():
            if ev == ckind:
                counters[cname] += 1
        if ev == EV_RESOURCE_WAIT:
            res_pending[a] = t
        elif ev == EV_RESOURCE_ACQUIRE:
            t0 = res_pending.pop(a, None)
            if t0 is not None:
                rt.resource_waits.append((a, t0, t))
        elif ev == EV_STEAL_ATTEMPT:
            victims.setdefault(a, [0, 0])[0] += 1
        elif ev == EV_STEAL_HIT:
            victims.setdefault(a, [0, 0])[1] += 1
        elif ev == EV_FRAME_WAKE:
            wakes[(a, b)] = (w, t)
        elif ev == EV_FRAME_RESUME:
            resumes_by_w = rt.frame_resumes_by_worker
            resumes_by_w[w] = resumes_by_w.get(w, 0) + 1
            wake = wakes.pop((a, b), None)
            if wake is not None:
                src_w, t_wake = wake
                parked = suspends.pop((a, b), None)
                flow_label = parked[2] if parked is not None else label
                rt.frame_flows.append((src_w, t_wake, w, t, flow_label))
                rt.resume_latencies.append(max(0.0, t - t_wake))

    spans.sort(key=lambda e: (e.t0, e.worker, e.t1))
    rt.events = spans
    for k in _COUNTER_EVENTS:
        counters.setdefault(k, 0)
    rt.counters = dict(counters)
    rt.steal_victims = victims
    return rt
