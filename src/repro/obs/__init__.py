"""Observability for the live executor stack (the runtime flight recorder).

* :mod:`repro.obs.recorder` — per-worker lock-free ring buffers of
  timestamped point events, with a module-level no-op emitter so tracing
  costs one attribute call when off;
* :mod:`repro.obs.trace` — assembles recorded events into a
  :class:`RuntimeTrace` sharing the simulator's ``Event``/kind schema
  (``breakdown()`` / ``utilization()`` work on both), plus multi-run
  metrics (steal success, resume latency, idle fractions, fallback rate);
* :mod:`repro.obs.perfetto` — Chrome/Perfetto ``trace_event`` JSON export
  (one row per worker, flow arrows for steals and channel sends→recvs,
  frame segments as slices) and the matching loader/validator;
* ``python -m repro.obs.export`` — CLI: demo traces, re-export, validation.
"""

from .recorder import NULL_RECORDER, FlightRecorder, NullRecorder, live_recorders
from .trace import RuntimeTrace, assemble
from .perfetto import (load_trace, to_perfetto, validate_trace_json,
                       write_trace)

__all__ = [
    "FlightRecorder", "NullRecorder", "NULL_RECORDER", "live_recorders",
    "RuntimeTrace", "assemble",
    "to_perfetto", "write_trace", "load_trace", "validate_trace_json",
]
