"""The flight recorder: per-worker lock-free ring buffers of point events.

Design constraints (Taskgraph's low-contention argument — instrumentation
must be cheap enough to leave on):

* **one writer per ring** — worker ``w`` appends only to ``rings[w]``, so
  no lock is needed on the hot path: a ring append is one ``perf_counter``
  call, one tuple pack, one CPython-atomic list store and an int add.
  Events emitted from *non-worker* threads (a channel send from outside
  the pool, a background re-record) go to one extra "external" ring,
  guarded by a small lock (those paths are rare and never hot).
* **bounded memory** — each ring holds ``capacity`` events; older events
  are overwritten and counted as dropped (surfaced on the assembled
  :class:`~repro.obs.trace.RuntimeTrace`).
* **near-zero cost when off** — executors hold :data:`NULL_RECORDER`, a
  module-level singleton whose ``emit`` does nothing.  The hot loops do
  ``self.recorder.emit(...)`` unconditionally: no branch, one attribute
  call.  The signature is positional and fixed (no ``*args``) so a no-op
  emit allocates nothing — tested in ``tests/test_obs.py``.

Recorders register in a ``WeakSet`` so the test suite can assert no trace
buffer outlives its session (``live_recorders``).
"""

from __future__ import annotations

import threading
import weakref
from time import perf_counter
from typing import List, Tuple

from ..core.tracing import EV_FRAME_RESUME, EV_FRAME_SUSPEND, EV_TASK_START

__all__ = ["FlightRecorder", "NullRecorder", "NULL_RECORDER",
           "live_recorders"]

#: raw record: (t, event kind, label, a, b) — worker id is the ring index
RawEvent = Tuple[float, str, str, int, int]

_live: "weakref.WeakSet[FlightRecorder]" = weakref.WeakSet()


def live_recorders() -> List["FlightRecorder"]:
    """Every :class:`FlightRecorder` still referenced somewhere — the
    suite-level leak check asserts this drains when sessions close."""
    return list(_live)


class _Ring:
    """Fixed-capacity single-writer ring of raw events."""

    __slots__ = ("buf", "cap", "n")

    def __init__(self, capacity: int):
        self.cap = capacity
        self.buf: List[RawEvent] = [None] * capacity  # type: ignore[list-item]
        self.n = 0

    def append(self, item: RawEvent) -> None:
        self.buf[self.n % self.cap] = item
        self.n += 1

    def reset(self) -> None:
        self.n = 0

    @property
    def dropped(self) -> int:
        return max(0, self.n - self.cap)

    def snapshot(self) -> List[RawEvent]:
        """Events in emission order (oldest surviving first)."""
        n, cap, buf = self.n, self.cap, self.buf
        if n <= cap:
            return [e for e in buf[:n] if e is not None]
        head = n % cap
        return [e for e in buf[head:] + buf[:head] if e is not None]


class NullRecorder:
    """The off-switch: every method is a no-op.  ``emit`` keeps the exact
    positional signature of :meth:`FlightRecorder.emit` — fixed arity, no
    ``*args`` (packing a ``*args`` tuple would allocate per call).  The
    ``emit_*`` helpers exist so hot call sites pass raw objects instead of
    building label strings: with tracing off, a call allocates nothing."""

    __slots__ = ()
    enabled = False

    def emit(self, worker, kind, label="", a=-1, b=-1):
        return None

    def emit_task_start(self, worker, task):
        return None

    def emit_frame_resume(self, worker, frame):
        return None

    def emit_frame_suspend(self, worker, frame, request):
        return None

    def emit_resource(self, worker, kind, task, n_res=0):
        return None

    def begin_run(self):
        return None


#: module-level singleton installed on every executor while tracing is off
NULL_RECORDER = NullRecorder()


class FlightRecorder:
    """Per-worker event rings for one executor (dispatch strategy).

    ``emit(worker, kind, label, a, b)`` timestamps with ``perf_counter``
    and appends to ``worker``'s ring; ``worker=-1`` routes to the shared
    external ring (non-worker threads).  ``begin_run`` resets the rings so
    a snapshot only ever covers the current run.
    """

    __slots__ = ("n_workers", "rings", "_ext_lock", "__weakref__")

    enabled = True

    def __init__(self, n_workers: int, capacity: int = 1 << 15):
        self.n_workers = n_workers
        # ring [-1] is the external ring: Python's negative indexing makes
        # `rings[worker]` correct for worker ids in [-1, n_workers)
        self.rings = [_Ring(capacity) for _ in range(n_workers + 1)]
        self._ext_lock = threading.Lock()
        _live.add(self)

    def emit(self, worker, kind, label="", a=-1, b=-1):
        if worker >= 0:
            self.rings[worker].append((perf_counter(), kind, label, a, b))
        else:
            with self._ext_lock:
                self.rings[-1].append((perf_counter(), kind, label, a, b))

    # -- hot-path helpers: label building lives HERE, not at call sites,
    # so a NullRecorder call allocates nothing ---------------------------
    def emit_task_start(self, worker, task):
        self.emit(worker, EV_TASK_START, task.kind + "|" + task.name,
                  task.tid, 0)

    def emit_frame_resume(self, worker, frame):
        task = frame.task
        self.emit(worker, EV_FRAME_RESUME, task.kind + "|" + task.name,
                  task.tid, frame.resumes)

    def emit_frame_suspend(self, worker, frame, request):
        uid = request.source_uid()
        label = request.describe()
        if uid >= 0:
            label = f"{label}@c{uid}"     # channel/event identity
        self.emit(worker, EV_FRAME_SUSPEND, label, frame.task.tid,
                  frame.resumes + 1)

    def emit_resource(self, worker, kind, task, n_res=0):
        """Resource acquire/wait/release for ``task`` (kind is one of the
        EV_RESOURCE_* constants; label building stays off the null path)."""
        self.emit(worker, kind, task.name, task.tid, n_res)

    def begin_run(self):
        for ring in self.rings:
            ring.reset()

    @property
    def dropped(self) -> int:
        return sum(r.dropped for r in self.rings)

    def snapshot(self) -> List[Tuple[int, float, str, str, int, int]]:
        """All events of the current run as ``(worker, t, kind, label, a,
        b)`` tuples, globally sorted by timestamp.  External-ring events
        come back with ``worker = -1``."""
        out: List[Tuple[int, float, str, str, int, int]] = []
        for w in range(self.n_workers):
            for (t, kind, label, a, b) in self.rings[w].snapshot():
                out.append((w, t, kind, label, a, b))
        for (t, kind, label, a, b) in self.rings[-1].snapshot():
            out.append((-1, t, kind, label, a, b))
        out.sort(key=lambda e: e[1])
        return out
