"""LM substrate: configs, layers, SSD, and the unified model assembly."""

from .config import ModelConfig
from .lm import (
    abstract_params,
    cache_pspecs,
    cache_struct,
    decode_step,
    forward,
    init_params,
    loss_fn,
    model_spec,
    param_pspecs,
    prefill,
    zeros_cache,
)

__all__ = [
    "ModelConfig",
    "abstract_params",
    "cache_pspecs",
    "cache_struct",
    "decode_step",
    "forward",
    "init_params",
    "loss_fn",
    "model_spec",
    "param_pspecs",
    "prefill",
    "zeros_cache",
]
