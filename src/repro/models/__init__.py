"""LM substrate: configs, layers, SSD, and the unified model assembly."""

from .config import ModelConfig
from .lm import (
    abstract_params,
    cache_pspecs,
    cache_struct,
    decode_step,
    forward,
    init_params,
    loss_fn,
    model_spec,
    param_pspecs,
    prefill,
    zeros_cache,
)
from .serving import (
    DecodeShard,
    DecodeState,
    build_decode_graph,
    decode_graph_key,
    greedy_sample,
    make_decode_state,
    shard_batch,
)

__all__ = [
    "DecodeShard",
    "DecodeState",
    "build_decode_graph",
    "decode_graph_key",
    "greedy_sample",
    "make_decode_state",
    "shard_batch",
    "ModelConfig",
    "abstract_params",
    "cache_pspecs",
    "cache_struct",
    "decode_step",
    "forward",
    "init_params",
    "loss_fn",
    "model_spec",
    "param_pspecs",
    "prefill",
    "zeros_cache",
]
