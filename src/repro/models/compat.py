"""Version-compat shims for JAX API drift.

``shard_map`` moved from ``jax.experimental.shard_map`` to ``jax.shard_map``
(and the replication-check kwarg was renamed ``check_rep`` -> ``check_vma``
along the way).  Model code always calls :func:`shard_map` from here with the
*new* kwarg spelling; the shim translates for older installs.
"""

from __future__ import annotations

import jax

if hasattr(jax, "shard_map"):          # jax >= 0.6: top-level, check_vma kwarg
    shard_map = jax.shard_map
else:                                   # older jax: experimental, check_rep kwarg
    from jax.experimental.shard_map import shard_map as _shard_map_experimental

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma=True, **kw):
        return _shard_map_experimental(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_rep=check_vma, **kw)

__all__ = ["shard_map"]
