"""Unified LM assembly for all assigned architecture families.

One repeating *block* per family, stacked along a leading ``layers`` axis and
driven by ``lax.scan`` (MaxText-style: HLO size and compile time independent
of depth).  Heterogeneous stacks (gemma3 local:global, zamba2 shared
attention, llama-vision cross-attention) use per-layer flag arrays as scan
xs — one compiled body, no per-layer HLO.

Entry points (all pure; jit/shard them from repro.launch):

* ``model_spec(cfg)`` / ``init_params(cfg, key)`` / ``abstract_params(cfg)``
* ``forward(params, cfg, batch, ctx)``           -> final hidden states
* ``loss_fn(params, cfg, batch, ctx)``           -> scalar CE loss
* ``zeros_cache(cfg, batch, max_len, ctx)``      -> decode cache pytree
* ``prefill(params, cfg, batch, ctx, max_len)``  -> (cache, last logits)
* ``decode_step(params, cfg, cache, tok, ctx)``  -> (cache, logits)
"""

from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from .compat import shard_map

from . import layers as L
from . import ssm as S
from .config import ModelConfig


def padded_vocab(cfg: ModelConfig) -> int:
    """Vocab rounded up so the vocab axis shards evenly (CE masks padding)."""
    return -(-cfg.vocab_size // 256) * 256


# ---------------------------------------------------------------------------
# specs
# ---------------------------------------------------------------------------
def block_spec(cfg: ModelConfig) -> Dict:
    s: Dict = {}
    fam = cfg.family
    if fam in ("dense", "moe", "encdec", "vlm"):
        s["ln1"] = ((cfg.d_model,), ("embed",))
        s["attn"] = L.attn_spec(cfg)
        s["ln2"] = ((cfg.d_model,), ("embed",))
        if fam == "moe":
            s["moe"] = L.moe_spec(cfg)
        else:
            s["mlp"] = L.mlp_spec(cfg)
        if fam == "vlm":
            s["lnx"] = ((cfg.d_model,), ("embed",))
            s["xattn"] = L.attn_spec(cfg)
            s["xgate"] = ((1,), (None,))
        if fam == "encdec":
            s["lnx"] = ((cfg.d_model,), ("embed",))
            s["xattn"] = L.attn_spec(cfg)
    elif fam in ("ssm", "hybrid"):
        s["ln1"] = ((cfg.d_model,), ("embed",))
        s["ssm"] = S.ssm_spec(cfg)
    return s


def model_spec(cfg: ModelConfig) -> Dict:
    v = padded_vocab(cfg)
    d = cfg.d_model
    spec: Dict = {
        "embed": {"table": ((v, d), ("vocab", "embed"))},
        "final_norm": ((d,), ("embed",)),
        "blocks": L.stack_spec(block_spec(cfg), cfg.n_layers),
    }
    if not cfg.tie_embeddings:
        spec["unembed"] = {"out": ((d, v), ("embed", "vocab"))}
    if cfg.family == "hybrid":
        spec["shared"] = {
            "ln1": ((d,), ("embed",)),
            "attn": L.attn_spec(cfg),
            "ln2": ((d,), ("embed",)),
            "mlp": L.mlp_spec(cfg),
        }
    if cfg.family == "encdec":
        enc_block = {
            "ln1": ((d,), ("embed",)),
            "attn": L.attn_spec(cfg),
            "ln2": ((d,), ("embed",)),
            "mlp": L.mlp_spec(cfg),
        }
        spec["enc_blocks"] = L.stack_spec(enc_block, cfg.enc_layers)
    return spec


def init_params(cfg: ModelConfig, key: jax.Array):
    return L.materialize(model_spec(cfg), key, cfg.jdtype)


def abstract_params(cfg: ModelConfig):
    return L.abstract(model_spec(cfg), cfg.jdtype)


def param_pspecs(cfg: ModelConfig, ctx):
    from ..sharding.rules import params_pspecs
    return params_pspecs(L.spec_axes(model_spec(cfg)), ctx)


def n_attn_slots(cfg: ModelConfig) -> int:
    return cfg.n_layers // max(1, cfg.attn_every) if cfg.family == "hybrid" else cfg.n_layers


# ---------------------------------------------------------------------------
# per-layer flags (scan xs)
# ---------------------------------------------------------------------------
def layer_flags(cfg: ModelConfig) -> Dict[str, jnp.ndarray]:
    n = cfg.n_layers
    fam = cfg.family
    flags: Dict[str, jnp.ndarray] = {}
    if fam in ("dense", "moe", "vlm", "encdec"):
        if cfg.local_global_ratio:
            r = cfg.local_global_ratio
            is_global = (jnp.arange(n) % (r + 1)) == r
            flags["window"] = jnp.where(is_global, 0, cfg.window).astype(jnp.int32)
            flags["theta"] = jnp.where(is_global, 1e6, cfg.rope_theta).astype(jnp.float32)
        else:
            flags["window"] = jnp.full((n,), cfg.window, jnp.int32)
            flags["theta"] = jnp.full((n,), cfg.rope_theta, jnp.float32)
    if fam == "hybrid" and cfg.attn_every:
        use = (jnp.arange(n) % cfg.attn_every) == cfg.attn_every - 1
        flags["use_attn"] = use
        flags["attn_slot"] = jnp.maximum(jnp.cumsum(use) - 1, 0).astype(jnp.int32)
    if fam == "vlm" and cfg.cross_attn_every:
        flags["use_cross"] = ((jnp.arange(n) % cfg.cross_attn_every)
                              == cfg.cross_attn_every - 1)
    return flags


# ---------------------------------------------------------------------------
# embedding / loss (vocab-sharded shard_map paths)
# ---------------------------------------------------------------------------
def _usable_batch_axes(ctx, batch_size: int):
    """Batch axes only when the batch divides the DP extent (a batch-1
    decode step keeps activations replicated over the data axes)."""
    dp = 1
    for a in ctx.batch_axes:
        dp *= ctx.mesh.shape[a]
    return ctx.batch_axes if batch_size % dp == 0 else None


def embed_lookup(table: jnp.ndarray, ids: jnp.ndarray, ctx) -> jnp.ndarray:
    if ctx is None or ctx.mesh is None:
        return table[ids]
    mesh = ctx.mesh
    v_local = table.shape[0] // mesh.shape[ctx.model_axis]
    batch_axes = _usable_batch_axes(ctx, ids.shape[0])

    def f(tab, idl):
        start = lax.axis_index(ctx.model_axis) * v_local
        local = idl - start
        ok = (local >= 0) & (local < v_local)
        safe = jnp.clip(local, 0, v_local - 1)
        out = jnp.where(ok[..., None], tab[safe], 0).astype(tab.dtype)
        return lax.psum(out, ctx.model_axis)

    return shard_map(
        f, mesh=mesh,
        in_specs=(P(ctx.model_axis, None), P(batch_axes, None)),
        out_specs=P(batch_axes, None, None), check_vma=False,
    )(table, ids)


def sharded_ce_loss(h: jnp.ndarray, wout: jnp.ndarray, labels: jnp.ndarray,
                    cfg: ModelConfig, ctx) -> jnp.ndarray:
    """Token-mean cross entropy with vocab-sharded logits (the full logit
    matrix never materializes on one device).  labels < 0 are masked."""
    v_real = cfg.vocab_size

    if ctx is None or ctx.mesh is None:
        logits = (h @ wout).astype(jnp.float32)
        gidx = jnp.arange(logits.shape[-1])
        logits = jnp.where(gidx < v_real, logits, -jnp.inf)
        lse = jax.nn.logsumexp(logits, axis=-1)
        picked = jnp.take_along_axis(
            logits, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
        mask = labels >= 0
        return jnp.sum(jnp.where(mask, lse - picked, 0.0)) / jnp.maximum(mask.sum(), 1)

    mesh = ctx.mesh
    v_local = wout.shape[-1] // mesh.shape[ctx.model_axis]
    batch_axes = _usable_batch_axes(ctx, h.shape[0])
    CE_CHUNK = 2048   # tokens per chunk: bounds the f32 logit buffer

    def f(hs, w, lab):
        start = lax.axis_index(ctx.model_axis) * v_local
        gidx = start + jnp.arange(v_local)
        neg = jnp.float32(-1e30)
        B, S, D = hs.shape
        T = B * S
        tc = min(CE_CHUNK, T)
        nc = -(-T // tc)
        hflat = hs.reshape(T, D)
        lflat = lab.reshape(T)
        if nc * tc != T:
            hflat = jnp.pad(hflat, ((0, nc * tc - T), (0, 0)))
            lflat = jnp.pad(lflat, (0, nc * tc - T), constant_values=-1)
        hflat = hflat.reshape(nc, tc, D)
        lflat = lflat.reshape(nc, tc)

        def chunk(carry, inp):
            num, cnt = carry
            hc, lc = inp
            logits = (hc @ w).astype(jnp.float32)            # (tc, v_local)
            logits = jnp.where(gidx < v_real, logits, neg)
            # stop_gradient BEFORE pmax: the shift is stability-only and
            # gradient-neutral (pmax has no differentiation rule; a
            # symbolically-zero tangent never invokes it).
            lmax = lax.pmax(lax.stop_gradient(jnp.max(logits, axis=-1)),
                            ctx.model_axis)
            z = jnp.exp(logits - lmax[:, None])
            denom = lax.psum(jnp.sum(z, -1), ctx.model_axis)
            lse = jnp.log(denom) + lmax
            onloc = (lc[:, None] == gidx)
            picked = lax.psum(jnp.sum(jnp.where(onloc, logits, 0.0), -1),
                              ctx.model_axis)
            mask = lc >= 0
            num = num + jnp.sum(jnp.where(mask, lse - picked, 0.0))
            cnt = cnt + jnp.sum(mask)
            return (num, cnt), None

        # rank-1 (1,) carries: scalar carries become scalar autodiff
        # residuals at the shard_map boundary, which older jax fails to
        # promote to rank 1 (fixed upstream; harmless on new jax)
        (num, cnt), _ = lax.scan(
            jax.checkpoint(chunk, policy=jax.checkpoint_policies.nothing_saveable),
            (jnp.zeros((1,), jnp.float32), jnp.zeros((1,), jnp.int32)),
            (hflat, lflat))
        if batch_axes:
            num = lax.psum(num, batch_axes)
            cnt = lax.psum(cnt, batch_axes)
        return num / jnp.maximum(cnt, 1)

    loss = shard_map(
        f, mesh=mesh,
        in_specs=(P(batch_axes, None, None), P(None, ctx.model_axis),
                  P(batch_axes, None)),
        out_specs=P(None), check_vma=False,
    )(h, wout, labels)
    return loss[0]


# ---------------------------------------------------------------------------
# block pieces
# ---------------------------------------------------------------------------
def _self_attn(bp, cfg, x, *, window, theta, positions, cache=None,
               cache_index=None):
    h = L.rmsnorm(x, bp["ln1"], cfg.norm_eps)
    out, kv = L.attention(bp["attn"], cfg, h, causal=True, window=window,
                          theta=theta, positions=positions, cache=cache,
                          cache_index=cache_index)
    return x + out, kv


def _cross_attn(bp, cfg, x, memory, gated: bool):
    h = L.rmsnorm(x, bp["lnx"], cfg.norm_eps)
    out, _ = L.attention(bp["xattn"], cfg, h, memory=memory)
    if gated:
        out = jnp.tanh(bp["xgate"]) * out
    return x + out


def _ffn(bp, cfg, x, ctx):
    h = L.rmsnorm(x, bp["ln2"], cfg.norm_eps)
    if "moe" in bp:
        return x + L.moe(bp["moe"], cfg, h, shard_ctx=ctx)
    return x + L.mlp(bp["mlp"], h)


def _shared_attn_block(sp, cfg, x, positions, cache=None, cache_index=None):
    h = L.rmsnorm(x, sp["ln1"], cfg.norm_eps)
    out, kv = L.attention(sp["attn"], cfg, h, causal=True, positions=positions,
                          cache=cache, cache_index=cache_index)
    x = x + out
    h = L.rmsnorm(x, sp["ln2"], cfg.norm_eps)
    return x + L.mlp(sp["mlp"], h), kv


def _maybe_remat(fn, remat: bool):
    return jax.checkpoint(fn, policy=jax.checkpoint_policies.nothing_saveable) \
        if remat else fn


def _block_constrainer(cfg: ModelConfig, ctx, spec=None):
    """Returns a function constraining a per-layer param slice to its
    sharding INSIDE the scan body.  with_sharding_constraint transposes to
    itself, so the per-layer *cotangent* (the backward while-loop's gradient
    accumulator update) inherits the sharding — without this XLA leaves the
    full stacked-gradient accumulator replicated (~4x param bytes per
    device)."""
    if ctx is None or ctx.mesh is None:
        return lambda bp: bp
    from jax.sharding import NamedSharding
    from ..sharding.rules import params_pspecs
    from . import layers as LL
    pspec_tree = params_pspecs(LL.spec_axes(spec or block_spec(cfg)), ctx)
    sh_tree = jax.tree.map(lambda p: NamedSharding(ctx.mesh, p), pspec_tree,
                           is_leaf=lambda x: isinstance(x, P))

    def constrain(bp):
        return jax.tree.map(lax.with_sharding_constraint, bp, sh_tree,
                            is_leaf=lambda x: not isinstance(x, dict))

    return constrain


# ---------------------------------------------------------------------------
# forward (training / scoring)
# ---------------------------------------------------------------------------
def forward(params, cfg: ModelConfig, batch: Dict[str, jnp.ndarray], ctx=None,
            *, remat: bool = True) -> jnp.ndarray:
    tokens = batch["tokens"]
    B, Sq = tokens.shape
    x = embed_lookup(params["embed"]["table"], tokens, ctx)
    positions = jnp.arange(Sq)[None, :]
    flags = layer_flags(cfg)
    fam = cfg.family

    memory = None
    if fam == "encdec":
        memory = _encode(params, cfg, batch["enc_input"], ctx, remat=remat)
    elif fam == "vlm":
        memory = batch["patches"]

    constrain = _block_constrainer(cfg, ctx)

    if fam in ("ssm", "hybrid"):
        def body(x, scanned):
            bp, fl = scanned
            bp = constrain(bp)
            if fam == "hybrid":
                x = lax.cond(
                    fl["use_attn"],
                    lambda v: _shared_attn_block(params["shared"], cfg, v, positions)[0],
                    lambda v: v, x)
            h = L.rmsnorm(x, bp["ln1"], cfg.norm_eps)
            out, _ = S.ssm_block(bp["ssm"], cfg, h)
            return x + out, None
    else:
        def body(x, scanned):
            bp, fl = scanned
            bp = constrain(bp)
            x, _ = _self_attn(bp, cfg, x, window=fl["window"],
                              theta=fl["theta"], positions=positions)
            if fam == "vlm":
                x = lax.cond(fl["use_cross"],
                             lambda v: _cross_attn(bp, cfg, v, memory, gated=True),
                             lambda v: v, x)
            if fam == "encdec":
                x = _cross_attn(bp, cfg, x, memory, gated=False)
            return _ffn(bp, cfg, x, ctx), None

    group = getattr(ctx, "remat_group", 1) if ctx is not None else 1
    if remat and group > 1 and cfg.n_layers % group == 0:
        # 2-level remat: checkpoint at group boundaries only — the saved
        # carry stash shrinks by ~group at the cost of re-running `group`
        # layers per backward step (memory<->recompute trade, §Perf).
        ng = cfg.n_layers // group
        blocks_g = jax.tree.map(
            lambda a: a.reshape((ng, group) + a.shape[1:]), params["blocks"])
        flags_g = {k: v.reshape((ng, group) + v.shape[1:])
                   for k, v in flags.items()}

        def group_body(xc, scanned):
            bpg, flg = scanned
            xc, _ = lax.scan(body, xc, (bpg, flg))
            return xc, None

        x, _ = lax.scan(_maybe_remat(group_body, True), x, (blocks_g, flags_g))
    else:
        x, _ = lax.scan(_maybe_remat(body, remat), x, (params["blocks"], flags))
    return L.rmsnorm(x, params["final_norm"], cfg.norm_eps)


def _encode(params, cfg: ModelConfig, enc_input, ctx, *, remat=True):
    x = enc_input
    positions = jnp.arange(x.shape[1])[None, :]
    enc_spec = {
        "ln1": ((cfg.d_model,), ("embed",)),
        "attn": L.attn_spec(cfg),
        "ln2": ((cfg.d_model,), ("embed",)),
        "mlp": L.mlp_spec(cfg),
    }
    constrain = _block_constrainer(cfg, ctx, spec=enc_spec)

    def body(x, bp):
        bp = constrain(bp)
        h = L.rmsnorm(x, bp["ln1"], cfg.norm_eps)
        out, _ = L.attention(bp["attn"], cfg, h, causal=False, positions=positions)
        x = x + out
        h = L.rmsnorm(x, bp["ln2"], cfg.norm_eps)
        return x + L.mlp(bp["mlp"], h), None

    x, _ = lax.scan(_maybe_remat(body, remat), x, params["enc_blocks"])
    return x


def logits_from_hidden(params, cfg: ModelConfig, h: jnp.ndarray) -> jnp.ndarray:
    wout = params["unembed"]["out"] if "unembed" in params \
        else params["embed"]["table"].T
    return h @ wout


def loss_fn(params, cfg: ModelConfig, batch, ctx=None, *, remat: bool = True):
    h = forward(params, cfg, batch, ctx, remat=remat)
    wout = params["unembed"]["out"] if "unembed" in params \
        else params["embed"]["table"].T
    return sharded_ce_loss(h, wout, batch["labels"], cfg, ctx)


# ---------------------------------------------------------------------------
# decode caches
# ---------------------------------------------------------------------------
def cache_struct(cfg: ModelConfig, batch: int, max_len: int,
                 n_patches: int = 0):
    fam = cfg.family
    dt = cfg.jdtype
    caches: Dict[str, Any] = {}
    if fam in ("dense", "moe", "encdec", "vlm", "hybrid"):
        kv = jax.ShapeDtypeStruct(
            (n_attn_slots(cfg), batch, max_len, cfg.n_kv_heads, cfg.head_dim), dt)
        caches["k"] = kv
        caches["v"] = kv
    if fam in ("ssm", "hybrid"):
        per = S.ssm_state_spec(cfg, batch, dt)
        caches["ssm"] = {
            k: jax.ShapeDtypeStruct((cfg.n_layers,) + v.shape, v.dtype)
            for k, v in per.items()
        }
    if fam in ("encdec", "vlm"):
        m = max(1, n_patches or cfg.n_patches)
        caches["memory"] = jax.ShapeDtypeStruct((batch, m, cfg.d_model), dt)
    caches["index"] = jax.ShapeDtypeStruct((), jnp.int32)
    return caches


def zeros_cache(cfg, batch, max_len, ctx=None, n_patches: int = 0):
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        cache_struct(cfg, batch, max_len, n_patches))


def cache_pspecs(cfg: ModelConfig, ctx):
    """PartitionSpecs for the decode cache.

    * standard decode: batch on batch axes; KV heads on "model" when they
      divide evenly, otherwise the cache *sequence* shards on "model"
      (flash-decoding split-K: partial softmax + psum — pjit input shardings
      cannot pad, and replicating 32k caches does not fit the big archs);
    * long-context (seq_shard_cache): the sequence dim shards over the batch
      axes — plus "model" too when the KV heads cannot use it; batch (=1) is
      unsharded.
    """
    if ctx is None or ctx.mesh is None:
        return jax.tree.map(lambda s: None, cache_struct(cfg, 1, 1))
    kv_div = bool(cfg.n_kv_heads) and cfg.n_kv_heads % ctx.model_size == 0
    if ctx.seq_shard_cache:
        seq_axes = tuple(ctx.batch_axes) + (() if kv_div else (ctx.model_axis,))
        kv_spec = P(None, None, seq_axes, ctx.model_axis if kv_div else None, None)
    else:
        kv_spec = P(None, ctx.batch_axes,
                    None if kv_div else ctx.model_axis,
                    ctx.model_axis if kv_div else None, None)
    out: Dict[str, Any] = {}
    fam = cfg.family
    if fam in ("dense", "moe", "encdec", "vlm", "hybrid"):
        out["k"] = kv_spec
        out["v"] = kv_spec
    if fam in ("ssm", "hybrid"):
        b_ax = None if ctx.seq_shard_cache else ctx.batch_axes
        inner_ax = ctx.model_axis
        out["ssm"] = {
            "ssm": P(None, b_ax, inner_ax, None, None),
            "conv_x": P(None, b_ax, None, inner_ax),
            "conv_b": P(None, b_ax, None, None),
            "conv_c": P(None, b_ax, None, None),
        }
    if fam in ("encdec", "vlm"):
        out["memory"] = P(None if ctx.seq_shard_cache else ctx.batch_axes, None, None)
    out["index"] = P()
    return out


# ---------------------------------------------------------------------------
# prefill
# ---------------------------------------------------------------------------
def prefill(params, cfg: ModelConfig, batch, ctx=None, max_len: int = 0):
    tokens = batch["tokens"]
    B, Sq = tokens.shape
    max_len = max_len or Sq + 1
    n_patches = 0
    if cfg.family == "vlm":
        n_patches = batch["patches"].shape[1]
    elif cfg.family == "encdec":
        n_patches = batch["enc_input"].shape[1]
    cache = zeros_cache(cfg, B, max_len, ctx, n_patches=n_patches)
    x = embed_lookup(params["embed"]["table"], tokens, ctx)
    positions = jnp.arange(Sq)[None, :]
    flags = layer_flags(cfg)
    fam = cfg.family

    memory = None
    if fam == "encdec":
        memory = _encode(params, cfg, batch["enc_input"], ctx)
        cache["memory"] = memory
    elif fam == "vlm":
        memory = batch["patches"]
        cache["memory"] = memory

    if fam in ("ssm", "hybrid"):
        def body(carry, scanned):
            x, kbuf, vbuf = carry
            bp, fl = scanned
            if fam == "hybrid":
                def do_attn(args):
                    v, kb, vb = args
                    v2, kv = _shared_attn_block(params["shared"], cfg, v, positions)
                    slot = jnp.asarray(fl["attn_slot"], jnp.int32)
                    z = jnp.zeros((), jnp.int32)
                    kb = lax.dynamic_update_slice(
                        kb, kv["k"].astype(kb.dtype)[None], (slot, z, z, z, z))
                    vb = lax.dynamic_update_slice(
                        vb, kv["v"].astype(vb.dtype)[None], (slot, z, z, z, z))
                    return v2, kb, vb
                x, kbuf, vbuf = lax.cond(fl["use_attn"], do_attn,
                                         lambda a: a, (x, kbuf, vbuf))
            h = L.rmsnorm(x, bp["ln1"], cfg.norm_eps)
            out, st = S.ssm_block(bp["ssm"], cfg, h)
            return (x + out, kbuf, vbuf), st

        kbuf = cache.get("k")
        vbuf = cache.get("v")
        if fam == "ssm":
            kbuf = jnp.zeros((1,), cfg.jdtype)   # dummy carries
            vbuf = jnp.zeros((1,), cfg.jdtype)
        (x, kbuf, vbuf), states = lax.scan(body, (x, kbuf, vbuf),
                                           (params["blocks"], flags))
        cache["ssm"] = states
        if fam == "hybrid":
            # buffers hold the prompt K/V in [:Sq]
            cache["k"], cache["v"] = kbuf, vbuf
    else:
        def body(x, scanned):
            bp, fl = scanned
            x, kv = _self_attn(bp, cfg, x, window=fl["window"],
                               theta=fl["theta"], positions=positions)
            if fam == "vlm":
                x = lax.cond(fl["use_cross"],
                             lambda v: _cross_attn(bp, cfg, v, memory, gated=True),
                             lambda v: v, x)
            if fam == "encdec":
                x = _cross_attn(bp, cfg, x, memory, gated=False)
            return _ffn(bp, cfg, x, ctx), (kv["k"], kv["v"])

        x, (ks, vs) = lax.scan(body, x, (params["blocks"], flags))
        cache["k"] = lax.dynamic_update_slice(
            cache["k"], ks.astype(cache["k"].dtype), (0, 0, 0, 0, 0))
        cache["v"] = lax.dynamic_update_slice(
            cache["v"], vs.astype(cache["v"].dtype), (0, 0, 0, 0, 0))

    cache["index"] = jnp.int32(Sq)
    h = L.rmsnorm(x[:, -1:], params["final_norm"], cfg.norm_eps)
    return cache, logits_from_hidden(params, cfg, h)


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------
def decode_step(params, cfg: ModelConfig, cache, tokens: jnp.ndarray, ctx=None):
    """One decode step.  tokens: (B, 1).  Returns (new_cache, logits)."""
    x = embed_lookup(params["embed"]["table"], tokens, ctx)
    idx = cache["index"]
    fam = cfg.family
    flags = layer_flags(cfg)
    new_cache = dict(cache)

    if fam in ("ssm", "hybrid"):
        def body(carry, scanned):
            x, kbuf, vbuf = carry
            bp, fl, st = scanned
            if fam == "hybrid":
                def do_attn(args):
                    v, kb, vb = args
                    slot = jnp.asarray(fl["attn_slot"], jnp.int32)
                    z = jnp.zeros((), jnp.int32)
                    ck = lax.dynamic_index_in_dim(kb, slot, 0, keepdims=False)
                    cv = lax.dynamic_index_in_dim(vb, slot, 0, keepdims=False)
                    v2, kv = _shared_attn_block(params["shared"], cfg, v, None,
                                                cache={"k": ck, "v": cv},
                                                cache_index=idx)
                    kb = lax.dynamic_update_slice(kb, kv["k"][None],
                                                  (slot, z, z, z, z))
                    vb = lax.dynamic_update_slice(vb, kv["v"][None],
                                                  (slot, z, z, z, z))
                    return v2, kb, vb
                x, kbuf, vbuf = lax.cond(fl["use_attn"], do_attn,
                                         lambda a: a, (x, kbuf, vbuf))
            h = L.rmsnorm(x, bp["ln1"], cfg.norm_eps)
            out, new_st = S.ssm_block(bp["ssm"], cfg, h, state=st)
            return (x + out, kbuf, vbuf), new_st

        kbuf = cache.get("k") if fam == "hybrid" else jnp.zeros((1,), cfg.jdtype)
        vbuf = cache.get("v") if fam == "hybrid" else jnp.zeros((1,), cfg.jdtype)
        (x, kbuf, vbuf), new_states = lax.scan(
            body, (x, kbuf, vbuf), (params["blocks"], flags, cache["ssm"]))
        new_cache["ssm"] = new_states
        if fam == "hybrid":
            new_cache["k"], new_cache["v"] = kbuf, vbuf
    else:
        memory = cache.get("memory")

        def body(x, scanned):
            bp, fl, ck, cv = scanned
            h = L.rmsnorm(x, bp["ln1"], cfg.norm_eps)
            out, upd = L.attention(bp["attn"], cfg, h, window=fl["window"],
                                   theta=fl["theta"],
                                   cache={"k": ck, "v": cv}, cache_index=idx)
            x = x + out
            if fam == "vlm":
                x = lax.cond(fl["use_cross"],
                             lambda v: _cross_attn(bp, cfg, v, memory, gated=True),
                             lambda v: v, x)
            if fam == "encdec":
                x = _cross_attn(bp, cfg, x, memory, gated=False)
            return _ffn(bp, cfg, x, ctx), (upd["k"], upd["v"])

        x, (nk, nv) = lax.scan(body, x, (params["blocks"], flags,
                                         cache["k"], cache["v"]))
        new_cache["k"], new_cache["v"] = nk, nv

    new_cache["index"] = idx + 1
    h = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return new_cache, logits_from_hidden(params, cfg, h)
