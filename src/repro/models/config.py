"""Unified model configuration covering all assigned architecture families
(dense / MoE / SSM / hybrid / enc-dec / VLM)."""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0
    d_ff: int = 0
    vocab_size: int = 32000

    # attention variants
    qk_norm: bool = False
    rope_theta: float = 1e4
    window: int = 0              # sliding-window size for local layers (0 = full)
    local_global_ratio: int = 0  # gemma3: N local layers per global layer
    attn_logit_softcap: float = 0.0
    tie_embeddings: bool = False

    # MoE
    n_experts: int = 0
    top_k: int = 0
    d_expert: int = 0
    shared_expert: bool = False
    capacity_factor: float = 1.25
    moe_every: int = 1        # llama4: 2 => alternate dense/MoE layers

    # SSM (mamba2 / SSD)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    conv_width: int = 4
    ssm_chunk: int = 256
    attn_every: int = 0          # hybrid: shared attention block period

    # enc-dec
    enc_layers: int = 0
    # vlm
    cross_attn_every: int = 0
    n_patches: int = 0

    dtype: str = "bfloat16"
    norm_eps: float = 1e-6

    # ----------------------------------------------------------------- #
    @property
    def jdtype(self):
        return jnp.dtype(self.dtype)

    @property
    def ssm_heads(self) -> int:
        return (self.ssm_expand * self.d_model) // self.ssm_head_dim if self.ssm_state else 0

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model if self.ssm_state else 0

    def validate(self) -> "ModelConfig":
        assert self.family in ("dense", "moe", "ssm", "hybrid", "encdec", "vlm"), self.family
        if self.family in ("dense", "moe", "encdec", "vlm"):
            assert self.n_heads > 0 and self.head_dim > 0
            assert self.n_heads % max(1, self.n_kv_heads) == 0
        if self.family == "moe":
            assert self.n_experts > 0 and self.top_k > 0 and self.d_expert > 0
        if self.family in ("ssm", "hybrid"):
            assert self.ssm_state > 0
            assert self.d_inner % self.ssm_head_dim == 0
        return self

    def reduced(self, **overrides) -> "ModelConfig":
        """A small same-family config for CPU smoke tests."""
        base = dict(
            n_layers=min(self.n_layers, 4),
            d_model=128,
            n_heads=4 if self.n_heads else 0,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads else 0,
            head_dim=32 if self.n_heads else 0,
            d_ff=256 if self.d_ff else 0,
            vocab_size=512,
            n_experts=min(self.n_experts, 8) if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            d_expert=64 if self.d_expert else 0,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_head_dim=32 if self.ssm_state else 64,
            ssm_chunk=32,
            enc_layers=min(self.enc_layers, 2) if self.enc_layers else 0,
            n_patches=16 if self.n_patches else 0,
            window=min(self.window, 64) if self.window else 0,
            attn_every=2 if self.attn_every else 0,
            cross_attn_every=2 if self.cross_attn_every else 0,
            dtype="float32",
            name=self.name + "-smoke",
        )
        # keep MHA for models whose kv == heads
        if self.n_kv_heads and self.n_kv_heads == self.n_heads:
            base["n_kv_heads"] = base["n_heads"]
        base.update(overrides)
        return dataclasses.replace(self, **base).validate()

    # -- parameter counting (for roofline MODEL_FLOPS = 6*N*D) ----------- #
    def param_count(self, active_only: bool = False) -> int:
        """Approximate parameter count; ``active_only`` counts MoE experts
        at top_k/n_experts weight (for 6*N_active*D)."""
        d, v = self.d_model, self.vocab_size
        emb = v * d * (1 if self.tie_embeddings else 2)
        att = 0
        if self.n_heads:
            q = d * self.n_heads * self.head_dim
            kv = 2 * d * self.n_kv_heads * self.head_dim
            o = self.n_heads * self.head_dim * d
            att = q + kv + o
        ffn = 3 * d * self.d_ff if self.d_ff else 0
        moe = 0
        if self.n_experts:
            per_expert = 3 * d * self.d_expert
            n_eff = self.top_k if active_only else self.n_experts
            moe = per_expert * n_eff + d * self.n_experts  # + router
            if self.shared_expert:
                moe += 3 * d * self.d_ff if self.d_ff else per_expert
        ssm = 0
        if self.ssm_state:
            di = self.d_inner
            ssm = d * (2 * di + 2 * self.ssm_state + self.ssm_heads) + di * d + di
        per_layer = (att + (moe if self.n_experts else ffn)
                     + (ssm if self.family in ("ssm", "hybrid") else 0))
        if self.family == "ssm":
            per_layer = ssm
        if self.family == "hybrid":
            # mamba layers + one shared attention/ffn block
            return emb + self.n_layers * ssm + (att + ffn)  # shared block counted once
        n = self.n_layers + (self.enc_layers if self.family == "encdec" else 0)
        return emb + n * per_layer
