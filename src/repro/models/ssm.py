"""Mamba2 (SSD — state-space duality) block, chunked for training/prefill
and recurrent for decode.

Layout: x (B, T, D) -> in-projections (separate z/x/BC/dt projections so TP
sharding stays clean — the fused in_proj of the reference implementation is
split; same math, documented in DESIGN.md):

* z  (B,T,di)         gate branch            [di = expand*D, sharded "model"]
* xs (B,T,di)         conv -> SSD input (heads H = di/P, P = head_dim)
* B,C (B,T,N)         state in/out projections (replicated; single group)
* dt (B,T,H)          per-head step size

SSD chunked algorithm (Dao & Gu 2024): split T into chunks of L; within a
chunk the recurrence is materialized as a masked decay "attention"; across
chunks a (B,H,N,P) state is carried by a scan.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from .layers import Params, Spec, rmsnorm


def ssm_spec(cfg) -> Spec:
    d, di, n, h = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    w = cfg.conv_width
    return {
        "wz": ((d, di), ("embed", "ssm_inner")),
        "wx": ((d, di), ("embed", "ssm_inner")),
        "wb": ((d, n), ("embed", "state")),
        "wc": ((d, n), ("embed", "state")),
        "wdt": ((d, h), ("embed", "ssm_inner")),
        "dt_bias": ((h,), ("ssm_inner",)),
        "a_log": ((h,), ("ssm_inner",)),
        "d_skip": ((h,), ("ssm_inner",)),
        "conv_x": ((w, di), (None, "ssm_inner")),
        "conv_b": ((w, n), (None, "state")),
        "conv_c": ((w, n), (None, "state")),
        "norm": ((di,), ("ssm_inner",)),
        "wo": ((di, d), ("ssm_inner", "embed")),
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray,
                 state: Optional[jnp.ndarray] = None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Depthwise causal conv.  x: (B,T,C), w: (W,C).  Returns (y, new_state)
    with state = last W-1 inputs (for decode continuation)."""
    W = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], W - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)           # (B, T+W-1, C)
    y = jnp.zeros_like(x)
    for i in range(W):
        y = y + xp[:, i:i + x.shape[1]] * w[i]
    new_state = xp[:, -(W - 1):] if W > 1 else state
    return y, new_state


def ssd_chunked(xs, dt, a, Bm, Cm, *, chunk: int,
                initial_state: Optional[jnp.ndarray] = None):
    """SSD scan. xs: (B,T,H,P); dt: (B,T,H); a: (H,) (negative);
    Bm/Cm: (B,T,N).  Returns (y (B,T,H,P), final_state (B,H,N,P))."""
    Bsz, T, H, P = xs.shape
    N = Bm.shape[-1]
    L = min(chunk, T)
    nc = -(-T // L)
    pad = nc * L - T
    if pad:
        xs = jnp.pad(xs, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))

    xs = xs.reshape(Bsz, nc, L, H, P)
    dt = dt.reshape(Bsz, nc, L, H).astype(jnp.float32)
    Bm = Bm.reshape(Bsz, nc, L, N)
    Cm = Cm.reshape(Bsz, nc, L, N)

    la = dt * a                                   # log-decay per step (B,c,L,H)
    cs = jnp.cumsum(la, axis=2)                   # within-chunk cumulative
    seg_end = cs[:, :, -1, :]                     # (B,c,H) total chunk decay

    # ---- intra-chunk (masked decay attention) -----------------------------
    # decay(i,j) = exp(cs_i - cs_j) for i >= j
    diff = cs[:, :, :, None, :] - cs[:, :, None, :, :]       # (B,c,L,L,H)
    mask = jnp.tril(jnp.ones((L, L), bool))
    decay = jnp.where(mask[None, None, :, :, None], jnp.exp(diff), 0.0)
    cb = jnp.einsum("bcin,bcjn->bcij", Cm.astype(jnp.float32),
                    Bm.astype(jnp.float32))                   # (B,c,L,L)
    xdt = xs.astype(jnp.float32) * dt[..., None]              # (B,c,L,H,P)
    y_intra = jnp.einsum("bcij,bcijh,bcjhp->bcihp", cb, decay, xdt)

    # ---- chunk states ------------------------------------------------------
    # state contribution of chunk c: sum_j exp(seg_end - cs_j) * dt_j B_j x_j
    w_end = jnp.exp(seg_end[:, :, None, :] - cs)              # (B,c,L,H)
    states = jnp.einsum("bcjn,bcjh,bcjhp->bchnp", Bm.astype(jnp.float32),
                        w_end * dt, xs.astype(jnp.float32))   # (B,c,H,N,P)

    # ---- inter-chunk recurrence -------------------------------------------
    def step(carry, inp):
        s_prev = carry                                        # (B,H,N,P)
        st, dec = inp                                         # (B,H,N,P), (B,H)
        s_new = s_prev * jnp.exp(dec)[:, :, None, None] + st
        return s_new, s_prev

    s0 = (initial_state.astype(jnp.float32) if initial_state is not None
          else jnp.zeros((Bsz, H, N, P), jnp.float32))
    final, s_prevs = lax.scan(step,
                              s0,
                              (states.transpose(1, 0, 2, 3, 4),
                               seg_end.transpose(1, 0, 2)))
    s_prevs = s_prevs.transpose(1, 0, 2, 3, 4)                # (B,c,H,N,P)

    # y_inter_i = (C_i . S_prev) * exp(cs_i)
    y_inter = jnp.einsum("bcin,bchnp,bcih->bcihp", Cm.astype(jnp.float32),
                         s_prevs, jnp.exp(cs))
    y = (y_intra + y_inter).reshape(Bsz, nc * L, H, P)[:, :T]
    return y, final


def ssm_block(p: Params, cfg, x: jnp.ndarray, *,
              state: Optional[Dict[str, jnp.ndarray]] = None,
              ) -> Tuple[jnp.ndarray, Optional[Dict[str, jnp.ndarray]]]:
    """Mamba2 block.  Training/prefill: state=None -> (y, final_state).
    Decode: state={'ssm','conv_x','conv_b','conv_c'} -> one-step update."""
    B, T, D = x.shape
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    z = x @ p["wz"]
    xs = x @ p["wx"]
    bm = x @ p["wb"]
    cm = x @ p["wc"]
    dt = jax.nn.softplus((x @ p["wdt"]).astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(p["a_log"].astype(jnp.float32))

    cs_x = state["conv_x"] if state else None
    cs_b = state["conv_b"] if state else None
    cs_c = state["conv_c"] if state else None
    xs, ns_x = _causal_conv(xs, p["conv_x"], cs_x)
    bm, ns_b = _causal_conv(bm, p["conv_b"], cs_b)
    cm, ns_c = _causal_conv(cm, p["conv_c"], cs_c)
    xs, bm, cm = jax.nn.silu(xs), jax.nn.silu(bm), jax.nn.silu(cm)
    xs_h = xs.reshape(B, T, H, P)

    if state is None:
        y, final = ssd_chunked(xs_h, dt, a, bm, cm, chunk=cfg.ssm_chunk)
        new_state = {"ssm": final, "conv_x": ns_x, "conv_b": ns_b, "conv_c": ns_c}
    else:
        # single-step recurrence (T == 1)
        s = state["ssm"].astype(jnp.float32)                  # (B,H,N,P)
        dt1 = dt[:, 0]                                        # (B,H)
        dec = jnp.exp(dt1 * a)                                # (B,H)
        upd = jnp.einsum("bn,bh,bhp->bhnp", bm[:, 0].astype(jnp.float32),
                         dt1, xs_h[:, 0].astype(jnp.float32))
        s = s * dec[:, :, None, None] + upd
        y = jnp.einsum("bn,bhnp->bhp", cm[:, 0].astype(jnp.float32), s)[:, None]
        new_state = {"ssm": s, "conv_x": ns_x, "conv_b": ns_b, "conv_c": ns_c}

    y = y + xs_h.astype(jnp.float32) * p["d_skip"].astype(jnp.float32)[:, None]
    y = y.reshape(B, T, H * P)
    y = rmsnorm(y.astype(x.dtype), p["norm"], cfg.norm_eps) * jax.nn.silu(z)
    return y @ p["wo"], new_state


def ssm_state_spec(cfg, batch: int, dtype) -> Dict[str, jax.ShapeDtypeStruct]:
    H, P, N, W = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state, cfg.conv_width
    di = cfg.d_inner
    return {
        "ssm": jax.ShapeDtypeStruct((batch, H, N, P), jnp.float32),
        "conv_x": jax.ShapeDtypeStruct((batch, W - 1, di), dtype),
        "conv_b": jax.ShapeDtypeStruct((batch, W - 1, N), dtype),
        "conv_c": jax.ShapeDtypeStruct((batch, W - 1, N), dtype),
    }
