"""Transformer / MoE layer primitives as pure functions over param pytrees.

Conventions
-----------
* params are nested dicts of jnp arrays; every leaf has a parallel entry in
  the *spec tree* built by the ``*_spec`` functions: ``(shape, logical_axes)``
  where logical axes are drawn from LOGICAL_AXES and mapped to mesh axes by
  ``repro.sharding.rules``.
* activations are (batch, seq, d_model); batch shards over ("pod","data"),
  d_model is unsharded (Megatron TP), heads/ff/vocab/experts shard on
  "model".
* attention uses a two-level chunked lazy-softmax sweep (pure XLA; memory
  O(q_chunk x kv_chunk)) so 32k-sequence prefill fits HBM without Pallas —
  the Pallas flash kernel in ``repro.kernels`` is the TPU fast path.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from .compat import shard_map

# logical axis vocabulary (mapped to mesh axes in repro.sharding.rules)
LOGICAL_AXES = ("batch", "seq", "embed", "heads", "kv_heads", "ff", "vocab",
                "experts", "ssm_inner", "state", None)

Spec = Dict[str, Any]          # nested dict: leaf = (shape, axes)
Params = Dict[str, Any]        # nested dict: leaf = jnp.ndarray


# ---------------------------------------------------------------------------
# spec/materialize machinery
# ---------------------------------------------------------------------------
def materialize(spec: Spec, key: jax.Array, dtype, scale_rule=None) -> Params:
    """Initialize a param tree from a spec tree (trunc-normal fan-in)."""
    leaves = []

    def _walk(s, path):
        if isinstance(s, dict):
            return {k: _walk(v, path + (k,)) for k, v in s.items()}
        leaves.append((path, s))
        return None

    _walk(spec, ())
    keys = jax.random.split(key, max(1, len(leaves)))
    out: Dict = {}
    for (path, (shape, axes)), k in zip(leaves, keys):
        if len(shape) >= 2:
            fan_in = shape[-2] if len(shape) == 2 else math.prod(shape[:-1])
            std = 1.0 / math.sqrt(fan_in)
            v = (jax.random.truncated_normal(k, -2.0, 2.0, shape, jnp.float32) * std).astype(dtype)
        elif path[-1].startswith(("norm", "gamma")) or path[-1] in ("scale",):
            v = jnp.ones(shape, dtype)
        else:
            v = jnp.zeros(shape, dtype)
        node = out
        for p in path[:-1]:
            node = node.setdefault(p, {})
        node[path[-1]] = v
    return out


def abstract(spec: Spec, dtype) -> Params:
    """ShapeDtypeStruct tree from a spec tree (for dry-run lowering)."""
    if isinstance(spec, dict):
        return {k: abstract(v, dtype) for k, v in spec.items()}
    shape, _ = spec
    return jax.ShapeDtypeStruct(shape, dtype)


def spec_axes(spec: Spec):
    """Logical-axes tree parallel to the param tree."""
    if isinstance(spec, dict):
        return {k: spec_axes(v) for k, v in spec.items()}
    _, axes = spec
    return axes


def stack_spec(spec: Spec, n: int) -> Spec:
    """Prepend a layer axis of size n to every leaf (for scan stacks)."""
    if isinstance(spec, dict):
        return {k: stack_spec(v, n) for k, v in spec.items()}
    shape, axes = spec
    return ((n,) + tuple(shape), ("layers",) + tuple(axes))


# ---------------------------------------------------------------------------
# norms / rope
# ---------------------------------------------------------------------------
def rmsnorm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * scale.astype(jnp.float32)).astype(dt)


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta) -> jnp.ndarray:
    """x: (..., S, H, hd); positions: (..., S).  ``theta`` may be a traced
    scalar (heterogeneous stacks pass per-layer theta through scan)."""
    hd = x.shape[-1]
    half = hd // 2
    log_theta = jnp.log(jnp.asarray(theta, jnp.float32))
    freqs = jnp.exp(-jnp.arange(0, half, dtype=jnp.float32) * (log_theta / half))
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    cos = cos[..., :, None, :]
    sin = sin[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------
def attn_spec(cfg) -> Spec:
    hd, d = cfg.head_dim, cfg.d_model
    s: Spec = {
        "wq": ((d, cfg.n_heads * hd), ("embed", "heads")),
        "wk": ((d, cfg.n_kv_heads * hd), ("embed", "kv_heads")),
        "wv": ((d, cfg.n_kv_heads * hd), ("embed", "kv_heads")),
        "wo": ((cfg.n_heads * hd, d), ("heads", "embed")),
    }
    if cfg.qk_norm:
        s["gamma_q"] = ((hd,), (None,))
        s["gamma_k"] = ((hd,), (None,))
    return s


def _chunked_attn(q, k, v, *, causal: bool, window: int, q_offset,
                  q_chunk: int = 512, kv_chunk: int = 1024):
    """Lazy-softmax chunked attention.

    q: (B, Sq, H, hd); k/v: (B, Sk, KV, hd); returns (B, Sq, H, hd).
    ``q_offset``: absolute position of q[0] (decode/prefill continuation).
    Memory: O(q_chunk * kv_chunk) per (batch, head).
    """
    B, Sq, H, hd = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    rep = H // KV
    if rep > 1:
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    scale = 1.0 / math.sqrt(hd)
    qc = min(q_chunk, Sq)
    kc = min(kv_chunk, Sk)
    nq = -(-Sq // qc)
    nk = -(-Sk // kc)
    # pad to chunk multiples
    q = jnp.pad(q, ((0, 0), (0, nq * qc - Sq), (0, 0), (0, 0)))
    k = jnp.pad(k, ((0, 0), (0, nk * kc - Sk), (0, 0), (0, 0)))
    v = jnp.pad(v, ((0, 0), (0, nk * kc - Sk), (0, 0), (0, 0)))
    q = q.reshape(B, nq, qc, H, hd).transpose(1, 0, 3, 2, 4)   # (nq,B,H,qc,hd)
    k = k.reshape(B, nk, kc, H, hd).transpose(1, 0, 3, 2, 4)
    v = v.reshape(B, nk, kc, H, hd).transpose(1, 0, 3, 2, 4)

    q_pos_base = jnp.arange(qc)
    k_pos_base = jnp.arange(kc)

    def q_block(qi_and_qb):
        qi, qb = qi_and_qb
        q_pos = q_offset + qi * qc + q_pos_base          # (qc,)

        def kv_step(carry, kj_and_kv):
            m, l, acc = carry
            kj, kb, vb = kj_and_kv
            k_pos = kj * kc + k_pos_base                 # (kc,)
            s = jnp.einsum("bhqd,bhkd->bhqk", qb, kb,
                           preferred_element_type=jnp.float32) * scale
            mask = jnp.ones((qc, kc), dtype=bool)
            if causal:
                mask &= q_pos[:, None] >= k_pos[None, :]
            win = jnp.asarray(window)
            mask &= (win <= 0) | (q_pos[:, None] - k_pos[None, :] < win)
            s = jnp.where(mask[None, None], s, -jnp.inf)
            m_new = jnp.maximum(m, s.max(axis=-1))
            # guard fully-masked rows (m_new = -inf)
            m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
            p = jnp.exp(s - m_safe[..., None])          # masked -> exp(-inf) = 0
            corr = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhqk,bhkd->bhqd", p.astype(vb.dtype), vb,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, H, qc), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((B, H, qc), jnp.float32)
        a0 = jnp.zeros((B, H, qc, hd), jnp.float32)
        # checkpoint the kv step: backward re-materializes s/p per chunk
        # instead of saving O(qc*kc) residuals for every chunk pair
        (m, l, acc), _ = lax.scan(
            jax.checkpoint(kv_step,
                           policy=jax.checkpoint_policies.nothing_saveable),
            (m0, l0, a0), (jnp.arange(nk), k, v))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return out                                        # (B,H,qc,hd)

    outs = lax.map(q_block, (jnp.arange(nq), q))          # (nq,B,H,qc,hd)
    out = outs.transpose(1, 0, 3, 2, 4).reshape(B, nq * qc, H, hd)
    return out[:, :Sq].astype(v.dtype)


def attention(p: Params, cfg, x: jnp.ndarray, *,
              causal: bool = True,
              window: int = 0,
              theta=None,
              positions: Optional[jnp.ndarray] = None,
              memory: Optional[jnp.ndarray] = None,
              cache: Optional[Dict[str, jnp.ndarray]] = None,
              cache_index: Optional[jnp.ndarray] = None,
              use_rope: bool = True,
              ) -> Tuple[jnp.ndarray, Optional[Dict[str, jnp.ndarray]]]:
    """Self- or cross-attention with optional KV cache.

    * training/prefill: ``cache is None`` -> returns (out, new_kv) where
      new_kv is the full K/V (for prefill cache construction).
    * decode: ``cache={'k','v'}`` (B, S_max, KV, hd), ``cache_index`` the
      current length; x is (B, 1, D); returns (out, updated_cache).
    * cross-attention: ``memory`` (B, M, D) supplies K/V (no cache logic,
      no causal mask).
    """
    B, S, D = x.shape
    hd = cfg.head_dim
    if theta is None:
        theta = cfg.rope_theta
    kv_src = memory if memory is not None else x
    q = (x @ p["wq"]).reshape(B, S, cfg.n_heads, hd)
    k = (kv_src @ p["wk"]).reshape(B, kv_src.shape[1], cfg.n_kv_heads, hd)
    v = (kv_src @ p["wv"]).reshape(B, kv_src.shape[1], cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        q = rmsnorm(q, p["gamma_q"], cfg.norm_eps)
        k = rmsnorm(k, p["gamma_k"], cfg.norm_eps)

    if memory is not None:
        # cross attention: full, non-causal, no rope
        out = _chunked_attn(q, k, v, causal=False, window=0, q_offset=0)
        out = out.reshape(B, S, cfg.n_heads * hd) @ p["wo"]
        return out, None

    if cache is None:
        if positions is None:
            positions = jnp.arange(S)[None, :]
        if use_rope:
            q = rope(q, positions, theta)
            k = rope(k, positions, theta)
        out = _chunked_attn(q, k, v, causal=causal, window=window, q_offset=0)
        out = out.reshape(B, S, cfg.n_heads * hd) @ p["wo"]
        return out, {"k": k, "v": v}

    # -- decode step ------------------------------------------------------
    idx = cache_index  # scalar int32: current cache fill
    if use_rope:
        q = rope(q, jnp.full((B, S), idx, jnp.int32), theta)
        k = rope(k, jnp.full((B, S), idx, jnp.int32), theta)
    z = jnp.zeros((), jnp.int32)
    idx32 = jnp.asarray(idx, jnp.int32)
    ck = lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                  (z, idx32, z, z))
    cv = lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                  (z, idx32, z, z))
    out = decode_attention(q, ck, cv, idx + S, window=window)
    out = out.reshape(B, S, cfg.n_heads * hd) @ p["wo"]
    return out, {"k": ck, "v": cv}


def decode_attention(q, ck, cv, length, *, window: int = 0):
    """Single-step attention against a (possibly longer-than-filled) cache.

    q: (B, 1, H, hd); ck/cv: (B, S_max, KV, hd); `length` = #valid entries.
    O(S_max) memory — fine for decode.  Sequence-sharded variant lives in
    repro.sharding.sp (flash-decoding split-K with LSE combine).
    """
    B, _, H, hd = q.shape
    S_max, KV = ck.shape[1], ck.shape[2]
    rep = H // KV
    scale = 1.0 / math.sqrt(hd)
    qh = q[:, 0].reshape(B, KV, rep, hd)
    s = jnp.einsum("bgrd,bsgd->bgrs", qh, ck,
                   preferred_element_type=jnp.float32) * scale
    pos = jnp.arange(S_max)
    mask = pos[None, None, None, :] < length
    win = jnp.asarray(window)
    mask &= (win <= 0) | (pos[None, None, None, :] > length - 1 - win)
    s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bgrs,bsgd->bgrd", p.astype(cv.dtype), cv,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, 1, H, hd).astype(cv.dtype)


# ---------------------------------------------------------------------------
# MLP (SwiGLU)
# ---------------------------------------------------------------------------
def mlp_spec(cfg, d_ff: Optional[int] = None) -> Spec:
    f = d_ff or cfg.d_ff
    d = cfg.d_model
    return {
        "wg": ((d, f), ("embed", "ff")),
        "wu": ((d, f), ("embed", "ff")),
        "wd": ((f, d), ("ff", "embed")),
    }


def mlp(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    return (jax.nn.silu(x @ p["wg"]) * (x @ p["wu"])) @ p["wd"]


# ---------------------------------------------------------------------------
# MoE (dropless-ish: per-expert static capacity, EP over 'model')
# ---------------------------------------------------------------------------
def moe_spec(cfg) -> Spec:
    d, e, fe = cfg.d_model, cfg.n_experts, cfg.d_expert
    s: Spec = {
        "router": ((d, e), ("embed", None)),
        "wg": ((e, d, fe), ("experts", "embed", None)),
        "wu": ((e, d, fe), ("experts", "embed", None)),
        "wd": ((e, fe, d), ("experts", None, "embed")),
    }
    if cfg.shared_expert:
        s["shared"] = mlp_spec(cfg, cfg.d_ff or cfg.d_expert)
    return s


def _moe_compute(x_flat, ids, wts, wg, wu, wd, e_offset, n_local, capacity):
    """Compute contributions of experts [e_offset, e_offset+n_local) to the
    tokens in x_flat.  ids/wts: (T, k) global routing.  Returns (T, D)."""
    T, D = x_flat.shape
    capacity = min(capacity, T)
    y = jnp.zeros((T, D), jnp.float32)
    for le in range(n_local):
        ge = e_offset + le
        match = (ids == ge)                      # (T, k)
        weight = jnp.sum(jnp.where(match, wts, 0.0), axis=1)   # (T,)
        assigned = weight > 0
        # top-`capacity` assigned token slots (ties keep lowest index)
        score = assigned.astype(jnp.float32)
        _, token_idx = lax.top_k(score, capacity)             # (C,)
        valid = assigned[token_idx]
        xe = x_flat[token_idx]                                 # (C, D)
        h = jax.nn.silu(xe @ wg[le]) * (xe @ wu[le])
        ye = (h @ wd[le]).astype(jnp.float32)
        ye = ye * (weight[token_idx] * valid)[:, None]
        y = y.at[token_idx].add(jnp.where(valid[:, None], ye, 0.0))
    return y


def moe(p: Params, cfg, x: jnp.ndarray, *, shard_ctx=None) -> jnp.ndarray:
    """Top-k MoE FFN.  With ``shard_ctx`` (repro.sharding.rules.ShardCtx):
    experts shard over the model axis via shard_map — tokens stay sharded on
    the batch axes and replicated on the model axis; per-chip experts compute
    their capacity-cropped assignments and outputs psum-combine over the
    model axis (Megatron-style EP).  Without: single-device reference path."""
    B, S, D = x.shape
    T = B * S
    x_flat = x.reshape(T, D)
    logits = (x_flat @ p["router"]).astype(jnp.float32)        # (T, E)
    wts, ids = lax.top_k(jax.nn.softmax(logits, axis=-1), cfg.top_k)
    wts = wts / jnp.maximum(wts.sum(-1, keepdims=True), 1e-9)

    if shard_ctx is None or shard_ctx.mesh is None:
        cap = _moe_capacity(T, cfg)
        y = _moe_compute(x_flat, ids, wts, p["wg"], p["wu"], p["wd"],
                         0, cfg.n_experts, cap)
    else:
        from jax.sharding import PartitionSpec as P
        mesh = shard_ctx.mesh
        ep_axis = shard_ctx.model_axis
        batch_axes = shard_ctx.batch_axes
        ep = mesh.shape[ep_axis]
        dp = 1
        for a in batch_axes:
            dp *= mesh.shape[a]
        n_local = cfg.n_experts // ep
        t_local = T // dp
        cap = _moe_capacity(t_local, cfg)

        wire_bf16 = bool(getattr(shard_ctx, "moe_wire_bf16", False))
        gather_tokens = bool(getattr(shard_ctx, "moe_gather_tokens", False))

        if gather_tokens:
            # Beyond-baseline EP (EXPERIMENTS §Perf cell C): expert weights
            # stay 2D-sharded (experts on model, d_model on data) and are
            # NEVER gathered; instead the (much smaller) tokens all-gather
            # over the data axes and the expert contractions run partial
            # over the d_model shard + psum.  Collective volume per layer
            # drops from O(expert_params) to O(tokens x d_model).
            cap_g = min(_moe_capacity(T, cfg), T)

            def _shard_fn_g(xf, idl, wtl, wg, wu, wd):
                eidx = lax.axis_index(ep_axis)
                xg = lax.all_gather(xf, batch_axes, axis=0, tiled=True)
                idg = lax.all_gather(idl, batch_axes, axis=0, tiled=True)
                wtg = lax.all_gather(wtl, batch_axes, axis=0, tiled=True)
                Tg, _ = xg.shape
                dloc = wg.shape[1]
                didx = lax.axis_index(batch_axes) if len(batch_axes) == 1 else (
                    lax.axis_index(batch_axes[0]) * mesh.shape[batch_axes[1]]
                    + lax.axis_index(batch_axes[1]))
                # accumulate each expert's output in the chip's LOCAL d_model
                # columns only — (Tg, dloc) instead of (Tg, D)
                y = jnp.zeros((Tg, dloc), jnp.float32)
                for le in range(n_local):
                    ge = eidx * n_local + le
                    match = (idg == ge)
                    weight = jnp.sum(jnp.where(match, wtg, 0.0), axis=1)
                    assigned = weight > 0
                    _, token_idx = lax.top_k(assigned.astype(jnp.float32), cap_g)
                    valid = assigned[token_idx]
                    xe = xg[token_idx]                       # (C, D) full D
                    xe_part = lax.dynamic_slice(xe, (0, didx * dloc),
                                                (cap_g, dloc))
                    # partial contraction over the local d_model shard
                    hg = lax.psum((xe_part @ wg[le]).astype(jnp.float32),
                                  batch_axes)
                    hu = lax.psum((xe_part @ wu[le]).astype(jnp.float32),
                                  batch_axes)
                    h = jax.nn.silu(hg) * hu                 # (C, Fe) complete
                    ye = (h.astype(xg.dtype) @ wd[le]).astype(jnp.float32)
                    ye = ye * (weight[token_idx] * valid)[:, None]
                    y = y.at[token_idx].add(jnp.where(valid[:, None], ye, 0.0))
                # redistribute rows<->cols: (Tg, dloc) -> (T_local, D): one
                # all-to-all over the batch axes, then combine experts over
                # the model axis on local rows only
                wire = y.astype(jnp.bfloat16) if wire_bf16 else y
                yl = lax.all_to_all(wire, batch_axes, split_axis=0,
                                    concat_axis=1, tiled=True)
                return lax.psum(yl, ep_axis).astype(jnp.float32)

            y = shard_map(
                _shard_fn_g, mesh=mesh,
                in_specs=(P(batch_axes, None), P(batch_axes, None),
                          P(batch_axes, None),
                          P(ep_axis, batch_axes, None),
                          P(ep_axis, batch_axes, None),
                          P(ep_axis, None, batch_axes)),
                out_specs=P(batch_axes, None),
                check_vma=False,
            )(x_flat, ids, wts, p["wg"], p["wu"], p["wd"])
            y = y.astype(x.dtype).reshape(B, S, D)
            if cfg.shared_expert and "shared" in p:
                y = y + mlp(p["shared"], x)
            return y

        def _shard_fn(xf, idl, wtl, wg, wu, wd):
            eidx = lax.axis_index(ep_axis)
            y = _moe_compute(xf, idl, wtl, wg, wu, wd,
                             eidx * n_local, n_local, cap)
            if wire_bf16:
                # EP combine on the wire in bf16 (halves the all-reduce)
                return lax.psum(y.astype(jnp.bfloat16), ep_axis).astype(jnp.float32)
            return lax.psum(y, ep_axis)

        y = shard_map(
            _shard_fn, mesh=mesh,
            in_specs=(P(batch_axes, None), P(batch_axes, None), P(batch_axes, None),
                      P(ep_axis), P(ep_axis), P(ep_axis)),
            out_specs=P(batch_axes, None),
            check_vma=False,
        )(x_flat, ids, wts, p["wg"], p["wu"], p["wd"])

    y = y.astype(x.dtype).reshape(B, S, D)
    if cfg.shared_expert and "shared" in p:
        y = y + mlp(p["shared"], x)
    return y


def _moe_capacity(t_local: int, cfg) -> int:
    return max(1, int(t_local * cfg.top_k * cfg.capacity_factor / cfg.n_experts))


# ---------------------------------------------------------------------------
# embedding / unembedding / loss (vocab-sharded via shard_map at model level)
# ---------------------------------------------------------------------------
def embed_spec(cfg) -> Spec:
    return {"table": ((cfg.vocab_size, cfg.d_model), ("vocab", "embed"))}


def unembed_spec(cfg) -> Spec:
    return {"out": ((cfg.d_model, cfg.vocab_size), ("embed", "vocab"))}
