"""Decode-step task graphs for the serving loop.

``examples/serve_lm.py`` decodes token-by-token: every step applies the same
computation to every request in the batch.  This module expresses one decode
step as a :class:`~repro.core.taskgraph.TaskGraph` — the batch is split into
*shards*, each shard gets a ``decode -> sample`` task chain, and a final
``gather`` task joins the step — so the step can run on the task-graph
runtime and, because every step builds the *same graph shape* (names, kinds,
costs, dependencies — the callables differ but :func:`~repro.replay.graph_key`
ignores callables), the whole decode loop replays from one recording via the
:class:`~repro.replay.ReplayPool`.

State lives in a mutable :class:`DecodeState` (the serving analogue of the
tile stores the factorization graphs close over): each shard owns its KV
cache and current token, task bodies read/write their own shard, and the
dependency/channel edges order every access — replay is bit-identical to
dynamic execution regardless of interleaving.

The gather join is a *suspendable frame* over a
:class:`~repro.core.taskgraph.Channel`: each shard's sample task ``send``\\ s
its token as soon as it is drawn, and the gather generator ``recv``\\ s them
one by one — overlapping the join's assembly with the remaining shards'
decode/sample instead of barriering on all of them (and never pinning a
worker while it waits; the frame suspends).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional

from ..api.graph import Graph
from ..compile.fuse import FuseSpec
from ..core.taskgraph import Channel, TaskGraph
from ..resources import Resource

# decode_fn(params, cache, tok) -> (new_cache, logits); sample_fn(logits) -> tok
DecodeFn = Callable[[Any, Any, Any], Any]
SampleFn = Callable[[Any], Any]


def greedy_sample(logits: Any) -> Any:
    """Argmax over the last position — the serve_lm default sampler."""
    import jax.numpy as jnp

    return jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)


@dataclasses.dataclass
class DecodeShard:
    """One batch shard's mutable serving state."""

    cache: Any
    tok: Any
    logits: Any = None


class _DecodeFuseState:
    """Fuse-state adapter over a :class:`DecodeState`: ``("params",)``
    resolves to the shared parameters, ``("cache", s)`` / ``("tok", s)`` /
    ``("logits", s)`` to shard ``s``'s fields."""

    __slots__ = ("state",)

    def __init__(self, state: "DecodeState"):
        self.state = state

    def __getitem__(self, k):
        if k[0] == "params":
            return self.state.params
        return getattr(self.state.shards[k[1]], k[0])

    def __setitem__(self, k, v):
        if k[0] == "params":
            self.state.params = v
        else:
            setattr(self.state.shards[k[1]], k[0], v)


class DecodeState:
    """Sharded decode-loop state driven by the decode-step graph.

    ``shards[s]`` is read and written only by shard ``s``'s tasks;
    ``step_tokens`` / ``history`` are written only by the gather task.
    """

    def __init__(self, params: Any, shards: List[DecodeShard]):
        self.params = params
        self.shards = shards
        self.step_tokens: Any = None
        self.history: List[Any] = []

    @property
    def n_shards(self) -> int:
        return len(self.shards)

    def tokens(self) -> Any:
        """All sampled tokens so far, concatenated (batch, steps)."""
        import jax.numpy as jnp

        return jnp.concatenate(self.history, axis=1)


def kv_page_resources(n_shards: int) -> List[Resource]:
    """One exclusive KV-page :class:`~repro.resources.Resource` per decode
    lane.  Resource identity in the graph digest is (name, capacity), so
    rebuilding per step — even with fresh handles — keeps the digest stable
    and the decode loop replayable."""
    return [Resource(f"kv_page{s}") for s in range(n_shards)]


def build_decode_graph(
    state: DecodeState,
    decode_fn: DecodeFn,
    sample_fn: Optional[SampleFn] = None,
    *,
    kv_pages: Optional[List[Resource]] = None,
    maintenance_fn: Optional[Callable[["DecodeState"], Any]] = None,
) -> TaskGraph:
    """One decode step over ``state``: per shard ``decode -> sample``, plus a
    ``gather`` frame receiving each shard's token over a
    :class:`~repro.core.taskgraph.Channel` as it is sampled.  Rebuilding per
    step yields an identical :func:`~repro.replay.graph_key` digest, so a
    :class:`~repro.replay.ReplayPool` records step 1 (including the gather
    frame's suspension points) and replays every later step.

    ``kv_pages`` (see :func:`kv_page_resources`) opts each lane's decode
    task into an exclusive per-lane KV-page resource; ``maintenance_fn``
    then adds a ``kv_maint`` task that takes *every* page exclusively with
    no ordering edges at all — the arbiter serializes it against the decode
    tasks wherever it lands, and the recorded grant order replays the same
    placement bit-identically.  Without ``kv_pages`` the graph (and its
    digest) is byte-identical to the resource-free form."""
    sample = sample_fn or greedy_sample
    if kv_pages is not None and len(kv_pages) != state.n_shards:
        raise ValueError(
            f"kv_pages has {len(kv_pages)} entries for {state.n_shards} "
            "shards")
    if maintenance_fn is not None and kv_pages is None:
        raise ValueError("maintenance_fn requires kv_pages")
    g = Graph(f"decode_step[{state.n_shards}]")
    g.fuse_state = _DecodeFuseState(state)
    tokens = Channel("decode.tokens")
    for s in range(state.n_shards):
        def _decode(s=s):
            sh = state.shards[s]
            sh.cache, sh.logits = decode_fn(state.params, sh.cache, sh.tok)
            return sh.logits

        # fusible: decode_fn is the pure kernel; the logits write feeds the
        # sample task's dataflow argument.  jit_safe=False — decode_fn is
        # caller-supplied (usually already jitted) and the compiled driver
        # must call it exactly as the dynamic body does for bit-identity.
        dec = g.add(_decode, name=f"decode{s}", kind="compute", cost=1.0,
                    uses=[kv_pages[s]] if kv_pages is not None else (),
                    fuse=FuseSpec(decode_fn,
                                  (("params",), ("cache", s), ("tok", s)),
                                  (("cache", s), ("logits", s)),
                                  result_key=("logits", s), jit_safe=False))

        def _sample(logits, s=s):
            sh = state.shards[s]
            sh.tok = sample(logits)
            tokens.send((s, sh.tok))
            return sh.tok

        # dataflow: the decode handle is the sample's argument — the edge
        # is inferred, and the logits flow as a value instead of through
        # shard state (the cache/tok mutations still ride the shard)
        g.add(_sample, dec, name=f"sample{s}", kind="compute", cost=0.1)

    n_shards = state.n_shards

    def _gather(ctx):
        # suspendable frame: assemble tokens as they stream in, suspending
        # (worker-free) between arrivals instead of barriering on all shards
        import jax.numpy as jnp

        toks: List[Any] = [None] * n_shards
        for _ in range(n_shards):
            s, tok = yield ctx.recv(tokens)
            toks[s] = tok
        state.step_tokens = jnp.concatenate(toks, axis=0)
        state.history.append(state.step_tokens)
        return state.step_tokens

    g.add(_gather, name="gather", kind="comm", cost=0.05)

    if maintenance_fn is not None:
        def _maint(ctx):
            return maintenance_fn(state)

        # conflicts-but-no-edges: the page resources are the ONLY thing
        # keeping this compaction pass out of the decode tasks' way
        g.add(_maint, name="kv_maint", kind="compute", cost=0.2,
              uses=list(kv_pages))
    return g


def decode_graph_key(n_shards: int):
    """Structural key of the ``n_shards`` decode-step graph (for priming a
    cache / registering a pool builder without building real state)."""
    from ..replay.graph_key import graph_key

    skeleton = DecodeState(None, [DecodeShard(None, None)] * n_shards)
    return graph_key(build_decode_graph(skeleton, lambda p, c, t: (c, t)))


def shard_batch(batch: Dict[str, Any], n_shards: int) -> List[Dict[str, Any]]:
    """Split every batch array along axis 0 into ``n_shards`` equal parts."""
    sizes = {v.shape[0] for v in batch.values()}
    if len(sizes) != 1:
        raise ValueError(f"inconsistent batch sizes: {sorted(sizes)}")
    (bsz,) = sizes
    if bsz % n_shards:
        raise ValueError(f"batch size {bsz} does not shard into {n_shards}")
    per = bsz // n_shards
    return [{k: v[s * per:(s + 1) * per] for k, v in batch.items()}
            for s in range(n_shards)]


def make_decode_state(
    params: Any,
    cfg: Any,
    batch: Dict[str, Any],
    *,
    n_shards: int,
    max_len: int,
    prefill_fn: Optional[Callable[[Any, Dict[str, Any]], Any]] = None,
    sample_fn: Optional[SampleFn] = None,
) -> DecodeState:
    """Prefill each shard and seed its first decode token.  The prefill
    logits' greedy token is recorded as step 0 of ``history``."""
    import jax

    from .lm import prefill

    if prefill_fn is None:
        prefill_fn = jax.jit(
            lambda p, b: prefill(p, cfg, b, None, max_len=max_len))
    sample = sample_fn or greedy_sample
    shards: List[DecodeShard] = []
    first: List[Any] = []
    for sub in shard_batch(batch, n_shards):
        cache, logits = prefill_fn(params, sub)
        tok = sample(logits)
        shards.append(DecodeShard(cache=cache, tok=tok, logits=logits))
        first.append(tok)
    state = DecodeState(params, shards)
    import jax.numpy as jnp

    state.step_tokens = jnp.concatenate(first, axis=0)
    state.history.append(state.step_tokens)
    return state
