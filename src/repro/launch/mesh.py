"""Production meshes.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — dryrun.py must set
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
device initialization.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single-pod: (data=16, model=16) = 256 chips.  Multi-pod: an outer
    "pod" data-parallel axis on top — (pod=2, data=16, model=16) = 512."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_data: int = 2, n_model: int = 2, *, multi_pod: bool = False):
    """Small mesh for in-process tests (requires enough host devices)."""
    if multi_pod:
        return jax.make_mesh((2, n_data, n_model), ("pod", "data", "model"))
    return jax.make_mesh((n_data, n_model), ("data", "model"))
