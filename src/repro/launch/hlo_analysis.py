"""Trip-count-aware HLO accounting.

XLA's ``cost_analysis()`` counts a while-loop body ONCE, so collectives and
dot FLOPs inside ``lax.scan`` (our layer stacks) are undercounted by the
trip count.  This module parses the optimized HLO text:

* splits it into named computations,
* builds the call multiplicity map: while bodies get (trip count) pulled
  from the loop condition's comparison constant; fusion/call/conditional
  computations inherit the caller's multiplicity,
* sums collective bytes (by kind) and dot-op FLOPs per computation, scaled
  by multiplicity.

Conventions: collective "bytes" = max(operand bytes, result bytes) of the
op (per-participant, as HLO is the per-device SPMD program).  Conditionals
count both branches (upper bound; branches are layer-flag variants whose
cost is similar).
"""

from __future__ import annotations

import math
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "s32": 4,
          "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2, "u8": 1,
          "pred": 1, "c64": 8, "c128": 16}

_SHAPE = re.compile(r"(f64|f32|f16|bf16|s64|s32|s16|s8|u64|u32|u16|u8|pred|c64|c128)\[([0-9,]*)\]")
_COLL_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_list_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE.findall(text):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _BYTES[dt]
    return total


def _shape_dims(text: str) -> List[Tuple[str, List[int]]]:
    out = []
    for dt, dims in _SHAPE.findall(text):
        out.append((dt, [int(d) for d in dims.split(",") if d]))
    return out


def split_computations(hlo: str) -> Dict[str, str]:
    """name -> body text.  Computations start at column 0 with
    ``%name (params) -> type {`` or ``ENTRY %name ...`` and end at '}'."""
    comps: Dict[str, str] = {}
    cur_name: Optional[str] = None
    cur_lines: List[str] = []
    for line in hlo.splitlines():
        if not line.startswith((" ", "\t")) and "{" in line and ("(" in line):
            m = re.match(r"(?:ENTRY\s+)?%?([\w.\-]+)\s*\(", line)
            if m:
                cur_name = m.group(1)
                cur_lines = []
                continue
        if line.startswith("}"):
            if cur_name is not None:
                comps[cur_name] = "\n".join(cur_lines)
            cur_name = None
            continue
        if cur_name is not None:
            cur_lines.append(line)
    return comps


def _find_entry(hlo: str) -> Optional[str]:
    m = re.search(r"^ENTRY\s+%?([\w.\-]+)\s*\(", hlo, re.M)
    return m.group(1) if m else None


def _trip_count(cond_body: str) -> int:
    """Extract the loop bound from the condition computation: the largest
    integer constant it compares against."""
    best = 1
    for m in re.finditer(r"constant\((\d+)\)", cond_body):
        best = max(best, int(m.group(1)))
    return best


def call_multiplicities(hlo: str) -> Dict[str, float]:
    comps = split_computations(hlo)
    entry = _find_entry(hlo)
    mult: Dict[str, float] = defaultdict(float)
    if entry is None:
        return {name: 1.0 for name in comps}

    edges: Dict[str, List[Tuple[str, float]]] = defaultdict(list)
    for name, body in comps.items():
        # while loops: condition=%c, body=%b
        for m in re.finditer(r"while\(.*?\).*?condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)", body):
            cond, wbody = m.group(1), m.group(2)
            trips = _trip_count(comps.get(cond, ""))
            edges[name].append((wbody, float(trips)))
            edges[name].append((cond, float(trips)))
        # fusions / calls
        for m in re.finditer(r"calls=%?([\w.\-]+)", body):
            edges[name].append((m.group(1), 1.0))
        for m in re.finditer(r"to_apply=%?([\w.\-]+)", body):
            edges[name].append((m.group(1), 1.0))
        # conditionals: branch_computations={%a, %b}  / true/false computations
        for m in re.finditer(r"branch_computations=\{([^}]*)\}", body):
            for b in m.group(1).split(","):
                edges[name].append((b.strip().lstrip("%"), 1.0))
        for m in re.finditer(r"(?:true|false)_computation=%?([\w.\-]+)", body):
            edges[name].append((m.group(1), 1.0))

    # propagate multiplicities topologically (graph is acyclic)
    mult[entry] = 1.0
    frontier = [entry]
    seen_guard = 0
    while frontier:
        seen_guard += 1
        if seen_guard > 100000:
            break
        cur = frontier.pop()
        for child, factor in edges.get(cur, ()):
            add = mult[cur] * factor
            mult[child] += add
            frontier.append(child)
    return dict(mult)


def collective_bytes(hlo: str) -> Dict[str, Dict[str, float]]:
    """Trip-count-scaled per-participant collective bytes by kind."""
    comps = split_computations(hlo)
    mult = call_multiplicities(hlo)
    out = {k: {"count": 0.0, "bytes": 0.0} for k in _COLL_KINDS}
    for name, body in comps.items():
        f = mult.get(name, 0.0)
        if f == 0.0:
            continue
        for line in body.splitlines():
            ls = line.strip()
            m = re.match(r"(?:ROOT )?%?[\w.\-]+ = (.*?) (all-gather|all-reduce|"
                         r"reduce-scatter|all-to-all|collective-permute)"
                         r"(?:-start|-done)?\((.*?)\)", ls)
            if not m:
                continue
            if "-done(" in ls:
                continue  # count start ops once
            kind = m.group(2)
            res_bytes = _shape_list_bytes(m.group(1))
            arg_bytes = _shape_list_bytes(m.group(3))
            out[kind]["count"] += f
            out[kind]["bytes"] += f * max(res_bytes, arg_bytes)
    out["total_bytes"] = sum(v["bytes"] for k, v in out.items() if isinstance(v, dict))
    out["total_count"] = sum(v["count"] for k, v in out.items() if isinstance(v, dict))
    return out


_DEF_RE = re.compile(r"^\s*(?:ROOT )?%?([\w.\-]+) = (\S+)")
_DOT_RE = re.compile(
    r"= (\S+) dot\(([^)]*)\), .*?lhs_contracting_dims=\{([0-9,]*)\}")


def dot_flops(hlo: str) -> float:
    """Trip-count-scaled FLOPs of dot ops (2 * prod(result) * contracted).
    Operand shapes are resolved through a per-computation symbol table
    (HLO prints operands as bare %names)."""
    comps = split_computations(hlo)
    mult = call_multiplicities(hlo)
    total = 0.0
    for name, body in comps.items():
        f = mult.get(name, 0.0)
        if f == 0.0:
            continue
        # symbol table: instruction name -> result type string
        sym = {}
        for line in body.splitlines():
            dm = _DEF_RE.match(line)
            if dm:
                sym[dm.group(1)] = dm.group(2)
        for line in body.splitlines():
            m = _DOT_RE.search(line)
            if not m:
                continue
            res = _shape_dims(m.group(1))
            cdims = [int(d) for d in m.group(3).split(",") if d]
            operands = [o.strip().lstrip("%") for o in m.group(2).split(",")]
            if not res or not operands:
                continue
            lhs_type = sym.get(operands[0], "")
            lhs = _shape_dims(lhs_type)
            res_elems = math.prod(res[0][1]) if res[0][1] else 1
            if lhs and cdims:
                contracted = math.prod(lhs[0][1][d] for d in cdims
                                       if d < len(lhs[0][1]))
            else:
                contracted = 1
            total += f * 2.0 * res_elems * contracted
    return total


def analyze(hlo: str) -> Dict[str, object]:
    return {
        "collectives": collective_bytes(hlo),
        "dot_flops": dot_flops(hlo),
    }
