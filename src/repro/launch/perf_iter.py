import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           "--xla_disable_hlo_passes="
                           "while-loop-invariant-code-motion")

"""Perf-iteration harness: compile one (arch x shape) cell under a named
variant and report the roofline terms (the hypothesis->change->measure loop
of EXPERIMENTS.md §Perf).

Usage:
    PYTHONPATH=src python -m repro.launch.perf_iter --arch deepseek-67b \
        --shape train_4k --variant baseline
Variants are keyword overrides, e.g.:
    --set micro=4 --set remat_group=5 --set fsdp=false --set compress=true
"""

import argparse
import json
import time
from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import get_config
from ..models import lm
from ..optim.adamw import AdamWConfig
from ..sharding.rules import make_ctx
from ..train.steps import StepConfig, make_train_step
from . import hlo_analysis
from .dryrun import pick_microbatches
from .mesh import make_production_mesh
from .shapes import SHAPE_DEFS, decode_cache_len, input_specs

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9
_KIND_FACTOR = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
                "all-to-all": 1.0, "collective-permute": 1.0}


def compile_cell(arch: str, shape: str, overrides: Dict[str, Any],
                 multi_pod: bool = False):
    cfg = get_config(arch)
    mesh = make_production_mesh(multi_pod=multi_pod)
    ctx = make_ctx(mesh, cfg)
    ctx.seq_shard_cache = shape == "long_500k"
    ctx.fsdp = overrides.get("fsdp", True)
    ctx.remat_group = int(overrides.get("remat_group", 1))
    ctx.moe_wire_bf16 = overrides.get("moe_wire_bf16", False)
    ctx.moe_gather_tokens = overrides.get("moe_gather_tokens", False)
    if overrides.get("no_shard_kv"):
        ctx.shard_kv = False

    pspecs = lm.param_pspecs(cfg, ctx)
    param_sh = jax.tree.map(lambda p: NamedSharding(mesh, p), pspecs,
                            is_leaf=lambda x: isinstance(x, P))
    params = lm.abstract_params(cfg)
    sd = SHAPE_DEFS[shape]
    kind = sd["kind"]

    def batch_sharding(struct):
        nd = len(struct.shape)
        if sd["global_batch"] == 1:
            return NamedSharding(mesh, P(*([None] * nd)))
        return NamedSharding(mesh, P(ctx.batch_axes, *([None] * (nd - 1))))

    t0 = time.time()
    if kind == "train":
        specs = input_specs(cfg, shape)
        batch_sh = {k: batch_sharding(v) for k, v in specs.items()}
        opt = {"m": jax.tree.map(lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32), params),
               "v": jax.tree.map(lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32), params),
               "step": jax.ShapeDtypeStruct((), jnp.int32)}
        opt_sh = {"m": param_sh, "v": param_sh, "step": NamedSharding(mesh, P())}
        micro = int(overrides.get("micro", 0)) or pick_microbatches(cfg, shape, ctx.dp_size)
        sc = StepConfig(microbatches=micro,
                        overlap=overrides.get("overlap", "hybrid"),
                        compress_grads=bool(overrides.get("compress", False)))
        fn = make_train_step(cfg, AdamWConfig(), ctx, sc, grad_pspecs=param_sh)
        jt = jax.jit(fn, in_shardings=(param_sh, opt_sh, batch_sh),
                     out_shardings=(param_sh, opt_sh, None))
        args = (params, opt, specs)
    elif kind == "prefill":
        specs = input_specs(cfg, shape)
        batch_sh = {k: batch_sharding(v) for k, v in specs.items()}
        fn = lambda p, b: lm.prefill(p, cfg, b, ctx, max_len=sd["seq_len"] + 1)
        jt = jax.jit(fn, in_shardings=(param_sh, batch_sh))
        args = (params, specs)
        micro = 1
    else:
        b = sd["global_batch"]
        cache = lm.cache_struct(cfg, b, decode_cache_len(shape),
                                n_patches=cfg.n_patches if cfg.family == "vlm"
                                else (256 if cfg.family == "encdec" else 0))
        cp = lm.cache_pspecs(cfg, ctx)
        cache_sh = jax.tree.map(lambda p: NamedSharding(mesh, p), cp,
                                is_leaf=lambda x: isinstance(x, P))
        tok = input_specs(cfg, shape)
        fn = lambda p, c, t: lm.decode_step(p, cfg, c, t["tokens"], ctx)
        jt = jax.jit(fn, in_shardings=(param_sh, cache_sh,
                                       {"tokens": batch_sharding(tok["tokens"])}))
        args = (params, cache, tok)
        micro = 1

    with mesh:
        compiled = jt.lower(*args).compile()
    dt = time.time() - t0
    return compiled, cfg, ctx, micro, dt


def report(arch: str, shape: str, overrides: Dict[str, Any],
           multi_pod: bool = False) -> Dict[str, Any]:
    compiled, cfg, ctx, micro, dt = compile_cell(arch, shape, overrides, multi_pod)
    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    coll = hlo_analysis.collective_bytes(hlo)
    dots = hlo_analysis.dot_flops(hlo)
    coll_t = sum(coll.get(k, {}).get("bytes", 0.0) * f / ICI_BW
                 for k, f in _KIND_FACTOR.items())
    compute_t = dots / PEAK_FLOPS
    out = {
        "arch": arch, "shape": shape, "overrides": overrides, "micro": micro,
        "temp_gib": round(mem.temp_size_in_bytes / 2 ** 30, 2),
        "fits_16g": mem.temp_size_in_bytes < 16 * 2 ** 30,
        "hlo_dot_flops": dots,
        "compute_s": round(compute_t, 4),
        "collective_s": round(coll_t, 4),
        "coll_by_kind": {k: round(v["bytes"] / 2 ** 30, 2)
                         for k, v in coll.items() if isinstance(v, dict) and v["bytes"]},
        "dominant": "collective" if coll_t > compute_t else "compute",
        "compile_s": round(dt, 1),
    }
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi", action="store_true")
    ap.add_argument("--set", action="append", default=[],
                    help="key=value overrides (micro, remat_group, fsdp, "
                         "compress, overlap, moe_wire_bf16, no_shard_kv)")
    args = ap.parse_args()
    overrides: Dict[str, Any] = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        overrides[k] = {"true": True, "false": False}.get(v.lower(), v)
    print(json.dumps(report(args.arch, args.shape, overrides, args.multi),
                     indent=1))


if __name__ == "__main__":
    main()
