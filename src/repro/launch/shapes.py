"""Assigned input-shape set and abstract input specs per (arch, shape) cell.

Shapes (assignment):
* ``train_4k``     seq_len=4096,   global_batch=256  -> lowers train_step
* ``prefill_32k``  seq_len=32768,  global_batch=32   -> lowers prefill_step
* ``decode_32k``   seq_len=32768,  global_batch=128  -> lowers serve_step
                   (one new token against a seq_len KV cache)
* ``long_500k``    seq_len=524288, global_batch=1    -> serve_step; only for
                   sub-quadratic archs (ssm/hybrid/sliding-window) — skipped
                   for pure full-attention archs (DESIGN.md §Shape-set).

``[audio]``/``[vlm]`` modality frontends are stubs: ``input_specs`` provides
precomputed frame/patch embeddings.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from ..models.config import ModelConfig

SHAPES = ("train_4k", "prefill_32k", "decode_32k", "long_500k")

SHAPE_DEFS = {
    "train_4k": dict(seq_len=4096, global_batch=256, kind="train"),
    "prefill_32k": dict(seq_len=32768, global_batch=32, kind="prefill"),
    "decode_32k": dict(seq_len=32768, global_batch=128, kind="decode"),
    "long_500k": dict(seq_len=524288, global_batch=1, kind="decode"),
}

# archs allowed to run long_500k (sub-quadratic attention)
LONG_OK_FAMILIES = ("ssm", "hybrid")


def long_ok(cfg: ModelConfig) -> bool:
    if cfg.family in LONG_OK_FAMILIES:
        return True
    # sliding-window archs (gemma3: 5/6 layers local) qualify; decode-time
    # cost of the remaining global layers is linear in context.
    return bool(cfg.local_global_ratio and cfg.window)


def cell_applicable(cfg: ModelConfig, shape: str) -> Tuple[bool, str]:
    if shape == "long_500k" and not long_ok(cfg):
        return False, "pure full-attention arch: long_500k skipped (DESIGN.md)"
    return True, ""


def enc_len_for(cfg: ModelConfig, seq_len: int) -> int:
    # audio frontend stub: one frame embedding per target token position
    return seq_len


def input_specs(cfg: ModelConfig, shape: str) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    sd = SHAPE_DEFS[shape]
    b, s = sd["global_batch"], sd["seq_len"]
    kind = sd["kind"]
    i32 = jnp.int32
    dt = cfg.jdtype

    def tok(bb, ss):
        return jax.ShapeDtypeStruct((bb, ss), i32)

    if kind == "train":
        batch = {"tokens": tok(b, s), "labels": tok(b, s)}
        if cfg.family == "encdec":
            batch["enc_input"] = jax.ShapeDtypeStruct((b, enc_len_for(cfg, s), cfg.d_model), dt)
        if cfg.family == "vlm":
            batch["patches"] = jax.ShapeDtypeStruct((b, cfg.n_patches, cfg.d_model), dt)
        return batch
    if kind == "prefill":
        batch = {"tokens": tok(b, s)}
        if cfg.family == "encdec":
            batch["enc_input"] = jax.ShapeDtypeStruct((b, enc_len_for(cfg, s), cfg.d_model), dt)
        if cfg.family == "vlm":
            batch["patches"] = jax.ShapeDtypeStruct((b, cfg.n_patches, cfg.d_model), dt)
        return batch
    # decode: one new token against a seq_len cache
    return {"tokens": tok(b, 1)}


def decode_cache_len(shape: str) -> int:
    return SHAPE_DEFS[shape]["seq_len"]
