import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           "--xla_disable_hlo_passes="
                           "while-loop-invariant-code-motion")
# (LICM hoists convert(saved-carry-stack) out of the backward while loop,
# materializing an f32 copy of every layer's residual stream — 2x the remat
# stash.  Disabling it is a deliberate, documented XLA tuning choice; see
# EXPERIMENTS.md §Perf iteration 1.)

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell on
512 placeholder host devices, record memory/cost analysis and collective
bytes for the roofline.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch deepseek-67b \
        --shape train_4k --mesh single --out results/
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both

The two XLA_FLAGS lines above MUST stay the first statements — jax locks
the device count at first init.
"""

import argparse
import json
import re
import sys
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import ARCHS, get_config
from ..models import lm
from ..optim.adamw import AdamWConfig
from ..sharding.rules import make_ctx
from ..train.steps import StepConfig, make_train_step
from .mesh import make_production_mesh
from .shapes import SHAPE_DEFS, SHAPES, cell_applicable, decode_cache_len, input_specs

OPT_CFG = AdamWConfig()


# ---------------------------------------------------------------------------
# collective-byte accounting from the compiled/lowered HLO text
# ---------------------------------------------------------------------------
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_SHAPE_RE = re.compile(r"(f64|f32|f16|bf16|s64|s32|s16|s8|u64|u32|u16|u8|pred)"
                       r"\[([0-9,]*)\]")
_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "s32": 4,
          "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2, "u8": 1, "pred": 1}


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _BYTES[dt]
    return total


def collective_stats(hlo_text: str) -> Dict[str, Any]:
    """Sum result-shape bytes of every collective op, by kind."""
    out = {k: {"count": 0, "bytes": 0} for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        ls = line.strip()
        # result-shape = text before ' = kind('; count each collective once
        m = re.match(r"(?:ROOT )?%?[\w.\-]+ = ([^=]*?) (all-gather|all-reduce|"
                     r"reduce-scatter|all-to-all|collective-permute)", ls)
        if m:
            kind = m.group(2)
            out[kind]["count"] += 1
            out[kind]["bytes"] += _shape_bytes(m.group(1))
    out["total_bytes"] = sum(v["bytes"] for k, v in out.items()
                             if isinstance(v, dict))
    out["total_count"] = sum(v["count"] for k, v in out.items()
                             if isinstance(v, dict))
    return out


# ---------------------------------------------------------------------------
# cell construction
# ---------------------------------------------------------------------------
def pick_microbatches(cfg, shape: str, dp: int) -> int:
    """Smallest power-of-two microbatch count whose per-microbatch remat
    carry stash (n_layers x per-seq residual stream, bf16) fits a ~4 GiB
    budget per device."""
    sd = SHAPE_DEFS[shape]
    if sd["kind"] != "train":
        return 1
    b_local = max(1, sd["global_batch"] // dp)
    n_layers = cfg.n_layers + getattr(cfg, "enc_layers", 0)
    per_seq = n_layers * sd["seq_len"] * cfg.d_model * 2  # bf16 carry
    budget = 4 * 2 ** 30
    need = max(1, -(-b_local * per_seq // budget))
    micro = 1
    while micro < need and micro < b_local:
        micro *= 2
    return micro


def build_cell(arch: str, shape: str, mesh, *, step_cfg: Optional[StepConfig] = None):
    """Returns (jitted_fn, arg_structs) for the cell, with shardings."""
    cfg = get_config(arch)
    kind = SHAPE_DEFS[shape]["kind"]
    seq_shard = shape == "long_500k"
    ctx = make_ctx(mesh, cfg)
    ctx.seq_shard_cache = seq_shard

    pspecs = lm.param_pspecs(cfg, ctx)
    param_sh = jax.tree.map(lambda p: NamedSharding(mesh, p), pspecs,
                            is_leaf=lambda x: isinstance(x, P))
    params = lm.abstract_params(cfg)
    batch_axes = ctx.batch_axes

    def batch_sharding(struct):
        ndim = len(struct.shape)
        if SHAPE_DEFS[shape]["global_batch"] == 1:
            return NamedSharding(mesh, P(*([None] * ndim)))
        return NamedSharding(mesh, P(batch_axes, *([None] * (ndim - 1))))

    if kind == "train":
        specs = input_specs(cfg, shape)
        batch_sh = {k: batch_sharding(v) for k, v in specs.items()}
        opt_state = {
            "m": jax.tree.map(lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32), params),
            "v": jax.tree.map(lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32), params),
            "step": jax.ShapeDtypeStruct((), jnp.int32),
        }
        opt_sh = {
            "m": param_sh, "v": param_sh,
            "step": NamedSharding(mesh, P()),
        }
        micro = pick_microbatches(cfg, shape, ctx.dp_size)
        sc = step_cfg or StepConfig(microbatches=micro, overlap="hybrid")
        fn = make_train_step(cfg, OPT_CFG, ctx, sc, grad_pspecs=param_sh)
        jitted = jax.jit(fn, in_shardings=(param_sh, opt_sh, batch_sh),
                         out_shardings=(param_sh, opt_sh, None))
        return jitted, (params, opt_state, specs), cfg, ctx

    if kind == "prefill":
        specs = input_specs(cfg, shape)
        batch_sh = {k: batch_sharding(v) for k, v in specs.items()}
        max_len = SHAPE_DEFS[shape]["seq_len"] + 1

        def fn(p, b):
            return lm.prefill(p, cfg, b, ctx, max_len=max_len)

        jitted = jax.jit(fn, in_shardings=(param_sh, batch_sh))
        return jitted, (params, specs), cfg, ctx

    # decode
    b = SHAPE_DEFS[shape]["global_batch"]
    cache_len = decode_cache_len(shape)
    n_patches = cfg.n_patches if cfg.family == "vlm" else (
        256 if cfg.family == "encdec" else 0)
    cache = lm.cache_struct(cfg, b, cache_len, n_patches=n_patches)
    cp = lm.cache_pspecs(cfg, ctx)
    cache_sh = jax.tree.map(lambda p: NamedSharding(mesh, p), cp,
                            is_leaf=lambda x: isinstance(x, P))
    tok = input_specs(cfg, shape)
    tok_sh = {"tokens": batch_sharding(tok["tokens"])}

    def fn(p, c, t):
        return lm.decode_step(p, cfg, c, t["tokens"], ctx)

    jitted = jax.jit(fn, in_shardings=(param_sh, cache_sh, tok_sh))
    return jitted, (params, cache, tok), cfg, ctx


# ---------------------------------------------------------------------------
# run one cell
# ---------------------------------------------------------------------------
def run_cell(arch: str, shape: str, mesh_kind: str,
             hlo_dir: Optional[str] = None) -> Dict[str, Any]:
    t0 = time.time()
    cfg = get_config(arch)
    ok, why = cell_applicable(cfg, shape)
    rec: Dict[str, Any] = {"arch": arch, "shape": shape, "mesh": mesh_kind}
    if not ok:
        rec["status"] = "skipped"
        rec["reason"] = why
        return rec
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    try:
        jitted, args, cfg, ctx = build_cell(arch, shape, mesh)
        with mesh:
            lowered = jitted.lower(*args)
            t_lower = time.time()
            compiled = lowered.compile()
            t_compile = time.time()
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()
        if hlo_dir:
            import gzip
            os.makedirs(hlo_dir, exist_ok=True)
            with gzip.open(os.path.join(
                    hlo_dir, f"{arch}__{shape}__{mesh_kind}.hlo.gz"), "wt") as hf:
                hf.write(hlo)
        coll = collective_stats(hlo)
        from . import hlo_analysis
        corrected = hlo_analysis.analyze(hlo)
        sd = SHAPE_DEFS[shape]
        cache_bytes = 0
        if sd["kind"] == "decode":
            cache = lm.cache_struct(cfg, sd["global_batch"],
                                    decode_cache_len(shape))
            cache_bytes = sum(
                int(jnp.dtype(s.dtype).itemsize) *
                int(__import__("math").prod(s.shape))
                for s in jax.tree.leaves(cache)) // mesh.devices.size
        rec.update({
            "status": "ok",
            "lower_s": round(t_lower - t0, 2),
            "compile_s": round(t_compile - t_lower, 2),
            "memory": {
                k: int(getattr(mem, k))
                for k in ("argument_size_in_bytes", "output_size_in_bytes",
                          "temp_size_in_bytes", "generated_code_size_in_bytes")
                if hasattr(mem, k)
            },
            "flops": float(cost.get("flops", 0.0)),
            "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
            "collectives": coll,
            "collectives_corrected": corrected["collectives"],
            "hlo_dot_flops": corrected["dot_flops"],
            "n_devices": mesh.devices.size,
            "params": cfg.param_count(),
            "params_active": cfg.param_count(active_only=True),
            "microbatches": pick_microbatches(cfg, shape, ctx.dp_size),
            "cache_bytes_per_dev": cache_bytes,
            "cell_meta": {
                "seq_len": sd["seq_len"], "global_batch": sd["global_batch"],
                "kind": sd["kind"],
                "n_layers": cfg.n_layers + (cfg.enc_layers or 0),
                "d_model": cfg.d_model, "n_heads": cfg.n_heads,
                "head_dim": cfg.head_dim, "window": cfg.window,
                "local_global_ratio": cfg.local_global_ratio,
            },
        })
    except Exception as e:  # noqa: BLE001 - report, don't crash the sweep
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    rec["total_s"] = round(time.time() - t0, 2)
    return rec


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS)
    ap.add_argument("--shape", choices=SHAPES)
    ap.add_argument("--mesh", choices=("single", "multi", "both"), default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    meshes = ("single", "multi") if args.mesh == "both" else (args.mesh,)
    cells = []
    if args.all:
        for a in ARCHS:
            for s in SHAPES:
                for m in meshes:
                    cells.append((a, s, m))
    else:
        if not (args.arch and args.shape):
            ap.error("--arch and --shape required unless --all")
        cells = [(args.arch, args.shape, m) for m in meshes]

    failures = 0
    for a, s, m in cells:
        fname = os.path.join(args.out, f"{a}__{s}__{m}.json")
        if os.path.exists(fname):
            with open(fname) as f:
                prev = json.load(f)
            if prev.get("status") in ("ok", "skipped"):
                print(f"[cached] {a} {s} {m}: {prev['status']}")
                continue
        rec = run_cell(a, s, m, hlo_dir=os.path.join(args.out, "hlo"))
        with open(fname, "w") as f:
            json.dump(rec, f, indent=1)
        status = rec["status"]
        extra = ""
        if status == "ok":
            tmp = rec["memory"].get("temp_size_in_bytes", 0)
            extra = (f" flops={rec['flops']:.3g} temp={tmp/2**30:.2f}GiB "
                     f"coll={rec['collectives']['total_bytes']/2**30:.2f}GiB "
                     f"({rec['compile_s']}s compile)")
        elif status == "error":
            extra = " " + rec["error"][:200]
            failures += 1
        print(f"[{status}] {a} {s} {m}{extra}", flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
