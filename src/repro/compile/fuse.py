"""Fusion metadata and fused-callable construction for compiled plans.

A task body is *fusible* when the graph builder attaches a :class:`FuseSpec`
to the task (``g.add(..., fuse=FuseSpec(...))``): a pure kernel plus the keys
it reads and writes in the graph's shared ``fuse_state`` (a mapping-like
store — :class:`~repro.linalg.tiles.TileStore` for the factorizations, a
small adapter for the decode step).  ``Task.meta`` is excluded from the
structural :func:`~repro.replay.graph_key` digest, so fuse metadata never
perturbs recording/cache keys.

Consecutive fusible tasks from one worker's run list are lowered into a
single :class:`FusedSegment`: the per-task Python dispatch (context
creation, result bookkeeping, scheduler hand-off) collapses into one call
that gathers the segment's external inputs from the state, runs the kernel
sequence, and scatters the outputs back.  When every spec in the segment is
``jit_safe`` the whole sequence is additionally wrapped in one outer
``jax.jit`` — one XLA computation per segment shape (callables are cached
process-wide by segment *structure*, so same-shaped segments across rebuilt
graphs share compilations).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

__all__ = ["FuseSpec", "FusedSegment", "fuse_spec_of", "fused_cache_info"]


@dataclasses.dataclass(frozen=True)
class FuseSpec:
    """Declares a task body as a pure kernel over ``graph.fuse_state`` keys.

    ``fn(*[state[k] for k in reads])`` must return the new value for the
    single write key, or a tuple matching ``writes``.  ``result_key`` names
    which written key's value becomes ``results[tid]`` (``None`` → the task
    result is ``None``, matching store-mutating bodies).  ``fn`` must be a
    stable module-level callable — fused-callable caching keys on its
    identity.
    """

    fn: Callable[..., Any]
    reads: Tuple[Any, ...]
    writes: Tuple[Any, ...]
    result_key: Optional[Any] = None
    jit_safe: bool = True


def fuse_spec_of(task) -> Optional[FuseSpec]:
    """The task's :class:`FuseSpec`, or ``None`` for opaque bodies."""
    meta = getattr(task, "meta", None)
    if not meta:
        return None
    spec = meta.get("fuse")
    return spec if isinstance(spec, FuseSpec) else None


# process-wide cache of composed callables keyed by segment structure
# (kernel identities + read/write slot topology); jax.jit's own shape-based
# retracing layers underneath this.
_FUSED_CACHE: Dict[Tuple[Any, ...], Callable[..., Any]] = {}


def fused_cache_info() -> Dict[str, int]:
    return {"entries": len(_FUSED_CACHE)}


def _compose(norm: Tuple[Tuple[Callable, Tuple[int, ...], Tuple[int, ...], int], ...],
             ext_slots: Tuple[int, ...], out_slots: Tuple[int, ...]):
    def run(*ext_vals):
        vals: Dict[int, Any] = dict(zip(ext_slots, ext_vals))
        res: List[Any] = []
        for fn, reads, writes, result_slot in norm:
            out = fn(*(vals[s] for s in reads))
            if len(writes) == 1:
                vals[writes[0]] = out
            else:
                for s, v in zip(writes, out):
                    vals[s] = v
            res.append(vals[result_slot] if result_slot >= 0 else None)
        return tuple(vals[s] for s in out_slots), tuple(res)

    return run


class FusedSegment:
    """One run of consecutive fusible tasks lowered to a single callable.

    Graph-independent: holds state *keys* and tids only, so a segment
    compiled from one graph executes against any same-digest graph's
    ``fuse_state`` (same structure → same keys and kernels).
    """

    __slots__ = ("tids", "ext_keys", "out_keys", "jitted", "_run", "ext_deps")

    def __init__(self, items: Sequence[Tuple[int, FuseSpec]], *,
                 jit_fuse: bool = True,
                 dep_map: Optional[Dict[int, Sequence[int]]] = None):
        slot: Dict[Any, int] = {}

        def sid(key: Any) -> int:
            if key not in slot:
                slot[key] = len(slot)
            return slot[key]

        norm: List[Tuple[Callable, Tuple[int, ...], Tuple[int, ...], int]] = []
        ext: List[int] = []
        written: set = set()
        for _tid, spec in items:
            reads = []
            for k in spec.reads:
                s = sid(k)
                if s not in written and s not in ext:
                    ext.append(s)
                reads.append(s)
            writes = [sid(k) for k in spec.writes]
            written.update(writes)
            result_slot = sid(spec.result_key) if spec.result_key is not None else -1
            norm.append((spec.fn, tuple(reads), tuple(writes), result_slot))

        by_slot = {s: k for k, s in slot.items()}
        out_slots = tuple(sorted(written))
        self.tids = tuple(tid for tid, _ in items)
        self.ext_keys = tuple(by_slot[s] for s in ext)
        self.out_keys = tuple(by_slot[s] for s in out_slots)
        # external dependencies: predecessor tids outside the segment
        members = set(self.tids)
        deps: set = set()
        if dep_map:
            for tid in self.tids:
                deps.update(d for d in dep_map.get(tid, ()) if d not in members)
        self.ext_deps = frozenset(deps)

        structure = (tuple(norm), tuple(ext), out_slots)
        all_jit_safe = all(spec.jit_safe for _, spec in items)
        self.jitted = bool(jit_fuse and all_jit_safe)
        cache_key = (structure, self.jitted)
        run = _FUSED_CACHE.get(cache_key)
        if run is None:
            run = _compose(tuple(norm), tuple(ext), out_slots)
            if self.jitted:
                import jax

                run = jax.jit(run)
            _FUSED_CACHE[cache_key] = run
        self._run = run

    def __call__(self, state, results: Dict[int, Any]) -> None:
        outs, res = self._run(*(state[k] for k in self.ext_keys))
        for k, v in zip(self.out_keys, outs):
            state[k] = v
        for tid, rv in zip(self.tids, res):
            results[tid] = rv
