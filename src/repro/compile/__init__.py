"""Compile stable recordings into fused jitted execution plans.

A validated :class:`~repro.replay.Recording` is a complete execution plan;
this package lowers one into a serial program of fused jit-compiled
segments plus inline opaque bodies, executed by a single-threaded driver
with Python only at segment boundaries — the record-once /
re-execute-at-near-zero-overhead endgame (Taskgraph, PAPERS.md) that
reverses the GIL-bound multi-worker dispatch collapse.
"""

from .driver import CompiledExecutor, CompiledRunError
from .fuse import FuseSpec, FusedSegment, fuse_spec_of
from .plan import CompiledPlan, CompiledPlanMeta, CompileError, compile_recording

__all__ = [
    "CompiledExecutor",
    "CompiledRunError",
    "CompiledPlan",
    "CompiledPlanMeta",
    "CompileError",
    "FuseSpec",
    "FusedSegment",
    "compile_recording",
    "fuse_spec_of",
]
