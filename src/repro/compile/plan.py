"""Lower a validated :class:`~repro.replay.Recording` into a compiled plan.

The recording's per-worker run lists are merged into one deterministic
serial program (the compiled driver is single-threaded — that is the whole
point: the multi-worker decode collapse is GIL-bound Python dispatch, so the
fastest dispatcher is no dispatcher).  The merge walks worker cursors
round-robin, emitting entries whose dependencies are already emitted; within
one worker's list, consecutive fusible tasks are grouped into
:class:`~repro.compile.fuse.FusedSegment` entries and segment boundaries are
recorded with their reasons (worker switch, opaque body, gang fork, frame
resume) — the observable shape of the lowering, round-tripped through
:class:`CompiledPlanMeta` into the on-disk cache.

Program entry forms::

    ("fused", FusedSegment)     # >= 1 fusible tasks, one callable
    ("task", tid)               # opaque body (noop joins, gang forks, frames)
    ("resume", tid, seg)        # parked frame's seg'th resume

Gang ULT entries ``(spawn_tid, thread)`` from the recording are consumed
silently: the driver runs the whole nested region inline (with real threads
for the barrier protocol) when the spawn task executes.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, Dict, List, Optional, Tuple

from ..core.taskgraph import FrameResume, TaskGraph
from ..replay.recording import Recording
from ..resources.arbiter import grants_by_resource, task_needs
from .fuse import FuseSpec, FusedSegment, fuse_spec_of

__all__ = ["CompiledPlan", "CompiledPlanMeta", "compile_recording", "CompileError"]


class CompileError(RuntimeError):
    """The recording cannot be lowered (stale digest, uncoverable entries)."""


@dataclasses.dataclass
class CompiledPlanMeta:
    """JSON-serializable description of a lowering — cached alongside the
    recording so warm processes can report plan shape without recompiling."""

    digest: str
    n_workers: int
    n_tasks: int
    n_segments: int
    n_fused: int          # fused program entries
    n_fused_tasks: int    # tasks covered by fused entries
    n_opaque: int
    n_resumes: int
    jit_segments: int
    boundaries: Dict[str, int] = dataclasses.field(default_factory=dict)

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "CompiledPlanMeta":
        fields = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in fields})


@dataclasses.dataclass
class CompiledPlan:
    """A lowered recording: the serial program plus its descriptive meta.
    Executable via :class:`~repro.compile.CompiledExecutor`; reusable across
    any graph with the recording's digest."""

    program: List[Tuple[Any, ...]]
    meta: CompiledPlanMeta
    recording: Recording


def _last_segments(recording: Recording) -> Dict[int, int]:
    """tid -> highest recorded resume segment (0 when the task never parks)."""
    last: Dict[int, int] = {}
    for entries in recording.worker_orders:
        for e in entries:
            if isinstance(e, FrameResume):
                last[e.tid] = max(last.get(e.tid, 0), e.seg)
    return last


def compile_recording(graph: TaskGraph, recording: Recording, *,
                      jit_fuse: bool = True) -> CompiledPlan:
    """Merge ``recording``'s per-worker run lists into a compiled plan for
    ``graph`` (which must match the recording's digest — callers validate)."""
    tasks = graph.tasks
    dep_map = {t.tid: t.deps for t in tasks}
    last_seg = _last_segments(recording)
    # resource gating: the merged serial order must reproduce the recorded
    # per-resource grant order (conflicting tasks have no edges between
    # them, so dependency gating alone could invert it).  A declaring task
    # is emittable only at the head of every relevant derived grant queue.
    # *Contended* resources (>= 2 declaring tasks) additionally cut the
    # fuse so each contended task is trackable in the executor's grant log;
    # a sole-user resource needs neither a cut nor gating beyond its queue.
    needs_map: Dict[int, Tuple[Tuple[int, bool], ...]] = {
        t.tid: task_needs(graph, t.tid) for t in tasks
        if getattr(t, "uses", ()) or getattr(t, "uses_shared", ())}
    rqueues: Dict[int, "deque"] = {
        r: deque(tids)
        for r, tids in grants_by_resource(
            graph, recording.resource_grants).items()}
    contended = {r for r, q in rqueues.items() if len(q) >= 2}
    orders = [list(w) for w in recording.worker_orders]
    n_workers = len(orders)
    cursors = [0] * n_workers
    emitted_done: set = set()     # tids whose final entry has been emitted
    started: set = set()          # tids whose initial entry has been emitted
    next_seg: Dict[int, int] = {}

    program: List[Tuple[Any, ...]] = []
    boundaries: Dict[str, int] = {}
    n_opaque = n_resumes = n_fused_tasks = jit_segments = 0
    pending_fuse: List[Tuple[int, FuseSpec]] = []
    pending_worker = -1

    def cut(reason: str) -> None:
        nonlocal pending_fuse, n_fused_tasks, jit_segments
        if pending_fuse:
            seg = FusedSegment(pending_fuse, jit_fuse=jit_fuse, dep_map=dep_map)
            program.append(("fused", seg))
            n_fused_tasks += len(pending_fuse)
            jit_segments += int(seg.jitted)
            pending_fuse = []
        boundaries[reason] = boundaries.get(reason, 0) + 1

    total = sum(len(w) for w in orders)
    consumed = 0
    while consumed < total:
        progressed = False
        for w in range(n_workers):
            while cursors[w] < len(orders[w]):
                entry = orders[w][cursors[w]]
                if isinstance(entry, FrameResume):
                    if entry.tid not in started or \
                            next_seg.get(entry.tid, 1) != entry.seg:
                        break
                    cut("resume")
                    program.append(("resume", entry.tid, entry.seg))
                    n_resumes += 1
                    next_seg[entry.tid] = entry.seg + 1
                    if entry.seg >= last_seg.get(entry.tid, 0):
                        emitted_done.add(entry.tid)
                elif isinstance(entry, tuple):
                    # gang ULT placement: no serial program entry — the
                    # driver runs the whole nested region inline (real
                    # threads) when the spawn task executes, so placements
                    # are consumed unconditionally
                    pass
                else:
                    tid = int(entry)
                    if any(d not in emitted_done for d in dep_map.get(tid, ())):
                        break
                    needs = needs_map.get(tid)
                    if needs is not None and any(
                            rqueues[r] and rqueues[r][0] != tid
                            for r, _ in needs):
                        break           # not this task's recorded grant turn
                    task = tasks[tid]
                    spec = fuse_spec_of(task)
                    if needs is not None:
                        for r, _ in needs:
                            if rqueues[r] and rqueues[r][0] == tid:
                                rqueues[r].popleft()
                        if any(r in contended for r, _ in needs):
                            cut("resource")
                    if spec is not None:
                        if pending_fuse and pending_worker != w:
                            cut("worker_switch")
                        pending_fuse.append((tid, spec))
                        pending_worker = w
                    else:
                        reason = "gang" if getattr(task, "parallel", None) is not None \
                            else "opaque"
                        cut(reason)
                        program.append(("task", tid))
                        n_opaque += 1
                    started.add(tid)
                    if last_seg.get(tid, 0) == 0:
                        emitted_done.add(tid)
                    else:
                        next_seg[tid] = 1
                cursors[w] += 1
                consumed += 1
                progressed = True
        if not progressed:
            stuck = {w: orders[w][cursors[w]] for w in range(n_workers)
                     if cursors[w] < len(orders[w])}
            raise CompileError(
                f"recording cannot be serialized for {graph.name!r}: "
                f"no ready entry (cursors stuck at {stuck!r}) — "
                "the recording is stale for this graph")
    cut("end")

    n_fused_entries = sum(1 for kind, *_ in program if kind == "fused")
    meta = CompiledPlanMeta(
        digest=recording.digest,
        n_workers=recording.n_workers,
        n_tasks=len(tasks),
        n_segments=len(program),
        n_fused=n_fused_entries,
        n_fused_tasks=n_fused_tasks,
        n_opaque=n_opaque,
        n_resumes=n_resumes,
        jit_segments=jit_segments,
        boundaries=boundaries,
    )
    return CompiledPlan(program=program, meta=meta, recording=recording)
