"""Single-threaded driver for compiled plans.

The dynamic executor loses throughput as workers grow because every task
pays GIL-bound Python dispatch (deque locks, park/wake, per-task context
churn); a compiled plan removes the scheduler entirely.  The driver walks
the serial program emitted by :func:`~repro.compile.compile_recording`:
fused segments are one callable each, opaque bodies run inline, and parked
frames resume at their recorded positions with recorded ``wait_any``
winners pinned — Python survives only *between* segments.

The program order is the recording's merged order, which is one valid
dependency-consistent serialization; because every write is gated by graph
edges (and channel/event values flow through explicit requests), any
dependency-consistent serial order is value-deterministic, so compiled
results are bit-identical to the dynamic run that produced the recording.
When an entry is momentarily not runnable (a frame resume whose channel
fills later in the program), the driver deterministically skips ahead to
the first runnable entry and retries the blocked prefix after each step.

Nested gang regions run inline with *real* threads behind the region
barrier — panel bodies interleave phases across threads via
``region.barrier()`` with cross-thread reductions, so serializing thread
bodies would be wrong, not just slow.

Limitation: suspension must use generator frames (``yield ctx.recv(...)``).
A *plain* body that blocks on an empty channel would deadlock a
single-threaded driver; the adapter raises :class:`CompiledRunError`
immediately instead.
"""

from __future__ import annotations

import threading
import time
from collections import deque
from types import GeneratorType
from typing import Any, Dict, List, Optional, Tuple

from ..core.taskgraph import (
    Channel,
    TaskContext,
    TaskEvent,
    TaskFrame,
    TaskGraph,
    WaitAnyRequest,
    YieldRequest,
)
from ..replay.graph_key import graph_key
from ..resources.arbiter import grants_by_resource, task_needs
from .plan import CompiledPlan

__all__ = ["CompiledExecutor", "CompiledRunError"]


class CompiledRunError(RuntimeError):
    """Compiled execution cannot make progress (stale plan / plain-body
    blocking).  Callers fall back to replay or dynamic execution."""


class _GangBarrierRegion:
    """Region handle for nested parallel bodies: a real ``threading.Barrier``
    so phase-interleaved panel protocols (shared scratch, thread-0
    reductions) stay correct."""

    __slots__ = ("_barrier", "n_threads")

    def __init__(self, n_threads: int):
        self.n_threads = n_threads
        self._barrier = threading.Barrier(n_threads)

    def barrier(self) -> None:
        self._barrier.wait()


class _SerialRuntimeAdapter:
    """The duck-typed runtime interface ``TaskContext`` probes, scoped to
    single-threaded compiled execution."""

    def parallel(self, n_threads: int, body, *, gang=None, spawn_ctx=None):
        if n_threads <= 1:
            region = _GangBarrierRegion(1)
            return [body(0, region)]
        region = _GangBarrierRegion(n_threads)
        results: List[Any] = [None] * n_threads
        errors: List[BaseException] = []

        def run(t: int) -> None:
            try:
                results[t] = body(t, region)
            except BaseException as e:  # noqa: BLE001 - re-raised below
                errors.append(e)
                region._barrier.abort()

        threads = [threading.Thread(target=run, args=(t,), daemon=True)
                   for t in range(1, n_threads)]
        for th in threads:
            th.start()
        run(0)
        for th in threads:
            th.join()
        if errors:
            raise errors[0]
        return results

    # plain-body suspension: a single-threaded driver cannot wait — satisfy
    # immediately or fail loudly (generator frames are the supported path)
    def ctx_recv(self, channel: Channel, ctx) -> Any:
        ok, value = channel.try_recv()
        if not ok:
            raise CompiledRunError(
                f"plain-body recv on empty channel in task "
                f"{ctx.task.name!r}: compiled plans require generator "
                "frames for suspension")
        return value

    def ctx_send(self, channel: Channel, value: Any, ctx) -> None:
        ok, _ = channel.try_send(value)
        if not ok:
            raise CompiledRunError(
                f"plain-body send on full channel in task {ctx.task.name!r}: "
                "compiled plans require generator frames for suspension")

    def ctx_wait(self, event: TaskEvent, ctx) -> None:
        if not event.is_set():
            raise CompiledRunError(
                f"plain-body wait on unset event in task {ctx.task.name!r}: "
                "compiled plans require generator frames for suspension")

    def ctx_wait_any(self, request: WaitAnyRequest, ctx) -> Any:
        ok, value = request.try_immediate()
        if not ok:
            raise CompiledRunError(
                f"plain-body wait_any with no ready source in task "
                f"{ctx.task.name!r}")
        return value

    def ctx_yield(self, ctx) -> None:
        return None


class CompiledExecutor:
    """Executes a :class:`~repro.compile.CompiledPlan` against same-digest
    graphs.  ``stats`` after each run reports wall time, time spent inside
    task bodies / fused kernels, and the resulting
    ``dispatch_overhead_fraction`` — the number the compilation exists to
    crush."""

    def __init__(self, graph: TaskGraph, plan: CompiledPlan):
        self.plan = plan
        self.graph = graph
        self.stats: Dict[str, Any] = {}
        self._adapter = _SerialRuntimeAdapter()

    # ------------------------------------------------------------------
    def run(self, graph: Optional[TaskGraph] = None, *,
            check_digest: bool = True) -> Dict[int, Any]:
        tg = graph if graph is not None else self.graph
        if check_digest and tg is not self.graph:
            if graph_key(tg).digest != self.plan.recording.digest:
                raise CompiledRunError(
                    f"graph {tg.name!r} does not match compiled plan digest "
                    f"{self.plan.recording.digest[:16]}")
        state = getattr(tg, "fuse_state", None)
        if state is None and self.plan.meta.n_fused:
            raise CompiledRunError(
                f"graph {tg.name!r} has fused segments but no fuse_state")

        results: Dict[int, Any] = {}
        completed: set = set()
        frames: Dict[int, TaskFrame] = {}      # parked frames by tid
        wait_choices = self.plan.recording.wait_choices
        adapter = self._adapter
        tasks = tg.tasks
        body_s = 0.0
        skip_ahead = 0
        perf = time.perf_counter

        # resource grant discipline: skip-ahead may not reorder conflicting
        # tasks, so a declaring task runs only at the head of its derived
        # per-resource grant queues; each start appends to the grant log,
        # compared per resource against the recording after the run.
        needs_map = {t.tid: task_needs(tg, t.tid) for t in tasks
                     if getattr(t, "uses", ()) or getattr(t, "uses_shared", ())}
        rqueues = {r: deque(tids) for r, tids in grants_by_resource(
            tg, self.plan.recording.resource_grants).items()} if needs_map else {}
        grant_log: List[int] = []

        def grant_turn(tids) -> bool:
            for tid in tids:
                for r, _ in needs_map.get(tid, ()):
                    q = rqueues[r]
                    if q and q[0] != tid:
                        return False
            return True

        def log_grants(tids) -> None:
            for tid in tids:
                if tid in needs_map:
                    for r, _ in needs_map[tid]:
                        q = rqueues[r]
                        if q and q[0] == tid:
                            q.popleft()
                    grant_log.append(tid)

        remaining: List[Tuple[Any, ...]] = list(self.plan.program)
        t_start = perf()
        while remaining:
            ran_index = -1
            for i, entry in enumerate(remaining):
                kind = entry[0]
                if kind == "fused":
                    seg = entry[1]
                    if not seg.ext_deps.issubset(completed):
                        continue
                    if needs_map and not grant_turn(seg.tids):
                        continue
                    log_grants(seg.tids)
                    t0 = perf()
                    seg(state, results)
                    body_s += perf() - t0
                    completed.update(seg.tids)
                elif kind == "task":
                    tid = entry[1]
                    task = tasks[tid]
                    if any(d not in completed for d in task.deps):
                        continue
                    if needs_map and not grant_turn((tid,)):
                        continue
                    log_grants((tid,))
                    t0 = perf()
                    done = self._start_task(tg, task, results, frames, adapter)
                    body_s += perf() - t0
                    if done:
                        completed.add(tid)
                else:  # ("resume", tid, seg)
                    tid, seg_no = entry[1], entry[2]
                    frame = frames.get(tid)
                    if frame is None or frame.resumes + 1 != seg_no:
                        continue
                    ok, value = self._poll(frame, tid, seg_no, wait_choices)
                    if not ok:
                        continue
                    frame.resumes += 1
                    t0 = perf()
                    done = self._advance(frame, value, results, frames)
                    body_s += perf() - t0
                    if done:
                        completed.add(tid)
                ran_index = i
                break
            if ran_index < 0:
                stuck = [e[0:2] if e[0] != "fused" else ("fused", e[1].tids)
                         for e in remaining[:4]]
                raise CompiledRunError(
                    f"compiled run stalled on {tg.name!r}: no runnable entry "
                    f"among {len(remaining)} remaining (head: {stuck!r})")
            skip_ahead += ran_index
            del remaining[ran_index]
        wall_s = perf() - t_start

        if frames:
            raise CompiledRunError(
                f"compiled run left {len(frames)} frame(s) parked on "
                f"{tg.name!r}: {sorted(frames)!r}")
        if needs_map:
            want = grants_by_resource(tg, self.plan.recording.resource_grants)
            got = grants_by_resource(tg, grant_log)
            if got != want:
                raise CompiledRunError(
                    f"compiled run diverged from the recorded resource grant "
                    f"order on {tg.name!r}: got {got!r}, recorded {want!r}")
        self.stats = {
            "wall_s": wall_s,
            "body_s": body_s,
            "dispatch_overhead_fraction":
                max(0.0, 1.0 - body_s / wall_s) if wall_s > 0 else 0.0,
            "segments": self.plan.meta.n_segments,
            "fused_tasks": self.plan.meta.n_fused_tasks,
            "opaque_tasks": self.plan.meta.n_opaque,
            "resumes": self.plan.meta.n_resumes,
            "skip_ahead": skip_ahead,
            "resource_grants": len(grant_log),
        }
        return results

    # ------------------------------------------------------------------
    def _start_task(self, tg: TaskGraph, task, results: Dict[int, Any],
                    frames: Dict[int, TaskFrame], adapter) -> bool:
        ctx = TaskContext(tg, task, results, runtime=adapter)
        ctx.worker_id = 0  # type: ignore[attr-defined]
        result = task.fn(ctx) if task.fn is not None else None
        if isinstance(result, GeneratorType):
            ctx._in_frame = True
            frame = TaskFrame(task, ctx, result)
            return self._advance(frame, None, results, frames)
        results[task.tid] = result
        return True

    def _advance(self, frame: TaskFrame, value: Any,
                 results: Dict[int, Any], frames: Dict[int, TaskFrame]) -> bool:
        """Step a frame until done or parked.  Mirrors the dynamic
        executor's recording-mode behaviour: EVERY request parks, so the
        program's resume entries align one-to-one."""
        while True:
            status, payload = frame.step(value)
            if status == "done":
                results[frame.task.tid] = payload
                frames.pop(frame.task.tid, None)
                return True
            frame.request = payload
            frames[frame.task.tid] = frame
            return False

    def _poll(self, frame: TaskFrame, tid: int, seg_no: int,
              wait_choices: Dict[Tuple[int, int], int]) -> Tuple[bool, Any]:
        """Is the parked frame's request satisfiable now?  Consuming probe:
        on success the popped value feeds the resume immediately."""
        request = frame.request
        if isinstance(request, YieldRequest):
            frame.request = None
            return True, None
        if isinstance(request, WaitAnyRequest):
            winner = wait_choices.get((tid, seg_no))
            if winner is not None:
                request = request.pinned(winner)
        ok, value = request.try_immediate()
        if ok:
            frame.request = None
        return ok, value
