"""SLATE-style tiled linear algebra on task graphs — the paper's evaluation
substrate: LU, QR (gang-scheduled multithreaded panels) and Cholesky
(overlap-sensitive light panels)."""

from .cholesky import (build_cholesky_graph, cholesky_extract,
                       cholesky_graph_key, random_spd, reference_cholesky)
from .lu import (build_lu_graph, lu_extract, lu_graph_key,
                 lu_static_recording, random_diagdom)
from .qr import (build_qr_graph, qr_extract_r, qr_graph_key, qr_reconstruct,
                 qr_static_recording)
from .tiles import CostModel, ShapeOnlyStore, TileStore, to_tiles

GRAPH_KEYS = {
    "cholesky": cholesky_graph_key,
    "lu": lu_graph_key,
    "qr": qr_graph_key,
}

KERNELS = {
    "cholesky": build_cholesky_graph,
    "lu": build_lu_graph,
    "qr": build_qr_graph,
}


def paper_graph(kernel: str, nb: int, b: int = 192, **kw):
    """Cost-model-only graph at paper scale (for the simulator / static
    scheduler benchmarks).  ``kernel`` in {cholesky, lu, qr}."""
    return KERNELS[kernel](nb, b, store=None, **kw)


__all__ = [
    "CostModel",
    "GRAPH_KEYS",
    "KERNELS",
    "TileStore",
    "build_cholesky_graph",
    "build_lu_graph",
    "build_qr_graph",
    "cholesky_extract",
    "cholesky_graph_key",
    "ShapeOnlyStore",
    "lu_extract",
    "lu_graph_key",
    "lu_static_recording",
    "paper_graph",
    "qr_graph_key",
    "qr_extract_r",
    "qr_reconstruct",
    "qr_static_recording",
    "random_diagdom",
    "random_spd",
    "reference_cholesky",
    "to_tiles",
]
