"""Tiled Householder QR as a SLATE-style task graph with gang-scheduled
panel regions (communication-avoiding flavor: per-column reductions are the
only panel synchronization; no pivoting — paper §5.2: "the panel
factorization is the most critical task to the task graph of QR").

Structure per step ``k``: like LU — gang-scheduled ``panel[k]`` (4 blocking
barriers per column), ``bcast[k]`` shipping {V, T}, a lookahead column task
and a trailing parent/children/join family applying
``A_j <- (I - V T V^T)^T A_j``.
"""

from __future__ import annotations

from typing import Dict, Optional

import jax.numpy as jnp
import numpy as np

from ..api.graph import Graph
from ..compile.fuse import FuseSpec
from ..core.taskgraph import ParallelSpec, TaskGraph
from .cholesky import SPAWN_COST
from .panels import qr_form_t, qr_panel_region
from .tiles import CostModel, ShapeOnlyStore, TileStore


class _QrFuseState:
    """Fuse-state adapter: tile keys ``(i, j)`` resolve to the tile store,
    ``("vt", k)`` to the panel-reflector side store."""

    __slots__ = ("store",)

    def __init__(self, store: TileStore):
        self.store = store

    def __getitem__(self, k):
        if k[0] == "vt":
            return self.store.vt_store[k[1]]
        return self.store[k]

    def __setitem__(self, k, v):
        if k[0] == "vt":
            self.store.vt_store[k[1]] = v
        else:
            self.store[k] = v


def _qr_col_fused(vt, *tiles):
    """Fused trailing-column update ``A_j <- (I - V T V^T)^T A_j`` over the
    stacked tiles of block column ``j``.  Module-level so compiled plans
    cache one jitted callable per column shape."""
    V, T = vt
    b = tiles[0].shape[0]
    a = jnp.concatenate(tiles, axis=0)
    a = a - V @ (T.T @ (V.T @ a))
    if len(tiles) == 1:
        return a
    return tuple(a[i * b:(i + 1) * b] for i in range(len(tiles)))


def build_qr_graph(
    nb: int,
    b: int = 64,
    *,
    store: Optional[TileStore] = None,
    cost: Optional[CostModel] = None,
    ranks: int = 4,
    panel_threads: int = 4,
    gang_panels: Optional[bool] = None,
    comm: bool = True,
) -> TaskGraph:
    cm = cost or CostModel()
    g = Graph(f"qr[{nb}x{nb},b={b}]")
    numeric = store is not None
    noop = (lambda ctx: None) if numeric else None
    # side store for the panel reflectors: k -> (V, T) with V (m x b)
    vt_store: Dict[int, tuple] = {}
    if store is not None:
        store.vt_store = vt_store  # exposed for validation

    def panel_body_factory(k: int, n_threads: int):
        def fn(ctx):
            panel = np.concatenate(
                [np.asarray(store[(i, k)]) for i in range(k, store.nb)], axis=0)
            body, taus = qr_panel_region(panel, store.b, n_threads)
            ctx.parallel(n_threads, body, gang=gang_panels)
            T = qr_form_t(panel, taus)
            V = np.tril(panel, -1)[:, :store.b] + np.eye(panel.shape[0], store.b)
            vt_store[k] = (jnp.asarray(V), jnp.asarray(T))
            # write back: R on/above the diagonal of the top tile, zeros below
            store[(k, k)] = jnp.asarray(np.triu(panel[:store.b]))
            for i in range(k + 1, store.nb):
                store[(i, k)] = jnp.zeros_like(store[(i, k)])
        return fn

    if numeric:
        g.fuse_state = _QrFuseState(store)

    def col_body(j: int, k: int):
        def fn(ctx):
            V, T = vt_store[k]
            a = jnp.concatenate([store[(i, j)] for i in range(k, store.nb)], axis=0)
            a = a - V @ (T.T @ (V.T @ a))
            for idx, i in enumerate(range(k, store.nb)):
                store[(i, j)] = a[idx * store.b:(idx + 1) * store.b]
        return fn if numeric else None

    def col_fuse(j: int, k: int):
        if not numeric:
            return None
        keys = [(i, j) for i in range(k, nb)]
        return FuseSpec(_qr_col_fused, (("vt", k),) + tuple(keys), tuple(keys))

    def col_cost(k: int) -> float:
        return 4.0 * (nb - k) * b ** 3 / cm.flop_rate

    join_look = None
    join_trail = None

    for k in range(nb):
        m_tiles = nb - k
        n_threads = max(1, min(panel_threads, m_tiles))
        pdeps = [join_look] if join_look is not None else []
        if numeric:
            p = g.add(panel_body_factory(k, n_threads), name=f"panel[{k}]",
                      kind="panel", cost=cm.panel_qr(m_tiles, b), priority=3,
                      deps=pdeps, step=k)
        else:
            p = g.add(None, name=f"panel[{k}]", kind="panel",
                      cost=0.05 * cm.panel_qr(m_tiles, b), priority=3, deps=pdeps,
                      parallel=ParallelSpec(
                          n_threads=n_threads,
                          cost_per_thread=cm.panel_qr(m_tiles, b) / n_threads,
                          n_barriers=4 * b, blocking=True),
                      step=k)

        col_dep = p
        if comm:
            col_dep = g.add(noop, name=f"bcast[{k}]", kind="comm",
                            cost=cm.bcast(m_tiles + 1, b, ranks), priority=3,
                            deps=[p], step=k)
        base_deps = [col_dep] + ([join_trail] if join_trail is not None else [])

        if k + 1 < nb:
            join_look = g.add(col_body(k + 1, k), name=f"col[{k + 1},{k}]",
                              kind="lookahead", cost=col_cost(k), priority=2,
                              deps=base_deps, step=k, fuse=col_fuse(k + 1, k))
        else:
            join_look = None

        if k + 2 < nb:
            tparent = g.add(noop, name=f"trail*[{k}]", kind="compute",
                            cost=SPAWN_COST * (nb - k - 2), priority=0,
                            deps=base_deps, step=k)
            tchildren = [
                g.add(col_body(j, k), name=f"col[{j},{k}]", kind="compute",
                      cost=col_cost(k), priority=0, deps=[tparent], step=k,
                      fuse=col_fuse(j, k))
                for j in range(k + 2, nb)
            ]
            join_trail = g.add(noop, name=f"trail.join[{k}]", kind="compute",
                               cost=0.0, priority=0, deps=tchildren, step=k)
        else:
            join_trail = None
    return g


def qr_graph_key(
    nb: int,
    b: int = 64,
    *,
    cost: Optional[CostModel] = None,
    ranks: int = 4,
    panel_threads: int = 4,
    comm: bool = True,
):
    """Structural replay-cache key for :func:`build_qr_graph` (cost-model
    shape; see the note on :func:`repro.linalg.lu.lu_graph_key` about
    numeric-vs-cost-model panel structure)."""
    from ..replay import graph_key
    return graph_key(build_qr_graph(nb, b, cost=cost, ranks=ranks,
                                    panel_threads=panel_threads, comm=comm))


def qr_static_recording(
    nb: int,
    b: int = 64,
    *,
    n_workers: int,
    cost: Optional[CostModel] = None,
    ranks: int = 4,
    panel_threads: int = 4,
    comm: bool = True,
    policy: str = "hybrid",
    seed: int = 0,
):
    """QR analogue of :func:`repro.linalg.lu.lu_static_recording`: simulate
    the cost-model twin, carry its gang reservations into the recording as
    placements, key it to the numeric build's digest."""
    from ..core.static_schedule import ListScheduler
    from ..replay.graph_key import graph_key
    from ..replay.recording import Recording

    kwargs = dict(cost=cost, ranks=ranks, panel_threads=panel_threads,
                  comm=comm)
    twin = build_qr_graph(nb, b, **kwargs)
    sched = ListScheduler(n_workers, policy=policy, seed=seed).schedule(twin)
    numeric_key = graph_key(
        build_qr_graph(nb, b, store=ShapeOnlyStore(nb, b), **kwargs))
    return Recording.from_static_schedule(sched, twin, key=numeric_key)


def qr_extract_r(store: TileStore) -> jnp.ndarray:
    return jnp.triu(store.assemble())


def qr_reconstruct(store: TileStore) -> jnp.ndarray:
    """Apply the stored panel transforms to R to reconstruct A = Q R:
    A = H_0 H_1 ... H_{nb-1} R with H_k = I - V_k T_k V_k^T acting on the
    trailing rows."""
    n = store.nb * store.b
    a = np.array(qr_extract_r(store))  # writable copy
    for k in reversed(range(store.nb)):
        V, T = (np.asarray(x) for x in store.vt_store[k])
        rows = slice(k * store.b, n)
        blk = a[rows]
        a[rows] = blk - V @ (T @ (V.T @ blk))
    return jnp.asarray(a)
