"""Multi-rank (MPI-style) SLATE factorization task graphs.

The paper's experiments run SLATE with 2-4 MPI ranks per node, each with its
own OpenMP thread pool (10-20 threads).  Block columns are distributed
1-D block-cyclic: column ``j`` lives on rank ``j % R``.  Per step ``k``:

* the owner rank factors the panel (family / gang region) and *sends* the
  factored column (``bcast[k]`` comm task on the owner),
* every other rank has a blocking ``recv[k,r]`` comm task (the MPI Recv that
  dominates Idle time in paper Fig. 11d),
* each rank updates its local block columns (lookahead/trailing families).

Work stealing never crosses ranks; tasks are pinned via ``meta['rank']`` and
the simulator routes cross-rank readiness through the destination pool.

This is where the paper's headline Cholesky result reproduces: under
history-based stealing the owner's trailing flood starves the panel children
and the broadcast, and *every other rank* idles at its recv — hybrid victim
selection pulls the send earlier and collapses the idle time.
"""

from __future__ import annotations

from typing import Optional

from ..core.taskgraph import ParallelSpec, TaskGraph
from .cholesky import SPAWN_COST
from .tiles import CostModel


def build_dist_cholesky_graph(
    nb: int,
    b: int = 192,
    *,
    ranks: int = 4,
    cost: Optional[CostModel] = None,
) -> TaskGraph:
    cm = cost or CostModel()
    g = TaskGraph(f"dist-cholesky[{nb}x{nb},b={b},R={ranks}]")

    # per-rank joins of the previous step's families
    join_look = {r: None for r in range(ranks)}   # lookahead join (by owner of col k)
    join_trail = {r: None for r in range(ranks)}  # trailing join per rank

    def owner(j: int) -> int:
        return j % ranks

    for k in range(nb):
        ok = owner(k)
        # ---- panel family on the owner rank --------------------------------
        # depends ONLY on the lookahead that updated column k (SLATE: the
        # trailing family concurrently updates later columns — this is the
        # concurrency the victim policy governs)
        pdeps = [join_look[ok]] if join_look[ok] is not None else []
        pparent = g.add(None, name=f"panel*[{k}]", kind="panel",
                        cost=SPAWN_COST * (nb - k), priority=3, deps=pdeps,
                        rank=ok, step=k)
        potrf = g.add(None, name=f"potrf[{k}]", kind="panel", cost=cm.potrf(b),
                      priority=3, deps=[pparent], rank=ok, step=k)
        trsms = [
            g.add(None, name=f"trsm[{i},{k}]", kind="panel", cost=cm.trsm(b),
                  priority=3, deps=[potrf], rank=ok, step=k)
            for i in range(k + 1, nb)
        ]
        pjoin = g.add(None, name=f"panel.join[{k}]", kind="panel", cost=0.0,
                      priority=3, deps=trsms or [potrf], rank=ok, step=k)

        # ---- communication: owner sends, everyone else receives ------------
        send = g.add(None, name=f"bcast[{k}]", kind="comm",
                     cost=cm.bcast(nb - k, b, ranks), priority=3,
                     deps=[pjoin], rank=ok, step=k)
        recvs = {}
        for r in range(ranks):
            if r == ok:
                recvs[r] = send
            else:
                recvs[r] = g.add(None, name=f"recv[{k},{r}]", kind="comm",
                                 cost=cm.comm_latency + (nb - k) * cm.tile_bytes(b) / cm.comm_bw,
                                 priority=3, deps=[send], rank=r, step=k)

        # ---- update families per rank --------------------------------------
        new_join_look = {r: None for r in range(ranks)}
        new_join_trail = {r: None for r in range(ranks)}
        for r in range(ranks):
            # local columns this rank updates at step k
            look_cols = [j for j in range(k + 1, min(k + 2, nb)) if owner(j) == r]
            trail_cols = [j for j in range(k + 2, nb) if owner(j) == r]

            if look_cols:
                deps = [recvs[r]] + ([join_trail[r]] if join_trail[r] is not None else [])
                lparent = g.add(None, name=f"look*[{k},{r}]", kind="lookahead",
                                cost=SPAWN_COST * (nb - k - 1), priority=2,
                                deps=deps, rank=r, step=k)
                j = look_cols[0]
                lch = [
                    g.add(None, name=f"upd[{i},{j},{k}]", kind="lookahead",
                          cost=cm.syrk(b) if i == j else cm.gemm(b), priority=2,
                          deps=[lparent], rank=r, step=k)
                    for i in range(j, nb)
                ]
                new_join_look[r] = g.add(None, name=f"look.join[{k},{r}]",
                                         kind="lookahead", cost=0.0, priority=2,
                                         deps=lch, rank=r, step=k)
            if trail_cols:
                deps = [recvs[r]] + ([join_trail[r]] if join_trail[r] is not None else [])
                n_tr = sum(nb - j for j in trail_cols)
                tparent = g.add(None, name=f"trail*[{k},{r}]", kind="compute",
                                cost=SPAWN_COST * n_tr, priority=0, deps=deps,
                                rank=r, step=k)
                tch = []
                for j in trail_cols:
                    for i in range(j, nb):
                        tch.append(g.add(None, name=f"upd[{i},{j},{k}]",
                                         kind="compute",
                                         cost=cm.syrk(b) if i == j else cm.gemm(b),
                                         priority=0, deps=[tparent], rank=r, step=k))
                new_join_trail[r] = g.add(None, name=f"trail.join[{k},{r}]",
                                          kind="compute", cost=0.0, priority=0,
                                          deps=tch, rank=r, step=k)
        # next step's panel (on owner(k+1)) must wait for that rank's
        # lookahead join; other ranks' families chain through their joins
        join_look = new_join_look
        for r in range(ranks):
            if new_join_trail[r] is not None:
                join_trail[r] = new_join_trail[r]
            # if a rank had no trailing work this step, keep the old join
    return g


def _panel_task(g, name, kind, k, m_tiles, b, cm, n_threads, n_barriers,
                deps, rank, serial_frac=0.05):
    flops_cost = cm.panel_lu(m_tiles, b) if kind == "lu" else cm.panel_qr(m_tiles, b)
    return g.add(None, name=name, kind="panel", cost=serial_frac * flops_cost,
                 priority=3, deps=deps, rank=rank, step=k,
                 parallel=ParallelSpec(n_threads=n_threads,
                                       cost_per_thread=flops_cost / n_threads,
                                       n_barriers=n_barriers, blocking=True))


def build_dist_panel_graph(
    kernel: str,
    nb: int,
    b: int = 192,
    *,
    ranks: int = 4,
    panel_threads: int = 4,
    cost: Optional[CostModel] = None,
) -> TaskGraph:
    """Distributed LU/QR graph: gang-scheduled panel regions on the owner
    rank + column-level lookahead/trailing families per rank (paper §5.2)."""
    if kernel not in ("lu", "qr"):
        raise ValueError(kernel)
    cm = cost or CostModel()
    g = TaskGraph(f"dist-{kernel}[{nb}x{nb},b={b},R={ranks}]")
    join_look = {r: None for r in range(ranks)}
    join_trail = {r: None for r in range(ranks)}

    def owner(j: int) -> int:
        return j % ranks

    def col_cost(k: int) -> float:
        if kernel == "lu":
            return cm.trsm(b) + 2.0 * (nb - k - 1) * b ** 3 / cm.flop_rate
        return 4.0 * (nb - k) * b ** 3 / cm.flop_rate

    for k in range(nb):
        ok = owner(k)
        m_tiles = nb - k
        n_threads = max(1, min(panel_threads, m_tiles))
        n_barriers = 2 * b if kernel == "lu" else 4 * b
        pdeps = [join_look[ok]] if join_look[ok] is not None else []
        p = _panel_task(g, f"panel[{k}]", kernel, k, m_tiles, b, cm,
                        n_threads, n_barriers, pdeps, ok)

        send = g.add(None, name=f"bcast[{k}]", kind="comm",
                     cost=cm.bcast(m_tiles, b, ranks), priority=3, deps=[p],
                     rank=ok, step=k)
        recvs = {}
        for r in range(ranks):
            recvs[r] = send if r == ok else g.add(
                None, name=f"recv[{k},{r}]", kind="comm",
                cost=cm.comm_latency + m_tiles * cm.tile_bytes(b) / cm.comm_bw,
                priority=3, deps=[send], rank=r, step=k)

        new_join_look = {r: None for r in range(ranks)}
        for r in range(ranks):
            look_cols = [j for j in range(k + 1, min(k + 2, nb)) if owner(j) == r]
            trail_cols = [j for j in range(k + 2, nb) if owner(j) == r]
            if look_cols:
                deps = [recvs[r]] + ([join_trail[r]] if join_trail[r] is not None else [])
                new_join_look[r] = g.add(None, name=f"col[{look_cols[0]},{k}]",
                                         kind="lookahead", cost=col_cost(k),
                                         priority=2, deps=deps, rank=r, step=k)
            if trail_cols:
                deps = [recvs[r]] + ([join_trail[r]] if join_trail[r] is not None else [])
                tparent = g.add(None, name=f"trail*[{k},{r}]", kind="compute",
                                cost=SPAWN_COST * len(trail_cols), priority=0,
                                deps=deps, rank=r, step=k)
                tch = [g.add(None, name=f"col[{j},{k}]", kind="compute",
                             cost=col_cost(k), priority=0, deps=[tparent],
                             rank=r, step=k)
                       for j in trail_cols]
                join_trail[r] = g.add(None, name=f"trail.join[{k},{r}]",
                                      kind="compute", cost=0.0, priority=0,
                                      deps=tch, rank=r, step=k)
        join_look = new_join_look
    return g
